//! End-to-end driver: the paper's motivating use case —
//! **checkpoint/restart of a distributed iterative solver** — across all
//! three layers of the stack.
//!
//! Phase 1 (before the "crash"): 8 workers hold a cage-like Kronecker
//! matrix row-wise and run power iteration with the **PJRT-compiled
//! JAX/Pallas kernel** (Layer 1/2 artifacts executed from Rust, no Python
//! at runtime). After a few steps the matrix is checkpointed to ABHSF
//! files and the iterate vector saved.
//!
//! Phase 2 (after the "crash"): the job restarts with a *different
//! configuration* — 5 workers, column-wise mapping — reloads the matrix
//! with the paper's all-read-all algorithm, resumes the same power
//! iteration, and must converge to the same dominant eigenpair.
//!
//! ```sh
//! make artifacts && cargo run --release --example checkpoint_restart
//! ```

use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions, Strategy};
use abhsf::formats::Csr;
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Colwise, ProcessMapping};
use abhsf::runtime::Runtime;
use abhsf::spmv::{power_iteration_step_parts, SpmvParts};
use abhsf::util::human;

/// Distributed power iteration on CSR parts; returns (eigenvector, norm).
fn iterate(parts: &[Csr], x0: Vec<f64>, steps: usize) -> (Vec<f64>, f64) {
    let mut x = x0;
    let mut norm = 0.0;
    for _ in 0..steps {
        let (x2, n2) = power_iteration_step_parts(&SpmvParts::Csr(parts), &x);
        x = x2;
        norm = n2;
    }
    (x, norm)
}

fn main() -> anyhow::Result<()> {
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(16, 3), 2));
    let n = gen.dim();
    println!(
        "== phase 1: compute with 8 workers (row-wise) on {} x {} ({} nnz)",
        human::count(n),
        human::count(n),
        human::count(gen.nnz())
    );
    let p1 = 8;
    let map1: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p1));
    let cluster1 = Cluster::new(p1, 64);
    let parts1: Vec<Csr> = (0..p1)
        .map(|k| Csr::from_coo(&gen.local_coo(map1.as_ref(), k)))
        .collect();

    // A few power-iteration steps before checkpointing.
    let x0 = vec![1.0 / (n as f64).sqrt(); n as usize];
    let (x_ckpt, norm_ckpt) = iterate(&parts1, x0, 10);
    println!("  after 10 steps: ||A x|| = {norm_ckpt:.6}");

    // Cross-check one local part against the PJRT artifact (Layers 1+2).
    match Runtime::from_default_dir() {
        Ok(rt) => {
            let mut checked = 0;
            let mut maxd = 0f64;
            for part in &parts1 {
                if let Ok(y) = rt.spmv_csr(part, &x_ckpt) {
                    let mut want = vec![0.0; n as usize];
                    part.spmv_into(&x_ckpt, &mut want);
                    let ro = part.info.m_offset as usize;
                    for i in 0..part.info.m_local as usize {
                        maxd = maxd.max((y[i] as f64 - want[ro + i]).abs());
                    }
                    checked += 1;
                }
            }
            println!(
                "  PJRT kernel check: {checked}/{p1} parts, max |Δ| = {maxd:.2e} (f32 artifact)"
            );
            assert!(checked > 0, "no local part packed into any spmv artifact");
            assert!(maxd < 1e-2);
        }
        Err(e) => println!("  (PJRT check skipped: {e} — run `make artifacts`)"),
    }

    // Checkpoint: matrix to an ABHSF dataset + iterate vector. The
    // manifest records the phase-1 configuration, so the restart below
    // does not need to be told how the checkpoint was written.
    let dir = std::env::temp_dir().join("abhsf-ckpt-demo");
    let _ = std::fs::remove_dir_all(&dir);
    let t0 = std::time::Instant::now();
    let (_, report) = Dataset::store_parts(
        &cluster1,
        parts1.iter().map(|c| c.to_coo()).collect(),
        &map1,
        &dir,
        StoreOptions::default(),
    )?;
    println!(
        "  checkpoint: {} -> {} in {:.3} s",
        human::count(report.total_nnz()),
        human::bytes(report.total_bytes()),
        t0.elapsed().as_secs_f64()
    );
    drop(parts1);
    drop(cluster1);

    println!("== simulated crash; restarting with 5 workers (column-wise)");

    // Phase 2: different configuration — 5 workers, column-wise regular.
    // The stored file count and mapping come from the manifest; the
    // explicit strategy pins the paper's all-read-all algorithm.
    let dataset = Dataset::open(&dir)?;
    assert_eq!(dataset.nprocs(), p1, "manifest remembers the store config");
    let p2 = 5;
    let map2: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p2));
    let cluster2 = Cluster::new(p2, 64);
    let (mats, load) = dataset
        .load()
        .nprocs(p2)
        .mapping(&map2)
        .strategy(Strategy::Independent)
        .format(InMemFormat::Csr)
        .run(&cluster2)?;
    println!(
        "  reloaded {} nnz with all-read-all in {:.3} s (read {})",
        human::count(load.total_nnz()),
        load.wall_s,
        human::bytes(load.total_read_bytes())
    );
    assert_eq!(load.total_nnz(), gen.nnz());

    // Resume the iteration from the checkpointed vector.
    let parts2: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
    let (_, norm_resumed) = iterate(&parts2, x_ckpt.clone(), 1);
    println!("  first resumed step: ||A x|| = {norm_resumed:.6}");
    // The matrix is identical, so applying A to the checkpointed iterate
    // must give the same norm as phase 1 would have.
    let (x_long, norm_long) = iterate(&parts2, x_ckpt, 60);
    println!("  after 60 more steps: dominant |lambda| ~= {norm_long:.6}");
    let peak = x_long
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
        .unwrap();
    println!("  dominant component at row {} ({:.4})", peak.0, peak.1);

    println!("checkpoint_restart OK: matrix survived a configuration change");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
