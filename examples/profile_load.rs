//! Perf probe: phase-by-phase timing of the Algorithm-1 load path
//! (hardware perf counters are unavailable in this container, so the
//! §Perf pass uses section timing over many iterations).
//!
//! ```sh
//! cargo run --release --example profile_load
//! ```

use abhsf::abhsf::cost::CostModel;
use abhsf::abhsf::{load_csr, store_data, visit_elements, AbhsfData};
use abhsf::formats::Csr;
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::h5::H5Reader;

fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<38} {:>9.3} ms/iter", per * 1e3);
    per
}

fn main() {
    let gen = KroneckerGen::new(SeedMatrix::cage_like(24, 5), 2);
    let map = gen.balanced_rowwise(1);
    let coo = gen.local_coo(&map, 0);
    let nnz = coo.nnz() as f64;
    let data = AbhsfData::from_coo(&coo, 16, &CostModel::default()).unwrap();
    let path = std::env::temp_dir().join("profile-load.h5spm");
    store_data(&path, &data).unwrap();
    let fsize = std::fs::metadata(&path).unwrap().len();
    println!("workload: {} nnz, file {} bytes, s=16\n", nnz as u64, fsize);
    let iters = 200;

    // Phase 1: container open (superblock + directory parse).
    time("open (directory parse)", iters, || {
        std::hint::black_box(H5Reader::open(&path).unwrap());
    });

    // Phase 2: raw dataset reads (I/O + CRC + typed decode).
    time("read_all payload datasets", iters, || {
        let r = H5Reader::open(&path).unwrap();
        std::hint::black_box(r.read_all::<u16>("coo_lrows").unwrap());
        std::hint::black_box(r.read_all::<u16>("coo_lcols").unwrap());
        std::hint::black_box(r.read_all::<f64>("coo_vals").unwrap());
        std::hint::black_box(r.read_all::<u8>("bitmap_bitmap").unwrap());
        std::hint::black_box(r.read_all::<f64>("bitmap_vals").unwrap());
        std::hint::black_box(r.read_all::<f64>("dense_vals").unwrap());
        std::hint::black_box(r.read_all::<u16>("csr_lcolinds").unwrap());
        std::hint::black_box(r.read_all::<u32>("csr_rowptrs").unwrap());
        std::hint::black_box(r.read_all::<f64>("csr_vals").unwrap());
    });

    // Phase 2b: same with checksum verification disabled.
    time("read_all (no CRC verify)", iters, || {
        let mut r = H5Reader::open(&path).unwrap();
        r.verify_checksums = false;
        std::hint::black_box(r.read_all::<u16>("coo_lrows").unwrap());
        std::hint::black_box(r.read_all::<u16>("coo_lcols").unwrap());
        std::hint::black_box(r.read_all::<f64>("coo_vals").unwrap());
        std::hint::black_box(r.read_all::<u8>("bitmap_bitmap").unwrap());
        std::hint::black_box(r.read_all::<f64>("bitmap_vals").unwrap());
        std::hint::black_box(r.read_all::<f64>("dense_vals").unwrap());
        std::hint::black_box(r.read_all::<u16>("csr_lcolinds").unwrap());
        std::hint::black_box(r.read_all::<u32>("csr_rowptrs").unwrap());
        std::hint::black_box(r.read_all::<f64>("csr_vals").unwrap());
    });

    // Phase 3: streaming element decode only (no CSR assembly).
    time("visit_elements (decode only)", iters, || {
        let r = H5Reader::open(&path).unwrap();
        let mut acc = 0.0f64;
        visit_elements(&r, |_, _, v| acc += v).unwrap();
        std::hint::black_box(acc);
    });

    // Phase 4: the full Algorithm 1.
    let per = time("load_csr (Algorithm 1, full)", iters, || {
        let r = H5Reader::open(&path).unwrap();
        std::hint::black_box(load_csr(&r).unwrap());
    });
    println!(
        "\nAlgorithm 1: {:.1} Mnnz/s | {:.0} MB/s of file bytes",
        nnz / per / 1e6,
        fsize as f64 / per / 1e6
    );

    // References: in-memory conversion and raw file read.
    time("COO -> CSR (in-memory reference)", iters, || {
        std::hint::black_box(Csr::from_coo(&coo));
    });
    time("std::fs::read (raw I/O bound)", iters, || {
        std::hint::black_box(std::fs::read(&path).unwrap());
    });
}
