//! Quickstart: generate a distributed sparse matrix, store it as ABHSF
//! files (one per process), load it back, and verify.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions};
use abhsf::formats::Csr;
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::ProcessMapping;
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    // 1. A workload: cage-like seed enlarged by a Kronecker product
    //    (the paper's cage12-based generator, scaled down).
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(16, 7), 2));
    println!(
        "matrix: {} x {}, {} nonzeros",
        human::count(gen.dim()),
        human::count(gen.dim()),
        human::count(gen.nnz())
    );

    // 2. A configuration: 4 processes, balanced row-wise mapping
    //    (equal amortized nonzeros — the paper's storage setup).
    let p = 4;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p));
    let cluster = Cluster::new(p, 64);

    // 3. Store: every worker generates its own portion and writes
    //    matrix-<k>.h5spm (ABHSF, adaptively chosen block schemes) plus
    //    a dataset.json manifest describing the configuration.
    let dir = std::env::temp_dir().join("abhsf-quickstart");
    let _ = std::fs::remove_dir_all(&dir);
    let (_, store) = Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default())?;
    println!(
        "stored  {} nnz -> {} ABHSF payload in {:.3} s",
        human::count(store.total_nnz()),
        human::bytes(store.total_bytes()),
        store.wall_s
    );

    // 4. Reopen the dataset — the storing configuration is discovered
    //    from the manifest — and load. Strategy::Auto (the default) sees
    //    the configurations match and takes the same-config fast path
    //    (Algorithm 1 per rank).
    let dataset = Dataset::open(&dir)?;
    let (parts, load) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
    let auto = load.auto.as_ref().expect("auto decision");
    println!(
        "loaded  {} nnz back in {:.3} s (auto chose {})",
        human::count(load.total_nnz()),
        load.wall_s,
        auto.chosen
    );

    // 5. Verify through SpMV against direct generation.
    let n = gen.dim();
    let x: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
    let csrs: Vec<Csr> = parts.into_iter().map(|m| m.into_csr()).collect();
    let y = abhsf::spmv::SpmvParts::Csr(&csrs).spmv(&x);
    let mut want = vec![0.0; n as usize];
    gen.visit_row_range(0, n, |i, j, v| want[i as usize] += v * x[j as usize]);
    let diff = abhsf::spmv::max_abs_diff(&y, &want);
    println!("verify  spmv max |Δ| = {diff:.2e}");
    assert!(diff < 1e-9);
    println!("quickstart OK");
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
