//! Mini Figure 1: store once, reload under different configurations and
//! strategies, reporting wall times and the calibrated Lustre simulation.
//!
//! ```sh
//! cargo run --release --example reconfigure_load
//! ```

use abhsf::experiments::{run_fig1, Fig1Config};

fn main() -> anyhow::Result<()> {
    let cfg = Fig1Config {
        seed_n: 14,
        order: 2,
        p_store: 6,
        p_loads: vec![2, 3, 4, 6, 8],
        block_size: 32,
        rng_seed: 2014,
        reps: 3,
    };
    let rows = run_fig1(&cfg, true)?;

    // Assert the paper's qualitative conclusions on the simulated times.
    let same = rows
        .iter()
        .find(|r| r.scenario == "same-config")
        .expect("same-config row");
    let indep: Vec<_> = rows
        .iter()
        .filter(|r| r.scenario == "diff/independent")
        .collect();
    let coll: Vec<_> = rows
        .iter()
        .filter(|r| r.scenario == "diff/collective")
        .collect();
    for (i, c) in indep.iter().zip(&coll) {
        assert!(same.sim_s < i.sim_s, "same-config must be fastest");
        assert!(i.sim_s < c.sim_s, "independent must beat collective");
    }
    let tmin = indep.iter().map(|r| r.sim_s).fold(f64::INFINITY, f64::min);
    let tmax = indep.iter().map(|r| r.sim_s).fold(0.0, f64::max);
    println!(
        "\nindependent flatness: max/min = {:.3} (paper: nearly independent of P)",
        tmax / tmin
    );
    println!(
        "vs proportional bound: T_indep_max = {:.3} s << T_same x P = {:.3} s",
        tmax,
        same.sim_s * indep.last().unwrap().p_load as f64
    );
    Ok(())
}
