//! Full three-layer pipeline: generate → store (ABHSF) → load → pack →
//! **PJRT-compiled Pallas kernels** (blocked SpMV, block assembly, power
//! iteration) validated against the native Rust oracles.
//!
//! ```sh
//! make artifacts && cargo run --release --example spmv_pipeline
//! ```

use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions};
use abhsf::formats::Csr;
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::ProcessMapping;
use abhsf::runtime::{BlockedTensors, Runtime};
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    let rt = match Runtime::from_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            // No artifacts (run `make artifacts`) or built without the
            // `pjrt` feature: the pipeline demo has nothing to execute.
            println!("spmv_pipeline skipped: {e}");
            return Ok(());
        }
    };
    println!("PJRT platform: {}", rt.platform());
    println!(
        "artifacts: {}",
        rt.manifest()
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .collect::<Vec<_>>()
            .join(", ")
    );

    // Workload sized for the largest spmv artifact (R*s = 1024 rows).
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(10, 5), 2));
    let n = gen.dim();
    let p = 4;
    let mapping: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p));
    let cluster = Cluster::new(p, 64);
    let dir = std::env::temp_dir().join("abhsf-spmv-pipeline");
    let _ = std::fs::remove_dir_all(&dir);
    let (dataset, _) =
        Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default())?;
    let (mats, _) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
    let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
    println!(
        "loaded {} x {} ({} nnz) across {p} parts",
        human::count(n),
        human::count(n),
        human::count(gen.nnz())
    );

    let x: Vec<f64> = (0..n).map(|i| 0.5 + ((i * i) % 9) as f64 * 0.1).collect();
    let mut y_native = vec![0.0f64; n as usize];
    for part in &parts {
        part.spmv_into(&x, &mut y_native);
    }

    // Execute every rank's SpMV on the PJRT artifact and stitch y.
    let mut y_pjrt = vec![0.0f64; n as usize];
    let mut total_util = 0.0;
    for part in &parts {
        let (art, t) = rt.pack_best_spmv(part)?;
        total_util += t.slot_utilization();
        println!(
            "  rank rows [{}, {}): artifact {} | VMEM/grid-step {} | slot util {:.1}%",
            part.info.m_offset,
            part.info.m_offset + part.info.m_local,
            art.name,
            human::bytes(t.vmem_per_grid_step() as u64),
            t.slot_utilization() * 100.0
        );
        let y = rt.spmv(&art, &t, &t.pack_x(&x)?)?;
        let ro = part.info.m_offset as usize;
        for i in 0..part.info.m_local as usize {
            y_pjrt[ro + i] += y[i] as f64;
        }
    }
    let maxd = abhsf::spmv::max_abs_diff(&y_native, &y_pjrt);
    println!("PJRT vs native SpMV: max |Δ| = {maxd:.3e} (f32 artifact)");
    assert!(maxd < 1e-2);

    // Power iteration through the power_step artifact on one part that
    // spans the whole matrix: use a single-process store/load.
    let whole_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(1));
    let coo = gen.local_coo(whole_map.as_ref(), 0);
    let whole = Csr::from_coo(&coo);
    if let Some(art) = rt.manifest().of_kind("power_step").first().cloned().cloned() {
        let pn = art.param("n")? as usize;
        if whole.info.m_local as usize <= pn {
            let t = BlockedTensors::pack_csr(&whole, &art)?;
            let mut xv = vec![0f32; pn];
            for (i, v) in xv.iter_mut().enumerate().take(n as usize) {
                *v = 1.0 / (n as f32).sqrt() * ((i % 3) as f32 + 1.0);
            }
            let mut norm = 0f32;
            for step in 0..30 {
                let (x2, nv) = rt.power_step(&art, &t, &xv)?;
                xv = x2;
                norm = nv;
                if step % 10 == 9 {
                    println!("  power step {:>2}: ||A x|| = {norm:.6}", step + 1);
                }
            }
            println!("dominant |lambda| (PJRT power iteration) ~= {norm:.6}");
        }
    }

    println!(
        "pipeline OK (mean MXU slot utilization {:.1}%)",
        total_util / p as f64 * 100.0
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
