"""AOT pipeline: lower the Layer-2 JAX functions to HLO *text* artifacts.

Run once at build time (`make artifacts`); the Rust coordinator loads the
text with `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
client, and executes it on the request path with no Python anywhere.

HLO text — NOT `lowered.compiler_ir(...).serialize()` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which
the pinned xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo/ and its README.

Every artifact is registered in `manifest.json` with its input/output
shapes so the Rust runtime can size its buffers without re-parsing HLO.

Usage:
    python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Artifact configurations. Shapes are chosen so that (a) the end-to-end
# example's matrix fits, (b) VMEM-per-grid-step stays TPU-plausible
# (DESIGN.md §Perf), and (c) CPU interpret-mode execution stays fast.
CONFIGS = [
    # name, R (block rows), K (blocks/row), s (block size), n (vector len)
    # Low-K variants suit block-banded matrices; the K=R*? full-width
    # variants accept any structure (cage-like Kronecker rows scatter
    # across most block columns).
    {"name": "spmv_r64_k8_s16_n1024", "r": 64, "k": 8, "s": 16, "n": 1024},
    {"name": "spmv_r32_k8_s16_n512", "r": 32, "k": 8, "s": 16, "n": 512},
    {"name": "spmv_r16_k4_s8_n128", "r": 16, "k": 4, "s": 8, "n": 128},
    {"name": "spmv_r64_k64_s16_n1024", "r": 64, "k": 64, "s": 16, "n": 1024},
    {"name": "spmv_r32_k32_s16_n512", "r": 32, "k": 32, "s": 16, "n": 512},
]

ASSEMBLE_CONFIGS = [
    # name, Z (blocks), t (padded triplets/block), s
    {"name": "assemble_z128_t64_s16", "z": 128, "t": 64, "s": 16},
    {"name": "assemble_z32_t32_s8", "z": 32, "t": 32, "s": 8},
]

POWER_CONFIGS = [
    # closed iteration: R*s == n
    {"name": "power_r64_k8_s16_n1024", "r": 64, "k": 8, "s": 16, "n": 1024},
]


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_spmv(cfg):
    r, k, s, n = cfg["r"], cfg["k"], cfg["s"], cfg["n"]
    lowered = jax.jit(model.spmv).lower(f32(r, k, s, s), i32(r, k), f32(n))
    return lowered, {
        "kind": "spmv",
        "inputs": [
            {"name": "blocks", "dtype": "f32", "shape": [r, k, s, s]},
            {"name": "cols", "dtype": "i32", "shape": [r, k]},
            {"name": "x", "dtype": "f32", "shape": [n]},
        ],
        "outputs": [{"name": "y", "dtype": "f32", "shape": [r * s]}],
        "params": {"r": r, "k": k, "s": s, "n": n},
    }


def lower_power(cfg):
    r, k, s, n = cfg["r"], cfg["k"], cfg["s"], cfg["n"]
    assert r * s == n, "power iteration needs R*s == n"
    lowered = jax.jit(model.power_step).lower(f32(r, k, s, s), i32(r, k), f32(n))
    return lowered, {
        "kind": "power_step",
        "inputs": [
            {"name": "blocks", "dtype": "f32", "shape": [r, k, s, s]},
            {"name": "cols", "dtype": "i32", "shape": [r, k]},
            {"name": "x", "dtype": "f32", "shape": [n]},
        ],
        "outputs": [
            {"name": "x_next", "dtype": "f32", "shape": [n]},
            {"name": "norm", "dtype": "f32", "shape": []},
        ],
        "params": {"r": r, "k": k, "s": s, "n": n},
    }


def lower_assemble(cfg):
    z, t, s = cfg["z"], cfg["t"], cfg["s"]
    fn = functools.partial(model.assemble, s=s)
    lowered = jax.jit(fn).lower(i32(z, t), i32(z, t), f32(z, t))
    return lowered, {
        "kind": "assemble",
        "inputs": [
            {"name": "lrows", "dtype": "i32", "shape": [z, t]},
            {"name": "lcols", "dtype": "i32", "shape": [z, t]},
            {"name": "vals", "dtype": "f32", "shape": [z, t]},
        ],
        "outputs": [{"name": "blocks", "dtype": "f32", "shape": [z, s, s]}],
        "params": {"z": z, "t": t, "s": s},
    }


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    jobs = (
        [(c, lower_spmv) for c in CONFIGS]
        + [(c, lower_power) for c in POWER_CONFIGS]
        + [(c, lower_assemble) for c in ASSEMBLE_CONFIGS]
    )
    for cfg, lower in jobs:
        lowered, meta = lower(cfg)
        text = to_hlo_text(lowered)
        fname = cfg["name"] + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        meta["name"] = cfg["name"]
        meta["file"] = fname
        manifest["artifacts"].append(meta)
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
