"""Pallas kernel: assemble ABHSF COO-block triplets into dense blocks.

This is the paper's block-decode hot spot (LoadBlockCOO, Algorithm 3)
rethought for TPU: a serial scatter has no efficient TPU equivalent (no
CUDA-style atomics), so the scatter is re-expressed as two one-hot
matmuls that run on the MXU:

    dense = onehot(lrows)^T @ (vals[:, None] * onehot(lcols))

Each grid step assembles one block from its (padded) triplet list.
Padding slots carry val == 0 and therefore contribute nothing, whatever
their coordinates.

VMEM per grid step ~= (2*t*s + t*3 + s*s) * 4 bytes; t=256, s=32 ->
~0.2 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assemble_kernel(lrows_ref, lcols_ref, vals_ref, out_ref, *, s):
    lrows = lrows_ref[0]  # [t] i32
    lcols = lcols_ref[0]  # [t] i32
    vals = vals_ref[0]  # [t] f32
    iota = jax.lax.iota(jnp.int32, s)
    oh_r = (lrows[:, None] == iota[None, :]).astype(vals.dtype)  # [t, s]
    oh_c = (lcols[:, None] == iota[None, :]).astype(vals.dtype)  # [t, s]
    # [s, t] @ [t, s] -> [s, s] on the MXU.
    out_ref[0] = oh_r.T @ (vals[:, None] * oh_c)


def block_assemble(lrows, lcols, vals, s, *, interpret=True):
    """Assemble dense blocks from padded per-block COO triplets.

    Args:
      lrows: i32[Z, t] in-block row indexes (padding arbitrary).
      lcols: i32[Z, t] in-block column indexes (padding arbitrary).
      vals: f32[Z, t] values, exactly 0 in padding slots.
      s: block size.
      interpret: lower in interpret mode (required for CPU PJRT).

    Returns:
      f32[Z, s, s] dense blocks; matches `ref.block_assemble_ref`.
    """
    z, t = lrows.shape
    assert lcols.shape == (z, t) and vals.shape == (z, t)
    kernel = functools.partial(_assemble_kernel, s=s)
    return pl.pallas_call(
        kernel,
        grid=(z,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
            pl.BlockSpec((1, t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, s), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((z, s, s), vals.dtype),
        interpret=interpret,
    )(lrows, lcols, vals)
