"""Pallas kernel: blocked SpMV over the ABHSF block-dense representation.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot loop
is per-block decode + multiply on a CPU cluster. On TPU we tile by *block
row*: one grid step holds a block row's K dense s*s blocks plus the full
input vector in VMEM, contracts them on the MXU, and writes one s-segment
of y. BlockSpec expresses the HBM->VMEM schedule that the paper's code
does with per-process loops.

VMEM per grid step ~= (K*s*s + n + s) * 4 bytes; K=16, s=32, n=16384 ->
~2.1 MiB, comfortably inside a TPU core's ~16 MiB VMEM.

Must be lowered with interpret=True for CPU PJRT execution (real TPU
lowering emits a Mosaic custom-call the CPU plugin cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, blocks_ref, x_ref, y_ref, *, k, s):
    """One grid step: y[r*s:(r+1)*s] = sum_k blocks[r,k] @ x[cols[r,k]*s : +s]."""
    acc = jnp.zeros((s,), dtype=y_ref.dtype)
    for kk in range(k):  # static K, unrolled
        c = cols_ref[0, kk]
        xseg = x_ref[pl.dslice(c * s, s)]
        acc = acc + blocks_ref[0, kk] @ xseg
    y_ref[...] = acc


def blocked_spmv(blocks, cols, x, *, interpret=True):
    """Blocked SpMV via a Pallas kernel; matches `ref.blocked_spmv_ref`.

    Args:
      blocks: f32[R, K, s, s] padded dense blocks.
      cols: i32[R, K] block-column index per block.
      x: f32[n] input vector (n a multiple of s).
      interpret: lower in interpret mode (required for CPU PJRT).

    Returns:
      f32[R * s].
    """
    r, k, s, s2 = blocks.shape
    assert s == s2, f"blocks must be square, got {s}x{s2}"
    (n,) = x.shape
    assert n % s == 0, f"n={n} not a multiple of s={s}"
    kernel = functools.partial(_spmv_kernel, k=k, s=s)
    return pl.pallas_call(
        kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),  # cols
            pl.BlockSpec((1, k, s, s), lambda i: (i, 0, 0, 0)),  # blocks
            pl.BlockSpec((n,), lambda i: (0,)),  # x, whole vector
        ],
        out_specs=pl.BlockSpec((s,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r * s,), x.dtype),
        interpret=interpret,
    )(cols, blocks, x)
