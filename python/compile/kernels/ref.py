"""Pure-jnp reference oracles for the Pallas kernels.

These definitions are the correctness contract: pytest compares every
Pallas kernel against them (exact math, no kernel tricks), and the Rust
side validates the AOT artifacts against its own native implementation of
the same contract.
"""

import jax.numpy as jnp


def blocked_spmv_ref(blocks, cols, x):
    """Reference blocked SpMV.

    Args:
      blocks: f32[R, K, s, s] — up to K dense s*s blocks per block row
        (zero-padded when a block row has fewer).
      cols: i32[R, K] — block-column index of each block (padding entries
        must point at any valid column, conventionally 0, with zero
        blocks).
      x: f32[n] — input vector, n a multiple of s.

    Returns:
      f32[R * s] — y = A @ x for the block-sparse matrix A.
    """
    r, k, s, _ = blocks.shape
    xb = x.reshape(-1, s)  # [n/s, s]
    xsel = xb[cols]  # [R, K, s]
    y = jnp.einsum("rkij,rkj->ri", blocks, xsel)
    return y.reshape(r * s)


def block_assemble_ref(lrows, lcols, vals, s):
    """Reference block assembly (ABHSF COO-block decode).

    Scatters per-block COO triplets into dense s*s blocks. Padding slots
    must carry val == 0 (their coordinates are ignored by construction
    since they contribute zero).

    Args:
      lrows: i32[Z, t] — in-block row index per element slot.
      lcols: i32[Z, t] — in-block column index per element slot.
      vals: f32[Z, t] — element values, 0 for padding slots.
      s: int — block size.

    Returns:
      f32[Z, s, s] — dense blocks.
    """
    oh_r = (lrows[..., None] == jnp.arange(s)).astype(vals.dtype)  # [Z,t,s]
    oh_c = (lcols[..., None] == jnp.arange(s)).astype(vals.dtype)  # [Z,t,s]
    return jnp.einsum("zti,ztj,zt->zij", oh_r, oh_c, vals)


def power_step_ref(blocks, cols, x):
    """One normalized power-iteration step over the blocked matrix.

    Returns (x_next, norm) with x_next = A@x / ||A@x||_2 (zero-safe).
    """
    y = blocked_spmv_ref(blocks, cols, x)
    norm = jnp.sqrt(jnp.sum(y * y))
    safe = jnp.where(norm > 0, norm, 1.0)
    return y / safe, norm
