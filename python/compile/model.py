"""Layer 2: the JAX compute graph a loaded matrix feeds.

The loading paper's matrices exist to be computed with after restart; the
canonical downstream consumer is SpMV / power iteration. This module
composes the Layer-1 Pallas kernels into the functions that get
AOT-lowered (aot.py) and executed from the Rust coordinator via PJRT:

* `spmv` — y = A @ x over the blocked representation (Pallas kernel);
* `power_step` — one normalized power-iteration step (kernel + jnp);
* `assemble` — ABHSF COO-block decode into dense blocks (Pallas kernel);
* `assemble_spmv` — fused decode + SpMV, the full "load consumes file
  bytes, compute consumes blocks" path in one HLO module.

All functions are shape-polymorphic in Python but are lowered at fixed
shapes chosen in `aot.py` (PJRT artifacts are static-shape).
"""

import jax.numpy as jnp

from compile.kernels.block_assemble import block_assemble
from compile.kernels.blocked_spmv import blocked_spmv


def spmv(blocks, cols, x):
    """y = A @ x; blocks f32[R,K,s,s], cols i32[R,K], x f32[n] -> f32[R*s]."""
    return (blocked_spmv(blocks, cols, x),)


def power_step(blocks, cols, x):
    """One normalized power-iteration step.

    Returns (x_next f32[R*s], norm f32[]). R*s must equal n for the
    iteration to be closed under repeated application.
    """
    y = blocked_spmv(blocks, cols, x)
    norm = jnp.sqrt(jnp.sum(y * y))
    safe = jnp.where(norm > 0, norm, 1.0)
    return y / safe, norm


def assemble(lrows, lcols, vals, *, s):
    """Dense blocks from padded COO triplets; see block_assemble."""
    return (block_assemble(lrows, lcols, vals, s),)


def assemble_spmv(lrows, lcols, vals, cols, x, *, s, k):
    """Decode COO-triplet blocks, then SpMV — one fused HLO module.

    Args:
      lrows/lcols/vals: [Z, t] padded triplets, Z = R*K blocks in block-row
        major order (K per block row, zero-padded).
      cols: i32[R, K] block-column indexes.
      x: f32[n].

    Returns:
      (y f32[R*s],)
    """
    z, _t = lrows.shape
    r = z // k
    dense = block_assemble(lrows, lcols, vals, s)  # [Z, s, s]
    blocks = dense.reshape(r, k, s, s)
    return (blocked_spmv(blocks, cols, x),)
