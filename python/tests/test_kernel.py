"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and data; fixed cases pin the exact contracts
(padding semantics, dtype handling, degenerate shapes).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.block_assemble import block_assemble
from compile.kernels.blocked_spmv import blocked_spmv

TOL = dict(rtol=2e-5, atol=2e-5)


def make_spmv_case(rng, r, k, s, nb):
    """Random blocked matrix with nb block columns (n = nb * s)."""
    blocks = rng.normal(size=(r, k, s, s)).astype(np.float32)
    cols = rng.integers(0, nb, size=(r, k)).astype(np.int32)
    x = rng.normal(size=(nb * s,)).astype(np.float32)
    return jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(x)


class TestBlockedSpmv:
    @settings(max_examples=25, deadline=None)
    @given(
        r=st.integers(1, 6),
        k=st.integers(1, 5),
        s=st.sampled_from([2, 4, 8, 16]),
        nb=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, r, k, s, nb, seed):
        rng = np.random.default_rng(seed)
        blocks, cols, x = make_spmv_case(rng, r, k, s, nb)
        got = blocked_spmv(blocks, cols, x)
        want = ref.blocked_spmv_ref(blocks, cols, x)
        np.testing.assert_allclose(got, want, **TOL)

    def test_matches_dense_oracle(self):
        """Assemble the implied dense matrix and compare with full matmul."""
        rng = np.random.default_rng(7)
        r, k, s, nb = 4, 3, 8, 4
        blocks, cols, x = make_spmv_case(rng, r, k, s, nb)
        dense = np.zeros((r * s, nb * s), dtype=np.float64)
        for ri in range(r):
            for ki in range(k):
                c = int(cols[ri, ki])
                dense[ri * s:(ri + 1) * s, c * s:(c + 1) * s] += np.asarray(
                    blocks[ri, ki], dtype=np.float64
                )
        want = dense @ np.asarray(x, dtype=np.float64)
        got = blocked_spmv(blocks, cols, x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_padding_blocks_are_inert(self):
        rng = np.random.default_rng(3)
        blocks, cols, x = make_spmv_case(rng, 3, 4, 4, 3)
        # Zero out the last two blocks of each row, point them anywhere.
        blocks = blocks.at[:, 2:].set(0.0)
        cols2 = cols.at[:, 2:].set(0)
        y1 = blocked_spmv(blocks, cols, x)
        y2 = blocked_spmv(blocks, cols2, x)
        np.testing.assert_allclose(y1, y2, **TOL)

    def test_identity_blocks(self):
        s, nb = 8, 4
        r, k = nb, 1
        blocks = jnp.eye(s, dtype=jnp.float32)[None, None].repeat(r, axis=0)
        cols = jnp.arange(r, dtype=jnp.int32)[:, None]
        x = jnp.arange(nb * s, dtype=jnp.float32)
        y = blocked_spmv(blocks, cols, x)
        np.testing.assert_allclose(y, x, **TOL)

    def test_single_block(self):
        rng = np.random.default_rng(11)
        blocks, cols, x = make_spmv_case(rng, 1, 1, 2, 1)
        got = blocked_spmv(blocks, cols, x)
        want = np.asarray(blocks[0, 0]) @ np.asarray(x)
        np.testing.assert_allclose(got, want, **TOL)

    def test_rejects_bad_shapes(self):
        with pytest.raises(AssertionError):
            blocked_spmv(
                jnp.zeros((1, 1, 4, 4), jnp.float32),
                jnp.zeros((1, 1), jnp.int32),
                jnp.zeros((6,), jnp.float32),  # not a multiple of s
            )


class TestBlockAssemble:
    @settings(max_examples=25, deadline=None)
    @given(
        z=st.integers(1, 8),
        t=st.integers(1, 32),
        s=st.sampled_from([2, 4, 8, 16]),
        fill=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, z, t, s, fill, seed):
        rng = np.random.default_rng(seed)
        lrows = rng.integers(0, s, size=(z, t)).astype(np.int32)
        lcols = rng.integers(0, s, size=(z, t)).astype(np.int32)
        vals = rng.normal(size=(z, t)).astype(np.float32)
        # Zero a suffix to emulate padding.
        keep = int(round(fill * t))
        vals[:, keep:] = 0.0
        got = block_assemble(
            jnp.asarray(lrows), jnp.asarray(lcols), jnp.asarray(vals), s
        )
        want = ref.block_assemble_ref(
            jnp.asarray(lrows), jnp.asarray(lcols), jnp.asarray(vals), s
        )
        np.testing.assert_allclose(got, want, **TOL)

    def test_scatter_semantics_exact(self):
        """Hand-built case: distinct coordinates land exactly."""
        s = 4
        lrows = jnp.asarray([[0, 1, 3, 0]], dtype=jnp.int32)
        lcols = jnp.asarray([[0, 2, 3, 0]], dtype=jnp.int32)
        vals = jnp.asarray([[1.0, 2.0, 3.0, 0.0]], dtype=jnp.float32)
        out = np.asarray(block_assemble(lrows, lcols, vals, s))[0]
        want = np.zeros((s, s), dtype=np.float32)
        want[0, 0] = 1.0
        want[1, 2] = 2.0
        want[3, 3] = 3.0
        np.testing.assert_array_equal(out, want)

    def test_duplicate_coordinates_sum(self):
        """Matmul scatter accumulates duplicates (COO semantics)."""
        s = 2
        lrows = jnp.asarray([[1, 1]], dtype=jnp.int32)
        lcols = jnp.asarray([[0, 0]], dtype=jnp.int32)
        vals = jnp.asarray([[2.0, 3.0]], dtype=jnp.float32)
        out = np.asarray(block_assemble(lrows, lcols, vals, s))[0]
        assert out[1, 0] == 5.0

    def test_all_padding_gives_zero_block(self):
        s = 4
        lrows = jnp.zeros((2, 5), jnp.int32)
        lcols = jnp.zeros((2, 5), jnp.int32)
        vals = jnp.zeros((2, 5), jnp.float32)
        out = np.asarray(block_assemble(lrows, lcols, vals, s))
        assert (out == 0).all()


class TestComposition:
    def test_assemble_then_spmv_matches_ref_pipeline(self):
        """The fused assemble_spmv model path equals ref composition."""
        from compile import model

        rng = np.random.default_rng(5)
        r, k, s, t = 3, 2, 4, 6
        z = r * k
        lrows = rng.integers(0, s, size=(z, t)).astype(np.int32)
        lcols = rng.integers(0, s, size=(z, t)).astype(np.int32)
        vals = rng.normal(size=(z, t)).astype(np.float32)
        vals[:, 4:] = 0.0
        cols = rng.integers(0, 3, size=(r, k)).astype(np.int32)
        x = rng.normal(size=(3 * s,)).astype(np.float32)
        (got,) = model.assemble_spmv(
            jnp.asarray(lrows),
            jnp.asarray(lcols),
            jnp.asarray(vals),
            jnp.asarray(cols),
            jnp.asarray(x),
            s=s,
            k=k,
        )
        dense = ref.block_assemble_ref(
            jnp.asarray(lrows), jnp.asarray(lcols), jnp.asarray(vals), s
        ).reshape(r, k, s, s)
        want = ref.blocked_spmv_ref(dense, jnp.asarray(cols), jnp.asarray(x))
        np.testing.assert_allclose(got, want, **TOL)
