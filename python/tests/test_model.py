"""Layer-2 model tests: shapes, power-iteration math, AOT lowering."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


class TestModel:
    def test_spmv_shapes(self):
        r, k, s, n = 3, 2, 4, 16
        (y,) = model.spmv(
            jnp.zeros((r, k, s, s), jnp.float32),
            jnp.zeros((r, k), jnp.int32),
            jnp.zeros((n,), jnp.float32),
        )
        assert y.shape == (r * s,)

    def test_power_step_normalizes(self):
        rng = np.random.default_rng(0)
        r, k, s = 4, 2, 4
        n = r * s
        blocks = jnp.asarray(rng.normal(size=(r, k, s, s)).astype(np.float32))
        cols = jnp.asarray(rng.integers(0, n // s, size=(r, k)).astype(np.int32))
        x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        x2, norm = model.power_step(blocks, cols, x)
        assert x2.shape == (n,)
        assert float(norm) > 0
        np.testing.assert_allclose(float(jnp.linalg.norm(x2)), 1.0, rtol=1e-5)
        # Reference agreement.
        want, wnorm = ref.power_step_ref(blocks, cols, x)
        np.testing.assert_allclose(x2, want, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(norm), float(wnorm), rtol=2e-5)

    def test_power_step_zero_matrix_is_safe(self):
        r, k, s = 2, 1, 4
        n = r * s
        x2, norm = model.power_step(
            jnp.zeros((r, k, s, s), jnp.float32),
            jnp.zeros((r, k), jnp.int32),
            jnp.ones((n,), jnp.float32),
        )
        assert float(norm) == 0.0
        assert not bool(jnp.isnan(x2).any())

    def test_power_iteration_converges_on_diagonal(self):
        """Dominant eigenvector of diag(1..n) is e_n."""
        s, r, k = 4, 2, 1
        n = r * s
        diag = jnp.arange(1, n + 1, dtype=jnp.float32)
        blocks = jnp.stack(
            [jnp.diag(diag[i * s:(i + 1) * s])[None] for i in range(r)]
        )  # [r, 1, s, s]
        cols = jnp.arange(r, dtype=jnp.int32)[:, None]
        x = jnp.ones((n,), jnp.float32) / np.sqrt(n)
        norm = 0.0
        for _ in range(120):
            x, norm = model.power_step(blocks, cols, x)
        assert float(norm) == pytest.approx(float(n), rel=1e-2)
        assert abs(float(x[-1])) == pytest.approx(1.0, rel=1e-2)


class TestAot:
    def test_hlo_text_lowering(self):
        cfg = {"name": "t", "r": 2, "k": 2, "s": 4, "n": 16}
        lowered, meta = aot.lower_spmv(cfg)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "HloModule" in text
        assert meta["outputs"][0]["shape"] == [8]

    def test_manifest_schema(self, tmp_path):
        # Build a reduced artifact set into a temp dir and check the
        # manifest describes every file.
        old_configs = aot.CONFIGS, aot.ASSEMBLE_CONFIGS, aot.POWER_CONFIGS
        aot.CONFIGS = [{"name": "spmv_tiny", "r": 2, "k": 2, "s": 4, "n": 16}]
        aot.ASSEMBLE_CONFIGS = [{"name": "asm_tiny", "z": 4, "t": 8, "s": 4}]
        aot.POWER_CONFIGS = [{"name": "pow_tiny", "r": 2, "k": 2, "s": 4, "n": 8}]
        try:
            aot.build_all(str(tmp_path))
        finally:
            aot.CONFIGS, aot.ASSEMBLE_CONFIGS, aot.POWER_CONFIGS = old_configs
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["format"] == "hlo-text"
        assert len(manifest["artifacts"]) == 3
        for art in manifest["artifacts"]:
            assert (tmp_path / art["file"]).exists()
            assert {"name", "kind", "inputs", "outputs", "params"} <= set(art)

    def test_repo_artifacts_match_manifest(self):
        """If `make artifacts` has run, the manifest must be consistent."""
        art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        manifest_path = os.path.join(art_dir, "manifest.json")
        if not os.path.exists(manifest_path):
            pytest.skip("artifacts not built")
        manifest = json.loads(open(manifest_path).read())
        for art in manifest["artifacts"]:
            path = os.path.join(art_dir, art["file"])
            assert os.path.exists(path), art["file"]
            head = open(path).read(64)
            assert head.startswith("HloModule"), art["file"]


class TestNumericsAcrossDtypes:
    def test_f32_inputs_required_by_artifacts(self):
        """The artifact contract is f32/i32; confirm kernels accept and
        produce f32 without silent upcasts."""
        r, k, s, n = 2, 1, 4, 8
        (y,) = model.spmv(
            jnp.zeros((r, k, s, s), jnp.float32),
            jnp.zeros((r, k), jnp.int32),
            jnp.zeros((n,), jnp.float32),
        )
        assert y.dtype == jnp.float32

    def test_kernel_f64_mode(self):
        """Interpret-mode kernels also run in f64 (used by oracle checks)."""
        with jax.enable_x64(True):
            rng = np.random.default_rng(1)
            blocks = jnp.asarray(rng.normal(size=(2, 2, 4, 4)))
            cols = jnp.asarray(rng.integers(0, 2, size=(2, 2)).astype(np.int32))
            x = jnp.asarray(rng.normal(size=(8,)))
            from compile.kernels.blocked_spmv import blocked_spmv

            got = blocked_spmv(blocks, cols, x)
            want = ref.blocked_spmv_ref(blocks, cols, x)
            np.testing.assert_allclose(got, want, rtol=1e-12)
