//! Bench: **Table C** (ablation) — block size `s` sweep: file size, store
//! time, Algorithm-1 load time and scheme mix, exposing the size/speed
//! trade-off behind the paper's fixed-`s` design choice.
//!
//! Run: `cargo bench --bench blocksize`

use abhsf::abhsf::cost::CostModel;
use abhsf::abhsf::stats::{SchemeHistogram, SizeReport};
use abhsf::abhsf::{load_csr, store_data, AbhsfData, Scheme};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::h5::H5Reader;
use abhsf::util::bench::{fmt_time, Bencher, Table};
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    println!("== Table C: block-size sweep (size vs speed) ==\n");
    let gen = KroneckerGen::new(SeedMatrix::cage_like(22, 11), 2);
    let map = gen.balanced_rowwise(1);
    let coo = gen.local_coo(&map, 0);
    println!(
        "workload: cage-kron {} x {}, {} nnz\n",
        human::count(gen.dim()),
        human::count(gen.dim()),
        human::count(coo.nnz() as u64)
    );
    let dir = std::env::temp_dir().join("abhsf-blocksize-bench");
    std::fs::create_dir_all(&dir)?;
    let b = Bencher::quick();

    let mut t = Table::new(&[
        "s",
        "payload",
        "vs COO",
        "blocks",
        "dominant scheme",
        "build",
        "load (Alg.1)",
    ]);
    let mut best_ratio = f64::INFINITY;
    for s in [4u64, 8, 16, 32, 64, 128, 256] {
        let model = CostModel::default();
        let data = AbhsfData::from_coo(&coo, s, &model)?;
        let rep = SizeReport::of(&coo, &data);
        best_ratio = best_ratio.min(rep.ratio_vs_coo());
        let h = SchemeHistogram::of(&data);
        let dominant = Scheme::ALL
            .iter()
            .max_by_key(|&&sch| h.nonzeros_of(sch))
            .unwrap();
        let build = b.run(&format!("build-{s}"), || {
            std::hint::black_box(AbhsfData::from_coo(&coo, s, &model).unwrap());
        });
        let path = dir.join(format!("bs-{s}.h5spm"));
        store_data(&path, &data)?;
        let load = b.run(&format!("load-{s}"), || {
            let r = H5Reader::open(&path).unwrap();
            std::hint::black_box(load_csr(&r).unwrap());
        });
        t.row(&[
            s.to_string(),
            human::bytes(rep.abhsf_bytes),
            format!("{:.3}", rep.ratio_vs_coo()),
            data.blocks().to_string(),
            format!("{} ({} nnz)", dominant.name(), human::count(h.nonzeros_of(*dominant))),
            fmt_time(build.mean_s()),
            fmt_time(load.mean_s()),
        ]);
    }
    t.print();
    println!(
        "\nverdict: best compression ratio over the sweep = {best_ratio:.3} \
         (size is U-shaped in s: tiny blocks pay descriptor overhead, huge \
         blocks degrade to near-dense/bitmap)"
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
