//! Bench: **Table B** (ablation, ref [1]) — conversion throughput
//! COO→ABHSF and CSR→ABHSF (the storing-side overhead the paper's
//! pipeline pays to get small files), plus container write and the
//! loading-side inverse (ABHSF→CSR, Algorithm 1).
//!
//! Run: `cargo bench --bench conversion`

use abhsf::abhsf::cost::CostModel;
use abhsf::abhsf::{load_csr, store_data, AbhsfData};
use abhsf::formats::{Coo, Csr};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::h5::H5Reader;
use abhsf::util::bench::{fmt_rate, fmt_time, Bencher, Table};
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    println!("== Table B: conversion + store/load throughput (ref [1] ablation) ==\n");
    let gen = KroneckerGen::new(SeedMatrix::cage_like(24, 5), 2);
    let map = gen.balanced_rowwise(1);
    let coo = gen.local_coo(&map, 0);
    let csr = Csr::from_coo(&coo);
    let nnz = coo.nnz() as f64;
    println!(
        "workload: cage-kron {} x {}, {} nnz\n",
        human::count(gen.dim()),
        human::count(gen.dim()),
        human::count(coo.nnz() as u64)
    );

    let b = Bencher::default();
    let dir = std::env::temp_dir().join("abhsf-conversion-bench");
    std::fs::create_dir_all(&dir)?;

    let mut t = Table::new(&["operation", "time/iter", "throughput", "rsd"]);
    let mut add = |label: &str, m: &abhsf::util::bench::Measurement| {
        t.row(&[
            label.to_string(),
            fmt_time(m.mean_s()),
            fmt_rate(m.throughput().unwrap(), "nnz"),
            format!("{:.1}%", m.summary.rsd() * 100.0),
        ]);
    };

    for s in [16u64, 64] {
        let model = CostModel::default();
        let m1 = b.run_with_items(&format!("coo->abhsf s={s}"), nnz, || {
            std::hint::black_box(AbhsfData::from_coo(&coo, s, &model).unwrap());
        });
        add(&format!("COO -> ABHSF (s={s})"), &m1);
        let m2 = b.run_with_items(&format!("csr->abhsf s={s}"), nnz, || {
            std::hint::black_box(AbhsfData::from_csr(&csr, s, &model).unwrap());
        });
        add(&format!("CSR -> ABHSF (s={s})"), &m2);

        let data = AbhsfData::from_coo(&coo, s, &model)?;
        let path = dir.join(format!("conv-{s}.h5spm"));
        let m3 = b.run_with_items(&format!("store s={s}"), nnz, || {
            store_data(&path, &data).unwrap();
        });
        add(&format!("ABHSF -> file (s={s})"), &m3);

        let m4 = b.run_with_items(&format!("load s={s}"), nnz, || {
            let r = H5Reader::open(&path).unwrap();
            std::hint::black_box(load_csr(&r).unwrap());
        });
        add(&format!("file -> CSR, Alg. 1 (s={s})"), &m4);
    }

    // Baselines: the format conversions the loader competes against.
    let m5 = b.run_with_items("coo->csr", nnz, || {
        std::hint::black_box(Csr::from_coo(&coo));
    });
    add("COO -> CSR (in-memory baseline)", &m5);
    let mut coo2 = coo.clone();
    let m6 = b.run_with_items("sort", nnz, || {
        coo2.sort();
        std::hint::black_box(&coo2);
    });
    add("COO sort (lower bound)", &m6);

    t.print();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
