//! Bench: **Figure 1** — loading times for same vs different
//! configurations × {independent, collective} HDF5-style I/O strategies,
//! plus the exchange-loader extension.
//!
//! Protocol mirrors the paper (§4) at testbed scale: cage-like Kronecker
//! workload, balanced row-wise storage with `P_store` processes, reloads
//! with a regular column-wise mapping sweeping `P_load`. Reported times:
//! measured local-FS wall clock and the Anselm/Lustre cost-model makespan
//! driven by the measured per-rank I/O traces (see DESIGN.md §2).
//!
//! Run: `cargo bench --bench fig1_loading` (env `FIG1_SEED_N`,
//! `FIG1_STORE_PROCS` override the workload size).

use abhsf::experiments::{run_fig1, Fig1Config};
use abhsf::parfs::FsModel;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let cfg = Fig1Config {
        seed_n: env_u64("FIG1_SEED_N", 20),
        order: 2,
        p_store: env_u64("FIG1_STORE_PROCS", 12) as usize,
        p_loads: vec![3, 4, 6, 8, 12, 16],
        block_size: 32,
        rng_seed: 2014,
        reps: 3,
    };
    println!("== Figure 1: loading times across configurations ==\n");
    let rows = run_fig1(&cfg, true)?;

    // Shape verdicts (the paper's stated observations).
    let same = rows.iter().find(|r| r.scenario == "same-config").unwrap();
    let indep: Vec<_> = rows
        .iter()
        .filter(|r| r.scenario == "diff/independent")
        .collect();
    let coll: Vec<_> = rows
        .iter()
        .filter(|r| r.scenario == "diff/collective")
        .collect();
    let exch: Vec<_> = rows
        .iter()
        .filter(|r| r.scenario == "diff/exchange")
        .collect();

    let imax = indep.iter().map(|r| r.sim_s).fold(0.0, f64::max);
    let imin = indep.iter().map(|r| r.sim_s).fold(f64::INFINITY, f64::min);
    let ok1 = indep
        .iter()
        .zip(&coll)
        .all(|(i, c)| same.sim_s < i.sim_s && i.sim_s < c.sim_s);
    let ok2 = imax / imin < 1.5;
    let ok3 = imax < same.sim_s * indep.last().unwrap().p_load as f64;
    let ok4 = exch.iter().all(|e| {
        indep
            .iter()
            .find(|i| i.p_load == e.p_load)
            .is_none_or(|i| e.sim_s <= i.sim_s)
    });
    println!("\nshape verdicts (simulated Lustre):");
    println!(
        "  [{}] same-config < independent < collective for all P",
        tick(ok1)
    );
    println!(
        "  [{}] independent ~flat in P (max/min = {:.2})",
        tick(ok2),
        imax / imin
    );
    println!(
        "  [{}] independent << T_same x P ({:.3} s vs {:.3} s)",
        tick(ok3),
        imax,
        same.sim_s * indep.last().unwrap().p_load as f64
    );
    println!(
        "  [{}] exchange loader <= all-read-all (future-work ablation)",
        tick(ok4)
    );

    // Cost-model sensitivity: the independent < collective ordering must
    // hold across a wide parameter range, not just the calibrated point.
    println!("\ncost-model sensitivity (independent vs collective ordering):");
    let mut holds = 0;
    let mut total = 0;
    for disk in [2.0e9, 6.0e9, 20.0e9] {
        for net in [20.0e9, 100.0e9, 400.0e9] {
            for client in [0.5e9, 1.0e9, 4.0e9] {
                let m = FsModel {
                    disk_agg_bps: disk,
                    net_agg_bps: net,
                    client_bps: client,
                    ..FsModel::anselm_lustre()
                };
                let profiles: Vec<_> = (0..8)
                    .map(|_| abhsf::parfs::RankLoadProfile {
                        opens: 12,
                        ops: 2000,
                        bytes: 512 << 20,
                    })
                    .collect();
                let i = m
                    .simulate(&profiles, 512 << 20, abhsf::parfs::IoStrategy::Independent)
                    .makespan_s;
                let c = m
                    .simulate(&profiles, 512 << 20, abhsf::parfs::IoStrategy::Collective)
                    .makespan_s;
                total += 1;
                if i < c {
                    holds += 1;
                }
            }
        }
    }
    println!("  ordering holds in {holds}/{total} parameter combinations");
    anyhow::ensure!(ok1 && ok2 && ok3, "Figure 1 shape checks failed");
    Ok(())
}

fn tick(b: bool) -> &'static str {
    if b {
        "ok"
    } else {
        "FAIL"
    }
}
