//! Bench: **Table A** (ablation, ref [3]) — file size of ABHSF vs raw
//! COO / CSR / dense-binary storage, across matrix structures and block
//! sizes, with the per-scheme block histogram that explains each result.
//!
//! Run: `cargo bench --bench filesize`

use abhsf::abhsf::cost::CostModel;
use abhsf::abhsf::stats::{SchemeHistogram, SizeReport};
use abhsf::abhsf::{AbhsfData, Scheme};
use abhsf::formats::{Coo, LocalInfo};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::util::bench::Table;
use abhsf::util::human;
use abhsf::util::rng::Xoshiro256;

/// A dense-band matrix (SpMV stencils): ABHSF's best case.
fn banded(n: u64, half: u64) -> Coo {
    let mut coo = Coo::with_info(LocalInfo::whole(n, n, 0));
    for i in 0..n {
        for j in i.saturating_sub(half)..=(i + half).min(n - 1) {
            coo.push(i, j, 1.0 + (i + j) as f64 * 0.01);
        }
    }
    coo.info.z = coo.nnz() as u64;
    coo
}

/// Uniform random sprinkle: ABHSF's worst case.
fn uniform(n: u64, nnz: usize, seed: u64) -> Coo {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut coo = Coo::with_info(LocalInfo::whole(n, n, nnz as u64));
    let mut seen = std::collections::HashSet::new();
    while coo.nnz() < nnz {
        let r = rng.next_below(n);
        let c = rng.next_below(n);
        if seen.insert((r, c)) {
            coo.push(r, c, rng.next_f64());
        }
    }
    coo
}

fn main() -> anyhow::Result<()> {
    println!("== Table A: storage format sizes (paper ref [3] ablation) ==\n");
    let kron = KroneckerGen::new(SeedMatrix::cage_like(20, 9), 2);
    let map = kron.balanced_rowwise(1);
    let matrices: Vec<(String, Coo)> = vec![
        ("cage-kron-400".into(), kron.local_coo(&map, 0)),
        ("banded-1024".into(), banded(1024, 8)),
        ("uniform-1024".into(), uniform(1024, 40_000, 4)),
        ("dense-192".into(), banded(192, 192)),
    ];

    for (name, coo) in &matrices {
        let mut t = Table::new(&[
            "s", "ABHSF", "COO", "CSR", "dense", "vs COO", "blocks", "B:coo/csr/bmp/dns",
        ]);
        let mut best: Option<(u64, f64)> = None;
        for s in [8u64, 16, 32, 64, 128] {
            let data = AbhsfData::from_coo(coo, s, &CostModel::default())?;
            let rep = SizeReport::of(coo, &data);
            let h = SchemeHistogram::of(&data);
            if best.is_none() || rep.ratio_vs_coo() < best.unwrap().1 {
                best = Some((s, rep.ratio_vs_coo()));
            }
            t.row(&[
                s.to_string(),
                human::bytes(rep.abhsf_bytes),
                human::bytes(rep.coo_bytes),
                human::bytes(rep.csr_bytes),
                human::bytes(rep.dense_bytes),
                format!("{:.3}", rep.ratio_vs_coo()),
                data.blocks().to_string(),
                format!(
                    "{}/{}/{}/{}",
                    h.blocks_of(Scheme::Coo),
                    h.blocks_of(Scheme::Csr),
                    h.blocks_of(Scheme::Bitmap),
                    h.blocks_of(Scheme::Dense)
                ),
            ]);
        }
        let (bs, br) = best.unwrap();
        println!(
            "{name} ({} nnz, fill {:.3}%):",
            human::count(coo.nnz() as u64),
            coo.nnz() as f64 / (coo.info.m_local * coo.info.n_local) as f64 * 100.0
        );
        t.print();
        println!("  best: s={bs} at {br:.3}x of COO\n");
    }

    // Paper-shape verdicts: structured matrices compress below COO at the
    // right block size; the dense case approaches the 0.5x bound (values
    // only, no indexes).
    let banded_best = {
        let coo = &matrices[1].1;
        [8u64, 16, 32, 64]
            .iter()
            .map(|&s| {
                let d = AbhsfData::from_coo(coo, s, &CostModel::default()).unwrap();
                SizeReport::of(coo, &d).ratio_vs_coo()
            })
            .fold(f64::INFINITY, f64::min)
    };
    println!("verdict: banded best ratio {banded_best:.3} (< 1.0 expected)");
    anyhow::ensure!(banded_best < 1.0, "ABHSF must beat raw COO on banded");
    Ok(())
}
