//! Bench: per-scheme block SpMV kernel calibration.
//!
//! Times `spmv_block_into` on seeded random blocks across block sizes
//! `s` and fills ζ for every scheme, fits the affine per-block cost
//! `base_ps + per_elem_ps·ζ` per (s, scheme), and persists the result as
//! `BENCH_kernels.json` — the measured cost table `abhsf store
//! --calibrate` and `CostModel::from_measurements` consume, so adaptive
//! scheme selection can minimize kernel time on *this* machine instead
//! of stored bytes.
//!
//! Run: `cargo bench --bench kernels` (`--json PATH` to override the
//! output path). `abhsf calibrate` pretty-prints the resulting decision
//! maps against the analytic model.

use std::collections::BTreeMap;

use abhsf::abhsf::load::DecodedBlock;
use abhsf::abhsf::{CostModel, MeasuredCosts, Scheme};
use abhsf::spmv::kernels::spmv_block_into;
use abhsf::util::bench::{fmt_rate, fmt_time, Bencher, Table};
use abhsf::util::json::Json;
use abhsf::util::rng::Xoshiro256;

/// `--json PATH` from the bench's argv (cargo passes through everything
/// after `--`); the results file is always written.
fn json_path() -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_kernels.json".to_string())
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// A seeded random `s × s` block with exactly `zeta` nonzeros, encoded
/// under `scheme`.
fn random_block(rng: &mut Xoshiro256, scheme: Scheme, s: u64, zeta: u64) -> DecodedBlock {
    let mut cells = rng.sample_indices((s * s) as usize, zeta as usize);
    cells.sort_unstable();
    let elems: Vec<(u16, u16, f64)> = cells
        .into_iter()
        .map(|cell| {
            let (lr, lc) = ((cell as u64 / s) as u16, (cell as u64 % s) as u16);
            (lr, lc, rng.range_f64(0.5, 1.5))
        })
        .collect();
    DecodedBlock::build(scheme, 0, 0, s, &elems).expect("random block is well-formed")
}

/// Fill grid for one block size: from a single element to completely
/// full, dense enough that the affine fit sees both regimes.
fn fills(s: u64) -> Vec<u64> {
    let cells = s * s;
    let mut out = vec![
        1,
        s,
        cells / 8,
        cells / 4,
        cells / 2,
        cells * 3 / 4,
        cells,
    ];
    out.retain(|&z| z >= 1);
    out.sort_unstable();
    out.dedup();
    out
}

fn main() -> anyhow::Result<()> {
    println!("== Per-scheme block kernel calibration ==\n");
    let block_sizes = [8u64, 16, 32, 64];
    let b = Bencher::quick();
    let mut rng = Xoshiro256::seed_from_u64(0xB10C);

    let mut table = Table::new(&["s", "scheme", "zeta", "t/block", "rate"]);
    // (s, scheme, zeta, seconds-per-block) samples for the affine fit.
    let mut samples: Vec<(u64, Scheme, u64, f64)> = Vec::new();
    let mut json_rows = Vec::new();
    for &s in &block_sizes {
        let x: Vec<f64> = (0..s).map(|i| 1.0 + (i % 7) as f64 * 0.25).collect();
        let mut y = vec![0.0f64; s as usize];
        for scheme in Scheme::ALL {
            for &zeta in &fills(s) {
                let block = random_block(&mut rng, scheme, s, zeta);
                // Batch enough kernel calls per timed sample that the
                // clock overhead vanishes even for near-empty blocks.
                let reps = (16_384 / zeta.max(1)).clamp(8, 4096);
                let label = format!("{}-s{s}-z{zeta}", scheme.name());
                let m = b.run(&label, || {
                    for _ in 0..reps {
                        spmv_block_into(std::hint::black_box(&block), &x, &mut y);
                    }
                    std::hint::black_box(&mut y);
                });
                let secs = m.mean_s() / reps as f64;
                samples.push((s, scheme, zeta, secs));
                table.row(&[
                    s.to_string(),
                    scheme.name().to_string(),
                    zeta.to_string(),
                    fmt_time(secs),
                    fmt_rate(zeta as f64 / secs, "elem"),
                ]);
                json_rows.push(obj(vec![
                    ("s", Json::num(s)),
                    ("scheme", Json::str(scheme.name())),
                    ("zeta", Json::num(zeta)),
                    ("ps_per_block", Json::num((secs * 1e12).round() as u64)),
                ]));
            }
        }
    }
    table.print();

    let fitted = MeasuredCosts::fit(&samples)
        .map_err(|e| anyhow::anyhow!("fitting measured cost table: {e}"))?;
    println!("\nfitted table: {}", fitted.label());
    let analytic = CostModel::default();
    let measured = CostModel::from_measurements(fitted.clone());
    for &s in &block_sizes {
        let cells = s * s;
        let flips = (1..=cells)
            .filter(|&z| measured.choose(s, z) != analytic.choose(s, z))
            .count();
        println!(
            "s={s}: measured table flips {flips} of {cells} scheme decisions \
             ({:.1}%) vs analytic",
            flips as f64 * 100.0 / cells as f64
        );
    }

    let doc = obj(vec![
        ("bench", Json::str("kernels")),
        (
            "note",
            Json::str(
                "per-block SpMV kernel cost, fitted as base_ps + per_elem_ps*zeta \
                 per (s, scheme); consumed by `abhsf store --calibrate` / \
                 CostModel::from_measurements",
            ),
        ),
        (
            "grid",
            obj(vec![
                ("block_sizes", Json::arr_u64(&block_sizes)),
                (
                    "fills",
                    Json::str("1, s, s^2/8, s^2/4, s^2/2, 3s^2/4, s^2 (deduped)"),
                ),
            ]),
        ),
        ("measurements", Json::Arr(json_rows)),
        ("table", fitted.to_json()),
    ]);
    let path = json_path();
    std::fs::write(&path, format!("{doc}\n"))
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
