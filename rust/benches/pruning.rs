//! Bench: **Table E** — block-pruned vs unpruned different-configuration
//! loading. The per-file block directory localizes nonzeros to `s × s`
//! blocks, so a rank whose mapping region cannot intersect a block never
//! fetches or decodes its payload; this table quantifies the win across
//! two remaps of a Rowwise-stored dataset:
//!
//! * Rowwise → Colwise — the paper's §4 reload configuration: every rank
//!   keeps a 1/P column strip of every stored row band;
//! * Rowwise → Block2d — checkerboard reload: each rank intersects only
//!   the stored files covering its row band.
//!
//! Run: `cargo bench --bench pruning`

use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions, Strategy};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Block2d, Colwise, ProcessMapping};
use abhsf::parfs::FsModel;
use abhsf::util::bench::Table;
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    println!("== Table E: block-pruned vs unpruned diff-config loading ==\n");
    // Dense enough that surviving payloads span several 128 KiB
    // read-ahead batches per file, so the prefetch columns are live.
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::random(64, 0.15, 13), 2));
    let n = gen.dim();
    let p_store = 8;
    let model = FsModel::anselm_lustre();
    let dir = std::env::temp_dir().join("abhsf-pruning-bench");
    let _ = std::fs::remove_dir_all(&dir);
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let store_cluster = Cluster::new(p_store, 64);
    // Fine-grained container chunks so skipped blocks translate into
    // skipped chunk reads, not just skipped decoding.
    let (dataset, sreport) = Dataset::store(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: 32,
            chunk_elems: 4096,
            ..Default::default()
        },
    )?;
    println!(
        "workload: {} x {}, {} nnz, {} stored row-wise in {p_store} files\n",
        human::count(n),
        human::count(n),
        human::count(gen.nnz()),
        human::bytes(sreport.total_bytes())
    );

    let mut t = Table::new(&[
        "remap",
        "P_load",
        "pruned",
        "wall [ms]",
        "sim [s]",
        "bytes read",
        "blk skip",
        "payload skip",
        "RA hits",
        "RA stall [ms]",
    ]);
    for p_load in [4usize, 8, 16] {
        let remaps: Vec<(&str, Arc<dyn ProcessMapping>)> = vec![
            ("rowwise->colwise", Arc::new(Colwise::regular(n, n, p_load))),
            ("rowwise->block2d", Arc::new(Block2d::regular(n, n, 2, p_load / 2))),
        ];
        for (label, mapping) in remaps {
            let cluster = Cluster::new(p_load, 64);
            let mut unpruned_bytes = 0u64;
            for prune in [false, true] {
                let (_, r) = dataset
                    .load()
                    .mapping(&mapping)
                    .strategy(Strategy::Independent)
                    .prune(prune)
                    .format(InMemFormat::Csr)
                    .run(&cluster)?;
                assert_eq!(r.total_nnz(), gen.nnz(), "{label} prune={prune}");
                if !prune {
                    unpruned_bytes = r.total_read_bytes();
                } else {
                    assert!(r.blocks_skipped() > 0, "{label}: nothing pruned");
                    assert!(
                        r.total_read_bytes() <= unpruned_bytes,
                        "{label}: pruned read more bytes than unpruned"
                    );
                }
                t.row(&[
                    label.into(),
                    p_load.to_string(),
                    (if prune { "yes" } else { "no" }).into(),
                    format!("{:.2}", r.wall_s * 1e3),
                    format!("{:.3}", r.simulate(&model).makespan_s),
                    human::bytes(r.total_read_bytes()),
                    r.prune_ratio()
                        .map(|x| format!("{:.1}%", x * 100.0))
                        .unwrap_or_else(|| "-".into()),
                    human::bytes(r.bytes_skipped()),
                    if prune {
                        r.prefetch_hits().to_string()
                    } else {
                        "-".into()
                    },
                    if prune {
                        format!("{:.2}", r.prefetch_stall_s() * 1e3)
                    } else {
                        "-".into()
                    },
                ]);
            }
        }
    }
    t.print();
    println!(
        "\nreading: pruned loads fetch only block ranges intersecting the rank's \
         region (exact for rectangular mappings); the unpruned rows are the \
         paper's literal all-read-all §3 loop. RA columns: double-buffered \
         read-ahead — hits are batches fetched entirely behind the decoder's \
         back, stall is the time the decoder waited for the fetcher."
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
