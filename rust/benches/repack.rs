//! Bench: **Table F** — out-of-core dataset repacking. A Rowwise-stored
//! dataset is stream-transcoded to new configurations (process count,
//! mapping, block size); the table shows the pruned read phase's block
//! skipping, the re-encoded output, the bounded staging memory, and the
//! parfs forecast's break-even load count (repack-then-load vs direct
//! different-configuration loads).
//!
//! Run: `cargo bench --bench repack`

use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, StoreOptions};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Block2d, Colwise, CyclicRows, ProcessMapping};
use abhsf::util::bench::Table;
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    println!("== Table F: out-of-core dataset repacking ==\n");
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(18, 13), 2));
    let n = gen.dim();
    let p_store = 8;
    let dir = std::env::temp_dir().join("abhsf-repack-bench");
    let _ = std::fs::remove_dir_all(&dir);
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let (dataset, sreport) = Dataset::store(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: 32,
            chunk_elems: 4096,
            ..Default::default()
        },
    )?;
    println!(
        "workload: {} x {}, {} nnz, {} stored row-wise in {p_store} files (s=32)\n",
        human::count(n),
        human::count(n),
        human::count(gen.nnz()),
        human::bytes(sreport.total_bytes())
    );

    type Target = (&'static str, usize, u64, Option<Arc<dyn ProcessMapping>>);
    let targets: Vec<Target> = vec![
        ("reblock s=64", p_store, 64, None),
        (
            "-> colwise",
            4,
            32,
            Some(Arc::new(Colwise::regular(n, n, 4))),
        ),
        (
            "-> block2d 2x3",
            6,
            16,
            Some(Arc::new(Block2d::regular(n, n, 2, 3))),
        ),
        (
            "-> cyclic",
            4,
            32,
            Some(Arc::new(CyclicRows { m: n, n, p: 4 })),
        ),
    ];
    let mut t = Table::new(&[
        "target",
        "P",
        "s",
        "wall [ms]",
        "read",
        "blk skip",
        "written",
        "peak stage",
        "break-even",
    ]);
    for (label, p_new, s_new, mapping) in targets {
        let out = std::env::temp_dir().join(format!("abhsf-repack-bench-out-{p_new}-{s_new}"));
        let _ = std::fs::remove_dir_all(&out);
        let mut plan = dataset
            .repack()
            .nprocs(p_new)
            .block_size(s_new)
            .chunk_elems(4096);
        if let Some(mapping) = &mapping {
            plan = plan.mapping(mapping);
        }
        let forecast = plan.forecast();
        let cluster = Cluster::new(p_new, 64);
        let (repacked, report) = plan.run(&cluster, &out)?;
        assert_eq!(report.total_nnz(), gen.nnz(), "{label}: nnz lost");
        assert_eq!(repacked.nprocs(), p_new, "{label}");
        t.row(&[
            label.into(),
            p_new.to_string(),
            s_new.to_string(),
            format!("{:.2}", report.wall_s * 1e3),
            human::bytes(report.read.total_bytes()),
            report
                .prune_ratio()
                .map(|x| format!("{:.1}%", x * 100.0))
                .unwrap_or_else(|| "-".into()),
            human::bytes(report.write.total_bytes()),
            human::count(report.max_peak_staging()),
            forecast
                .break_even_loads
                .map(|k| k.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
        let _ = std::fs::remove_dir_all(&out);
    }
    t.print();
    println!(
        "\nreading: the read phase is the block-pruned §3 loop (skip ratio as in \
         Table E); \"peak stage\" is the largest per-rank staging set — bounded \
         by that rank's target region, never the whole matrix. Break-even is \
         the parfs-predicted load count after which repack-then-load beats \
         repeated direct different-config loads (\"-\" = direct is already \
         ~disk-bound)."
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
