//! Bench: **Table G** — concurrent dataset serving through the
//! decoded-block cache. One stored dataset is queried by the closed-loop
//! harness twice per budget — a *cold* run (empty cache: every block
//! fetched and decoded once) and a *warm* run (same seeded query stream
//! against the now-populated cache) — across cache budgets of ×0.25,
//! ×0.5 and ×2 the measured working set. The table shows how throughput
//! and hit rate move with the budget: at ×2 the warm run should serve
//! (almost) entirely from memory, at ×0.25 eviction churn caps the hit
//! rate no matter how often the queries repeat.
//!
//! **Table H** then fixes the *total* budget at ×0.5 the working set
//! and compares the single-tier cache against a two-tier T1/T2 split of
//! the same bytes under uniform and Zipfian (θ = 1.1) traffic: skew
//! concentrates claims on a hot template set, and blocks evicted from
//! T1 while still warm revive from the encoded T2 tier with an
//! in-memory re-decode instead of a storage round trip.
//!
//! Run: `cargo bench --bench serve`
//!
//! Besides the printed table, the results are persisted as JSON (default
//! `BENCH_serve.json` at the working directory, `--json PATH` to
//! override) so future changes can diff per-config q/s, p99 and hit rate
//! against the committed baseline.

use std::collections::BTreeMap;
use std::sync::Arc;

use abhsf::cache::BlockCache;
use abhsf::coordinator::{Cluster, Dataset, StoreOptions};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::ProcessMapping;
use abhsf::serve::{run_closed_loop, ServeConfig, Workload};
use abhsf::util::bench::Table;
use abhsf::util::human;
use abhsf::util::json::Json;

/// `--json PATH` from the bench's argv (cargo passes through everything
/// after `--`); the results file is always written.
fn json_path() -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_serve.json".to_string())
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn main() -> anyhow::Result<()> {
    println!("== Table G: cold vs warm serving across cache budgets ==\n");
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(16, 11), 2));
    let n = gen.dim();
    let p_store = 4;
    let dir = std::env::temp_dir().join("abhsf-serve-bench");
    let _ = std::fs::remove_dir_all(&dir);
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let cluster = Cluster::new(p_store, 64);
    let (dataset, sreport) = Dataset::store(
        &cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: 16,
            ..Default::default()
        },
    )?;

    // Working set = decoded bytes of every block, measured exactly by one
    // whole-matrix pass through an unbounded cache.
    let probe = BlockCache::with_budget(u64::MAX);
    {
        let reader = dataset.reader(&probe)?;
        let all = reader.rect(0..n, 0..n)?;
        anyhow::ensure!(!all.is_empty(), "empty dataset");
    }
    let ws = probe.stats().resident_bytes;
    println!(
        "workload: {} x {}, {} nnz in {p_store} files ({} on disk); \
         decoded working set {} in {} blocks\n",
        human::count(n),
        human::count(n),
        human::count(gen.nnz()),
        human::bytes(sreport.total_bytes()),
        human::bytes(ws),
        human::count(probe.stats().resident_blocks),
    );

    let cfg = ServeConfig {
        threads: 4,
        queries: 400,
        seed: 4242,
        spmv_every: 20,
        workload: Workload::Uniform,
    };
    let mut table = Table::new(&[
        "budget",
        "bytes",
        "cold q/s",
        "cold p99 ms",
        "warm q/s",
        "warm p99 ms",
        "warm hit%",
        "evictions",
        "storage reads",
    ]);
    let mut json_rows = Vec::new();
    for (label, budget) in [
        ("ws x0.25", ws / 4),
        ("ws x0.5", ws / 2),
        ("ws x2", ws * 2),
    ] {
        let cache = BlockCache::with_budget(budget);
        let cold = run_closed_loop(std::slice::from_ref(&dataset), &cache, &cfg)?;
        let before = cache.stats();
        let warm = run_closed_loop(std::slice::from_ref(&dataset), &cache, &cfg)?;
        let after = cache.stats();
        let warm_claims = (after.hits - before.hits) + (after.misses - before.misses);
        let warm_hit_rate = if warm_claims == 0 {
            0.0
        } else {
            (after.hits - before.hits) as f64 / warm_claims as f64
        };
        table.row(&[
            label.to_string(),
            human::bytes(budget),
            format!("{:.0}", cold.qps()),
            format!("{:.3}", cold.p99_ms),
            format!("{:.0}", warm.qps()),
            format!("{:.3}", warm.p99_ms),
            format!("{:.1}", warm_hit_rate * 100.0),
            human::count(after.evictions),
            human::bytes(cold.io.bytes + warm.io.bytes),
        ]);
        json_rows.push(obj(vec![
            ("label", Json::str(label)),
            ("budget_bytes", Json::num(budget)),
            ("cold_qps", Json::Num(cold.qps())),
            ("cold_p99_ms", Json::Num(cold.p99_ms)),
            ("warm_qps", Json::Num(warm.qps())),
            ("warm_p99_ms", Json::Num(warm.p99_ms)),
            ("warm_hit_rate", Json::Num(warm_hit_rate)),
            ("evictions", Json::num(after.evictions)),
            ("storage_read_bytes", Json::num(cold.io.bytes + warm.io.bytes)),
        ]));
    }
    table.print();
    println!(
        "\n(cold = empty cache, warm = same seeded query stream repeated; \
         hit% is the warm run's claims answered from residency)"
    );

    // Table H: at one fixed *total* budget (ws x0.5 — tight enough that
    // eviction decides everything), pit the single-tier cache against a
    // two-tier split of the same bytes, under uniform and Zipfian
    // traffic. Skew is where T2 earns its keep: the hot template set
    // cycles through T1 while the warm-but-evicted tail revives from T2
    // with an in-memory re-decode instead of a storage round trip.
    println!("\n== Table H: two-tier vs single-tier T1-only at equal total budget ==\n");
    let total = ws / 2;
    let mut skew_table = Table::new(&[
        "workload",
        "variant",
        "t1",
        "t2",
        "cold q/s",
        "warm q/s",
        "warm p99 ms",
        "warm hit%",
        "t2 hits",
        "demotions",
        "storage reads",
    ]);
    let mut skew_rows = Vec::new();
    for workload in [Workload::Uniform, Workload::Zipf(1.1)] {
        for (variant, t1, t2) in [("t1-only", total, 0), ("two-tier", total / 2, total - total / 2)]
        {
            let cache = BlockCache::with_tiered_budget(t1, t2);
            let scfg = ServeConfig {
                workload,
                ..cfg.clone()
            };
            let cold = run_closed_loop(std::slice::from_ref(&dataset), &cache, &scfg)?;
            let before = cache.stats();
            let warm = run_closed_loop(std::slice::from_ref(&dataset), &cache, &scfg)?;
            let after = cache.stats();
            let served =
                (after.hits - before.hits) + (after.decode_saves - before.decode_saves);
            let warm_claims = served + (after.misses - before.misses);
            let warm_hit_rate = if warm_claims == 0 {
                0.0
            } else {
                served as f64 / warm_claims as f64
            };
            skew_table.row(&[
                workload.to_string(),
                variant.to_string(),
                human::bytes(t1),
                human::bytes(t2),
                format!("{:.0}", cold.qps()),
                format!("{:.0}", warm.qps()),
                format!("{:.3}", warm.p99_ms),
                format!("{:.1}", warm_hit_rate * 100.0),
                human::count(after.decode_saves),
                human::count(after.demotions),
                human::bytes(cold.io.bytes + warm.io.bytes),
            ]);
            skew_rows.push(obj(vec![
                ("workload", Json::str(workload.to_string())),
                ("variant", Json::str(variant)),
                ("t1_budget", Json::num(t1)),
                ("t2_budget", Json::num(t2)),
                ("cold_qps", Json::Num(cold.qps())),
                ("warm_qps", Json::Num(warm.qps())),
                ("warm_p99_ms", Json::Num(warm.p99_ms)),
                ("warm_hit_rate", Json::Num(warm_hit_rate)),
                ("decode_saves", Json::num(after.decode_saves)),
                ("demotions", Json::num(after.demotions)),
                (
                    "storage_read_bytes",
                    Json::num(cold.io.bytes + warm.io.bytes),
                ),
            ]));
        }
    }
    skew_table.print();
    println!(
        "\n(equal total budget per row pair; t2 hits = warm-but-evicted blocks \
         revived by an in-memory re-decode, never a storage fetch)"
    );

    // Table I: tracing overhead. Same warm workload (ws x2 budget, so the
    // cache answers nearly every claim and the span machinery is the
    // dominant per-query delta) served once untraced and once with
    // `--trace` routing every span to a JSONL sink. The acceptance budget
    // is <= 5% q/s (DESIGN.md §14); the measured ratio lands in
    // BENCH_serve.json under "obs_overhead" for CI to shape-check.
    println!("\n== Table I: tracing overhead (warm serving, ws x2 budget) ==\n");
    let cache = BlockCache::with_budget(ws * 2);
    run_closed_loop(std::slice::from_ref(&dataset), &cache, &cfg)?; // prime
    let untraced = run_closed_loop(std::slice::from_ref(&dataset), &cache, &cfg)?;
    let trace_path = dir.join("bench-trace.jsonl");
    abhsf::obs::trace::enable(&trace_path)?;
    let traced = run_closed_loop(std::slice::from_ref(&dataset), &cache, &cfg)?;
    abhsf::obs::trace::finish()?;
    let trace_events = abhsf::obs::trace::read_trace(&trace_path)?.len();
    let overhead_pct = if traced.qps() > 0.0 {
        (untraced.qps() / traced.qps() - 1.0) * 100.0
    } else {
        0.0
    };
    let mut obs_table = Table::new(&["variant", "q/s", "p99 ms", "trace events"]);
    obs_table.row(&[
        "untraced".to_string(),
        format!("{:.0}", untraced.qps()),
        format!("{:.3}", untraced.p99_ms),
        "-".to_string(),
    ]);
    obs_table.row(&[
        "traced".to_string(),
        format!("{:.0}", traced.qps()),
        format!("{:.3}", traced.p99_ms),
        human::count(trace_events as u64),
    ]);
    obs_table.print();
    println!("\ntracing overhead: {overhead_pct:.1}% q/s (budget: <= 5%)");

    let doc = obj(vec![
        ("bench", Json::str("serve")),
        (
            "workload",
            obj(vec![
                ("n", Json::num(n)),
                ("nnz", Json::num(gen.nnz())),
                ("files", Json::num(p_store as u64)),
                ("stored_bytes", Json::num(sreport.total_bytes())),
                ("working_set_bytes", Json::num(ws)),
            ]),
        ),
        (
            "config",
            obj(vec![
                ("threads", Json::num(cfg.threads as u64)),
                ("queries", Json::num(cfg.queries)),
                ("seed", Json::num(cfg.seed)),
                ("spmv_every", Json::num(cfg.spmv_every)),
            ]),
        ),
        ("results", Json::Arr(json_rows)),
        ("skewed", Json::Arr(skew_rows)),
        (
            "obs_overhead",
            obj(vec![
                ("untraced_qps", Json::Num(untraced.qps())),
                ("traced_qps", Json::Num(traced.qps())),
                ("overhead_pct", Json::Num(overhead_pct)),
                ("trace_events", Json::num(trace_events as u64)),
            ]),
        ),
    ]);
    let path = json_path();
    std::fs::write(&path, format!("{doc}\n"))
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
