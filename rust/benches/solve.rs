//! Bench: distributed-vs-resident SpMV throughput and CG
//! time-to-tolerance across mappings and process counts.
//!
//! For every mapping kind (rowwise / colwise / 2d) and P ∈ {1, 2, 4, 8}
//! over a generated SPD operand: one resident (single-address-space)
//! SpMV timing, the distributed halo-exchange SpMV timing (per
//! application, engine build amortized over a fixed iteration budget)
//! with its measured and predicted halo bytes, and a CG solve to 1e-8
//! with iteration count and wall time. Persists `BENCH_solve.json`
//! (committed baseline at the repo root; CI regenerates and
//! shape-checks it like `BENCH_kernels.json`).
//!
//! Run: `cargo bench --bench solve` (`--json PATH` to override the
//! output path).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use abhsf::coordinator::Cluster;
use abhsf::dist::solvers::conjugate_gradient;
use abhsf::dist::{predict_spmv_comm, spmv_partitions, CsrOperator, LocalOperator, RankEngine};
use abhsf::formats::Csr;
use abhsf::gen::{spd_parts, KroneckerGen, SeedMatrix};
use abhsf::mapping::{Block2d, Colwise, ProcessMapping, Rowwise};
use abhsf::spmv::SpmvParts;
use abhsf::util::bench::{fmt_time, Bencher, Table};
use abhsf::util::json::Json;

/// `--json PATH` from the bench's argv; the results file is always
/// written.
fn json_path() -> String {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_solve.json".to_string())
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Distributed SpMV applications per timing run (engine build and
/// thread spawn amortize across them).
const SPMV_REPS: usize = 20;

fn mapping_for(kind: &str, n: u64, p: usize) -> Arc<dyn ProcessMapping> {
    match kind {
        "rowwise" => Arc::new(Rowwise::regular(n, n, p)),
        "colwise" => Arc::new(Colwise::regular(n, n, p)),
        _ => Arc::new(Block2d::regular_auto(n, n, p)),
    }
}

fn main() -> anyhow::Result<()> {
    println!("== Distributed SpMV / CG solve benchmark ==\n");
    let gen = KroneckerGen::new(SeedMatrix::cage_like(10, 42), 2);
    let n = gen.dim();
    let tol = 1e-8;
    let b = Bencher::quick();

    let mut table = Table::new(&[
        "mapping",
        "P",
        "resident/spmv",
        "dist/spmv",
        "halo B/spmv",
        "pred B/spmv",
        "cg iters",
        "cg time",
    ]);
    let mut json_rows = Vec::new();
    for kind in ["rowwise", "colwise", "2d"] {
        for p in [1usize, 2, 4, 8] {
            let mapping = mapping_for(kind, n, p);
            let desc = mapping.descriptor();
            let (coo_parts, _sigma) = spd_parts(&gen, mapping.as_ref(), 0.0);
            let nnz: u64 = coo_parts.iter().map(|c| c.nnz() as u64).sum();
            let parts: Arc<Vec<Csr>> = Arc::new(coo_parts.iter().map(Csr::from_coo).collect());
            let x: Arc<Vec<f64>> =
                Arc::new((0..n).map(|i| 0.5 + ((i % 7) as f64) * 0.25).collect());
            let b_rhs: Arc<Vec<f64>> =
                Arc::new((0..n).map(|i| 1.0 + ((i % 17) as f64) * 0.25).collect());

            // Resident: the whole product in one address space.
            let resident_parts = Arc::clone(&parts);
            let resident_x = Arc::clone(&x);
            let mut y = vec![0.0f64; n as usize];
            let m = b.run(&format!("resident-{kind}-p{p}"), || {
                y = SpmvParts::Csr(&resident_parts).spmv(&resident_x);
                std::hint::black_box(&mut y);
            });
            let resident_s = m.mean_s();

            // Distributed: SPMV_REPS applications per rank, engine build
            // amortized; leader wall time over the whole cluster run.
            let cluster = Cluster::new(p, 64);
            let run_desc = desc.clone();
            let run_parts = Arc::clone(&parts);
            let run_x = Arc::clone(&x);
            let t0 = Instant::now();
            let stats = cluster.run(move |ctx| {
                let (xp, yp) = spmv_partitions(&run_desc, n, n);
                let mut op = CsrOperator::new(std::slice::from_ref(&run_parts[ctx.rank]));
                let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
                let (x0, x1) = engine.x_owned_range();
                let x_local = run_x[x0 as usize..x1 as usize].to_vec();
                let (y0, y1) = engine.y_owned_range();
                let mut y_local = vec![0.0f64; (y1 - y0) as usize];
                for _ in 0..SPMV_REPS {
                    engine
                        .spmv(&mut op, &x_local, &mut y_local)
                        .expect("CSR operator cannot fail");
                }
                std::hint::black_box(&y_local);
                engine.stats().clone()
            });
            let dist_s = t0.elapsed().as_secs_f64() / SPMV_REPS as f64;
            let halo_per_spmv: u64 =
                stats.iter().map(|s| s.halo_bytes_sent).sum::<u64>() / SPMV_REPS as u64;
            let pred = predict_spmv_comm(&desc, n, n);

            // CG to tolerance on the SPD operand.
            let cg_cluster = Cluster::new(p, 64);
            let cg_desc = desc.clone();
            let cg_parts = Arc::clone(&parts);
            let cg_b = Arc::clone(&b_rhs);
            let t0 = Instant::now();
            let outcomes = cg_cluster.run(move |ctx| {
                let (xp, yp) = spmv_partitions(&cg_desc, n, n);
                let mut op = CsrOperator::new(std::slice::from_ref(&cg_parts[ctx.rank]));
                let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
                let (y0, y1) = engine.y_owned_range();
                conjugate_gradient(
                    &mut engine,
                    &mut op,
                    &cg_b[y0 as usize..y1 as usize],
                    tol,
                    500,
                )
                .expect("CSR operator cannot fail")
            });
            let cg_s = t0.elapsed().as_secs_f64();
            let cg = &outcomes[0];
            assert!(cg.converged, "CG must converge on the SPD operand");

            table.row(&[
                kind.to_string(),
                p.to_string(),
                fmt_time(resident_s),
                fmt_time(dist_s),
                halo_per_spmv.to_string(),
                pred.total_bytes().to_string(),
                cg.iterations.to_string(),
                fmt_time(cg_s),
            ]);
            json_rows.push(obj(vec![
                ("mapping", Json::str(kind)),
                ("p", Json::num(p as u64)),
                ("n", Json::num(n)),
                ("nnz", Json::num(nnz)),
                ("spmv_resident_s", Json::Num(resident_s)),
                ("spmv_dist_s", Json::Num(dist_s)),
                ("halo_bytes_per_spmv", Json::num(halo_per_spmv)),
                ("predicted_bytes_per_spmv", Json::num(pred.total_bytes())),
                ("comm_exact", Json::Bool(pred.exact)),
                ("cg_iters", Json::num(cg.iterations as u64)),
                ("cg_s", Json::Num(cg_s)),
                ("cg_converged", Json::Bool(cg.converged)),
            ]));
        }
    }
    table.print();

    let doc = obj(vec![
        ("bench", Json::str("solve")),
        (
            "note",
            Json::str(
                "distributed-vs-resident SpMV and CG time-to-tolerance over the \
                 halo-exchange engine; halo bytes measured per SpMV next to the \
                 predict_spmv_comm model (exact for rectangular mappings)",
            ),
        ),
        (
            "grid",
            obj(vec![
                ("mappings", Json::Arr(vec![
                    Json::str("rowwise"),
                    Json::str("colwise"),
                    Json::str("2d"),
                ])),
                ("procs", Json::arr_u64(&[1, 2, 4, 8])),
                ("tol", Json::Num(tol)),
                ("spmv_reps", Json::num(SPMV_REPS as u64)),
            ]),
        ),
        ("rows", Json::Arr(json_rows)),
    ]);
    let path = json_path();
    std::fs::write(&path, format!("{doc}\n"))
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}
