//! Bench: **Table E** (stack) — the compute path a loaded matrix feeds:
//! native Rust CSR SpMV vs the PJRT-executed Pallas artifacts (blocked
//! SpMV, block assembly, power step), with FLOP rates and the TPU
//! structure estimates from DESIGN.md §Perf (VMEM per grid step, MXU slot
//! utilization).
//!
//! Run: `make artifacts && cargo bench --bench spmv_bench`

use abhsf::formats::{Coo, Csr, LocalInfo};
use abhsf::runtime::pack::blocked_spmv_native;
use abhsf::runtime::{BlockedTensors, Runtime};
use abhsf::util::bench::{fmt_rate, fmt_time, Bencher, Table};
use abhsf::util::human;
use abhsf::util::rng::Xoshiro256;

fn block_banded_csr(seed: u64, m: u64, n: u64, per_row: usize) -> Csr {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let info = LocalInfo::whole(m, n, (m as usize * per_row) as u64);
    let mut coo = Coo::with_info(info);
    let mut seen = std::collections::HashSet::new();
    let groups = m.div_ceil(16);
    let bases: Vec<u64> = (0..groups)
        .map(|_| rng.next_below(n.saturating_sub(64).max(1)))
        .collect();
    for r in 0..m {
        let base = bases[(r / 16) as usize];
        for _ in 0..per_row {
            let c = (base + rng.next_below(64)).min(n - 1);
            if seen.insert((r, c)) {
                coo.push(r, c, rng.range_f64(-1.0, 1.0));
            }
        }
    }
    Csr::from_coo(&coo)
}

fn main() -> anyhow::Result<()> {
    println!("== Table E: SpMV across the stack (native vs PJRT artifacts) ==\n");
    let rt = match Runtime::from_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP: {e}\nrun `make artifacts` first");
            return Ok(());
        }
    };
    println!("PJRT platform: {}\n", rt.platform());
    let b = Bencher::default();

    let mut t = Table::new(&[
        "path",
        "config",
        "time/iter",
        "rate",
        "VMEM/step",
        "slot util",
    ]);

    for art in rt.manifest().of_kind("spmv") {
        let r = art.param("r")? as u64;
        let k = art.param("k")?;
        let s = art.param("s")? as u64;
        let n = art.param("n")? as u64;
        let m_rows = r * s;
        let per_row = (k.min(6) * 2) as usize;
        let csr = block_banded_csr(7, m_rows, n, per_row);
        let Ok(tensors) = BlockedTensors::pack_csr(&csr, art) else {
            continue;
        };
        let x64: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).cos()).collect();
        let xf = tensors.pack_x(&x64)?;
        let nnz = csr.nnz() as f64;
        let flops_csr = 2.0 * nnz;
        // The blocked kernel multiplies every (padded) slot: R*K*s*s MACs.
        let flops_blocked = 2.0 * (tensors.r * tensors.k * tensors.s * tensors.s) as f64;

        // Native CSR (f64).
        let mut y = vec![0.0f64; m_rows as usize];
        let m1 = b.run_with_items("native", flops_csr, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            csr.spmv_into(&x64, &mut y);
            std::hint::black_box(&y);
        });
        t.row(&[
            "native CSR f64".into(),
            art.name.clone(),
            fmt_time(m1.mean_s()),
            fmt_rate(m1.throughput().unwrap(), "FLOP"),
            "-".into(),
            "-".into(),
        ]);

        // Native blocked (f32) — the artifact's own algorithm in Rust.
        let m2 = b.run_with_items("blocked-native", flops_blocked, || {
            std::hint::black_box(blocked_spmv_native(&tensors, &xf));
        });
        t.row(&[
            "native blocked f32".into(),
            art.name.clone(),
            fmt_time(m2.mean_s()),
            fmt_rate(m2.throughput().unwrap(), "FLOP"),
            human::bytes(tensors.vmem_per_grid_step() as u64),
            format!("{:.1}%", tensors.slot_utilization() * 100.0),
        ]);

        // PJRT artifact (interpret-lowered Pallas on CPU).
        let art2 = art.clone();
        let m3 = b.run_with_items("pjrt", flops_blocked, || {
            std::hint::black_box(rt.spmv(&art2, &tensors, &xf).unwrap());
        });
        t.row(&[
            "PJRT pallas f32".into(),
            art.name.clone(),
            fmt_time(m3.mean_s()),
            fmt_rate(m3.throughput().unwrap(), "FLOP"),
            human::bytes(tensors.vmem_per_grid_step() as u64),
            format!("{:.1}%", tensors.slot_utilization() * 100.0),
        ]);

        // Correctness gate while we're here.
        let y_pjrt = rt.spmv(art, &tensors, &xf)?;
        let y_nat = blocked_spmv_native(&tensors, &xf);
        let maxd = y_pjrt
            .iter()
            .zip(&y_nat)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        anyhow::ensure!(maxd < 1e-3, "{}: pjrt/native divergence {maxd}", art.name);
    }

    // Assemble artifacts.
    for art in rt.manifest().of_kind("assemble") {
        let z = art.param("z")? as usize;
        let tt = art.param("t")? as usize;
        let s = art.param("s")? as usize;
        let mut rng = Xoshiro256::seed_from_u64(1);
        let lrows: Vec<i32> = (0..z * tt).map(|_| rng.next_below(s as u64) as i32).collect();
        let lcols: Vec<i32> = (0..z * tt).map(|_| rng.next_below(s as u64) as i32).collect();
        let vals: Vec<f32> = (0..z * tt).map(|_| rng.next_f64() as f32).collect();
        let elems = (z * tt) as f64;
        let m = b.run_with_items("assemble", elems, || {
            std::hint::black_box(rt.assemble(art, &lrows, &lcols, &vals).unwrap());
        });
        t.row(&[
            "PJRT assemble".into(),
            art.name.clone(),
            fmt_time(m.mean_s()),
            fmt_rate(m.throughput().unwrap(), "elem"),
            human::bytes(((2 * tt * s + 3 * tt + s * s) * 4) as u64),
            "-".into(),
        ]);
    }

    // Power step.
    for art in rt.manifest().of_kind("power_step") {
        let r = art.param("r")? as u64;
        let s = art.param("s")? as u64;
        let n = art.param("n")? as u64;
        let csr = block_banded_csr(9, r * s, n, 8);
        let Ok(tensors) = BlockedTensors::pack_csr(&csr, art) else {
            continue;
        };
        let x = vec![1.0f32; n as usize];
        let flops = 2.0 * (tensors.r * tensors.k * tensors.s * tensors.s) as f64;
        let art2 = art.clone();
        let m = b.run_with_items("power", flops, || {
            std::hint::black_box(rt.power_step(&art2, &tensors, &x).unwrap());
        });
        t.row(&[
            "PJRT power_step".into(),
            art.name.clone(),
            fmt_time(m.mean_s()),
            fmt_rate(m.throughput().unwrap(), "FLOP"),
            human::bytes(tensors.vmem_per_grid_step() as u64),
            format!("{:.1}%", tensors.slot_utilization() * 100.0),
        ]);
    }

    t.print();
    println!(
        "\nnote: PJRT numbers execute the *interpret-lowered* Pallas kernel on \
         CPU — a correctness artifact, not a TPU performance proxy. TPU \
         estimates (VMEM fit, MXU utilization) are structural; see DESIGN.md \
         §Perf and EXPERIMENTS.md."
    );
    Ok(())
}
