//! Bench: **Table D** (ablation / future-work) — different-configuration
//! loading strategies head-to-head: all-read-all (independent and
//! collective, paper §3) vs the exchange loader (paper's "future
//! research" — each file read once, elements routed over backpressured
//! channels), including bytes moved and channel-blocking time.
//!
//! All loads go through the `Dataset`/`LoadPlan` API: the storing
//! configuration is discovered from the dataset manifest, and a final
//! `Strategy::Auto` row shows what the cost model would have picked.
//!
//! Run: `cargo bench --bench strategies`

use std::sync::Arc;

use abhsf::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions, Strategy};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Colwise, ProcessMapping};
use abhsf::parfs::FsModel;
use abhsf::util::bench::Table;
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    println!("== Table D: diff-config loading strategies ==\n");
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(18, 13), 2));
    let n = gen.dim();
    let p_store = 8;
    let model = FsModel::anselm_lustre();
    let dir = std::env::temp_dir().join("abhsf-strategies-bench");
    let _ = std::fs::remove_dir_all(&dir);
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let (dataset, sreport) = Dataset::store(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: 32,
            ..Default::default()
        },
    )?;
    println!(
        "workload: {} x {}, {} nnz, {} stored in {p_store} files\n",
        human::count(n),
        human::count(n),
        human::count(gen.nnz()),
        human::bytes(sreport.total_bytes())
    );

    let mut t = Table::new(&[
        "strategy", "P_load", "wall [ms]", "sim [s]", "bytes read", "opens", "blocked [ms]",
    ]);

    // Reference: same-config (Auto fast path on the matching cluster).
    {
        let cluster = Cluster::new(p_store, 64);
        let (_, r) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
        assert!(r.auto.as_ref().is_some_and(|a| a.same_config));
        t.row(&[
            "same-config".into(),
            p_store.to_string(),
            format!("{:.2}", r.wall_s * 1e3),
            format!("{:.3}", r.simulate(&model).makespan_s),
            human::bytes(r.total_read_bytes()),
            r.per_rank_io.iter().map(|s| s.opens).sum::<u64>().to_string(),
            "-".into(),
        ]);
    }

    for p_load in [4usize, 8, 12] {
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 64);
        for strategy in [Strategy::Independent, Strategy::Collective, Strategy::Exchange] {
            // Paper-literal ablation: pruning off so "bytes read" shows the
            // all-read-all volume (benches/pruning.rs covers the pruned A/B).
            let (_, r) = dataset
                .load()
                .mapping(&mapping)
                .strategy(strategy)
                .prune(false)
                .format(InMemFormat::Csr)
                .run(&cluster)?;
            let blocked: u64 = r.send_blocked_ns.iter().sum();
            t.row(&[
                match strategy {
                    Strategy::Exchange => "exchange".into(),
                    other => format!("all-read-all/{}", other.label()),
                },
                p_load.to_string(),
                format!("{:.2}", r.wall_s * 1e3),
                format!("{:.3}", r.simulate(&model).makespan_s),
                human::bytes(r.total_read_bytes()),
                r.per_rank_io.iter().map(|s| s.opens).sum::<u64>().to_string(),
                if strategy == Strategy::Exchange {
                    format!("{:.2}", blocked as f64 / 1e6)
                } else {
                    "-".into()
                },
            ]);
        }
        // What would Auto have picked for this diff-config point?
        let (_, r) = dataset
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Auto)
            .format(InMemFormat::Csr)
            .run(&cluster)?;
        let auto = r.auto.as_ref().expect("auto decision recorded");
        t.row(&[
            format!("auto -> {}", auto.chosen),
            p_load.to_string(),
            format!("{:.2}", r.wall_s * 1e3),
            format!("{:.3}", r.simulate(&model).makespan_s),
            human::bytes(r.total_read_bytes()),
            r.per_rank_io.iter().map(|s| s.opens).sum::<u64>().to_string(),
            "-".into(),
        ]);
    }
    t.print();

    // Backpressure sensitivity: shrink channel capacity, watch blocking.
    println!("\nbackpressure sensitivity (exchange, P=8, channel capacity sweep):");
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 8));
    let mut t2 = Table::new(&["capacity", "wall [ms]", "blocked [ms]"]);
    for cap in [1usize, 4, 16, 64, 256] {
        let cluster = Cluster::new(8, cap);
        let (_, r) = dataset
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Csr)
            .run(&cluster)?;
        t2.row(&[
            cap.to_string(),
            format!("{:.2}", r.wall_s * 1e3),
            format!("{:.2}", r.send_blocked_ns.iter().sum::<u64>() as f64 / 1e6),
        ]);
    }
    t2.print();
    println!(
        "\nverdict: exchange reads each byte once (same-config I/O volume) at the \
         cost of inter-rank traffic — the adapted-algorithm direction the paper \
         names for future research."
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
