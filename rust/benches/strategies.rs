//! Bench: **Table D** (ablation / future-work) — different-configuration
//! loading strategies head-to-head: all-read-all (independent and
//! collective, paper §3) vs the exchange loader (paper's "future
//! research" — each file read once, elements routed over backpressured
//! channels), including bytes moved and channel-blocking time.
//!
//! Run: `cargo bench --bench strategies`

use std::sync::Arc;

use abhsf::coordinator::{
    load_different_config, load_exchange, load_same_config, storer::StoreOptions, Cluster,
    DiffLoadOptions, InMemFormat,
};
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::mapping::{Colwise, ProcessMapping};
use abhsf::parfs::{FsModel, IoStrategy};
use abhsf::util::bench::Table;
use abhsf::util::human;

fn main() -> anyhow::Result<()> {
    println!("== Table D: diff-config loading strategies ==\n");
    let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(18, 13), 2));
    let n = gen.dim();
    let p_store = 8;
    let model = FsModel::anselm_lustre();
    let dir = std::env::temp_dir().join("abhsf-strategies-bench");
    let _ = std::fs::remove_dir_all(&dir);
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(p_store));
    let store_cluster = Cluster::new(p_store, 64);
    let sreport = abhsf::coordinator::store_distributed(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: 32,
            ..Default::default()
        },
    )?;
    println!(
        "workload: {} x {}, {} nnz, {} stored in {p_store} files\n",
        human::count(n),
        human::count(n),
        human::count(gen.nnz()),
        human::bytes(sreport.total_bytes())
    );

    let mut t = Table::new(&[
        "strategy", "P_load", "wall [ms]", "sim [s]", "bytes read", "opens", "blocked [ms]",
    ]);

    // Reference: same-config.
    {
        let cluster = Cluster::new(p_store, 64);
        let (_, r) = load_same_config(&cluster, &dir, InMemFormat::Csr)?;
        t.row(&[
            "same-config".into(),
            p_store.to_string(),
            format!("{:.2}", r.wall_s * 1e3),
            format!("{:.3}", r.simulate(&model).makespan_s),
            human::bytes(r.total_read_bytes()),
            r.per_rank_io.iter().map(|s| s.opens).sum::<u64>().to_string(),
            "-".into(),
        ]);
    }

    for p_load in [4usize, 8, 12] {
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 64);
        for strategy in [IoStrategy::Independent, IoStrategy::Collective] {
            let (_, r) = load_different_config(
                &cluster,
                &dir,
                &mapping,
                &DiffLoadOptions {
                    stored_files: p_store,
                    strategy,
                    format: InMemFormat::Csr,
                },
            )?;
            t.row(&[
                format!("all-read-all/{}", strategy.label()),
                p_load.to_string(),
                format!("{:.2}", r.wall_s * 1e3),
                format!("{:.3}", r.simulate(&model).makespan_s),
                human::bytes(r.total_read_bytes()),
                r.per_rank_io.iter().map(|s| s.opens).sum::<u64>().to_string(),
                "-".into(),
            ]);
        }
        let (_, r) = load_exchange(&cluster, &dir, &mapping, p_store, InMemFormat::Csr)?;
        let blocked_ms: f64 = r.send_blocked_ns.iter().sum::<u64>() as f64 / 1e6;
        t.row(&[
            "exchange".into(),
            p_load.to_string(),
            format!("{:.2}", r.wall_s * 1e3),
            format!("{:.3}", r.simulate(&model).makespan_s),
            human::bytes(r.total_read_bytes()),
            r.per_rank_io.iter().map(|s| s.opens).sum::<u64>().to_string(),
            format!("{blocked_ms:.2}"),
        ]);
    }
    t.print();

    // Backpressure sensitivity: shrink channel capacity, watch blocking.
    println!("\nbackpressure sensitivity (exchange, P=8, channel capacity sweep):");
    let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 8));
    let mut t2 = Table::new(&["capacity", "wall [ms]", "blocked [ms]"]);
    for cap in [1usize, 4, 16, 64, 256] {
        let cluster = Cluster::new(8, cap);
        let (_, r) = load_exchange(&cluster, &dir, &mapping, p_store, InMemFormat::Csr)?;
        t2.row(&[
            cap.to_string(),
            format!("{:.2}", r.wall_s * 1e3),
            format!("{:.2}", r.send_blocked_ns.iter().sum::<u64>() as f64 / 1e6),
        ]);
    }
    t2.print();
    println!(
        "\nverdict: exchange reads each byte once (same-config I/O volume) at the \
         cost of inter-rank traffic — the adapted-algorithm direction the paper \
         names for future research."
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
