//! Partitioning a local submatrix into fixed `s × s` blocks.

use crate::formats::Element;

/// One nonzero block: its block coordinates and the contained elements in
/// *in-block* coordinates (`0 ≤ lrow, lcol < s`), sorted lexicographically.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    /// Block row index (`brow * s` = first covered local row).
    pub brow: u64,
    /// Block column index.
    pub bcol: u64,
    /// Elements as `(lrow, lcol, val)`, lexicographically sorted.
    pub elems: Vec<(u16, u16, f64)>,
}

impl Block {
    /// Nonzero count ζ of this block.
    pub fn zeta(&self) -> u64 {
        self.elems.len() as u64
    }
}

/// Partition local-coordinate elements into nonzero blocks, ordered
/// row-major by `(brow, bcol)` — the dataset order Algorithms 1–6 expect
/// (all blocks of one block row are contiguous, block rows ascending).
///
/// Duplicate coordinates must have been combined beforehand; `s` must fit
/// in-block indexes in u16 (`s ≤ 65536`).
pub fn partition_into_blocks(elements: &[Element], s: u64) -> Vec<Block> {
    assert!(s > 0 && s <= u16::MAX as u64 + 1, "block size {s} out of range");
    // Key each element by (brow, bcol, lrow, lcol) and sort.
    let mut keyed: Vec<(u64, u64, u16, u16, f64)> = elements
        .iter()
        .map(|e| {
            (
                e.row / s,
                e.col / s,
                (e.row % s) as u16,
                (e.col % s) as u16,
                e.val,
            )
        })
        .collect();
    keyed.sort_unstable_by(|a, b| (a.0, a.1, a.2, a.3).partial_cmp(&(b.0, b.1, b.2, b.3)).unwrap());

    let mut blocks: Vec<Block> = Vec::new();
    for (brow, bcol, lrow, lcol, val) in keyed {
        match blocks.last_mut() {
            Some(b) if b.brow == brow && b.bcol == bcol => b.elems.push((lrow, lcol, val)),
            _ => blocks.push(Block {
                brow,
                bcol,
                elems: vec![(lrow, lcol, val)],
            }),
        }
    }
    blocks
}

/// Reassemble local-coordinate elements from blocks (inverse of
/// [`partition_into_blocks`] up to ordering).
pub fn blocks_to_elements(blocks: &[Block], s: u64) -> Vec<Element> {
    let mut out = Vec::with_capacity(blocks.iter().map(|b| b.elems.len()).sum());
    for b in blocks {
        for &(lr, lc, v) in &b.elems {
            out.push(Element::new(b.brow * s + lr as u64, b.bcol * s + lc as u64, v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::element::sort_lex;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn partitions_into_expected_blocks() {
        let s = 4;
        let elements = vec![
            Element::new(0, 0, 1.0),  // block (0,0)
            Element::new(3, 3, 2.0),  // block (0,0)
            Element::new(0, 4, 3.0),  // block (0,1)
            Element::new(5, 1, 4.0),  // block (1,0)
            Element::new(7, 7, 5.0),  // block (1,1)
        ];
        let blocks = partition_into_blocks(&elements, s);
        let keys: Vec<(u64, u64, u64)> = blocks.iter().map(|b| (b.brow, b.bcol, b.zeta())).collect();
        assert_eq!(keys, vec![(0, 0, 2), (0, 1, 1), (1, 0, 1), (1, 1, 1)]);
        assert_eq!(blocks[0].elems, vec![(0, 0, 1.0), (3, 3, 2.0)]);
    }

    #[test]
    fn block_order_is_row_major() {
        let s = 2;
        let elements = vec![
            Element::new(3, 3, 1.0),
            Element::new(0, 3, 2.0),
            Element::new(2, 0, 3.0),
            Element::new(0, 0, 4.0),
        ];
        let blocks = partition_into_blocks(&elements, s);
        let keys: Vec<(u64, u64)> = blocks.iter().map(|b| (b.brow, b.bcol)).collect();
        assert_eq!(keys, vec![(0, 0), (0, 1), (1, 0), (1, 1)]);
    }

    #[test]
    fn in_block_elements_sorted() {
        let s = 8;
        let elements = vec![
            Element::new(1, 7, 1.0),
            Element::new(1, 2, 2.0),
            Element::new(0, 5, 3.0),
        ];
        let blocks = partition_into_blocks(&elements, s);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].elems, vec![(0, 5, 3.0), (1, 2, 2.0), (1, 7, 1.0)]);
    }

    #[test]
    fn roundtrip_random() {
        let mut rng = Xoshiro256::seed_from_u64(2024);
        for s in [3u64, 4, 16] {
            let mut elements = Vec::new();
            let mut seen = std::collections::HashSet::new();
            for _ in 0..300 {
                let r = rng.next_below(100);
                let c = rng.next_below(100);
                if seen.insert((r, c)) {
                    elements.push(Element::new(r, c, rng.next_f64()));
                }
            }
            let blocks = partition_into_blocks(&elements, s);
            let mut back = blocks_to_elements(&blocks, s);
            sort_lex(&mut back);
            sort_lex(&mut elements);
            assert_eq!(elements.len(), back.len());
            for (a, b) in elements.iter().zip(&back) {
                assert_eq!((a.row, a.col), (b.row, b.col));
                assert_eq!(a.val, b.val);
            }
        }
    }

    #[test]
    fn empty_input() {
        assert!(partition_into_blocks(&[], 8).is_empty());
    }
}
