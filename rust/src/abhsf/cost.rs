//! Cost model and adaptive scheme selection (Langr et al. [5]).
//!
//! For each nonzero block the builder picks the scheme minimizing cost.
//! Two cost definitions coexist behind one [`CostModel::block_cost`]:
//!
//! * **Analytic** (the default): stored *bytes*, mirroring the exact byte
//!   layout this crate writes (u16 in-block indexes, u32 per-block row
//!   pointers, f64 values, LSB-packed bitmap) — the adaptive choice
//!   literally minimizes file size.
//! * **Measured**: per-block SpMV *time* from a calibration run of the
//!   `kernels` bench (`BENCH_kernels.json`), attached via
//!   [`CostModel::from_measurements`] — the choice then minimizes kernel
//!   latency on the hardware that produced the table.
//!
//! The two are never mixed: a model either carries a [`MeasuredCosts`]
//! table (and every cost is picoseconds) or it does not (and every cost
//! is bytes). [`CostModel::choose`] is the argmin of `block_cost` either
//! way, so downstream invariants (ties toward the lower tag, monotone
//! fill regions for the analytic model) are stated once.

use std::sync::Arc;

use crate::abhsf::Scheme;
use crate::util::json::Json;

/// Scheme-selection cost model: analytic byte widths plus an optional
/// measured kernel-cost table that, when present, takes precedence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CostModel {
    /// Bytes per in-block row/column index (COO lrows/lcols, CSR lcolinds).
    pub idx_bytes: u64,
    /// Bytes per value.
    pub val_bytes: u64,
    /// Bytes per CSR in-block row pointer.
    pub rowptr_bytes: u64,
    /// Calibrated per-scheme kernel costs; `None` selects by bytes.
    pub measured: Option<Arc<MeasuredCosts>>,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            idx_bytes: 2,
            val_bytes: 8,
            rowptr_bytes: 4,
            measured: None,
        }
    }
}

impl CostModel {
    /// A purely analytic model with explicit byte widths (test hook for
    /// forcing a particular scheme to win).
    pub fn analytic(idx_bytes: u64, val_bytes: u64, rowptr_bytes: u64) -> Self {
        Self {
            idx_bytes,
            val_bytes,
            rowptr_bytes,
            measured: None,
        }
    }

    /// Default byte widths plus a measured kernel-cost table; `choose`
    /// then minimizes calibrated SpMV time instead of stored bytes.
    pub fn from_measurements(table: MeasuredCosts) -> Self {
        Self {
            measured: Some(Arc::new(table)),
            ..Self::default()
        }
    }

    /// Storage cost in bytes of one `s × s` block holding `zeta` nonzeros
    /// under `scheme`, ignoring any measured table. Excludes the per-block
    /// descriptor overhead (scheme tag, zeta, brow, bcol), which is
    /// identical for all schemes and therefore irrelevant to the choice.
    pub fn analytic_cost(&self, scheme: Scheme, s: u64, zeta: u64) -> u64 {
        debug_assert!(zeta <= s * s, "zeta {zeta} exceeds s^2 {}", s * s);
        match scheme {
            Scheme::Coo => zeta * (2 * self.idx_bytes + self.val_bytes),
            Scheme::Csr => zeta * (self.idx_bytes + self.val_bytes) + (s + 1) * self.rowptr_bytes,
            Scheme::Bitmap => (s * s).div_ceil(8) + zeta * self.val_bytes,
            Scheme::Dense => s * s * self.val_bytes,
        }
    }

    /// Cost of one block under `scheme`: calibrated picoseconds when a
    /// measured table is attached, stored bytes otherwise. Only relative
    /// order matters to [`choose`](Self::choose), so the unit switch is
    /// safe — but absolute values must never be compared across models.
    pub fn block_cost(&self, scheme: Scheme, s: u64, zeta: u64) -> u64 {
        match &self.measured {
            Some(table) => table.cost_ps(scheme, s, zeta),
            None => self.analytic_cost(scheme, s, zeta),
        }
    }

    /// The cheapest scheme for a block (ties broken toward the lower tag,
    /// i.e. the more general scheme).
    pub fn choose(&self, s: u64, zeta: u64) -> Scheme {
        let mut best = Scheme::Coo;
        let mut best_cost = self.block_cost(best, s, zeta);
        for scheme in [Scheme::Csr, Scheme::Bitmap, Scheme::Dense] {
            let c = self.block_cost(scheme, s, zeta);
            if c < best_cost {
                best = scheme;
                best_cost = c;
            }
        }
        best
    }

    /// Which table chose the schemes — recorded in the dataset manifest
    /// so a stored layout can be traced back to its calibration.
    pub fn table_id(&self) -> String {
        match &self.measured {
            Some(table) => table.label(),
            None => "analytic".to_string(),
        }
    }
}

/// One calibrated (block size, scheme) entry: affine per-block kernel
/// cost `base_ps + per_elem_ps · ζ`, in integer picoseconds.
///
/// The affine form is deliberate: the lower envelope of affine functions
/// of ζ gives each scheme one contiguous winning interval, so measured
/// crossover points are monotone in ζ by construction — the same
/// structural property the analytic byte model has.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeasuredEntry {
    /// Calibrated block size.
    pub s: u64,
    /// Scheme this entry prices.
    pub scheme: Scheme,
    /// Fixed per-block cost (dispatch, pointer walks), picoseconds.
    pub base_ps: u64,
    /// Marginal cost per nonzero, picoseconds.
    pub per_elem_ps: u64,
}

/// A calibration table: per-scheme affine kernel costs for a set of
/// measured block sizes, as produced by `cargo bench --bench kernels`
/// (persisted in `BENCH_kernels.json`) and consumed by
/// [`CostModel::from_measurements`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeasuredCosts {
    /// Sorted by (s, scheme tag); every block size carries all 4 schemes.
    entries: Vec<MeasuredEntry>,
}

impl MeasuredCosts {
    /// Validate and normalize a set of entries: at least one block size,
    /// and for every present block size exactly one entry per scheme.
    pub fn new(mut entries: Vec<MeasuredEntry>) -> Result<Self, String> {
        if entries.is_empty() {
            return Err("measured cost table is empty".to_string());
        }
        entries.sort_by_key(|e| (e.s, e.scheme as u8));
        for pair in entries.windows(2) {
            if pair[0].s == pair[1].s && pair[0].scheme == pair[1].scheme {
                return Err(format!(
                    "duplicate entry for s={} scheme={}",
                    pair[0].s,
                    pair[0].scheme.name()
                ));
            }
        }
        for chunk in entries.chunks(Scheme::ALL.len()) {
            let s = chunk[0].s;
            if s == 0 {
                return Err("calibrated block size 0".to_string());
            }
            let complete = chunk.len() == Scheme::ALL.len()
                && chunk.iter().all(|e| e.s == s)
                && chunk
                    .iter()
                    .zip(Scheme::ALL)
                    .all(|(e, scheme)| e.scheme == scheme);
            if !complete {
                return Err(format!("block size {s} is missing scheme entries"));
            }
        }
        Ok(Self { entries })
    }

    /// The calibrated entries, sorted by (s, scheme tag).
    pub fn entries(&self) -> &[MeasuredEntry] {
        &self.entries
    }

    /// Calibrated block sizes, ascending.
    pub fn block_sizes(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self.entries.iter().map(|e| e.s).collect();
        out.dedup();
        out
    }

    /// Kernel cost of one block in picoseconds. A block size that was not
    /// calibrated uses the nearest calibrated size (ties toward the
    /// smaller), so the table generalizes to any store configuration.
    pub fn cost_ps(&self, scheme: Scheme, s: u64, zeta: u64) -> u64 {
        let nearest = self
            .block_sizes()
            .into_iter()
            .min_by_key(|&cal| (cal.abs_diff(s), cal))
            .expect("table is never empty");
        let e = self
            .entries
            .iter()
            .find(|e| e.s == nearest && e.scheme == scheme)
            .expect("every calibrated s carries all schemes");
        e.base_ps.saturating_add(e.per_elem_ps.saturating_mul(zeta))
    }

    /// Short identifier, e.g. `measured(s=8,16,32,64)`.
    pub fn label(&self) -> String {
        let sizes: Vec<String> = self.block_sizes().iter().map(|s| s.to_string()).collect();
        format!("measured(s={})", sizes.join(","))
    }

    /// Serialize as the JSON table embedded in `BENCH_kernels.json`:
    /// `{"entries": [{"s":…, "scheme":"COO", "base_ps":…, "per_elem_ps":…}, …]}`.
    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|e| {
                let mut obj = std::collections::BTreeMap::new();
                obj.insert("s".to_string(), Json::num(e.s));
                obj.insert("scheme".to_string(), Json::str(e.scheme.name()));
                obj.insert("base_ps".to_string(), Json::num(e.base_ps));
                obj.insert("per_elem_ps".to_string(), Json::num(e.per_elem_ps));
                Json::Obj(obj)
            })
            .collect();
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("entries".to_string(), Json::Arr(entries));
        Json::Obj(obj)
    }

    /// Parse the table produced by [`to_json`](Self::to_json). Also
    /// accepts a whole `BENCH_kernels.json` document (looks up its
    /// `"table"` field first).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let table = v.get("table").unwrap_or(v);
        let entries = table
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("measured cost table: missing entries[]")?;
        let mut out = Vec::with_capacity(entries.len());
        for e in entries {
            let s = e
                .get("s")
                .and_then(Json::as_u64)
                .ok_or("table entry: missing s")?;
            let name = e
                .get("scheme")
                .and_then(Json::as_str)
                .ok_or("table entry: missing scheme")?;
            let scheme = Scheme::ALL
                .into_iter()
                .find(|sch| sch.name() == name)
                .ok_or_else(|| format!("table entry: unknown scheme {name:?}"))?;
            let base_ps = e
                .get("base_ps")
                .and_then(Json::as_u64)
                .ok_or("table entry: missing base_ps")?;
            let per_elem_ps = e
                .get("per_elem_ps")
                .and_then(Json::as_u64)
                .ok_or("table entry: missing per_elem_ps")?;
            out.push(MeasuredEntry {
                s,
                scheme,
                base_ps,
                per_elem_ps,
            });
        }
        Self::new(out)
    }

    /// Least-squares affine fit per (s, scheme) from raw bench samples
    /// `(s, scheme, zeta, seconds-per-block)`; negative fitted
    /// coefficients are clamped to zero (they arise from measurement
    /// noise at tiny ζ, never from real kernels).
    pub fn fit(samples: &[(u64, Scheme, u64, f64)]) -> Result<Self, String> {
        let mut keys: Vec<(u64, Scheme)> = samples.iter().map(|&(s, sch, _, _)| (s, sch)).collect();
        keys.sort_by_key(|&(s, sch)| (s, sch as u8));
        keys.dedup();
        let mut entries = Vec::with_capacity(keys.len());
        for (s, scheme) in keys {
            let pts: Vec<(f64, f64)> = samples
                .iter()
                .filter(|&&(ps, psch, _, _)| ps == s && psch == scheme)
                .map(|&(_, _, zeta, secs)| (zeta as f64, secs * 1e12))
                .collect();
            let (base_ps, per_elem_ps) = affine_fit(&pts);
            entries.push(MeasuredEntry {
                s,
                scheme,
                base_ps: base_ps.max(0.0).round() as u64,
                per_elem_ps: per_elem_ps.max(0.0).round() as u64,
            });
        }
        Self::new(entries)
    }
}

/// Ordinary least squares `y ≈ a + b·x` over the given points; a single
/// point degenerates to `(y, 0)`.
fn affine_fit(pts: &[(f64, f64)]) -> (f64, f64) {
    let n = pts.len() as f64;
    if pts.is_empty() {
        return (0.0, 0.0);
    }
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < f64::EPSILON {
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Cost of one block under the default analytic model. Always bytes —
/// the byte-accounting paths (`BlockDirectory::payload_bytes`, pruning
/// I/O estimates) use this regardless of any calibration.
pub fn scheme_cost(scheme: Scheme, s: u64, zeta: u64) -> u64 {
    CostModel::default().analytic_cost(scheme, s, zeta)
}

/// Adaptive scheme choice under the default analytic model.
pub fn choose_scheme(s: u64, zeta: u64) -> Scheme {
    CostModel::default().choose(s, zeta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_blocks_prefer_coo_or_csr() {
        // One element in a 64x64 block: COO = 12 B, CSR = 10 + 65*4 = 270 B,
        // bitmap = 512 + 8 B, dense = 32 KiB.
        assert_eq!(choose_scheme(64, 1), Scheme::Coo);
    }

    #[test]
    fn half_full_blocks_prefer_bitmap() {
        let s = 64;
        let zeta = s * s / 2;
        // COO: 2048*12 = 24576; CSR: 2048*10 + 260 = 20740;
        // bitmap: 512 + 16384 = 16896; dense: 32768.
        assert_eq!(choose_scheme(s, zeta), Scheme::Bitmap);
    }

    #[test]
    fn full_blocks_prefer_dense() {
        let s = 64;
        assert_eq!(choose_scheme(s, s * s), Scheme::Dense);
        // 90% full is still bitmap (bitmap = 512 + 0.9*32768 < 32768).
        assert_eq!(choose_scheme(s, s * s * 9 / 10), Scheme::Bitmap);
        // ~99% full: bitmap = 512 + 32440 > 32768 -> dense.
        assert_eq!(choose_scheme(s, s * s - 10), Scheme::Dense);
    }

    #[test]
    fn csr_wins_at_moderate_fill() {
        // CSR beats COO once zeta > 2(s+1) and beats bitmap while
        // zeta < (s*s/8 - (s+1)*4) / 2; the window is nonempty for s >= 96.
        // s=128, zeta=300: COO 3600, CSR 3516, bitmap 4448, dense 131072.
        assert_eq!(choose_scheme(128, 300), Scheme::Csr);
        // For small blocks the bitmap's fixed cost is tiny and CSR never
        // wins under the default widths.
        assert_ne!(choose_scheme(8, 20), Scheme::Csr);
    }

    #[test]
    fn cost_formulas_exact() {
        let m = CostModel::default();
        assert_eq!(m.block_cost(Scheme::Coo, 8, 5), 5 * 12);
        assert_eq!(m.block_cost(Scheme::Csr, 8, 5), 5 * 10 + 9 * 4);
        assert_eq!(m.block_cost(Scheme::Bitmap, 8, 5), 8 + 5 * 8);
        assert_eq!(m.block_cost(Scheme::Dense, 8, 5), 64 * 8);
    }

    #[test]
    fn choice_is_argmin_for_all_fills() {
        let m = CostModel::default();
        for s in [4u64, 8, 16, 32] {
            for zeta in 1..=s * s {
                let chosen = m.choose(s, zeta);
                let cmin = Scheme::ALL
                    .iter()
                    .map(|&sch| m.block_cost(sch, s, zeta))
                    .min()
                    .unwrap();
                assert_eq!(
                    m.block_cost(chosen, s, zeta),
                    cmin,
                    "s={s} zeta={zeta}: {chosen:?} not argmin"
                );
            }
        }
    }

    #[test]
    fn selection_monotone_regions() {
        // As fill grows for fixed s the chosen scheme should move through
        // COO/CSR -> bitmap -> dense without returning.
        let s = 32u64;
        let mut stage = 0; // 0 = coo/csr, 1 = bitmap, 2 = dense
        for zeta in 1..=s * s {
            let next = match choose_scheme(s, zeta) {
                Scheme::Coo | Scheme::Csr => 0,
                Scheme::Bitmap => 1,
                Scheme::Dense => 2,
            };
            assert!(next >= stage, "regression at zeta={zeta}");
            stage = next;
        }
        assert_eq!(stage, 2);
    }

    /// A synthetic but plausible table: COO cheapest per element, dense
    /// cheapest per block once fill is high, bitmap in between.
    pub(crate) fn sample_table(s: u64) -> MeasuredCosts {
        MeasuredCosts::new(
            Scheme::ALL
                .into_iter()
                .map(|scheme| {
                    let (base_ps, per_elem_ps) = match scheme {
                        Scheme::Coo => (500, 900),
                        Scheme::Csr => (900, 700),
                        Scheme::Bitmap => (1200, 500),
                        Scheme::Dense => (300 * s, 150),
                    };
                    MeasuredEntry {
                        s,
                        scheme,
                        base_ps,
                        per_elem_ps,
                    }
                })
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn measured_table_drives_choose() {
        let s = 16u64;
        let model = CostModel::from_measurements(sample_table(s));
        for zeta in 1..=s * s {
            let chosen = model.choose(s, zeta);
            let cmin = Scheme::ALL
                .iter()
                .map(|&sch| model.block_cost(sch, s, zeta))
                .min()
                .unwrap();
            assert_eq!(model.block_cost(chosen, s, zeta), cmin);
        }
        // Affine envelope: per-element order COO < bitmap makes COO win
        // sparse blocks, bitmap's lower slope wins mid fill.
        assert_eq!(model.choose(s, 1), Scheme::Coo);
    }

    #[test]
    fn measured_costs_reject_incomplete_tables() {
        assert!(MeasuredCosts::new(Vec::new()).is_err());
        let mut entries = sample_table(8).entries().to_vec();
        entries.pop();
        assert!(MeasuredCosts::new(entries).is_err());
        let mut dup = sample_table(8).entries().to_vec();
        dup.push(dup[0]);
        assert!(MeasuredCosts::new(dup).is_err());
    }

    #[test]
    fn nearest_block_size_interpolation() {
        let mut entries = sample_table(8).entries().to_vec();
        entries.extend(sample_table(64).entries().iter().copied());
        let t = MeasuredCosts::new(entries).unwrap();
        assert_eq!(t.block_sizes(), vec![8, 64]);
        // s=16 maps to calibrated 8; s=36 ties 8 vs 64 and takes the smaller.
        assert_eq!(t.cost_ps(Scheme::Coo, 16, 3), t.cost_ps(Scheme::Coo, 8, 3));
        assert_eq!(t.cost_ps(Scheme::Coo, 36, 3), t.cost_ps(Scheme::Coo, 8, 3));
        assert_eq!(
            t.cost_ps(Scheme::Coo, 37, 3),
            t.cost_ps(Scheme::Coo, 64, 3)
        );
    }

    #[test]
    fn affine_fit_recovers_exact_line() {
        let pts: Vec<(f64, f64)> = (0..10).map(|x| (x as f64, 3.0 + 2.0 * x as f64)).collect();
        let (a, b) = affine_fit(&pts);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fit_builds_table_from_samples() {
        let mut samples = Vec::new();
        for scheme in Scheme::ALL {
            for zeta in [1u64, 8, 32, 64] {
                // 1 ns base + 0.5 ns per element, scheme-independent.
                samples.push((8u64, scheme, zeta, 1e-9 + 0.5e-9 * zeta as f64));
            }
        }
        let t = MeasuredCosts::fit(&samples).unwrap();
        for e in t.entries() {
            assert!((e.base_ps as i64 - 1000).abs() <= 1, "{e:?}");
            assert!((e.per_elem_ps as i64 - 500).abs() <= 1, "{e:?}");
        }
    }

    #[test]
    fn table_id_labels() {
        assert_eq!(CostModel::default().table_id(), "analytic");
        let model = CostModel::from_measurements(sample_table(8));
        assert_eq!(model.table_id(), "measured(s=8)");
    }
}
