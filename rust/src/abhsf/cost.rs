//! Space cost model and adaptive scheme selection (Langr et al. [5]).
//!
//! For each nonzero block the builder picks the scheme minimizing stored
//! bytes. The model mirrors the *exact* byte layout this crate writes (u16
//! in-block indexes, u32 per-block row pointers, f64 values, LSB-packed
//! bitmap), so the adaptive choice literally minimizes file size.

use crate::abhsf::Scheme;

/// Byte widths of the on-disk representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// Bytes per in-block row/column index (COO lrows/lcols, CSR lcolinds).
    pub idx_bytes: u64,
    /// Bytes per value.
    pub val_bytes: u64,
    /// Bytes per CSR in-block row pointer.
    pub rowptr_bytes: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            idx_bytes: 2,
            val_bytes: 8,
            rowptr_bytes: 4,
        }
    }
}

impl CostModel {
    /// Storage cost in bytes of one `s × s` block holding `zeta` nonzeros
    /// under `scheme`. Excludes the per-block descriptor overhead
    /// (scheme tag, zeta, brow, bcol), which is identical for all schemes
    /// and therefore irrelevant to the choice.
    pub fn block_cost(&self, scheme: Scheme, s: u64, zeta: u64) -> u64 {
        debug_assert!(zeta <= s * s, "zeta {zeta} exceeds s^2 {}", s * s);
        match scheme {
            Scheme::Coo => zeta * (2 * self.idx_bytes + self.val_bytes),
            Scheme::Csr => zeta * (self.idx_bytes + self.val_bytes) + (s + 1) * self.rowptr_bytes,
            Scheme::Bitmap => (s * s).div_ceil(8) + zeta * self.val_bytes,
            Scheme::Dense => s * s * self.val_bytes,
        }
    }

    /// The cheapest scheme for a block (ties broken toward the lower tag,
    /// i.e. the more general scheme).
    pub fn choose(&self, s: u64, zeta: u64) -> Scheme {
        let mut best = Scheme::Coo;
        let mut best_cost = self.block_cost(best, s, zeta);
        for scheme in [Scheme::Csr, Scheme::Bitmap, Scheme::Dense] {
            let c = self.block_cost(scheme, s, zeta);
            if c < best_cost {
                best = scheme;
                best_cost = c;
            }
        }
        best
    }
}

/// Cost of one block under the default model.
pub fn scheme_cost(scheme: Scheme, s: u64, zeta: u64) -> u64 {
    CostModel::default().block_cost(scheme, s, zeta)
}

/// Adaptive scheme choice under the default model.
pub fn choose_scheme(s: u64, zeta: u64) -> Scheme {
    CostModel::default().choose(s, zeta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_blocks_prefer_coo_or_csr() {
        // One element in a 64x64 block: COO = 12 B, CSR = 10 + 65*4 = 270 B,
        // bitmap = 512 + 8 B, dense = 32 KiB.
        assert_eq!(choose_scheme(64, 1), Scheme::Coo);
    }

    #[test]
    fn half_full_blocks_prefer_bitmap() {
        let s = 64;
        let zeta = s * s / 2;
        // COO: 2048*12 = 24576; CSR: 2048*10 + 260 = 20740;
        // bitmap: 512 + 16384 = 16896; dense: 32768.
        assert_eq!(choose_scheme(s, zeta), Scheme::Bitmap);
    }

    #[test]
    fn full_blocks_prefer_dense() {
        let s = 64;
        assert_eq!(choose_scheme(s, s * s), Scheme::Dense);
        // 90% full is still bitmap (bitmap = 512 + 0.9*32768 < 32768).
        assert_eq!(choose_scheme(s, s * s * 9 / 10), Scheme::Bitmap);
        // ~99% full: bitmap = 512 + 32440 > 32768 -> dense.
        assert_eq!(choose_scheme(s, s * s - 10), Scheme::Dense);
    }

    #[test]
    fn csr_wins_at_moderate_fill() {
        // CSR beats COO once zeta > 2(s+1) and beats bitmap while
        // zeta < (s*s/8 - (s+1)*4) / 2; the window is nonempty for s >= 96.
        // s=128, zeta=300: COO 3600, CSR 3516, bitmap 4448, dense 131072.
        assert_eq!(choose_scheme(128, 300), Scheme::Csr);
        // For small blocks the bitmap's fixed cost is tiny and CSR never
        // wins under the default widths.
        assert_ne!(choose_scheme(8, 20), Scheme::Csr);
    }

    #[test]
    fn cost_formulas_exact() {
        let m = CostModel::default();
        assert_eq!(m.block_cost(Scheme::Coo, 8, 5), 5 * 12);
        assert_eq!(m.block_cost(Scheme::Csr, 8, 5), 5 * 10 + 9 * 4);
        assert_eq!(m.block_cost(Scheme::Bitmap, 8, 5), 8 + 5 * 8);
        assert_eq!(m.block_cost(Scheme::Dense, 8, 5), 64 * 8);
    }

    #[test]
    fn choice_is_argmin_for_all_fills() {
        let m = CostModel::default();
        for s in [4u64, 8, 16, 32] {
            for zeta in 1..=s * s {
                let chosen = m.choose(s, zeta);
                let cmin = Scheme::ALL
                    .iter()
                    .map(|&sch| m.block_cost(sch, s, zeta))
                    .min()
                    .unwrap();
                assert_eq!(
                    m.block_cost(chosen, s, zeta),
                    cmin,
                    "s={s} zeta={zeta}: {chosen:?} not argmin"
                );
            }
        }
    }

    #[test]
    fn selection_monotone_regions() {
        // As fill grows for fixed s the chosen scheme should move through
        // COO/CSR -> bitmap -> dense without returning.
        let s = 32u64;
        let mut stage = 0; // 0 = coo/csr, 1 = bitmap, 2 = dense
        for zeta in 1..=s * s {
            let next = match choose_scheme(s, zeta) {
                Scheme::Coo | Scheme::Csr => 0,
                Scheme::Bitmap => 1,
                Scheme::Dense => 2,
            };
            assert!(next >= stage, "regression at zeta={zeta}");
            stage = next;
        }
        assert_eq!(stage, 2);
    }
}
