//! In-memory image of one ABHSF file and the COO/CSR → ABHSF builders
//! (the storing-side conversions of refs [1, 3], needed so the loading
//! algorithms have files to load).

use crate::abhsf::cost::CostModel;
use crate::abhsf::{block, AbhsfError, Result, Scheme};
use crate::formats::{Coo, Csr, Element, LocalInfo};
use crate::util::bitset::BitSet;

/// All attributes and datasets of one `matrix-<k>.h5spm` file, mirroring
/// the paper's `abhsf` structure field for field.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AbhsfData {
    /// Shared matrix/submatrix metadata (m, n, z, locals, offsets).
    pub info: LocalInfo,
    /// Block size `s`.
    pub block_size: u64,
    /// Scheme tag per nonzero block.
    pub schemes: Vec<u8>,
    /// Nonzero count per block.
    pub zetas: Vec<u32>,
    /// Block row index per block.
    pub brows: Vec<u32>,
    /// Block column index per block.
    pub bcols: Vec<u32>,
    /// COO blocks: in-block row indexes.
    pub coo_lrows: Vec<u16>,
    /// COO blocks: in-block column indexes.
    pub coo_lcols: Vec<u16>,
    /// COO blocks: values.
    pub coo_vals: Vec<f64>,
    /// CSR blocks: in-block column indexes.
    pub csr_lcolinds: Vec<u16>,
    /// CSR blocks: row pointers, `s + 1` per block, block-relative.
    pub csr_rowptrs: Vec<u32>,
    /// CSR blocks: values.
    pub csr_vals: Vec<f64>,
    /// Bitmap blocks: packed occupancy, `ceil(s*s/8)` bytes per block,
    /// row-major, LSB-first (Algorithm 5 bit order).
    pub bitmap_bitmap: Vec<u8>,
    /// Bitmap blocks: values of set cells in row-major order.
    pub bitmap_vals: Vec<f64>,
    /// Dense blocks: all `s*s` values row-major, zeros included.
    pub dense_vals: Vec<f64>,
}

impl AbhsfData {
    /// Number of nonzero blocks `Z`.
    pub fn blocks(&self) -> u64 {
        self.schemes.len() as u64
    }

    /// Build from a local COO submatrix with block size `s`, choosing each
    /// block's scheme adaptively under `model`.
    pub fn from_coo(coo: &Coo, s: u64, model: &CostModel) -> Result<Self> {
        let mut canonical = coo.clone();
        canonical.sort_dedup();
        Self::from_elements(canonical.info, &canonical.to_elements(), s, model)
    }

    /// Build from a local CSR submatrix.
    pub fn from_csr(csr: &Csr, s: u64, model: &CostModel) -> Result<Self> {
        Self::from_elements(csr.info, &csr.to_elements(), s, model)
    }

    /// Build from canonical (sorted, duplicate-free) local elements.
    pub fn from_elements(
        info: LocalInfo,
        elements: &[Element],
        s: u64,
        model: &CostModel,
    ) -> Result<Self> {
        if s == 0 || s > u16::MAX as u64 + 1 {
            return Err(AbhsfError::Invalid(format!("block size {s} out of range")));
        }
        // Block coordinates must fit the u32 descriptor datasets.
        if info.m_local.div_ceil(s) > u32::MAX as u64 || info.n_local.div_ceil(s) > u32::MAX as u64 {
            return Err(AbhsfError::Invalid("submatrix too large for u32 block indexes".into()));
        }
        let mut data = AbhsfData {
            info,
            block_size: s,
            ..Default::default()
        };
        data.info.z_local = elements.len() as u64;
        let blocks = block::partition_into_blocks(elements, s);
        for b in &blocks {
            let zeta = b.zeta();
            if zeta > u32::MAX as u64 {
                return Err(AbhsfError::Invalid("block zeta exceeds u32".into()));
            }
            let scheme = model.choose(s, zeta);
            data.schemes.push(scheme as u8);
            data.zetas.push(zeta as u32);
            data.brows.push(b.brow as u32);
            data.bcols.push(b.bcol as u32);
            data.encode_block(scheme, b, s);
        }
        Ok(data)
    }

    /// Append one block's payload to the per-scheme streams.
    fn encode_block(&mut self, scheme: Scheme, b: &block::Block, s: u64) {
        match scheme {
            Scheme::Coo => {
                for &(lr, lc, v) in &b.elems {
                    self.coo_lrows.push(lr);
                    self.coo_lcols.push(lc);
                    self.coo_vals.push(v);
                }
            }
            Scheme::Csr => {
                // s+1 block-relative row pointers + column indexes + values.
                let mut ptr = 0u32;
                let mut iter = b.elems.iter().peekable();
                self.csr_rowptrs.push(0);
                for lrow in 0..s as u16 {
                    while let Some(&&(lr, lc, v)) = iter.peek() {
                        if lr != lrow {
                            break;
                        }
                        self.csr_lcolinds.push(lc);
                        self.csr_vals.push(v);
                        ptr += 1;
                        iter.next();
                    }
                    self.csr_rowptrs.push(ptr);
                }
            }
            Scheme::Bitmap => {
                let mut bits = BitSet::zeros((s * s) as usize);
                for &(lr, lc, v) in &b.elems {
                    bits.set(lr as usize * s as usize + lc as usize, true);
                    self.bitmap_vals.push(v);
                }
                self.bitmap_bitmap.extend_from_slice(bits.as_bytes());
            }
            Scheme::Dense => {
                let base = self.dense_vals.len();
                self.dense_vals.extend(std::iter::repeat(0.0).take((s * s) as usize));
                for &(lr, lc, v) in &b.elems {
                    self.dense_vals[base + lr as usize * s as usize + lc as usize] = v;
                }
            }
        }
    }

    /// Structural validation: dataset lengths consistent with descriptors.
    pub fn validate(&self) -> Result<()> {
        self.info.validate().map_err(AbhsfError::Invalid)?;
        let z = self.blocks() as usize;
        if self.zetas.len() != z || self.brows.len() != z || self.bcols.len() != z {
            return Err(AbhsfError::Invalid("descriptor dataset lengths differ".into()));
        }
        let s = self.block_size;
        let bitmap_block_bytes = ((s * s).div_ceil(8)) as usize;
        let mut want = [0usize; 8]; // coo_n, csr_n, csr_ptrs, bitmap_bytes, bitmap_n, dense_n
        let mut total_zeta = 0u64;
        for (i, &tag) in self.schemes.iter().enumerate() {
            let scheme = Scheme::from_tag(tag)
                .ok_or_else(|| AbhsfError::Invalid(format!("bad scheme tag {tag} at block {i}")))?;
            let zeta = self.zetas[i] as usize;
            if zeta == 0 || zeta as u64 > s * s {
                return Err(AbhsfError::Invalid(format!("block {i}: zeta {zeta} out of range")));
            }
            total_zeta += zeta as u64;
            match scheme {
                Scheme::Coo => want[0] += zeta,
                Scheme::Csr => {
                    want[1] += zeta;
                    want[2] += s as usize + 1;
                }
                Scheme::Bitmap => {
                    want[3] += bitmap_block_bytes;
                    want[4] += zeta;
                }
                Scheme::Dense => want[5] += (s * s) as usize,
            }
        }
        let checks = [
            (self.coo_lrows.len(), want[0], "coo_lrows"),
            (self.coo_lcols.len(), want[0], "coo_lcols"),
            (self.coo_vals.len(), want[0], "coo_vals"),
            (self.csr_lcolinds.len(), want[1], "csr_lcolinds"),
            (self.csr_vals.len(), want[1], "csr_vals"),
            (self.csr_rowptrs.len(), want[2], "csr_rowptrs"),
            (self.bitmap_bitmap.len(), want[3], "bitmap_bitmap"),
            (self.bitmap_vals.len(), want[4], "bitmap_vals"),
            (self.dense_vals.len(), want[5], "dense_vals"),
        ];
        for (got, expect, name) in checks {
            if got != expect {
                return Err(AbhsfError::Invalid(format!(
                    "{name} length {got}, descriptors imply {expect}"
                )));
            }
        }
        if total_zeta != self.info.z_local {
            return Err(AbhsfError::Invalid(format!(
                "sum of zetas {total_zeta} != z_local {}",
                self.info.z_local
            )));
        }
        Ok(())
    }

    /// Payload bytes this image occupies on disk (datasets only), i.e. the
    /// quantity the adaptive scheme choice minimizes.
    pub fn payload_bytes(&self) -> u64 {
        (self.schemes.len()
            + self.zetas.len() * 4
            + self.brows.len() * 4
            + self.bcols.len() * 4
            + self.coo_lrows.len() * 2
            + self.coo_lcols.len() * 2
            + self.coo_vals.len() * 8
            + self.csr_lcolinds.len() * 2
            + self.csr_rowptrs.len() * 4
            + self.csr_vals.len() * 8
            + self.bitmap_bitmap.len()
            + self.bitmap_vals.len() * 8
            + self.dense_vals.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mixed_coo(s: u64) -> Coo {
        // Construct a matrix with one very sparse block (COO), one
        // moderately filled (CSR for s >= 96), one half-full (bitmap) and
        // one full (dense).
        let info = LocalInfo::whole(2 * s, 2 * s, 0);
        let mut coo = Coo::with_info(info);
        // Block (0,0): 1 element -> COO.
        coo.push(0, 0, 1.0);
        // Block (0,1): ~2.5(s+1) elements, spread over rows.
        let target = (5 * (s + 1) / 2) as usize;
        let mut cnt = 0;
        'outer: for r in 0..s {
            for c in 0..s {
                if (r + 2 * c) % 3 == 0 {
                    coo.push(r, s + c, (r * s + c) as f64 + 0.5);
                    cnt += 1;
                    if cnt >= target {
                        break 'outer;
                    }
                }
            }
        }
        // Block (1,0): half full -> bitmap.
        for r in 0..s {
            for c in 0..s {
                if (r + c) % 2 == 0 {
                    coo.push(s + r, c, 1.0 + (r + c) as f64);
                }
            }
        }
        // Block (1,1): completely full -> dense.
        for r in 0..s {
            for c in 0..s {
                coo.push(s + r, s + c, -((r * s + c) as f64) - 1.0);
            }
        }
        coo.info.z = coo.nnz() as u64;
        coo
    }

    #[test]
    fn builder_selects_all_four_schemes() {
        // s = 128 gives CSR a nonempty optimality window (see cost tests).
        let s = 128;
        let data = AbhsfData::from_coo(&mixed_coo(s), s, &CostModel::default()).unwrap();
        data.validate().unwrap();
        assert_eq!(data.blocks(), 4);
        let schemes: Vec<Scheme> = data
            .schemes
            .iter()
            .map(|&t| Scheme::from_tag(t).unwrap())
            .collect();
        assert_eq!(
            schemes,
            vec![Scheme::Coo, Scheme::Csr, Scheme::Bitmap, Scheme::Dense]
        );
    }

    #[test]
    fn csr_block_rowptrs_structure() {
        let s = 16u64;
        let info = LocalInfo::whole(s, s, 0);
        let mut coo = Coo::with_info(info);
        // Rows 0 and 2 hold elements; zero-cost row pointers make CSR the
        // cheapest scheme (COO 36, CSR 30, bitmap 56 bytes).
        coo.push(0, 1, 1.0);
        coo.push(0, 3, 2.0);
        coo.push(2, 0, 3.0);
        let model = CostModel::analytic(2, 8, 0);
        let data = AbhsfData::from_coo(&coo, s, &model).unwrap();
        assert_eq!(data.schemes, vec![Scheme::Csr as u8]);
        let mut want_ptrs = vec![0u32, 2, 2];
        want_ptrs.extend(std::iter::repeat(3).take(s as usize - 2));
        assert_eq!(data.csr_rowptrs, want_ptrs);
        assert_eq!(data.csr_rowptrs.len() as u64, s + 1);
        assert_eq!(data.csr_lcolinds, vec![1, 3, 0]);
        assert_eq!(data.csr_vals, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn bitmap_block_bit_layout() {
        let s = 4u64;
        let info = LocalInfo::whole(s, s, 0);
        let mut coo = Coo::with_info(info);
        // Fill half the 4x4 block (diagonal-ish) and force bitmap.
        for i in 0..4 {
            coo.push(i, i, i as f64 + 1.0);
            coo.push(i, (i + 1) % 4, -(i as f64) - 1.0);
        }
        let model = CostModel::analytic(1000, 8, 1000);
        let data = AbhsfData::from_coo(&coo, s, &model).unwrap();
        assert_eq!(data.schemes, vec![Scheme::Bitmap as u8]);
        assert_eq!(data.bitmap_bitmap.len(), 2); // ceil(16/8)
        // Row 0 cells (0,0) and (0,1) set -> bits 0,1 of byte 0;
        // row 1 cells (1,1),(1,2) -> bits 5,6.
        assert_eq!(data.bitmap_bitmap[0], 0b0110_0011);
        // Values in row-major order of set cells.
        assert_eq!(data.bitmap_vals, vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0, -4.0, 4.0]);
    }

    #[test]
    fn dense_block_layout() {
        let s = 2u64;
        let info = LocalInfo::whole(s, s, 0);
        let mut coo = Coo::with_info(info);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 0, 3.0);
        coo.push(1, 1, 4.0);
        let data = AbhsfData::from_coo(&coo, s, &CostModel::default()).unwrap();
        assert_eq!(data.schemes, vec![Scheme::Dense as u8]);
        assert_eq!(data.dense_vals, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn validate_catches_corruption() {
        let s = 8;
        let mut data = AbhsfData::from_coo(&mixed_coo(s), s, &CostModel::default()).unwrap();
        data.coo_vals.pop();
        assert!(data.validate().is_err());
    }

    #[test]
    fn payload_smaller_than_coo_for_dense_blocks() {
        let s = 8;
        let coo = mixed_coo(s);
        let data = AbhsfData::from_coo(&coo, s, &CostModel::default()).unwrap();
        assert!(data.payload_bytes() < coo.payload_bytes_paper() + 200,
            "abhsf {} vs coo {}", data.payload_bytes(), coo.payload_bytes_paper());
    }

    #[test]
    fn empty_matrix_builds() {
        let info = LocalInfo::whole(16, 16, 0);
        let coo = Coo::with_info(info);
        let data = AbhsfData::from_coo(&coo, 4, &CostModel::default()).unwrap();
        data.validate().unwrap();
        assert_eq!(data.blocks(), 0);
    }

    #[test]
    fn rejects_bad_block_size() {
        let info = LocalInfo::whole(4, 4, 0);
        let coo = Coo::with_info(info);
        assert!(AbhsfData::from_coo(&coo, 0, &CostModel::default()).is_err());
    }
}
