//! Loading matrices from ABHSF files — the paper's Algorithms 1–6.
//!
//! [`load_csr`] is the faithful translation of Algorithm 1 with procedures
//! LoadBlock (Alg. 2), LoadBlockCOO (Alg. 3), LoadBlockCSR (Alg. 4),
//! LoadBlockBitmap (Alg. 5) and LoadBlockDense (Alg. 6): every dataset is
//! consumed strictly forward through a streaming cursor ("next value from
//! `abhsf.xxx[]`"), blocks of one block row are decoded into an `elements`
//! buffer, sorted lexicographically, and flushed into the output CSR.
//!
//! Two deviations from the printed pseudocode, both documented in
//! DESIGN.md §4 (the pseudocode as printed would not produce valid CSR):
//!
//! 1. Algorithm 1 line 24 guards the flush with
//!    `brow ≠ last_brow AND k = Z−1`; we flush when the block row
//!    *changes* or the *last* block was consumed (otherwise only the final
//!    block would ever flush).
//! 2. The flush appends `rowptrs` entries relative to the block-row-local
//!    `elements` buffer and skips block rows with no blocks; we add the
//!    running element base and emit row pointers for *all* local rows so
//!    `rowptrs` has the required `m_local + 1` monotone entries.
//!
//! [`visit_elements`] is the streaming decoder underlying
//! different-configuration loading (paper §3): it yields every stored
//! element in *global* coordinates without building a CSR, so the caller
//! can filter by an arbitrary new mapping `M(i, j)`.
//!
//! [`visit_elements_pruned`] is its block-pruned refinement: the per-file
//! block directory localizes nonzeros to `s × s` blocks, so a reader
//! whose mapping region cannot intersect a block's rectangle skips that
//! block's payload entirely — fewer bytes fetched and, asymptotically,
//! only `O(own share)` elements decoded instead of all of them.
//!
//! [`BlockDirectory`] + [`fetch_blocks`] expose the same machinery at
//! block granularity: the directory is parsed once (payload offsets
//! resolved, no payload bytes touched) and arbitrary subsets of blocks
//! can then be fetched and decoded in isolation — the primitive behind
//! the serving layer's decoded-block cache (`crate::serve`), where the
//! subset is exactly a query's cache misses.

use crate::abhsf::{names, AbhsfError, Result, Scheme};
use crate::formats::element::sort_lex;
use crate::formats::{Coo, Csr, Element, LocalInfo};
use crate::h5::dtype::{decode_slice, encode_slice};
use crate::h5::reader::BatchRequest;
use crate::h5::{Cursor, H5Reader};
use crate::obs::trace::{self, Tag};

/// Open cursors over all per-scheme payload datasets.
struct PayloadCursors<'r> {
    coo_lrows: Cursor<'r, u16>,
    coo_lcols: Cursor<'r, u16>,
    coo_vals: Cursor<'r, f64>,
    csr_lcolinds: Cursor<'r, u16>,
    csr_rowptrs: Cursor<'r, u32>,
    csr_vals: Cursor<'r, f64>,
    bitmap_bitmap: Cursor<'r, u8>,
    bitmap_vals: Cursor<'r, f64>,
    dense_vals: Cursor<'r, f64>,
}

impl<'r> PayloadCursors<'r> {
    fn open(r: &'r H5Reader) -> Result<Self> {
        Ok(Self {
            coo_lrows: Cursor::new(r, names::COO_LROWS)?,
            coo_lcols: Cursor::new(r, names::COO_LCOLS)?,
            coo_vals: Cursor::new(r, names::COO_VALS)?,
            csr_lcolinds: Cursor::new(r, names::CSR_LCOLINDS)?,
            csr_rowptrs: Cursor::new(r, names::CSR_ROWPTRS)?,
            csr_vals: Cursor::new(r, names::CSR_VALS)?,
            bitmap_bitmap: Cursor::new(r, names::BITMAP_BITMAP)?,
            bitmap_vals: Cursor::new(r, names::BITMAP_VALS)?,
            dense_vals: Cursor::new(r, names::DENSE_VALS)?,
        })
    }
}

/// File-level header read from attributes.
#[derive(Debug, Clone, Copy)]
pub struct Header {
    /// Shared matrix/submatrix metadata.
    pub info: LocalInfo,
    /// Block size `s`.
    pub block_size: u64,
    /// Number of nonzero blocks `Z`.
    pub blocks: u64,
}

/// Read the attribute header of an ABHSF file.
pub fn read_header(r: &H5Reader) -> Result<Header> {
    Ok(Header {
        info: LocalInfo {
            m: r.attr(names::M)?,
            n: r.attr(names::N)?,
            z: r.attr(names::Z)?,
            m_local: r.attr(names::M_LOCAL)?,
            n_local: r.attr(names::N_LOCAL)?,
            z_local: r.attr(names::Z_LOCAL)?,
            m_offset: r.attr(names::M_OFFSET)?,
            n_offset: r.attr(names::N_OFFSET)?,
        },
        block_size: r.attr(names::BLOCK_SIZE)?,
        blocks: r.attr(names::BLOCKS)?,
    })
}

/// Reusable bulk-decode buffers (perf: the loader is decode-CPU-bound;
/// bulk chunk copies beat per-element cursor calls by ~2x, see
/// EXPERIMENTS.md §Perf).
#[derive(Default)]
struct Scratch {
    idx_a: Vec<u16>,
    idx_b: Vec<u16>,
    vals: Vec<f64>,
    ptrs: Vec<u32>,
    bytes: Vec<u8>,
}

/// Procedure LoadBlockCOO (Algorithm 3): decode `zeta` triplets into
/// block-local elements offset to local submatrix coordinates.
fn load_block_coo(
    c: &mut PayloadCursors,
    sc: &mut Scratch,
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    sc.idx_a.clear();
    sc.idx_b.clear();
    sc.vals.clear();
    c.coo_lrows.take_exact_into(&mut sc.idx_a, zeta as usize)?;
    c.coo_lcols.take_exact_into(&mut sc.idx_b, zeta as usize)?;
    c.coo_vals.take_exact_into(&mut sc.vals, zeta as usize)?;
    Ok(decode_coo_block(&sc.idx_a, &sc.idx_b, &sc.vals, brow, bcol, s, elements))
}

/// Slice half of Algorithm 3, shared by the streaming and the pruned
/// (range-read) decoders; returns whether the triplets were
/// (lrow, lcol)-sorted.
fn decode_coo_block(
    lrows: &[u16],
    lcols: &[u16],
    vals: &[f64],
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> bool {
    let (ro, co) = (brow * s, bcol * s);
    // Track whether the stored triplets are (lrow, lcol)-sorted — the
    // builder always writes them sorted, but a foreign writer might not,
    // which disqualifies the counting-scatter fast path in load_csr.
    let mut ordered = true;
    let mut prev = (0u16, 0u16);
    elements.reserve(vals.len());
    for (i, ((&lr, &lc), &v)) in lrows.iter().zip(lcols).zip(vals).enumerate() {
        if i > 0 && (lr, lc) <= prev {
            ordered = false;
        }
        prev = (lr, lc);
        elements.push(Element::new(lr as u64 + ro, lc as u64 + co, v));
    }
    ordered
}

/// Procedure LoadBlockCSR (Algorithm 4): consume `s + 1` block-relative
/// row pointers and the referenced column indexes / values.
fn load_block_csr(
    c: &mut PayloadCursors,
    sc: &mut Scratch,
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    sc.ptrs.clear();
    c.csr_rowptrs.take_exact_into(&mut sc.ptrs, s as usize + 1)?;
    let total = *sc.ptrs.last().unwrap() as u64;
    if total != zeta {
        return Err(AbhsfError::Invalid(format!(
            "CSR block ({brow},{bcol}): row pointers imply {total} elements, zeta {zeta}"
        )));
    }
    sc.idx_b.clear();
    sc.vals.clear();
    c.csr_lcolinds.take_exact_into(&mut sc.idx_b, zeta as usize)?;
    c.csr_vals.take_exact_into(&mut sc.vals, zeta as usize)?;
    decode_csr_block(&sc.ptrs, &sc.idx_b, &sc.vals, zeta, brow, bcol, s, elements)
}

/// Slice half of Algorithm 4, shared by the streaming and the pruned
/// decoders. `ptrs` holds the `s + 1` block-relative row pointers.
#[allow(clippy::too_many_arguments)]
fn decode_csr_block(
    ptrs: &[u32],
    lcolinds: &[u16],
    vals: &[f64],
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    let total = ptrs.last().copied().unwrap_or(0) as u64;
    if ptrs.len() != s as usize + 1 || total != zeta {
        return Err(AbhsfError::Invalid(format!(
            "CSR block ({brow},{bcol}): row pointers imply {total} elements, zeta {zeta}"
        )));
    }
    let (ro, co) = (brow * s, bcol * s);
    for lrow in 0..s as usize {
        let (lo, hi) = (ptrs[lrow] as usize, ptrs[lrow + 1] as usize);
        if hi < lo || hi > zeta as usize {
            return Err(AbhsfError::Invalid(format!(
                "CSR block ({brow},{bcol}): non-monotone row pointers"
            )));
        }
        for e in lo..hi {
            elements.push(Element::new(
                lrow as u64 + ro,
                lcolinds[e] as u64 + co,
                vals[e],
            ));
        }
    }
    Ok(true)
}

/// Procedure LoadBlockBitmap (Algorithm 5): scan `s*s` bits LSB-first and
/// pull one value per set bit.
fn load_block_bitmap(
    c: &mut PayloadCursors,
    sc: &mut Scratch,
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    let nbytes = ((s * s).div_ceil(8)) as usize;
    sc.bytes.clear();
    sc.vals.clear();
    c.bitmap_bitmap.take_exact_into(&mut sc.bytes, nbytes)?;
    c.bitmap_vals.take_exact_into(&mut sc.vals, zeta as usize)?;
    decode_bitmap_block(&sc.bytes, &sc.vals, zeta, brow, bcol, s, elements)
}

/// Slice half of Algorithm 5, shared by the streaming and the pruned
/// decoders.
fn decode_bitmap_block(
    bytes: &[u8],
    vals: &[f64],
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    let (ro, co) = (brow * s, bcol * s);
    let mut decoded = 0usize;
    // Scan bytes LSB-first (Algorithm 5's bit order), skipping zero bytes
    // — the common case for sparse-ish bitmap blocks.
    let cells = (s * s) as usize;
    for (bi, &byte) in bytes.iter().enumerate() {
        if byte == 0 {
            continue;
        }
        let mut rest = byte;
        while rest != 0 {
            let bit = rest.trailing_zeros() as usize;
            let cell = bi * 8 + bit;
            if cell >= cells {
                return Err(AbhsfError::Invalid(format!(
                    "bitmap block ({brow},{bcol}): bit set beyond s*s"
                )));
            }
            if decoded >= zeta as usize {
                return Err(AbhsfError::Invalid(format!(
                    "bitmap block ({brow},{bcol}): more set bits than zeta {zeta}"
                )));
            }
            elements.push(Element::new(
                cell as u64 / s + ro,
                cell as u64 % s + co,
                vals[decoded],
            ));
            decoded += 1;
            rest &= rest - 1;
        }
    }
    if decoded != zeta as usize {
        return Err(AbhsfError::Invalid(format!(
            "bitmap block ({brow},{bcol}): decoded {decoded} elements, zeta {zeta}"
        )));
    }
    Ok(true)
}

/// Procedure LoadBlockDense (Algorithm 6): read `s*s` values, keep the
/// nonzeros.
fn load_block_dense(
    c: &mut PayloadCursors,
    sc: &mut Scratch,
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    sc.vals.clear();
    c.dense_vals.take_exact_into(&mut sc.vals, (s * s) as usize)?;
    decode_dense_block(&sc.vals, zeta, brow, bcol, s, elements)
}

/// Slice half of Algorithm 6, shared by the streaming and the pruned
/// decoders.
fn decode_dense_block(
    vals: &[f64],
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    let (ro, co) = (brow * s, bcol * s);
    let mut decoded = 0u64;
    for (cell, &val) in vals.iter().enumerate() {
        if val != 0.0 {
            elements.push(Element::new(
                cell as u64 / s + ro,
                cell as u64 % s + co,
                val,
            ));
            decoded += 1;
        }
    }
    if decoded != zeta {
        return Err(AbhsfError::Invalid(format!(
            "dense block ({brow},{bcol}): decoded {decoded} nonzeros, zeta {zeta}"
        )));
    }
    Ok(true)
}

/// Procedure LoadBlock (Algorithm 2): dispatch on the scheme tag.
#[allow(clippy::too_many_arguments)]
fn load_block(
    c: &mut PayloadCursors,
    sc: &mut Scratch,
    scheme_tag: u8,
    zeta: u64,
    brow: u64,
    bcol: u64,
    s: u64,
    elements: &mut Vec<Element>,
) -> Result<bool> {
    match Scheme::from_tag(scheme_tag) {
        Some(Scheme::Coo) => load_block_coo(c, sc, zeta, brow, bcol, s, elements),
        Some(Scheme::Csr) => load_block_csr(c, sc, zeta, brow, bcol, s, elements),
        Some(Scheme::Bitmap) => load_block_bitmap(c, sc, zeta, brow, bcol, s, elements),
        Some(Scheme::Dense) => load_block_dense(c, sc, zeta, brow, bcol, s, elements),
        None => Err(AbhsfError::Invalid(format!("wrong scheme tag {scheme_tag}"))),
    }
}

/// Algorithm 1: load one ABHSF file into an in-memory CSR structure.
pub fn load_csr(r: &H5Reader) -> Result<Csr> {
    let header = read_header(r)?;
    let s = header.block_size;
    let z_blocks = header.blocks;
    let mut csr = Csr::with_info(header.info);
    csr.vals.reserve(header.info.z_local as usize);
    csr.colinds.reserve(header.info.z_local as usize);
    csr.rowptrs.reserve(header.info.m_local as usize + 1);

    let mut schemes = Cursor::<u8>::new(r, names::SCHEMES)?;
    let mut zetas = Cursor::<u32>::new(r, names::ZETAS)?;
    let mut brows = Cursor::<u32>::new(r, names::BROWS)?;
    let mut bcols = Cursor::<u32>::new(r, names::BCOLS)?;
    let mut payload = PayloadCursors::open(r)?;
    let mut scratch = Scratch::default();

    // `elements` buffers the decoded blocks of the current block row.
    let mut elements: Vec<Element> = Vec::new();
    // First local row not yet covered by `rowptrs`.
    let mut next_row = 0u64;
    // Block row currently being accumulated.
    let mut cur_brow: Option<u64> = None;
    // Fast-path eligibility: within a block row, blocks arriving in
    // ascending bcol order with row-major in-block elements mean each
    // row's elements are already column-sorted in arrival order, so a
    // *stable counting scatter by row* replaces the comparison sort
    // (§Perf: ~2.5x on the assembly phase). The decoders emit row-major
    // by construction; only foreign files with unsorted bcols fall back.
    let mut bcol_ordered = true;
    let mut last_bcol: Option<u64> = None;
    // Scratch for the counting scatter: element count per row of the
    // current block row, then running write offsets.
    let mut row_counts: Vec<u64> = Vec::new();

    // Flush the accumulated block row: emit values/colinds and row
    // pointers for every local row up to the end of that block row.
    let flush = |csr: &mut Csr,
                 elements: &mut Vec<Element>,
                 next_row: &mut u64,
                 brow: u64,
                 ordered: bool,
                 row_counts: &mut Vec<u64>| {
        let base = csr.vals.len() as u64;
        // Rows before this block row (and any gap rows) have no elements.
        while *next_row < brow * s {
            csr.rowptrs.push(base);
            *next_row += 1;
        }
        let row_end = ((brow + 1) * s).min(csr.info.m_local);
        let row0 = brow * s;
        let rows = (row_end - row0) as usize;
        if ordered {
            // Counting scatter (stable => columns stay sorted per row).
            row_counts.clear();
            row_counts.resize(rows, 0);
            for e in elements.iter() {
                row_counts[(e.row - row0) as usize] += 1;
            }
            // Row pointers + per-row write offsets via prefix sums.
            let mut acc = base;
            for c in row_counts.iter_mut() {
                csr.rowptrs.push(acc);
                let n = *c;
                *c = acc; // becomes the running write offset
                acc += n;
            }
            let n0 = csr.vals.len();
            csr.vals.resize(n0 + elements.len(), 0.0);
            csr.colinds.resize(n0 + elements.len(), 0);
            for e in elements.iter() {
                let slot = &mut row_counts[(e.row - row0) as usize];
                csr.vals[*slot as usize] = e.val;
                csr.colinds[*slot as usize] = e.col;
                *slot += 1;
            }
        } else {
            // General path: the pseudocode's lexicographic sort.
            sort_lex(elements);
            let mut row = row0;
            for (l, e) in elements.iter().enumerate() {
                while row <= e.row {
                    csr.rowptrs.push(base + l as u64);
                    row += 1;
                }
                csr.colinds.push(e.col);
                csr.vals.push(e.val);
            }
            while row < row_end {
                csr.rowptrs.push(base + elements.len() as u64);
                row += 1;
            }
        }
        *next_row = row_end;
        elements.clear();
    };

    for k in 0..z_blocks {
        let scheme = schemes.next_required()?;
        let zeta = zetas.next_required()? as u64;
        let brow = brows.next_required()? as u64;
        let bcol = bcols.next_required()? as u64;
        if let Some(prev) = cur_brow {
            if brow != prev {
                if brow < prev {
                    return Err(AbhsfError::Invalid(format!(
                        "blocks not ordered by block row: {brow} after {prev}"
                    )));
                }
                flush(
                    &mut csr,
                    &mut elements,
                    &mut next_row,
                    prev,
                    bcol_ordered,
                    &mut row_counts,
                );
                bcol_ordered = true;
                last_bcol = None;
            }
        }
        if let Some(lb) = last_bcol {
            if bcol <= lb {
                bcol_ordered = false;
            }
        }
        last_bcol = Some(bcol);
        cur_brow = Some(brow);
        let block_ordered =
            load_block(&mut payload, &mut scratch, scheme, zeta, brow, bcol, s, &mut elements)?;
        bcol_ordered &= block_ordered;
        let _ = k;
    }
    if let Some(prev) = cur_brow {
        flush(
            &mut csr,
            &mut elements,
            &mut next_row,
            prev,
            bcol_ordered,
            &mut row_counts,
        );
    }
    // Tail rows after the last nonzero block row.
    let base = csr.vals.len() as u64;
    while next_row <= header.info.m_local {
        csr.rowptrs.push(base);
        next_row += 1;
    }
    // `flush` pushes pointers for rows [0, row_end); the loop above adds
    // the remaining pointers including the final sentinel, giving
    // m_local + 1 in total.

    csr.info.z_local = csr.vals.len() as u64;
    if csr.info.z_local != header.info.z_local {
        return Err(AbhsfError::Invalid(format!(
            "loaded {} elements, header says {}",
            csr.info.z_local, header.info.z_local
        )));
    }
    csr.validate().map_err(AbhsfError::Invalid)?;
    Ok(csr)
}

/// COO variant of Algorithm 1 (paper §3: "can be easily adapted"):
/// the decoded elements are returned directly in a COO structure, sorted
/// lexicographically.
pub fn load_coo(r: &H5Reader) -> Result<Coo> {
    let header = read_header(r)?;
    let mut elements = Vec::with_capacity(header.info.z_local as usize);
    visit_elements_local(r, |e| elements.push(e))?;
    sort_lex(&mut elements);
    let mut info = header.info;
    info.z_local = 0;
    Ok(Coo::from_elements(info, &elements))
}

/// Stream every stored element in *local* coordinates to `sink`, in block
/// order (not globally sorted).
pub fn visit_elements_local<F: FnMut(Element)>(r: &H5Reader, mut sink: F) -> Result<u64> {
    let header = read_header(r)?;
    let s = header.block_size;
    let mut schemes = Cursor::<u8>::new(r, names::SCHEMES)?;
    let mut zetas = Cursor::<u32>::new(r, names::ZETAS)?;
    let mut brows = Cursor::<u32>::new(r, names::BROWS)?;
    let mut bcols = Cursor::<u32>::new(r, names::BCOLS)?;
    let mut payload = PayloadCursors::open(r)?;
    let mut scratch = Scratch::default();
    let mut buf: Vec<Element> = Vec::new();
    let mut total = 0u64;
    for _ in 0..header.blocks {
        let scheme = schemes.next_required()?;
        let zeta = zetas.next_required()? as u64;
        let brow = brows.next_required()? as u64;
        let bcol = bcols.next_required()? as u64;
        buf.clear();
        let _ordered =
            load_block(&mut payload, &mut scratch, scheme, zeta, brow, bcol, s, &mut buf)?;
        total += buf.len() as u64;
        for &e in &buf {
            sink(e);
        }
    }
    if total != header.info.z_local {
        return Err(AbhsfError::Invalid(format!(
            "streamed {total} elements, header says {}",
            header.info.z_local
        )));
    }
    Ok(total)
}

/// Stream every stored element in *global* coordinates — the primitive for
/// different-configuration loading, where each reader keeps only elements
/// with `M(i, j) = own rank`.
pub fn visit_elements<F: FnMut(u64, u64, f64)>(r: &H5Reader, mut sink: F) -> Result<u64> {
    let header = read_header(r)?;
    let (ro, co) = (header.info.m_offset, header.info.n_offset);
    visit_elements_local(r, |e| sink(e.row + ro, e.col + co, e.val))
}

/// Outcome counters of one [`visit_elements_pruned`] pass over one file.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Blocks listed in the file's block directory.
    pub blocks_total: u64,
    /// Blocks whose payload was neither fetched nor decoded.
    pub blocks_skipped: u64,
    /// Payload bytes of the skipped blocks (element-level accounting,
    /// independent of container chunk granularity).
    pub bytes_skipped: u64,
    /// Elements actually decoded (from the surviving blocks).
    pub elements_decoded: u64,
}

impl PruneStats {
    /// Accumulate another file's counters.
    pub fn add(&mut self, other: PruneStats) {
        self.blocks_total += other.blocks_total;
        self.blocks_skipped += other.blocks_skipped;
        self.bytes_skipped += other.bytes_skipped;
        self.elements_decoded += other.elements_decoded;
    }
}

/// Minimum payload bytes per read-ahead batch of the pruned decoder —
/// small enough that multi-batch pipelining kicks in for any file worth
/// overlapping. [`visit_elements_pruned`] raises it to dominate the
/// file's largest container chunk (see the seam-cost bound there).
const READAHEAD_BATCH_BYTES: u64 = 128 * 1024;

/// The nine per-scheme payload datasets, in the fixed slot order the
/// read-ahead batches use, with their required dtypes (validated before
/// fetching: the prefetch path hands back raw bytes, so a wrong stored
/// dtype must surface as a typed error, not a decode panic).
const PAYLOAD_DATASETS: [&str; 9] = [
    names::COO_LROWS,
    names::COO_LCOLS,
    names::COO_VALS,
    names::CSR_ROWPTRS,
    names::CSR_LCOLINDS,
    names::CSR_VALS,
    names::BITMAP_BITMAP,
    names::BITMAP_VALS,
    names::DENSE_VALS,
];

/// Required dtype of each [`PAYLOAD_DATASETS`] slot.
const PAYLOAD_DTYPES: [crate::h5::Dtype; 9] = [
    crate::h5::Dtype::U16,
    crate::h5::Dtype::U16,
    crate::h5::Dtype::F64,
    crate::h5::Dtype::U32,
    crate::h5::Dtype::U16,
    crate::h5::Dtype::F64,
    crate::h5::Dtype::U8,
    crate::h5::Dtype::F64,
    crate::h5::Dtype::F64,
];

/// One block-directory entry with its resolved payload offsets: the
/// metadata needed to fetch and decode this block in isolation. Offsets
/// are in element units into the per-scheme payload datasets; which
/// datasets they index is scheme-dependent (see [`BlockDirectory`]).
#[derive(Debug, Clone, Copy)]
pub struct BlockEntry {
    /// Storage scheme of the block.
    pub scheme: Scheme,
    /// Nonzeros in the block.
    pub zeta: u64,
    /// Block row index (file-local grid).
    pub brow: u64,
    /// Block column index (file-local grid).
    pub bcol: u64,
    /// First payload offset: COO triplets / CSR row pointers / bitmap
    /// occupancy bytes / dense values, by scheme.
    off_a: u64,
    /// Second payload offset: CSR colinds+vals / bitmap values; unused
    /// for COO and dense.
    off_b: u64,
}

/// Parsed block directory of one ABHSF file: the header plus one
/// [`BlockEntry`] per stored block, in stored (block-row-major) order.
///
/// Reading the directory touches only the four directory datasets
/// (`schemes`/`zetas`/`brows`/`bcols`) — never any payload bytes — and
/// walks the per-scheme payload offsets once, so arbitrary subsets of
/// blocks can later be fetched in isolation with [`fetch_blocks`]. The
/// payload dtypes are validated here, up front: the raw-byte prefetch
/// path cannot type-check per read the way the cursor decoders do, so a
/// foreign writer's wrong dtype must surface as a typed error before any
/// fetch, never as a decode panic.
#[derive(Debug, Clone)]
pub struct BlockDirectory {
    /// File-level attribute header.
    pub header: Header,
    /// Directory entries in stored order.
    pub entries: Vec<BlockEntry>,
}

impl BlockDirectory {
    /// Read and resolve the block directory of `r`.
    pub fn read(r: &H5Reader) -> Result<Self> {
        let header = read_header(r)?;
        let _span = trace::span("dir_walk", &[("blocks", Tag::U(header.blocks))]);
        let s = header.block_size;
        let schemes: Vec<u8> = r.read_all(names::SCHEMES)?;
        let zetas: Vec<u32> = r.read_all(names::ZETAS)?;
        let brows: Vec<u32> = r.read_all(names::BROWS)?;
        let bcols: Vec<u32> = r.read_all(names::BCOLS)?;
        if schemes.len() as u64 != header.blocks
            || zetas.len() != schemes.len()
            || brows.len() != schemes.len()
            || bcols.len() != schemes.len()
        {
            return Err(AbhsfError::Invalid(format!(
                "block directory length mismatch: header says {} blocks",
                header.blocks
            )));
        }
        for (name, want) in PAYLOAD_DATASETS.iter().zip(PAYLOAD_DTYPES) {
            let stored = r.dataset_dtype(name)?;
            if stored != want {
                return Err(crate::h5::H5Error::DtypeMismatch {
                    name: (*name).to_string(),
                    stored,
                    requested: want,
                }
                .into());
            }
        }
        let mut entries = Vec::with_capacity(schemes.len());
        let (mut coo_off, mut csr_ptr_off, mut csr_off) = (0u64, 0u64, 0u64);
        let (mut bm_off, mut bmv_off, mut dn_off) = (0u64, 0u64, 0u64);
        let bm_bytes = (s * s).div_ceil(8);
        for k in 0..schemes.len() {
            let scheme = Scheme::from_tag(schemes[k]).ok_or_else(|| {
                AbhsfError::Invalid(format!("wrong scheme tag {}", schemes[k]))
            })?;
            let zeta = zetas[k] as u64;
            let (brow, bcol) = (brows[k] as u64, bcols[k] as u64);
            let (off_a, off_b) = match scheme {
                Scheme::Coo => (coo_off, 0),
                Scheme::Csr => (csr_ptr_off, csr_off),
                Scheme::Bitmap => (bm_off, bmv_off),
                Scheme::Dense => (dn_off, 0),
            };
            entries.push(BlockEntry {
                scheme,
                zeta,
                brow,
                bcol,
                off_a,
                off_b,
            });
            match scheme {
                Scheme::Coo => coo_off += zeta,
                Scheme::Csr => {
                    csr_ptr_off += s + 1;
                    csr_off += zeta;
                }
                Scheme::Bitmap => {
                    bm_off += bm_bytes;
                    bmv_off += zeta;
                }
                Scheme::Dense => dn_off += s * s,
            }
        }
        Ok(Self { header, entries })
    }

    /// Global rectangle `(r0, c0, rows, cols)` of entry `k`, clipped to
    /// the file's submatrix window (edge blocks are partial).
    pub fn global_rect(&self, k: usize) -> (u64, u64, u64, u64) {
        let s = self.header.block_size;
        let info = &self.header.info;
        let e = &self.entries[k];
        (
            info.m_offset + e.brow * s,
            info.n_offset + e.bcol * s,
            s.min(info.m_local.saturating_sub(e.brow * s)),
            s.min(info.n_local.saturating_sub(e.bcol * s)),
        )
    }

    /// On-disk payload bytes of entry `k` (the store-side cost model
    /// mirrors the exact on-disk layout).
    pub fn payload_bytes(&self, k: usize) -> u64 {
        let e = &self.entries[k];
        crate::abhsf::cost::scheme_cost(e.scheme, self.header.block_size, e.zeta)
    }
}

/// The read-ahead batch size [`visit_elements_pruned`] and
/// [`fetch_blocks`] use for `r`: [`READAHEAD_BATCH_BYTES`] raised to
/// dominate the file's largest container chunk.
///
/// Seam-cost bound: a container chunk straddling a batch boundary is
/// fetched once per side, so the batch must *dominate* the file's
/// largest payload chunk — 4x caps the worst-case read amplification at
/// ~25% (one chunk re-read per dataset per seam, one seam per batch)
/// while still engaging the pipeline on any multi-megabyte file. Default
/// chunking (64 Ki elements = 512 KiB for f64 values) thus yields 2 MiB
/// batches.
pub(crate) fn default_batch_bytes(r: &H5Reader) -> u64 {
    let mut batch_bytes = READAHEAD_BATCH_BYTES;
    for name in PAYLOAD_DATASETS {
        if let Ok(entry) = r.entry(name) {
            let width = entry.dtype.size() as u64;
            for c in &entry.chunks {
                batch_bytes = batch_bytes.max(4 * c.elems * width);
            }
        }
    }
    batch_bytes
}

/// Placement and size of one decoded block, shared by every
/// [`DecodedBlock`] variant. Coordinates are **global**: `row0`/`col0`
/// already include the owning file's submatrix offset, so a block can be
/// executed (or expanded) without any reference back to its file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGeom {
    /// Global row of the block's first cell.
    pub row0: u64,
    /// Global column of the block's first cell.
    pub col0: u64,
    /// Block size `s` (edge blocks keep the nominal `s`; their unused
    /// cells are simply never populated).
    pub s: u64,
    /// Nonzeros in the block.
    pub zeta: u64,
}

/// One ABHSF block decoded into its **scheme-native payload** — the
/// kernel-ready shape the decoded-block cache stores and the per-scheme
/// SpMV kernels (`crate::spmv::kernels`) consume directly, with no
/// expansion to `(row, col, val)` triplets.
///
/// The payload layouts mirror the on-disk datasets exactly
/// (`AbhsfData::encode_block`): validated constructors reject the same
/// corruptions the streaming decoders do, so a `DecodedBlock` is always
/// internally consistent (`geom.zeta` matches the payload).
#[derive(Debug, Clone, PartialEq)]
pub enum DecodedBlock {
    /// COO payload: `zeta` parallel block-local triplets, in stored
    /// order (the builder writes them row-major).
    Coo {
        /// Placement and size.
        geom: BlockGeom,
        /// Block-local row per nonzero.
        lrows: Vec<u16>,
        /// Block-local column per nonzero.
        lcols: Vec<u16>,
        /// Values, parallel to `lrows`/`lcols`.
        vals: Vec<f64>,
    },
    /// CSR-in-block payload: `s + 1` block-relative row pointers
    /// (starting at 0) over column indexes and values.
    CsrInBlock {
        /// Placement and size.
        geom: BlockGeom,
        /// Block-relative row pointers, `s + 1` entries.
        rowptrs: Vec<u32>,
        /// Block-local column per nonzero, row-major.
        lcolinds: Vec<u16>,
        /// Values, parallel to `lcolinds`.
        vals: Vec<f64>,
    },
    /// Bitmap payload: `⌈s²/8⌉` LSB-first occupancy bytes plus one value
    /// per set bit, in row-major cell order.
    Bitmap {
        /// Placement and size.
        geom: BlockGeom,
        /// Packed occupancy bitmap, bit `lr·s + lc` LSB-first.
        bits: Vec<u8>,
        /// Values of the set cells, row-major.
        vals: Vec<f64>,
    },
    /// Dense payload: all `s²` values row-major, zeros included.
    Dense {
        /// Placement and size.
        geom: BlockGeom,
        /// Row-major cell values, `s²` entries.
        vals: Vec<f64>,
    },
}

impl DecodedBlock {
    /// Validated COO block; `zeta` is the triplet count.
    pub fn coo(
        row0: u64,
        col0: u64,
        s: u64,
        lrows: Vec<u16>,
        lcols: Vec<u16>,
        vals: Vec<f64>,
    ) -> Result<Self> {
        if lrows.len() != vals.len() || lcols.len() != vals.len() {
            return Err(AbhsfError::Invalid(format!(
                "COO block: triplet arrays disagree ({}/{}/{})",
                lrows.len(),
                lcols.len(),
                vals.len()
            )));
        }
        if let Some(&bad) = lrows.iter().chain(&lcols).find(|&&i| i as u64 >= s) {
            return Err(AbhsfError::Invalid(format!(
                "COO block: in-block index {bad} beyond block size {s}"
            )));
        }
        let geom = BlockGeom {
            row0,
            col0,
            s,
            zeta: vals.len() as u64,
        };
        Ok(DecodedBlock::Coo {
            geom,
            lrows,
            lcols,
            vals,
        })
    }

    /// Validated CSR-in-block; `rowptrs` must hold `s + 1` monotone
    /// block-relative pointers covering `lcolinds`/`vals` exactly.
    pub fn csr(
        row0: u64,
        col0: u64,
        s: u64,
        rowptrs: Vec<u32>,
        lcolinds: Vec<u16>,
        vals: Vec<f64>,
    ) -> Result<Self> {
        let total = rowptrs.last().copied().unwrap_or(0) as u64;
        if rowptrs.len() as u64 != s + 1 || rowptrs[0] != 0 {
            return Err(AbhsfError::Invalid(format!(
                "CSR block: {} row pointers for block size {s}",
                rowptrs.len()
            )));
        }
        if rowptrs.windows(2).any(|w| w[1] < w[0]) {
            return Err(AbhsfError::Invalid(
                "CSR block: non-monotone row pointers".into(),
            ));
        }
        if total != lcolinds.len() as u64 || total != vals.len() as u64 {
            return Err(AbhsfError::Invalid(format!(
                "CSR block: row pointers imply {total} elements, payload has {}",
                vals.len()
            )));
        }
        if let Some(&bad) = lcolinds.iter().find(|&&c| c as u64 >= s) {
            return Err(AbhsfError::Invalid(format!(
                "CSR block: in-block column {bad} beyond block size {s}"
            )));
        }
        let geom = BlockGeom {
            row0,
            col0,
            s,
            zeta: total,
        };
        Ok(DecodedBlock::CsrInBlock {
            geom,
            rowptrs,
            lcolinds,
            vals,
        })
    }

    /// Validated bitmap block; the popcount of `bits` must equal
    /// `vals.len()` and no bit may be set at or beyond `s²`.
    pub fn bitmap(row0: u64, col0: u64, s: u64, bits: Vec<u8>, vals: Vec<f64>) -> Result<Self> {
        let cells = s * s;
        if bits.len() as u64 != cells.div_ceil(8) {
            return Err(AbhsfError::Invalid(format!(
                "bitmap block: {} occupancy bytes for block size {s}",
                bits.len()
            )));
        }
        let pop: u64 = bits.iter().map(|b| b.count_ones() as u64).sum();
        if pop != vals.len() as u64 {
            return Err(AbhsfError::Invalid(format!(
                "bitmap block: {pop} set bits, {} values",
                vals.len()
            )));
        }
        for (bi, &byte) in bits.iter().enumerate() {
            let mut rest = byte;
            while rest != 0 {
                let cell = (bi * 8) as u64 + rest.trailing_zeros() as u64;
                if cell >= cells {
                    return Err(AbhsfError::Invalid(
                        "bitmap block: bit set beyond s*s".into(),
                    ));
                }
                rest &= rest - 1;
            }
        }
        let geom = BlockGeom {
            row0,
            col0,
            s,
            zeta: pop,
        };
        Ok(DecodedBlock::Bitmap { geom, bits, vals })
    }

    /// Validated dense block; `zeta` is the count of nonzero cells.
    pub fn dense(row0: u64, col0: u64, s: u64, vals: Vec<f64>) -> Result<Self> {
        if vals.len() as u64 != s * s {
            return Err(AbhsfError::Invalid(format!(
                "dense block: {} values for block size {s}",
                vals.len()
            )));
        }
        let zeta = vals.iter().filter(|&&v| v != 0.0).count() as u64;
        let geom = BlockGeom {
            row0,
            col0,
            s,
            zeta,
        };
        Ok(DecodedBlock::Dense { geom, vals })
    }

    /// Build a block under `scheme` from block-local `(lr, lc, val)`
    /// elements (row-major sorted, no duplicates) — the encode side of
    /// the payload layouts, for tests and the calibration bench.
    pub fn build(
        scheme: Scheme,
        row0: u64,
        col0: u64,
        s: u64,
        elems: &[(u16, u16, f64)],
    ) -> Result<Self> {
        for pair in elems.windows(2) {
            if (pair[1].0, pair[1].1) <= (pair[0].0, pair[0].1) {
                return Err(AbhsfError::Invalid(
                    "build: elements not strictly row-major sorted".into(),
                ));
            }
        }
        match scheme {
            Scheme::Coo => Self::coo(
                row0,
                col0,
                s,
                elems.iter().map(|e| e.0).collect(),
                elems.iter().map(|e| e.1).collect(),
                elems.iter().map(|e| e.2).collect(),
            ),
            Scheme::Csr => {
                let mut rowptrs = Vec::with_capacity(s as usize + 1);
                rowptrs.push(0u32);
                let mut k = 0usize;
                for lr in 0..s {
                    while k < elems.len() && (elems[k].0 as u64) == lr {
                        k += 1;
                    }
                    rowptrs.push(k as u32);
                }
                Self::csr(
                    row0,
                    col0,
                    s,
                    rowptrs,
                    elems.iter().map(|e| e.1).collect(),
                    elems.iter().map(|e| e.2).collect(),
                )
            }
            Scheme::Bitmap => {
                let mut bits = vec![0u8; ((s * s).div_ceil(8)) as usize];
                for &(lr, lc, _) in elems {
                    let cell = lr as u64 * s + lc as u64;
                    bits[(cell / 8) as usize] |= 1 << (cell % 8);
                }
                Self::bitmap(row0, col0, s, bits, elems.iter().map(|e| e.2).collect())
            }
            Scheme::Dense => {
                let mut vals = vec![0.0f64; (s * s) as usize];
                for &(lr, lc, v) in elems {
                    vals[(lr as u64 * s + lc as u64) as usize] = v;
                }
                Self::dense(row0, col0, s, vals)
            }
        }
    }

    /// Placement and size.
    pub fn geom(&self) -> BlockGeom {
        match self {
            DecodedBlock::Coo { geom, .. }
            | DecodedBlock::CsrInBlock { geom, .. }
            | DecodedBlock::Bitmap { geom, .. }
            | DecodedBlock::Dense { geom, .. } => *geom,
        }
    }

    /// The block's storage scheme.
    pub fn scheme(&self) -> Scheme {
        match self {
            DecodedBlock::Coo { .. } => Scheme::Coo,
            DecodedBlock::CsrInBlock { .. } => Scheme::Csr,
            DecodedBlock::Bitmap { .. } => Scheme::Bitmap,
            DecodedBlock::Dense { .. } => Scheme::Dense,
        }
    }

    /// Nonzeros in the block.
    pub fn zeta(&self) -> u64 {
        self.geom().zeta
    }

    /// In-memory payload bytes of the scheme-native representation —
    /// what the decoded-block cache charges against its budget (plus its
    /// fixed per-block overhead). Equals the on-disk payload size under
    /// the default byte widths; crucially **not** 24·ζ triplet bytes.
    pub fn payload_bytes(&self) -> u64 {
        match self {
            DecodedBlock::Coo { vals, .. } => vals.len() as u64 * (2 * 2 + 8),
            DecodedBlock::CsrInBlock { rowptrs, vals, .. } => {
                rowptrs.len() as u64 * 4 + vals.len() as u64 * (2 + 8)
            }
            DecodedBlock::Bitmap { bits, vals, .. } => bits.len() as u64 + vals.len() as u64 * 8,
            DecodedBlock::Dense { vals, .. } => vals.len() as u64 * 8,
        }
    }

    /// Visit every nonzero as `(row, col, val)` in **global**
    /// coordinates, in the scheme's natural (row-major) decode order —
    /// exactly the element stream the triplet decoders emit for the same
    /// stored block.
    pub fn for_each_element<F: FnMut(u64, u64, f64)>(&self, mut f: F) {
        let g = self.geom();
        match self {
            DecodedBlock::Coo {
                lrows, lcols, vals, ..
            } => {
                for ((&lr, &lc), &v) in lrows.iter().zip(lcols).zip(vals) {
                    f(g.row0 + lr as u64, g.col0 + lc as u64, v);
                }
            }
            DecodedBlock::CsrInBlock {
                rowptrs,
                lcolinds,
                vals,
                ..
            } => {
                for lr in 0..g.s as usize {
                    for e in rowptrs[lr] as usize..rowptrs[lr + 1] as usize {
                        f(g.row0 + lr as u64, g.col0 + lcolinds[e] as u64, vals[e]);
                    }
                }
            }
            DecodedBlock::Bitmap { bits, vals, .. } => {
                let mut next = 0usize;
                for (bi, &byte) in bits.iter().enumerate() {
                    let mut rest = byte;
                    while rest != 0 {
                        let cell = (bi * 8) as u64 + rest.trailing_zeros() as u64;
                        f(g.row0 + cell / g.s, g.col0 + cell % g.s, vals[next]);
                        next += 1;
                        rest &= rest - 1;
                    }
                }
            }
            DecodedBlock::Dense { vals, .. } => {
                for (cell, &v) in vals.iter().enumerate() {
                    if v != 0.0 {
                        f(g.row0 + cell as u64 / g.s, g.col0 + cell as u64 % g.s, v);
                    }
                }
            }
        }
    }

    /// The block's nonzeros as owned global triplets (test/debug helper;
    /// the hot paths use [`for_each_element`](Self::for_each_element) or
    /// the per-scheme kernels and never materialize this).
    pub fn elements(&self) -> Vec<(u64, u64, f64)> {
        let mut out = Vec::with_capacity(self.zeta() as usize);
        self.for_each_element(|i, j, v| out.push((i, j, v)));
        out
    }

    /// Re-encode the payload into its on-disk byte form (the inverse of
    /// decoding): the [`EncodedBlock`] holds exactly the little-endian
    /// dataset bytes the container stores for this block, so a later
    /// [`EncodedBlock::decode`] needs no storage handle at all. This is
    /// the demotion path of the two-tier cache (`crate::cache`): an
    /// evicted-but-warm block is kept in encoded form (same payload
    /// bytes — the schemes are their own compact representation — but
    /// smaller fixed overhead and, crucially, no storage dependency) and
    /// a re-claim pays one decode instead of an I/O round trip.
    pub fn encode(&self) -> EncodedBlock {
        let parts = match self {
            DecodedBlock::Coo {
                lrows, lcols, vals, ..
            } => vec![encode_slice(lrows), encode_slice(lcols), encode_slice(vals)],
            DecodedBlock::CsrInBlock {
                rowptrs,
                lcolinds,
                vals,
                ..
            } => vec![
                encode_slice(rowptrs),
                encode_slice(lcolinds),
                encode_slice(vals),
            ],
            DecodedBlock::Bitmap { bits, vals, .. } => vec![bits.clone(), encode_slice(vals)],
            DecodedBlock::Dense { vals, .. } => vec![encode_slice(vals)],
        };
        EncodedBlock {
            scheme: self.scheme(),
            geom: self.geom(),
            parts,
        }
    }
}

/// One ABHSF block in its **encoded, on-disk byte form**: the scheme,
/// the placement, and the raw little-endian payload buffers exactly as
/// the per-scheme datasets store them (COO: lrows/lcols/vals; CSR:
/// rowptrs/lcolinds/vals; bitmap: bits/vals; dense: vals).
///
/// Only constructible via [`DecodedBlock::encode`], so the parts are
/// always internally consistent with the scheme and geometry;
/// [`decode`](Self::decode) re-runs the validated constructors and
/// therefore reproduces the original [`DecodedBlock`] bit-for-bit.
/// This is what the cache's T2 tier holds: kernel-unready, but
/// requiring no storage handle to revive — the byte win over the
/// decoded form is only the fixed per-block overhead (ABHSF's schemes
/// are their own compact in-memory representation), the latency win is
/// the whole I/O round trip.
#[derive(Debug, Clone, PartialEq)]
pub struct EncodedBlock {
    scheme: Scheme,
    geom: BlockGeom,
    parts: Vec<Vec<u8>>,
}

impl EncodedBlock {
    /// The block's storage scheme.
    pub fn scheme(&self) -> Scheme {
        self.scheme
    }

    /// Placement and size (as the decoded block's).
    pub fn geom(&self) -> BlockGeom {
        self.geom
    }

    /// Nonzeros in the block.
    pub fn zeta(&self) -> u64 {
        self.geom.zeta
    }

    /// Total payload bytes across the scheme's buffers — what the T2
    /// tier charges against its budget (plus its fixed per-entry
    /// overhead).
    pub fn payload_bytes(&self) -> u64 {
        self.parts.iter().map(|p| p.len() as u64).sum()
    }

    /// Decode back to the kernel-ready form **without any storage
    /// handle** — an in-memory re-run of the per-scheme decoders through
    /// the same validated constructors the fetch path uses, so a
    /// corrupted buffer surfaces as the same typed error.
    pub fn decode(&self) -> Result<DecodedBlock> {
        let g = self.geom;
        let block = match self.scheme {
            Scheme::Coo => DecodedBlock::coo(
                g.row0,
                g.col0,
                g.s,
                decode_slice::<u16>(&self.parts[0]),
                decode_slice::<u16>(&self.parts[1]),
                decode_slice::<f64>(&self.parts[2]),
            )?,
            Scheme::Csr => DecodedBlock::csr(
                g.row0,
                g.col0,
                g.s,
                decode_slice::<u32>(&self.parts[0]),
                decode_slice::<u16>(&self.parts[1]),
                decode_slice::<f64>(&self.parts[2]),
            )?,
            Scheme::Bitmap => DecodedBlock::bitmap(
                g.row0,
                g.col0,
                g.s,
                self.parts[0].clone(),
                decode_slice::<f64>(&self.parts[1]),
            )?,
            Scheme::Dense => {
                DecodedBlock::dense(g.row0, g.col0, g.s, decode_slice::<f64>(&self.parts[0]))?
            }
        };
        if block.zeta() != g.zeta {
            return Err(AbhsfError::Invalid(format!(
                "encoded block: payload decodes to zeta {} but geometry says {}",
                block.zeta(),
                g.zeta
            )));
        }
        Ok(block)
    }
}

/// Fetch and decode the directory entries at `indices` (strictly
/// ascending positions into `dir.entries`) through the double-buffered
/// read-ahead pipeline, calling `sink(k, elements)` for each block in
/// order with its decoded elements in **global** coordinates. Returns
/// the number of elements decoded.
///
/// This is the block-granular decode entry point: full pruned loads
/// ([`visit_elements_pruned`]) and the serving layer's cache-miss path
/// (`crate::serve`) share it, so both inherit the pipeline's chunk
/// coalescing (each container chunk read at most once per batch) and the
/// prefetch hit/stall accounting in the reader's
/// [`IoStats`](crate::h5::IoStats).
pub fn fetch_blocks<F>(
    r: &H5Reader,
    dir: &BlockDirectory,
    indices: &[usize],
    sink: F,
) -> Result<u64>
where
    F: FnMut(usize, &[(u64, u64, f64)]),
{
    fetch_blocks_batched(r, dir, indices, default_batch_bytes(r), sink)
}

/// [`fetch_blocks`] with an explicit read-ahead batch size in payload
/// bytes (tests force multi-batch pipelines on small files).
pub(crate) fn fetch_blocks_batched<F>(
    r: &H5Reader,
    dir: &BlockDirectory,
    indices: &[usize],
    batch_bytes: u64,
    mut sink: F,
) -> Result<u64>
where
    F: FnMut(usize, &[(u64, u64, f64)]),
{
    let mut global: Vec<(u64, u64, f64)> = Vec::new();
    fetch_decoded_blocks_batched(r, dir, indices, batch_bytes, |k, block| {
        global.clear();
        block.for_each_element(|i, j, v| global.push((i, j, v)));
        sink(k, &global);
    })
}

/// Like [`fetch_blocks`], but hand each block to `sink` in its
/// **scheme-native decoded form** ([`DecodedBlock`], owned) instead of
/// expanding to triplets — the serving layer's cache-miss path publishes
/// these directly, so a cached block's footprint stays at its compact
/// payload size. Triplet consumers ([`fetch_blocks`], the pruned loader)
/// wrap this and expand per block.
pub fn fetch_decoded_blocks_batched<F>(
    r: &H5Reader,
    dir: &BlockDirectory,
    indices: &[usize],
    batch_bytes: u64,
    mut sink: F,
) -> Result<u64>
where
    F: FnMut(usize, DecodedBlock),
{
    if indices.is_empty() {
        return Ok(0);
    }
    for w in indices.windows(2) {
        if w[1] <= w[0] {
            return Err(AbhsfError::Invalid(format!(
                "fetch_blocks: indices not strictly ascending at {}",
                w[1]
            )));
        }
    }
    if *indices.last().unwrap() >= dir.entries.len() {
        return Err(AbhsfError::Invalid(format!(
            "fetch_blocks: index {} beyond directory of {} blocks",
            indices.last().unwrap(),
            dir.entries.len()
        )));
    }
    let s = dir.header.block_size;
    let (ro, co) = (dir.header.info.m_offset, dir.header.info.n_offset);
    let bm_bytes = (s * s).div_ceil(8);

    // Pass 1: group the payload byte ranges of the requested blocks into
    // read-ahead batches of ~`batch_bytes` payload each. Slot indices
    // follow PAYLOAD_DATASETS order; ranges stay ascending because the
    // directory's payload offsets are monotone in stored order.
    let empty_batch = || BatchRequest {
        ranges: vec![Vec::new(); PAYLOAD_DATASETS.len()],
    };
    let mut batches: Vec<BatchRequest> = Vec::new();
    let mut blocks_per_batch: Vec<usize> = Vec::new();
    let mut cur = empty_batch();
    let (mut cur_blocks, mut cur_bytes) = (0usize, 0u64);
    for &k in indices {
        let e = &dir.entries[k];
        match e.scheme {
            Scheme::Coo => {
                cur.ranges[0].push((e.off_a, e.zeta));
                cur.ranges[1].push((e.off_a, e.zeta));
                cur.ranges[2].push((e.off_a, e.zeta));
            }
            Scheme::Csr => {
                cur.ranges[3].push((e.off_a, s + 1));
                cur.ranges[4].push((e.off_b, e.zeta));
                cur.ranges[5].push((e.off_b, e.zeta));
            }
            Scheme::Bitmap => {
                cur.ranges[6].push((e.off_a, bm_bytes));
                cur.ranges[7].push((e.off_b, e.zeta));
            }
            Scheme::Dense => cur.ranges[8].push((e.off_a, s * s)),
        }
        cur_blocks += 1;
        cur_bytes += dir.payload_bytes(k);
        if cur_bytes >= batch_bytes {
            batches.push(std::mem::replace(&mut cur, empty_batch()));
            blocks_per_batch.push(cur_blocks);
            cur_blocks = 0;
            cur_bytes = 0;
        }
    }
    if cur_blocks > 0 {
        batches.push(cur);
        blocks_per_batch.push(cur_blocks);
    }

    // Pass 2: the background fetcher streams the requested ranges batch
    // by batch while this thread decodes the previous batch. Each block
    // is decoded straight into its scheme-native [`DecodedBlock`] — the
    // bulk `decode_slice` copies are the only per-byte work; no triplet
    // materialization happens here.
    let mut total = 0u64;
    let mut stream = r.prefetch(&PAYLOAD_DATASETS, batches)?;
    let mut block_cursor = 0usize;
    for &nblocks in &blocks_per_batch {
        let _span = trace::span("block_decode", &[("blocks", Tag::U(nblocks as u64))]);
        let mut batch = stream.next(r)?.ok_or_else(|| {
            AbhsfError::Invalid("read-ahead stream ended before the last batch".into())
        })?;
        let (mut ci, mut ri, mut bi, mut di) = (0usize, 0usize, 0usize, 0usize);
        for &k in &indices[block_cursor..block_cursor + nblocks] {
            let e = dir.entries[k];
            let (row0, col0) = (ro + e.brow * s, co + e.bcol * s);
            let block = match e.scheme {
                Scheme::Coo => {
                    let b = DecodedBlock::coo(
                        row0,
                        col0,
                        s,
                        decode_slice::<u16>(&batch.data[0][ci]),
                        decode_slice::<u16>(&batch.data[1][ci]),
                        decode_slice::<f64>(&batch.data[2][ci]),
                    )?;
                    ci += 1;
                    b
                }
                Scheme::Csr => {
                    let b = DecodedBlock::csr(
                        row0,
                        col0,
                        s,
                        decode_slice::<u32>(&batch.data[3][ri]),
                        decode_slice::<u16>(&batch.data[4][ri]),
                        decode_slice::<f64>(&batch.data[5][ri]),
                    )?;
                    ri += 1;
                    b
                }
                Scheme::Bitmap => {
                    let b = DecodedBlock::bitmap(
                        row0,
                        col0,
                        s,
                        std::mem::take(&mut batch.data[6][bi]),
                        decode_slice::<f64>(&batch.data[7][bi]),
                    )?;
                    bi += 1;
                    b
                }
                Scheme::Dense => {
                    let b =
                        DecodedBlock::dense(row0, col0, s, decode_slice::<f64>(&batch.data[8][di]))?;
                    di += 1;
                    b
                }
            };
            if block.zeta() != e.zeta {
                return Err(AbhsfError::Invalid(format!(
                    "block ({},{}): decoded {} elements, zeta {}",
                    e.brow,
                    e.bcol,
                    block.zeta(),
                    e.zeta
                )));
            }
            total += e.zeta;
            sink(k, block);
        }
        block_cursor += nblocks;
    }
    // Drain the stream's end marker: this joins the fetcher and flushes
    // the prefetch hit/stall counters into the reader stats.
    if stream.next(r)?.is_some() {
        return Err(AbhsfError::Invalid(
            "read-ahead stream yielded an extra batch".into(),
        ));
    }
    crate::obs::metrics::global().counter("load.blocks_decoded").add(indices.len() as u64);
    Ok(total)
}

/// Block-pruned streaming decoder (global coordinates): walk the block
/// directory first, skip every block whose global rectangle fails `keep`,
/// and fetch only the payload byte ranges of the surviving blocks.
///
/// The surviving ranges are fetched through a **double-buffered
/// read-ahead pipeline** ([`H5Reader`]'s prefetch stream): blocks are
/// grouped into payload batches and a background fetcher stays up to two
/// batches ahead of the decoder, so storage latency overlaps decode time.
/// The overlap is measurable: the reader's
/// [`IoStats`](crate::h5::IoStats) gains `prefetch_hits` (batches already
/// resident when the decoder asked) and `prefetch_stall_ns` (time the
/// decoder waited for the fetcher). Within one batch every container
/// chunk is read at most once and untouched chunks never; a chunk
/// straddling a batch seam may be read once per side.
///
/// `keep` receives the block's global rectangle `(r0, c0, rows, cols)`
/// (edge blocks are clipped to the submatrix window) and must follow the
/// conservative contract of
/// [`ProcessMapping::intersects`](crate::mapping::ProcessMapping::intersects):
/// answering `true` for a useless block costs decode time, answering
/// `false` for a needed block loses elements.
///
/// With `keep = |_| true` this decodes exactly the same elements as
/// [`visit_elements`] (asserted against the stored element count);
/// otherwise the count check is per-block only, since skipped blocks
/// contribute nothing.
pub fn visit_elements_pruned<P, F>(r: &H5Reader, keep: P, sink: F) -> Result<PruneStats>
where
    P: FnMut(u64, u64, u64, u64) -> bool,
    F: FnMut(u64, u64, f64),
{
    visit_elements_pruned_batched(r, keep, sink, default_batch_bytes(r))
}

/// [`visit_elements_pruned`] with an explicit read-ahead batch size in
/// payload bytes (tests force multi-batch pipelines on small files).
pub(crate) fn visit_elements_pruned_batched<P, F>(
    r: &H5Reader,
    mut keep: P,
    mut sink: F,
    batch_bytes: u64,
) -> Result<PruneStats>
where
    P: FnMut(u64, u64, u64, u64) -> bool,
    F: FnMut(u64, u64, f64),
{
    let dir = BlockDirectory::read(r)?;
    let mut stats = PruneStats {
        blocks_total: dir.header.blocks,
        ..PruneStats::default()
    };
    let mut indices: Vec<usize> = Vec::new();
    for k in 0..dir.entries.len() {
        let (r0, c0, rows, cols) = dir.global_rect(k);
        if keep(r0, c0, rows, cols) {
            indices.push(k);
        } else {
            stats.blocks_skipped += 1;
            stats.bytes_skipped += dir.payload_bytes(k);
        }
    }
    stats.elements_decoded = fetch_blocks_batched(r, &dir, &indices, batch_bytes, |_, elems| {
        for &(i, j, v) in elems {
            sink(i, j, v);
        }
    })?;
    if stats.blocks_skipped == 0 && stats.elements_decoded != dir.header.info.z_local {
        return Err(AbhsfError::Invalid(format!(
            "decoded {} elements with nothing pruned, header says {}",
            stats.elements_decoded, dir.header.info.z_local
        )));
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abhsf::cost::CostModel;
    use crate::abhsf::store::store_data;
    use crate::abhsf::AbhsfData;
    use crate::formats::canonical_elements;
    use crate::util::rng::Xoshiro256;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("abhsf-load-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn roundtrip(coo: &Coo, s: u64, name: &str) -> Csr {
        let data = AbhsfData::from_coo(coo, s, &CostModel::default()).unwrap();
        data.validate().unwrap();
        let path = tmpdir().join(name);
        store_data(&path, &data).unwrap();
        let r = H5Reader::open(&path).unwrap();
        load_csr(&r).unwrap()
    }

    fn random_coo(seed: u64, m: u64, n: u64, nnz: usize, offset: (u64, u64)) -> Coo {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let info = LocalInfo {
            m: m + offset.0,
            n: n + offset.1,
            z: nnz as u64,
            m_local: m,
            n_local: n,
            z_local: 0,
            m_offset: offset.0,
            n_offset: offset.1,
        };
        let mut coo = Coo::with_info(info);
        let mut seen = std::collections::HashSet::new();
        while coo.nnz() < nnz {
            let r = rng.next_below(m);
            let c = rng.next_below(n);
            if seen.insert((r, c)) {
                coo.push(r, c, rng.range_f64(-10.0, 10.0));
            }
        }
        coo
    }

    #[test]
    fn roundtrip_random_matrices() {
        for (seed, m, n, nnz, s) in [
            (1u64, 64u64, 64u64, 400usize, 8u64),
            (2, 100, 80, 977, 16),
            (3, 33, 57, 200, 8),
            (4, 16, 16, 256, 4), // completely full
        ] {
            let coo = random_coo(seed, m, n, nnz, (0, 0));
            let csr = roundtrip(&coo, s, &format!("rt-{seed}.h5spm"));
            csr.validate().unwrap();
            assert_eq!(
                canonical_elements(&coo),
                canonical_elements(&csr.to_coo()),
                "mismatch at seed {seed}"
            );
        }
    }

    #[test]
    fn roundtrip_preserves_offsets() {
        let coo = random_coo(7, 40, 40, 300, (120, 64));
        let csr = roundtrip(&coo, 8, "rt-offset.h5spm");
        assert_eq!(csr.info.m_offset, 120);
        assert_eq!(csr.info.n_offset, 64);
        assert_eq!(canonical_elements(&coo), canonical_elements(&csr.to_coo()));
    }

    #[test]
    fn roundtrip_with_empty_block_rows() {
        // Elements only in block rows 0 and 3 (block rows 1, 2 empty).
        let info = LocalInfo::whole(32, 32, 4);
        let mut coo = Coo::with_info(info);
        coo.push(0, 5, 1.0);
        coo.push(7, 31, 2.0);
        coo.push(25, 0, 3.0);
        coo.push(31, 31, 4.0);
        let csr = roundtrip(&coo, 8, "rt-gaps.h5spm");
        csr.validate().unwrap();
        assert_eq!(canonical_elements(&coo), canonical_elements(&csr.to_coo()));
        assert_eq!(csr.rowptrs.len(), 33);
    }

    #[test]
    fn roundtrip_empty_matrix() {
        let info = LocalInfo::whole(16, 16, 0);
        let coo = Coo::with_info(info);
        let csr = roundtrip(&coo, 4, "rt-empty.h5spm");
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.rowptrs, vec![0; 17]);
    }

    #[test]
    fn roundtrip_nondivisible_block_size() {
        // m_local, n_local not multiples of s: edge blocks are partial.
        let coo = random_coo(11, 37, 29, 250, (0, 0));
        let csr = roundtrip(&coo, 8, "rt-edge.h5spm");
        csr.validate().unwrap();
        assert_eq!(canonical_elements(&coo), canonical_elements(&csr.to_coo()));
    }

    #[test]
    fn load_coo_matches_load_csr() {
        let coo = random_coo(13, 50, 50, 600, (10, 0));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-coo.h5spm");
        store_data(&path, &data).unwrap();
        let r = H5Reader::open(&path).unwrap();
        let csr = load_csr(&r).unwrap();
        let r2 = H5Reader::open(&path).unwrap();
        let loaded_coo = load_coo(&r2).unwrap();
        assert_eq!(canonical_elements(&loaded_coo), canonical_elements(&csr.to_coo()));
    }

    #[test]
    fn visit_elements_global_coordinates() {
        let coo = random_coo(17, 24, 24, 100, (48, 24));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-visit.h5spm");
        store_data(&path, &data).unwrap();
        let r = H5Reader::open(&path).unwrap();
        let mut got: Vec<(u64, u64, f64)> = Vec::new();
        let n = visit_elements(&r, |i, j, v| got.push((i, j, v))).unwrap();
        assert_eq!(n as usize, coo.nnz());
        got.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut want: Vec<(u64, u64, f64)> = coo
            .iter()
            .map(|(r0, c0, v)| (r0 + 48, c0 + 24, v))
            .collect();
        want.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(got, want);
    }

    #[test]
    fn all_schemes_decode_correctly() {
        // Force each scheme globally via extreme cost models and check the
        // roundtrip for each.
        let coo = random_coo(23, 32, 32, 512, (0, 0)); // 50% fill
        for (scheme, model) in [
            (Scheme::Coo, CostModel::analytic(0, 0, 9999)),
            (Scheme::Csr, CostModel::analytic(0, 0, 0)),
            (Scheme::Bitmap, CostModel::analytic(9999, 0, 9999)),
            (Scheme::Dense, CostModel::analytic(9999, 0, 9999)),
        ] {
            // For bitmap-vs-dense the tie depends on fill; just assert the
            // roundtrip and that the intended scheme family dominates.
            let data = AbhsfData::from_elements(
                coo.info,
                &canonical_elements(&coo),
                8,
                &model,
            )
            .unwrap();
            let path = tmpdir().join(format!("rt-scheme-{}.h5spm", scheme as u8));
            store_data(&path, &data).unwrap();
            let r = H5Reader::open(&path).unwrap();
            let csr = load_csr(&r).unwrap();
            assert_eq!(
                canonical_elements(&coo),
                canonical_elements(&csr.to_coo()),
                "scheme {scheme:?}"
            );
        }
    }

    /// With a keep-everything predicate the pruned decoder is element-
    /// identical to [`visit_elements`].
    #[test]
    fn pruned_with_keep_all_matches_unpruned() {
        let coo = random_coo(41, 48, 48, 500, (16, 8));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-prune-all.h5spm");
        store_data(&path, &data).unwrap();
        let collect = |pruned: bool| -> (Vec<(u64, u64, f64)>, u64, u64) {
            let r = H5Reader::open(&path).unwrap();
            let mut got = Vec::new();
            let (skipped, decoded) = if pruned {
                let st = visit_elements_pruned(
                    &r,
                    |_, _, _, _| true,
                    |i, j, v| got.push((i, j, v)),
                )
                .unwrap();
                assert_eq!(st.blocks_total, data.blocks());
                (st.blocks_skipped, st.elements_decoded)
            } else {
                let n = visit_elements(&r, |i, j, v| got.push((i, j, v))).unwrap();
                (0, n)
            };
            got.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
            (got, skipped, decoded)
        };
        let (want, _, n_unpruned) = collect(false);
        let (got, skipped, n_pruned) = collect(true);
        assert_eq!(got, want);
        assert_eq!(skipped, 0);
        assert_eq!(n_pruned, n_unpruned);
    }

    /// A half-plane predicate decodes exactly the elements inside it and
    /// skips payload bytes for the rest.
    #[test]
    fn pruned_decodes_only_surviving_blocks() {
        let coo = random_coo(43, 64, 64, 800, (0, 0));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-prune-half.h5spm");
        store_data(&path, &data).unwrap();
        // Keep blocks intersecting the left half of the columns.
        let r = H5Reader::open(&path).unwrap();
        let mut got = Vec::new();
        let st = visit_elements_pruned(
            &r,
            |_, c0, _, _| c0 < 32,
            |i, j, v| got.push((i, j, v)),
        )
        .unwrap();
        assert!(st.blocks_skipped > 0, "nothing pruned: {st:?}");
        assert!(st.bytes_skipped > 0);
        assert!(st.elements_decoded < coo.nnz() as u64);
        assert_eq!(st.elements_decoded as usize, got.len());
        // Everything left of the cut must be present (blocks are 8 wide,
        // the cut at 32 is block-aligned, so nothing leaks either way).
        let mut want: Vec<(u64, u64, f64)> =
            coo.iter().filter(|&(_, j, _)| j < 32).collect();
        want.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
        got.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
        assert_eq!(got, want);
        // The same file with nothing pruned decodes everything.
        let r2 = H5Reader::open(&path).unwrap();
        let st_all = visit_elements_pruned(&r2, |_, _, _, _| true, |_, _, _| {}).unwrap();
        assert_eq!(st_all.blocks_skipped, 0);
        assert_eq!(st_all.blocks_total, st.blocks_total);
        assert_eq!(st_all.elements_decoded, coo.nnz() as u64);
    }

    /// Pruning must also *read* fewer payload bytes once container chunks
    /// are fine-grained enough to be skippable.
    #[test]
    fn pruned_reads_fewer_bytes_with_small_chunks() {
        use crate::abhsf::store::store_data_chunked;
        let coo = random_coo(47, 96, 96, 2000, (0, 0));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-prune-bytes.h5spm");
        store_data_chunked(&path, &data, 64).unwrap();
        let read_bytes = |keep_all: bool| -> u64 {
            let r = H5Reader::open(&path).unwrap();
            visit_elements_pruned(
                &r,
                |_, c0, _, _| keep_all || c0 < 24,
                |_, _, _| {},
            )
            .unwrap();
            r.stats().bytes
        };
        let full = read_bytes(true);
        let pruned = read_bytes(false);
        assert!(
            pruned < full,
            "pruned read {pruned} bytes, unpruned {full}"
        );
    }

    /// Forcing tiny read-ahead batches (multi-batch pipeline) decodes
    /// exactly what the single-batch path does, and the overlap counters
    /// appear in the reader's statistics.
    #[test]
    fn pruned_readahead_batches_are_element_identical() {
        let coo = random_coo(53, 96, 96, 3000, (0, 0));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-readahead.h5spm");
        store_data(&path, &data).unwrap();
        type Run = (Vec<(u64, u64, f64)>, PruneStats, crate::h5::IoStats);
        let run = |batch_bytes: u64| -> Run {
            let r = H5Reader::open(&path).unwrap();
            let mut got = Vec::new();
            let st = visit_elements_pruned_batched(
                &r,
                |_, c0, _, _| c0 < 48,
                |i, j, v| got.push((i, j, v)),
                batch_bytes,
            )
            .unwrap();
            assert!(st.blocks_skipped > 0);
            got.sort_by(|a, b| (a.0, a.1).partial_cmp(&(b.0, b.1)).unwrap());
            (got, st, r.stats())
        };
        // One huge batch vs ~per-block batches.
        let (want, prune_one, _) = run(u64::MAX);
        let (got, prune_many, io_many) = run(1);
        assert_eq!(got, want, "multi-batch pipeline diverged");
        // The pipeline really handed over batches: hits and stalls are
        // only ever recorded by the prefetch stream.
        let handoffs = io_many.prefetch_hits + (io_many.prefetch_stall_ns > 0) as u64;
        assert!(handoffs >= 1, "no pipeline accounting: {io_many:?}");
        assert_eq!(
            prune_one.blocks_skipped, prune_many.blocks_skipped,
            "batching must not change pruning"
        );
        assert_eq!(prune_one.elements_decoded, prune_many.elements_decoded);
    }

    /// The block-granular fetch decodes exactly the requested blocks, in
    /// directory order, element-identical to the streaming decoder
    /// restricted to those blocks' rectangles.
    #[test]
    fn fetch_blocks_subset_matches_visit_elements() {
        let coo = random_coo(59, 64, 64, 900, (8, 4));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let path = tmpdir().join("rt-fetch-blocks.h5spm");
        store_data(&path, &data).unwrap();
        let r = H5Reader::open(&path).unwrap();
        let dir = BlockDirectory::read(&r).unwrap();
        assert_eq!(dir.entries.len() as u64, data.blocks());
        // Every other block of the directory.
        let indices: Vec<usize> = (0..dir.entries.len()).step_by(2).collect();
        let rects: Vec<(u64, u64, u64, u64)> =
            indices.iter().map(|&k| dir.global_rect(k)).collect();
        let mut got: Vec<(u64, u64, f64)> = Vec::new();
        let mut zeta_sum = 0u64;
        let n = fetch_blocks(&r, &dir, &indices, |k, elems| {
            zeta_sum += dir.entries[k].zeta;
            got.extend_from_slice(elems);
        })
        .unwrap();
        assert_eq!(n, zeta_sum);
        assert_eq!(got.len() as u64, n);
        // Reference: the full streaming decoder, restricted to the
        // selected blocks' (disjoint) rectangles.
        let r2 = H5Reader::open(&path).unwrap();
        let mut want: Vec<(u64, u64, f64)> = Vec::new();
        visit_elements(&r2, |i, j, v| {
            let inside = rects.iter().any(|&(r0, c0, rows, cols)| {
                i >= r0 && i < r0 + rows && j >= c0 && j < c0 + cols
            });
            if inside {
                want.push((i, j, v));
            }
        })
        .unwrap();
        let key = |e: &(u64, u64, f64)| (e.0, e.1);
        got.sort_by_key(key);
        want.sort_by_key(key);
        assert_eq!(got, want);
        // Out-of-order or out-of-range indices are usage errors.
        assert!(fetch_blocks(&r, &dir, &[1, 0], |_, _| {}).is_err());
        assert!(fetch_blocks(&r, &dir, &[dir.entries.len()], |_, _| {}).is_err());
        // The empty request is a no-op.
        assert_eq!(fetch_blocks(&r, &dir, &[], |_, _| {}).unwrap(), 0);
    }

    /// Live threads of this process (Linux); `None` elsewhere.
    fn live_threads() -> Option<usize> {
        std::fs::read_dir("/proc/self/task").ok().map(|d| d.count())
    }

    /// Satellite coverage for SimFs × read-ahead: a `truncate` fault that
    /// strikes *inside a prefetched batch* (the directory read fine; the
    /// background fetcher hits the cut mid-payload) must surface as a
    /// typed error carrying the injected-fault message — convertible to
    /// `DatasetError::Internal`, never a panic — and the fetcher thread
    /// must shut down cleanly every time: no leaked thread, no poisoned
    /// lock, and the reader stays usable once given a healthy handle.
    #[test]
    fn truncate_fault_inside_prefetched_batch_is_typed_error() {
        use crate::abhsf::store::store_data_chunked_on;
        use crate::parfs::FsModel;
        use crate::vfs::{FaultSpec, MemFs, SimFs, Storage};
        use std::sync::Arc;

        let coo = random_coo(61, 96, 96, 3000, (0, 0));
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let mem = MemFs::new();
        let path = std::path::Path::new("/prefetch-fault/m.h5spm");
        mem.create_dir_all(path.parent().unwrap()).unwrap();
        store_data_chunked_on(&mem, path, &data, 64).unwrap();

        // Open + directory read through the healthy map. (A fresh open
        // through the fault can never reach the payload: the h5 directory
        // lives at the file tail, behind any truncation cut. The scenario
        // under test is a file truncated *under* a live reader.)
        let mut r = H5Reader::open_on(&mem, path).unwrap();
        let dir = BlockDirectory::read(&r).unwrap();
        let indices: Vec<usize> = (0..dir.entries.len()).collect();
        let clean = Arc::clone(&r.file);

        // Swap in a truncate-faulted view of the same bytes: reads below
        // len/2 still succeed, so with per-block batches the pipeline
        // streams real data before the fetcher hits the cut mid-batch.
        let sim = SimFs::new(Arc::new(mem.clone()), FsModel::local_nvme())
            .faults(FaultSpec::parse("truncate:m.h5spm").unwrap());
        r.file = sim.open(path).unwrap();

        let before = live_threads();
        for _ in 0..50 {
            let err = fetch_blocks_batched(&r, &dir, &indices, 1, |_, _| {})
                .expect_err("truncated payload must fail the fetch");
            let any = anyhow::Error::from(err);
            assert!(
                format!("{any:#}").contains("past simulated truncation"),
                "wrong error: {any:#}"
            );
            let typed: crate::coordinator::DatasetError = any.into();
            assert!(
                matches!(typed, crate::coordinator::DatasetError::Internal(_)),
                "{typed}"
            );
        }
        // Every failed fetch joined its fetcher: 50 error paths must not
        // accumulate threads (slack absorbs unrelated test-harness noise).
        if let (Some(b), Some(a)) = (before, live_threads()) {
            assert!(a <= b + 4, "fetcher threads leaked: {b} -> {a}");
        }

        // No poisoned lock, no wedged state: the same reader decodes
        // everything once it gets a healthy handle back.
        r.file = clean;
        let stored: u64 = dir.entries.iter().map(|e| e.zeta).sum();
        let mut decoded = 0u64;
        let n = fetch_blocks_batched(&r, &dir, &indices, 1, |_, elems| {
            decoded += elems.len() as u64;
        })
        .unwrap();
        assert!(stored > 0, "degenerate workload");
        assert_eq!(n, stored);
        assert_eq!(decoded, n);
    }

    #[test]
    fn corrupted_zeta_detected() {
        let coo = random_coo(31, 16, 16, 64, (0, 0));
        let mut data = AbhsfData::from_coo(&coo, 4, &CostModel::default()).unwrap();
        // Tamper: bump one zeta (keeping sum harmless is not possible, so
        // the loader must notice either the per-block or the total count).
        data.zetas[0] += 1;
        let path = tmpdir().join("rt-corrupt.h5spm");
        // store_data validates; bypass by fixing z_local then corrupting.
        let res = store_data(&path, &data);
        assert!(res.is_err(), "store-side validation should catch it");
    }

    /// encode → decode round-trips every scheme bit-for-bit with no
    /// storage handle, the encoded payload matches the on-disk
    /// accounting, and the revived block's element stream is identical —
    /// the contract the cache's T2 tier (and its kernel consumers)
    /// stands on.
    #[test]
    fn encoded_block_roundtrips_all_schemes() {
        let s = 8u64;
        let elems: Vec<(u16, u16, f64)> = vec![
            (0, 0, 1.5),
            (0, 7, -2.0),
            (2, 3, 0.25),
            (5, 5, 4.0),
            (7, 1, -0.5),
        ];
        for scheme in [Scheme::Coo, Scheme::Csr, Scheme::Bitmap, Scheme::Dense] {
            let block = DecodedBlock::build(scheme, 24, 16, s, &elems).unwrap();
            let enc = block.encode();
            assert_eq!(enc.scheme(), scheme);
            assert_eq!(enc.geom(), block.geom());
            assert_eq!(
                enc.payload_bytes(),
                block.payload_bytes(),
                "{scheme:?}: encoded bytes must equal the on-disk payload accounting"
            );
            let back = enc.decode().unwrap();
            assert_eq!(back, block, "{scheme:?}: decode(encode(b)) != b");
            assert_eq!(back.elements(), block.elements());
        }
    }
}
