//! Adaptive-Blocking Hierarchical Storage Format (ABHSF).
//!
//! The local submatrix of each process is partitioned into fixed `s × s`
//! blocks; every nonzero block is stored in whichever of four *schemes* —
//! COO, CSR, bitmap, dense — costs the fewest bytes for its fill pattern
//! (Langr et al. [5], FedCSIS 2012). Block descriptors and per-scheme
//! payload streams become datasets of one `matrix-<k>.h5spm` container per
//! process (single-file-per-process strategy).
//!
//! * [`cost`] — the per-scheme space cost model and adaptive selection;
//! * [`block`] — partitioning a local submatrix into nonzero blocks;
//! * [`data`] — the in-memory image of one ABHSF file (attributes +
//!   datasets) and the COO/CSR → ABHSF builders (refs [1, 3]);
//! * [`store`] — writing that image into an h5spm container;
//! * [`load`] — **the paper's contribution**: streaming Algorithms 1–6
//!   that reconstruct an in-memory CSR (or visit raw elements, for
//!   different-configuration loading) from a stored file;
//! * [`rebucket`] — the repacking primitive: bounded-staging re-bucketing
//!   of an arbitrary-order element stream into a *new* `s × s` grid with
//!   fresh per-block scheme selection (see [`crate::repack`]);
//! * [`stats`] — size accounting and scheme histograms for the benches.

pub mod block;
pub mod cost;
pub mod data;
pub mod load;
pub mod rebucket;
pub mod stats;
pub mod store;

pub use block::{partition_into_blocks, Block};
pub use cost::{choose_scheme, scheme_cost, CostModel, MeasuredCosts, MeasuredEntry};
pub use data::AbhsfData;
pub use load::{
    fetch_blocks, fetch_decoded_blocks_batched, load_coo, load_csr, visit_elements,
    visit_elements_pruned, BlockDirectory, BlockEntry, BlockGeom, DecodedBlock, PruneStats,
};
pub use rebucket::{rebucket_into_abhsf, Rebucketer};
pub use store::{matrix_file_path, store_data};

/// Block storage scheme tags, as stored in the `schemes[]` dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Scheme {
    /// Coordinate list: `(lrow, lcol, val)` triplets.
    Coo = 0,
    /// Compressed sparse rows within the block.
    Csr = 1,
    /// `s*s` occupancy bitmap + packed values.
    Bitmap = 2,
    /// All `s*s` values, zeros included.
    Dense = 3,
}

impl Scheme {
    /// Decode a stored tag.
    pub fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => Scheme::Coo,
            1 => Scheme::Csr,
            2 => Scheme::Bitmap,
            3 => Scheme::Dense,
            _ => return None,
        })
    }

    /// All schemes, in tag order.
    pub const ALL: [Scheme; 4] = [Scheme::Coo, Scheme::Csr, Scheme::Bitmap, Scheme::Dense];

    /// Human-readable name.
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Coo => "COO",
            Scheme::Csr => "CSR",
            Scheme::Bitmap => "bitmap",
            Scheme::Dense => "dense",
        }
    }
}

/// Dataset and attribute names inside a `matrix-<k>.h5spm` container —
/// exactly the fields of the paper's `abhsf` structure (§2).
pub mod names {
    /// Global rows attribute.
    pub const M: &str = "m";
    /// Global columns attribute.
    pub const N: &str = "n";
    /// Global nonzeros attribute.
    pub const Z: &str = "z";
    /// Local rows attribute.
    pub const M_LOCAL: &str = "m_local";
    /// Local columns attribute.
    pub const N_LOCAL: &str = "n_local";
    /// Local nonzeros attribute.
    pub const Z_LOCAL: &str = "z_local";
    /// First local row attribute.
    pub const M_OFFSET: &str = "m_offset";
    /// First local column attribute.
    pub const N_OFFSET: &str = "n_offset";
    /// Block size attribute.
    pub const BLOCK_SIZE: &str = "block_size";
    /// Nonzero block count attribute.
    pub const BLOCKS: &str = "blocks";
    /// Scheme tag per nonzero block.
    pub const SCHEMES: &str = "schemes";
    /// Nonzero count per block.
    pub const ZETAS: &str = "zetas";
    /// Block row index per block.
    pub const BROWS: &str = "brows";
    /// Block column index per block.
    pub const BCOLS: &str = "bcols";
    /// COO-scheme in-block row indexes.
    pub const COO_LROWS: &str = "coo_lrows";
    /// COO-scheme in-block column indexes.
    pub const COO_LCOLS: &str = "coo_lcols";
    /// COO-scheme values.
    pub const COO_VALS: &str = "coo_vals";
    /// CSR-scheme in-block column indexes.
    pub const CSR_LCOLINDS: &str = "csr_lcolinds";
    /// CSR-scheme per-block row pointers (s+1 per block).
    pub const CSR_ROWPTRS: &str = "csr_rowptrs";
    /// CSR-scheme values.
    pub const CSR_VALS: &str = "csr_vals";
    /// Bitmap-scheme packed occupancy bytes.
    pub const BITMAP_BITMAP: &str = "bitmap_bitmap";
    /// Bitmap-scheme values.
    pub const BITMAP_VALS: &str = "bitmap_vals";
    /// Dense-scheme values (s*s per block).
    pub const DENSE_VALS: &str = "dense_vals";
}

/// Errors raised by ABHSF building, storing and loading.
#[derive(Debug, thiserror::Error)]
pub enum AbhsfError {
    /// Container-level failure.
    #[error(transparent)]
    H5(#[from] crate::h5::H5Error),
    /// Malformed stored data (bad scheme tag, inconsistent counts, …).
    #[error("invalid ABHSF data: {0}")]
    Invalid(String),
}

/// Result alias.
pub type Result<T> = std::result::Result<T, AbhsfError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_tags_roundtrip() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::from_tag(s as u8), Some(s));
        }
        assert_eq!(Scheme::from_tag(4), None);
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::Coo.name(), "COO");
        assert_eq!(Scheme::Dense.name(), "dense");
    }
}
