//! Streaming block re-bucketer — the storage-side primitive of dataset
//! repacking (the `repack` subsystem).
//!
//! Re-blocking a stored matrix to a new block size / partitioning means
//! every target rank receives its elements in *source-block* order — an
//! arbitrary order with respect to the **target** `s × s` grid. The
//! [`Rebucketer`] absorbs that stream with a bounded sorting working set:
//! elements accumulate in a staging buffer of at most `staging_limit`
//! entries; a full buffer is sealed into a sorted *run*, and
//! [`Rebucketer::into_sorted_global`] k-way-merges the runs into one
//! globally (row, col)-sorted stream. Sorting cost is thus
//! `O(n log staging_limit + n log runs)` with an unsorted working set
//! never exceeding `staging_limit` — the "chunked accumulation" mode for
//! irregular target mappings. Rectangular mappings (exact
//! [`crate::mapping::ProcessMapping::rank_rect`]) can use the spill-free
//! mode (`staging_limit = 0`, one buffer, one sort): their resident set is
//! already bounded by the rank's own region, never by the total nonzero
//! count.
//!
//! [`rebucket_into_abhsf`] finishes the pipeline: the sorted global
//! stream is shifted into the target rank's local window and re-encoded
//! block by block with fresh per-block scheme selection (COO / CSR /
//! bitmap / dense byte-cost minimization — the same
//! [`CostModel::choose`] the original store ran, now over the *new*
//! block geometry).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::abhsf::cost::CostModel;
use crate::abhsf::{AbhsfData, Result};
use crate::formats::{Element, LocalInfo};

/// Bounded-staging accumulator for elements arriving in arbitrary order,
/// produced back as one (row, col)-sorted stream. See the module docs for
/// the memory contract.
#[derive(Debug, Default)]
pub struct Rebucketer {
    /// Seal threshold for the staging buffer; `0` = unbounded
    /// (single-buffer spill-free mode).
    staging_limit: usize,
    staging: Vec<(u64, u64, f64)>,
    runs: Vec<Vec<(u64, u64, f64)>>,
    peak_unsorted: u64,
    total: u64,
}

impl Rebucketer {
    /// Create a re-bucketer. `staging_limit` bounds the *unsorted*
    /// working set (elements); `0` disables chunking — everything stages
    /// in one buffer sorted once at the end.
    pub fn new(staging_limit: usize) -> Self {
        Self {
            staging_limit,
            ..Self::default()
        }
    }

    /// Absorb one global element.
    pub fn push(&mut self, i: u64, j: u64, v: f64) {
        self.staging.push((i, j, v));
        self.total += 1;
        self.peak_unsorted = self.peak_unsorted.max(self.staging.len() as u64);
        if self.staging_limit > 0 && self.staging.len() >= self.staging_limit {
            self.seal_run();
        }
    }

    /// Elements absorbed so far — the rank's *resident* staging set (runs
    /// are kept until the merge; the bound the repack report certifies is
    /// that this never exceeds the rank's own region, i.e. no rank ever
    /// stages the whole matrix).
    pub fn len(&self) -> u64 {
        self.total
    }

    /// Whether nothing was absorbed.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Sealed sorted runs plus the active staging buffer (diagnostics).
    pub fn runs(&self) -> usize {
        self.runs.len() + usize::from(!self.staging.is_empty())
    }

    /// Largest unsorted working set observed (≤ `staging_limit` when
    /// bounded).
    pub fn peak_unsorted(&self) -> u64 {
        self.peak_unsorted
    }

    fn seal_run(&mut self) {
        if self.staging.is_empty() {
            return;
        }
        let mut run = std::mem::take(&mut self.staging);
        run.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        self.runs.push(run);
    }

    /// Merge all runs into one (row, col)-sorted global element stream.
    pub fn into_sorted_global(mut self) -> Vec<(u64, u64, f64)> {
        self.seal_run();
        match self.runs.len() {
            0 => Vec::new(),
            1 => self.runs.pop().unwrap(),
            _ => {
                let mut out = Vec::with_capacity(self.total as usize);
                // K-way merge keyed by (row, col); coordinates are unique
                // across runs (each stored element exists exactly once),
                // so the key never ties.
                let mut heads: Vec<usize> = vec![0; self.runs.len()];
                let mut heap: BinaryHeap<Reverse<(u64, u64, usize)>> = self
                    .runs
                    .iter()
                    .enumerate()
                    .filter(|(_, run)| !run.is_empty())
                    .map(|(r, run)| Reverse((run[0].0, run[0].1, r)))
                    .collect();
                while let Some(Reverse((_, _, r))) = heap.pop() {
                    let pos = heads[r];
                    out.push(self.runs[r][pos]);
                    heads[r] += 1;
                    if let Some(&(i, j, _)) = self.runs[r].get(heads[r]) {
                        heap.push(Reverse((i, j, r)));
                    }
                }
                out
            }
        }
    }
}

/// Re-encode a (row, col)-sorted *global* element stream as one target
/// rank's ABHSF image: shift into the local `window = (m_offset,
/// n_offset, m_local, n_local)`, partition into the new `s × s` grid and
/// run per-block scheme selection under `model`. `dims` is the global
/// `(m, n, z)` triple for the file header.
///
/// Takes the stream by value and frees it as soon as the local element
/// list exists, so the transient working set stays at a small constant
/// multiple of the *rank's* region (the keyed partition inside
/// [`AbhsfData::from_elements`] needs its own copy) — never of the whole
/// matrix.
pub fn rebucket_into_abhsf(
    sorted_global: Vec<(u64, u64, f64)>,
    window: (u64, u64, u64, u64),
    dims: (u64, u64, u64),
    s: u64,
    model: &CostModel,
) -> Result<AbhsfData> {
    let (ro, co, ml, nl) = window;
    let (m, n, z) = dims;
    let info = LocalInfo {
        m,
        n,
        z,
        m_local: ml,
        n_local: nl,
        z_local: 0,
        m_offset: ro,
        n_offset: co,
    };
    // A uniform offset shift preserves lexicographic order, so the input
    // is already the canonical element list `AbhsfData` expects.
    let elements: Vec<Element> = sorted_global
        .iter()
        .map(|&(i, j, v)| Element::new(i - ro, j - co, v))
        .collect();
    drop(sorted_global);
    AbhsfData::from_elements(info, &elements, s, model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_stream(seed: u64, count: usize) -> Vec<(u64, u64, f64)> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::with_capacity(count);
        while out.len() < count {
            let i = rng.next_below(200);
            let j = rng.next_below(200);
            if seen.insert((i, j)) {
                out.push((i, j, rng.range_f64(-5.0, 5.0)));
            }
        }
        out
    }

    #[test]
    fn chunked_merge_equals_plain_sort() {
        let stream = random_stream(7, 1000);
        let mut want = stream.clone();
        want.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        for limit in [0usize, 1, 7, 64, 1000, 5000] {
            let mut rb = Rebucketer::new(limit);
            for &(i, j, v) in &stream {
                rb.push(i, j, v);
            }
            assert_eq!(rb.len(), 1000);
            if limit > 0 {
                assert!(
                    rb.peak_unsorted() <= limit as u64,
                    "limit {limit}: peak {}",
                    rb.peak_unsorted()
                );
                assert!(rb.runs() >= 1000 / limit.min(1000), "limit {limit}");
            }
            assert_eq!(rb.into_sorted_global(), want, "limit {limit}");
        }
    }

    #[test]
    fn empty_rebucketer() {
        let rb = Rebucketer::new(16);
        assert!(rb.is_empty());
        assert_eq!(rb.runs(), 0);
        assert!(rb.into_sorted_global().is_empty());
    }

    #[test]
    fn rebucket_builds_valid_abhsf_in_new_grid() {
        let stream = random_stream(11, 500);
        let mut rb = Rebucketer::new(128);
        for &(i, j, v) in &stream {
            rb.push(i, j, v);
        }
        let sorted = rb.into_sorted_global();
        let data = rebucket_into_abhsf(
            sorted.clone(),
            (0, 0, 200, 200),
            (200, 200, 500),
            16,
            &CostModel::default(),
        )
        .unwrap();
        data.validate().unwrap();
        assert_eq!(data.info.z_local, 500);
        assert_eq!(data.block_size, 16);
        // Round-trip: the blocks reproduce exactly the input elements.
        let blocks = crate::abhsf::partition_into_blocks(
            &sorted
                .iter()
                .map(|&(i, j, v)| Element::new(i, j, v))
                .collect::<Vec<_>>(),
            16,
        );
        assert_eq!(data.blocks(), blocks.len() as u64);
    }

    #[test]
    fn rebucket_respects_offset_window() {
        let sorted = vec![(10u64, 20u64, 1.0), (10, 21, 2.0), (15, 20, 3.0)];
        let data = rebucket_into_abhsf(
            sorted,
            (10, 20, 6, 2),
            (32, 32, 3),
            4,
            &CostModel::default(),
        )
        .unwrap();
        data.validate().unwrap();
        assert_eq!(data.info.m_offset, 10);
        assert_eq!(data.info.n_offset, 20);
        assert_eq!(data.info.z_local, 3);
    }
}
