//! Size accounting and scheme histograms over ABHSF images/files —
//! the measurements behind the file-size and block-size ablation benches
//! (Tables A and C in DESIGN.md §5).

use std::collections::BTreeMap;

use crate::abhsf::{AbhsfData, Scheme};
use crate::formats::{Coo, Csr};

/// Per-scheme block/element histogram of one ABHSF image.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SchemeHistogram {
    /// Blocks per scheme.
    pub blocks: BTreeMap<u8, u64>,
    /// Nonzeros per scheme.
    pub nonzeros: BTreeMap<u8, u64>,
}

impl SchemeHistogram {
    /// Compute from an image.
    pub fn of(data: &AbhsfData) -> Self {
        let mut h = Self::default();
        for (i, &tag) in data.schemes.iter().enumerate() {
            *h.blocks.entry(tag).or_insert(0) += 1;
            *h.nonzeros.entry(tag).or_insert(0) += data.zetas[i] as u64;
        }
        h
    }

    /// Blocks stored under `scheme`.
    pub fn blocks_of(&self, scheme: Scheme) -> u64 {
        self.blocks.get(&(scheme as u8)).copied().unwrap_or(0)
    }

    /// Nonzeros stored under `scheme`.
    pub fn nonzeros_of(&self, scheme: Scheme) -> u64 {
        self.nonzeros.get(&(scheme as u8)).copied().unwrap_or(0)
    }

    /// Total block count.
    pub fn total_blocks(&self) -> u64 {
        self.blocks.values().sum()
    }
}

/// Size comparison of one local submatrix across storage formats, in the
/// paper's experimental representation (f64 values, 32-bit indexes for
/// COO/CSR files).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SizeReport {
    /// Nonzeros.
    pub nnz: u64,
    /// ABHSF payload bytes (what this crate writes).
    pub abhsf_bytes: u64,
    /// Raw COO file bytes (values + 2 × 32-bit indexes).
    pub coo_bytes: u64,
    /// Raw CSR file bytes (values + 32-bit colinds + 32-bit rowptrs).
    pub csr_bytes: u64,
    /// Dense binary bytes (m_local × n_local f64).
    pub dense_bytes: u64,
}

impl SizeReport {
    /// Build a report for a local COO and its ABHSF image.
    pub fn of(coo: &Coo, data: &AbhsfData) -> Self {
        let csr = Csr::from_coo(coo);
        Self {
            nnz: coo.nnz() as u64,
            abhsf_bytes: data.payload_bytes(),
            coo_bytes: coo.payload_bytes_paper(),
            csr_bytes: csr.payload_bytes_paper(),
            dense_bytes: coo.info.m_local * coo.info.n_local * 8,
        }
    }

    /// ABHSF size relative to COO (< 1 means ABHSF is smaller).
    pub fn ratio_vs_coo(&self) -> f64 {
        self.abhsf_bytes as f64 / self.coo_bytes as f64
    }

    /// ABHSF size relative to CSR.
    pub fn ratio_vs_csr(&self) -> f64 {
        self.abhsf_bytes as f64 / self.csr_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::abhsf::cost::CostModel;
    use crate::formats::LocalInfo;
    use crate::util::rng::Xoshiro256;

    fn random_coo(seed: u64, m: u64, n: u64, nnz: usize) -> Coo {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut coo = Coo::with_info(LocalInfo::whole(m, n, nnz as u64));
        let mut seen = std::collections::HashSet::new();
        while coo.nnz() < nnz {
            let r = rng.next_below(m);
            let c = rng.next_below(n);
            if seen.insert((r, c)) {
                coo.push(r, c, rng.next_f64() + 0.1);
            }
        }
        coo
    }

    #[test]
    fn histogram_counts_blocks_and_nonzeros() {
        let coo = random_coo(3, 64, 64, 800);
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let h = SchemeHistogram::of(&data);
        assert_eq!(h.total_blocks(), data.blocks());
        let total_nnz: u64 = Scheme::ALL.iter().map(|&s| h.nonzeros_of(s)).sum();
        assert_eq!(total_nnz, coo.nnz() as u64);
    }

    #[test]
    fn dense_matrix_compresses_well() {
        // Fully dense local matrix: ABHSF should pick dense blocks and beat
        // COO by ~2x (no index storage).
        let m = 64u64;
        let mut coo = Coo::with_info(LocalInfo::whole(m, m, m * m));
        for r in 0..m {
            for c in 0..m {
                coo.push(r, c, (r * m + c) as f64 + 1.0);
            }
        }
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let rep = SizeReport::of(&coo, &data);
        assert!(rep.ratio_vs_coo() < 0.6, "ratio {}", rep.ratio_vs_coo());
        let h = SchemeHistogram::of(&data);
        assert_eq!(h.blocks_of(Scheme::Dense), h.total_blocks());
    }

    #[test]
    fn hypersparse_matrix_close_to_coo() {
        // At ~1 element per occupied block ABHSF's best case is COO blocks;
        // payload ~ nnz*(4+8) + descriptors.
        let coo = random_coo(9, 1000, 1000, 300);
        let data = AbhsfData::from_coo(&coo, 8, &CostModel::default()).unwrap();
        let rep = SizeReport::of(&coo, &data);
        // Descriptor overhead dominates at this sparsity; just require the
        // blowup stays bounded and the scheme mix is COO-dominated.
        assert!(rep.ratio_vs_coo() < 2.0, "ratio {}", rep.ratio_vs_coo());
        let h = SchemeHistogram::of(&data);
        assert!(h.blocks_of(Scheme::Coo) > h.total_blocks() / 2);
    }
}
