//! Writing an [`AbhsfData`] image into an h5spm container
//! (the storage side of refs [1, 3], single-file-per-process strategy).

use std::path::{Path, PathBuf};

use crate::abhsf::{names, AbhsfData, Result};
use crate::h5::{H5Writer, IoStats};
use crate::vfs::{LocalFs, Storage};

/// Path of process `k`'s file inside the matrix directory:
/// `<dir>/matrix-<k>.h5spm` (paper §2).
pub fn matrix_file_path<P: AsRef<Path>>(dir: P, rank: usize) -> PathBuf {
    dir.as_ref().join(format!("matrix-{rank}.h5spm"))
}

/// Write `data` to `path` on the local filesystem, returning writer I/O
/// statistics.
///
/// Attribute and dataset names follow the paper's `abhsf` structure; empty
/// datasets are written too so loaders can open cursors unconditionally.
pub fn store_data<P: AsRef<Path>>(path: P, data: &AbhsfData) -> Result<IoStats> {
    store_data_chunked(path, data, crate::h5::DEFAULT_CHUNK_ELEMS)
}

/// [`store_data`] with an explicit dataset chunk size (elements).
pub fn store_data_chunked<P: AsRef<Path>>(
    path: P,
    data: &AbhsfData,
    chunk_elems: u64,
) -> Result<IoStats> {
    store_data_chunked_on(&LocalFs, path, data, chunk_elems)
}

/// [`store_data_chunked`] on an arbitrary storage backend.
pub fn store_data_chunked_on<P: AsRef<Path>>(
    storage: &dyn Storage,
    path: P,
    data: &AbhsfData,
    chunk_elems: u64,
) -> Result<IoStats> {
    data.validate()?;
    if let Some(parent) = path.as_ref().parent() {
        storage
            .create_dir_all(parent)
            .map_err(crate::h5::H5Error::Io)?;
    }
    let mut w = H5Writer::create_on(storage, &path)?;
    w.set_chunk_elems(chunk_elems);

    w.set_attr(names::M, data.info.m)?;
    w.set_attr(names::N, data.info.n)?;
    w.set_attr(names::Z, data.info.z)?;
    w.set_attr(names::M_LOCAL, data.info.m_local)?;
    w.set_attr(names::N_LOCAL, data.info.n_local)?;
    w.set_attr(names::Z_LOCAL, data.info.z_local)?;
    w.set_attr(names::M_OFFSET, data.info.m_offset)?;
    w.set_attr(names::N_OFFSET, data.info.n_offset)?;
    w.set_attr(names::BLOCK_SIZE, data.block_size)?;
    w.set_attr(names::BLOCKS, data.blocks())?;

    w.write_dataset(names::SCHEMES, &data.schemes)?;
    w.write_dataset(names::ZETAS, &data.zetas)?;
    w.write_dataset(names::BROWS, &data.brows)?;
    w.write_dataset(names::BCOLS, &data.bcols)?;
    w.write_dataset(names::COO_LROWS, &data.coo_lrows)?;
    w.write_dataset(names::COO_LCOLS, &data.coo_lcols)?;
    w.write_dataset(names::COO_VALS, &data.coo_vals)?;
    w.write_dataset(names::CSR_LCOLINDS, &data.csr_lcolinds)?;
    w.write_dataset(names::CSR_ROWPTRS, &data.csr_rowptrs)?;
    w.write_dataset(names::CSR_VALS, &data.csr_vals)?;
    w.write_dataset(names::BITMAP_BITMAP, &data.bitmap_bitmap)?;
    w.write_dataset(names::BITMAP_VALS, &data.bitmap_vals)?;
    w.write_dataset(names::DENSE_VALS, &data.dense_vals)?;

    Ok(w.finish()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_path_naming() {
        let p = matrix_file_path("/tmp/matrix", 7);
        assert_eq!(p, PathBuf::from("/tmp/matrix/matrix-7.h5spm"));
    }
}
