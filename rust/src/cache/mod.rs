//! Concurrent two-tier block cache — the resident working set behind
//! the serving layer (`crate::serve`).
//!
//! Every load path before this module was one-shot batch: each
//! [`LoadPlan`](crate::coordinator::LoadPlan) re-reads and re-decodes
//! every surviving ABHSF block, even when the same dataset is queried
//! repeatedly. A [`BlockCache`] keeps blocks resident so repeated
//! queries never touch storage for blocks already seen — in two tiers:
//!
//! * **T1** holds blocks in their **scheme-native decoded form**
//!   ([`DecodedBlock`], kernel-ready — the per-scheme SpMV kernels
//!   execute the cached payloads directly). Admission is
//!   **scan-resistant** (2Q/SLRU): a published block enters a
//!   *probationary* queue; only a second touch promotes it to the
//!   *protected* queue (capped at 80% of the tier). Single-touch
//!   streaming claims — a whole-matrix SpMV sweep — churn probation and
//!   die there without displacing the protected rect-query set.
//! * **T2** holds **encoded** blocks ([`EncodedBlock`] — the on-disk
//!   byte form: same payload bytes as decoded, since ABHSF's schemes
//!   are their own compact representation, but a smaller fixed
//!   per-entry charge and no kernel-ready structure). A block evicted
//!   from T1 is *demoted* into T2 (re-encoded, charged at encoded
//!   bytes); a later claim finds it there and pays one in-memory decode
//!   — priced from the measured kernel table ([`MeasuredCosts`]) when
//!   one is loaded — but **never an I/O round trip**. Tiering is
//!   exclusive: a block lives in at most one tier, so the budget is
//!   never double-charged.
//!
//! The cache is **sharded** (keys hash to one of N shards, each behind
//! its own mutex; both tiers of a key live in its shard, so a claim
//! takes one lock) and **single-flight** (concurrent requests for the
//! same absent block decode it once; see [`Claim`]). Eviction removes a
//! block from the map only — `Arc` hand-outs keep already-claimed
//! blocks alive for their holders ([`CacheStats::claimed_bytes`] tracks
//! exactly those live bytes, distinct from the budget-charged
//! [`CacheStats::resident_bytes`]).
//!
//! Per-dataset budget partitioning is planned by the
//! [`BudgetPlanner`] from the footprint model and applied as a *soft*
//! preference: eviction scans a bounded prefix of the LRU order and
//! prefers victims from datasets over their planned share
//! (see `planner`). See DESIGN.md §10 for the full contract.

use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::ops::Deref;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::abhsf::cost::MeasuredCosts;
use crate::obs::metrics::Counter;
use crate::obs::trace::{self, Tag};

pub mod planner;

pub use planner::{BudgetPlan, BudgetPlanner, DatasetBudget, DatasetFootprint};

/// Identity of one cached block: which dataset, which stored file,
/// which cell of that file's block grid.
///
/// `dataset` comes from [`BlockCache::dataset_id`], which canonicalizes
/// `(storage medium, dataset directory)` — two readers over the same
/// stored dataset share ids (and therefore blocks), readers over
/// distinct datasets never collide. Block coordinates are file-local:
/// two files of one dataset cover disjoint submatrix windows, so
/// `(file, brow, bcol)` is unambiguous within a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockKey {
    /// Cache-assigned dataset id (see [`BlockCache::dataset_id`]).
    pub dataset: u64,
    /// Stored file index (`matrix-<file>.h5spm`).
    pub file: u32,
    /// Block row in the file's grid.
    pub brow: u32,
    /// Block column in the file's grid.
    pub bcol: u32,
}

/// Fixed per-block bookkeeping charge (map entry, Arc, payload Vec
/// headers) added to the scheme-native payload when accounting a T1
/// block against the budget — keeps a pathological all-tiny-blocks
/// working set from looking free.
pub const BLOCK_FIXED_BYTES: u64 = 96;

/// Fixed per-entry bookkeeping charge for a T2 (encoded) entry: smaller
/// than [`BLOCK_FIXED_BYTES`] because an encoded entry is a few byte
/// buffers, not a kernel-ready structure.
pub const T2_FIXED_BYTES: u64 = 64;

/// Fraction of a shard's T1 budget the protected queue may occupy
/// (numerator/denominator): overflow demotes protected-LRU blocks back
/// to probation, so at least 20% of T1 always absorbs new admissions.
const PROTECTED_NUM: u64 = 4;
const PROTECTED_DEN: u64 = 5;

/// Eviction lookahead: how many LRU-oldest entries a shard scans for a
/// victim from a dataset over its planned share before falling back to
/// the absolute oldest. Bounded so eviction stays O(1)-ish under lock.
const EVICT_LOOKAHEAD: usize = 8;

pub use crate::abhsf::load::{BlockGeom, DecodedBlock, EncodedBlock};

impl DecodedBlock {
    /// Bytes this block is charged against the T1 budget: the
    /// scheme-native payload ([`payload_bytes`](Self::payload_bytes))
    /// plus [`BLOCK_FIXED_BYTES`]. This is the budget-accounting policy
    /// of the cache, so it lives here rather than with the decoder.
    pub fn decoded_bytes(&self) -> u64 {
        BLOCK_FIXED_BYTES + self.payload_bytes()
    }
}

/// Bytes one encoded entry is charged against the T2 budget.
fn t2_charge(enc: &EncodedBlock) -> u64 {
    T2_FIXED_BYTES + enc.payload_bytes()
}

/// A decoded block as handed out by the cache: derefs to the
/// [`DecodedBlock`] payload and keeps the cache's *claimed-bytes*
/// counter honest — the counter is incremented when the block is
/// published and decremented when the **last** `Arc<CachedBlock>`
/// drops, so [`CacheStats::claimed_bytes`] is exactly the decoded bytes
/// still live somewhere (resident in T1, or evicted but still held by
/// an in-progress query).
#[derive(Debug)]
pub struct CachedBlock {
    block: DecodedBlock,
    bytes: u64,
    claimed: Arc<AtomicU64>,
}

impl CachedBlock {
    /// The decoded payload (also available through `Deref`; this form
    /// reads better where an explicit `&DecodedBlock` is needed).
    pub fn block(&self) -> &DecodedBlock {
        &self.block
    }
}

impl Deref for CachedBlock {
    type Target = DecodedBlock;

    fn deref(&self) -> &DecodedBlock {
        &self.block
    }
}

impl Drop for CachedBlock {
    fn drop(&mut self) {
        self.claimed.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// Result of one in-flight decode, shared between the loader and any
/// coalesced waiters.
#[derive(Debug)]
enum FlightState {
    Pending,
    Done(Arc<CachedBlock>),
    Failed(String),
}

#[derive(Debug)]
struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Self {
        Self {
            state: Mutex::new(FlightState::Pending),
            cv: Condvar::new(),
        }
    }

    fn resolve(&self, outcome: Result<Arc<CachedBlock>, String>) {
        let mut st = self.state.lock().expect("flight poisoned");
        *st = match outcome {
            Ok(b) => FlightState::Done(b),
            Err(e) => FlightState::Failed(e),
        };
        self.cv.notify_all();
    }
}

/// One shard slot: a resident T1 block or a decode in flight. In-flight
/// slots are never in a recency index and are therefore never evicted —
/// eviction only forgets bytes that are actually resident.
#[derive(Debug)]
enum Slot {
    Resident {
        block: Arc<CachedBlock>,
        tick: u64,
        protected: bool,
    },
    InFlight(Arc<Flight>),
}

/// One T2 entry: the encoded payload and its recency tick.
#[derive(Debug)]
struct T2Entry {
    enc: EncodedBlock,
    tick: u64,
}

/// Per-dataset traffic counters of one shard (hits / decode-saves /
/// storage misses), aggregated by [`BlockCache::dataset_stats`].
#[derive(Debug, Default, Clone, Copy)]
struct Traffic {
    hits: u64,
    decode_saves: u64,
    misses: u64,
}

#[derive(Debug, Default)]
struct Shard {
    slots: HashMap<BlockKey, Slot>,
    /// Recency index over probationary residents: tick → key, oldest
    /// first. New admissions (including T2 revivals) land here.
    probation: BTreeMap<u64, BlockKey>,
    /// Recency index over protected residents (second-touch blocks).
    protected: BTreeMap<u64, BlockKey>,
    probation_bytes: u64,
    protected_bytes: u64,
    /// T2: encoded entries + their recency index.
    t2: HashMap<BlockKey, T2Entry>,
    t2_lru: BTreeMap<u64, BlockKey>,
    t2_bytes: u64,
    /// Per-dataset T1 resident bytes in this shard.
    t1_by_dataset: HashMap<u64, u64>,
    /// Per-dataset T2 resident bytes in this shard.
    t2_by_dataset: HashMap<u64, u64>,
    /// Per-dataset planned T1 share of this shard (from
    /// [`BlockCache::apply_plan`]); empty = no plan, plain LRU.
    t1_share: HashMap<u64, u64>,
    /// Per-dataset hit/decode-save/miss counters.
    traffic: HashMap<u64, Traffic>,
}

impl Shard {
    fn t1_bytes(&self) -> u64 {
        self.probation_bytes + self.protected_bytes
    }

    fn note_traffic(&mut self, dataset: u64, f: impl FnOnce(&mut Traffic)) {
        f(self.traffic.entry(dataset).or_default());
    }
}

/// Outcome of [`BlockCache::claim`].
pub enum Claim<'c> {
    /// The block is T1-resident; use it.
    Hit(Arc<CachedBlock>),
    /// The block is not decoded anywhere and the caller just became its
    /// loader: produce the decoded block and resolve the token with
    /// [`LoadToken::publish`] (or [`LoadToken::fail`]). If
    /// [`LoadToken::take_encoded`] yields a payload the block was
    /// T2-resident — decode it in memory, **no storage round trip**;
    /// otherwise fetch from storage. Dropping the token unresolved
    /// fails the flight so coalesced waiters never hang.
    Miss(LoadToken<'c>),
    /// Another thread is already decoding this block; park on
    /// [`FlightWaiter::wait`] for its result.
    InFlight(FlightWaiter),
}

/// The loader side of a single-flight slot (see [`Claim::Miss`]).
pub struct LoadToken<'c> {
    cache: &'c BlockCache,
    key: BlockKey,
    flight: Arc<Flight>,
    encoded: Option<EncodedBlock>,
    resolved: bool,
}

impl LoadToken<'_> {
    /// The block this token is responsible for.
    pub fn key(&self) -> BlockKey {
        self.key
    }

    /// Take the T2-resident encoded payload, if the claim found one:
    /// decode it in memory ([`EncodedBlock::decode`]) instead of going
    /// to storage, then `publish` the result. The entry has already
    /// left T2 (tiers are exclusive) — if the token is subsequently
    /// dropped or failed, the block is simply gone from both tiers and
    /// the next claim is a storage miss.
    pub fn take_encoded(&mut self) -> Option<EncodedBlock> {
        self.encoded.take()
    }

    /// Install the decoded block, wake every coalesced waiter, and
    /// return the shared block. May immediately evict older blocks (or,
    /// if this block alone exceeds the shard budget, the block itself —
    /// the returned `Arc` stays valid either way).
    pub fn publish(mut self, block: DecodedBlock) -> Arc<CachedBlock> {
        self.resolved = true;
        self.cache.publish_inner(self.key, &self.flight, block)
    }

    /// Abandon the flight with an error: the slot is removed (a retry
    /// will claim a fresh miss) and waiters receive the error.
    pub fn fail(mut self, error: String) {
        self.resolved = true;
        self.cache.fail_inner(self.key, &self.flight, error);
    }
}

impl Drop for LoadToken<'_> {
    fn drop(&mut self) {
        if !self.resolved {
            self.cache.fail_inner(
                self.key,
                &self.flight,
                "block loader dropped without publishing".to_string(),
            );
        }
    }
}

/// The waiter side of a single-flight slot (see [`Claim::InFlight`]).
pub struct FlightWaiter {
    flight: Arc<Flight>,
}

impl FlightWaiter {
    /// Block until the loader resolves the flight; returns its block or
    /// its error message.
    pub fn wait(&self) -> Result<Arc<CachedBlock>, String> {
        let mut st = self.flight.state.lock().expect("flight poisoned");
        while matches!(*st, FlightState::Pending) {
            st = self.flight.cv.wait(st).expect("flight poisoned");
        }
        match &*st {
            FlightState::Done(b) => Ok(Arc::clone(b)),
            FlightState::Failed(e) => Err(e.clone()),
            FlightState::Pending => unreachable!("loop exits only when resolved"),
        }
    }
}

/// Monotonic counters of one cache, plus the current residency. All
/// counters are lifetime totals; snapshot via [`BlockCache::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Claims answered from a T1-resident decoded block.
    pub hits: u64,
    /// Claims that became **storage** loaders (each corresponds to one
    /// fetch+decode, successful or not). T2 revivals are *not* misses —
    /// they never touch storage; see [`decode_saves`](Self::decode_saves).
    pub misses: u64,
    /// Claims answered from a T2-resident *encoded* block: the caller
    /// re-decoded it in memory — one decode paid, one I/O round trip
    /// saved. This is the two-tier design's reason to exist.
    pub decode_saves: u64,
    /// Modeled cost of all those re-decodes in picoseconds, priced from
    /// the measured kernel table when one was loaded
    /// ([`BlockCache::set_measured_costs`]); 0 without a table.
    pub decode_save_ps: u64,
    /// Blocks evicted out of T1 under budget pressure (whether or not
    /// they were captured into T2).
    pub evictions: u64,
    /// T1 evictions captured into T2 (re-encoded; always ≤ `evictions`,
    /// 0 when the T2 budget is 0).
    pub demotions: u64,
    /// Probation → protected promotions (a block's second touch).
    pub promotions: u64,
    /// Encoded entries evicted out of T2 under its budget pressure.
    pub t2_evictions: u64,
    /// Claims that found a decode already in flight and waited on it
    /// instead of decoding again.
    pub coalesced_waits: u64,
    /// Decoded bytes ever inserted (publishes).
    pub inserted_bytes: u64,
    /// Decoded bytes currently T1-resident (charged to the T1 budget).
    pub resident_bytes: u64,
    /// Blocks currently T1-resident.
    pub resident_blocks: u64,
    /// Of those, bytes in the protected queue.
    pub protected_bytes: u64,
    /// Of those, blocks in the protected queue.
    pub protected_blocks: u64,
    /// Encoded bytes currently T2-resident (charged to the T2 budget).
    pub t2_resident_bytes: u64,
    /// Entries currently T2-resident.
    pub t2_resident_blocks: u64,
    /// Decoded bytes held live by outstanding `Arc`s right now —
    /// resident blocks plus evicted-but-still-held ones. Residency is
    /// what the budget bounds; `claimed_bytes` is what actually sits in
    /// RAM and may transiently exceed the budget while queries hold
    /// evicted blocks.
    pub claimed_bytes: u64,
}

impl CacheStats {
    /// Fraction of resolved claims that never touched storage: T1 hits
    /// plus T2 decode-saves over those plus storage misses (coalesced
    /// waits count toward neither side: they are claims whose resolution
    /// someone else paid for).
    pub fn hit_rate(&self) -> f64 {
        let served = self.hits + self.decode_saves;
        let denom = served + self.misses;
        if denom == 0 {
            0.0
        } else {
            served as f64 / denom as f64
        }
    }
}

/// Per-dataset slice of the cache counters (see
/// [`BlockCache::dataset_stats`]) — what the budget planner's
/// traffic weighting and the `serve` CLI's per-dataset breakdown read.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DatasetStats {
    /// T1 hits against this dataset's blocks.
    pub hits: u64,
    /// T2 revivals of this dataset's blocks.
    pub decode_saves: u64,
    /// Storage misses for this dataset's blocks.
    pub misses: u64,
    /// This dataset's decoded bytes currently T1-resident.
    pub resident_bytes: u64,
    /// This dataset's encoded bytes currently T2-resident.
    pub t2_resident_bytes: u64,
}

impl DatasetStats {
    /// Storage-avoidance rate for this dataset (same definition as
    /// [`CacheStats::hit_rate`]).
    pub fn hit_rate(&self) -> f64 {
        let served = self.hits + self.decode_saves;
        let denom = served + self.misses;
        if denom == 0 {
            0.0
        } else {
            served as f64 / denom as f64
        }
    }
}

/// Default shard count (see [`BlockCache::with_budget`]).
const DEFAULT_SHARDS: usize = 16;

/// A concurrent, byte-budgeted, two-tier cache of ABHSF blocks (module
/// docs for the full contract).
#[derive(Debug)]
pub struct BlockCache {
    shards: Vec<Mutex<Shard>>,
    t1_shard_budget: u64,
    t2_shard_budget: u64,
    protected_shard_cap: u64,
    t1_budget: u64,
    t2_budget: u64,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    decode_saves: AtomicU64,
    decode_save_ps: AtomicU64,
    evictions: AtomicU64,
    demotions: AtomicU64,
    promotions: AtomicU64,
    t2_evictions: AtomicU64,
    coalesced_waits: AtomicU64,
    inserted_bytes: AtomicU64,
    claimed: Arc<AtomicU64>,
    costs: OnceLock<MeasuredCosts>,
    /// `(storage medium, canonical dataset dir)` → assigned dataset id.
    datasets: Mutex<HashMap<(usize, PathBuf), u64>>,
    obs: ObsCounters,
}

/// Global-registry handles for the claim-outcome counters, resolved once
/// at construction so the hot claim path never touches the registry lock.
#[derive(Debug)]
struct ObsCounters {
    hit_t1: Arc<Counter>,
    hit_t2: Arc<Counter>,
    miss: Arc<Counter>,
    inflight: Arc<Counter>,
}

impl ObsCounters {
    fn new() -> Self {
        let reg = crate::obs::metrics::global();
        Self {
            hit_t1: reg.counter("cache.claim.hit_t1"),
            hit_t2: reg.counter("cache.claim.hit_t2"),
            miss: reg.counter("cache.claim.miss"),
            inflight: reg.counter("cache.claim.inflight"),
        }
    }
}

impl BlockCache {
    /// Single-tier cache (T2 disabled) with the given decoded-byte
    /// budget and [`DEFAULT_SHARDS`] shards.
    pub fn with_budget(budget_bytes: u64) -> Self {
        Self::with_tiered_budget_sharded(budget_bytes, 0, DEFAULT_SHARDS)
    }

    /// Single-tier cache with an explicit shard count (tests use 1 shard
    /// to make recency order globally observable). The budget is split
    /// evenly across shards.
    pub fn with_budget_sharded(budget_bytes: u64, shards: usize) -> Self {
        Self::with_tiered_budget_sharded(budget_bytes, 0, shards)
    }

    /// Two-tier cache: `t1_bytes` of decoded blocks plus `t2_bytes` of
    /// encoded blocks, [`DEFAULT_SHARDS`] shards.
    pub fn with_tiered_budget(t1_bytes: u64, t2_bytes: u64) -> Self {
        Self::with_tiered_budget_sharded(t1_bytes, t2_bytes, DEFAULT_SHARDS)
    }

    /// Two-tier cache with an explicit shard count. Both budgets are
    /// split evenly across shards (slab-style; a shard over its slice
    /// evicts even if the global total is under budget).
    pub fn with_tiered_budget_sharded(t1_bytes: u64, t2_bytes: u64, shards: usize) -> Self {
        let shards = shards.max(1);
        let t1_shard_budget = t1_bytes / shards as u64;
        Self {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            t1_shard_budget,
            t2_shard_budget: t2_bytes / shards as u64,
            protected_shard_cap: t1_shard_budget / PROTECTED_DEN * PROTECTED_NUM,
            t1_budget: t1_bytes,
            t2_budget: t2_bytes,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            decode_saves: AtomicU64::new(0),
            decode_save_ps: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            demotions: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            t2_evictions: AtomicU64::new(0),
            coalesced_waits: AtomicU64::new(0),
            inserted_bytes: AtomicU64::new(0),
            claimed: Arc::new(AtomicU64::new(0)),
            costs: OnceLock::new(),
            datasets: Mutex::new(HashMap::new()),
            obs: ObsCounters::new(),
        }
    }

    /// The configured total budget (T1 + T2 bytes).
    pub fn budget_bytes(&self) -> u64 {
        self.t1_budget + self.t2_budget
    }

    /// The configured T1 (decoded) budget.
    pub fn t1_budget_bytes(&self) -> u64 {
        self.t1_budget
    }

    /// The configured T2 (encoded) budget; 0 = single-tier.
    pub fn t2_budget_bytes(&self) -> u64 {
        self.t2_budget
    }

    /// Load a measured kernel-cost table (`BENCH_kernels.json`) so every
    /// T2 revival accumulates its modeled decode cost into
    /// [`CacheStats::decode_save_ps`]. First call wins; later calls are
    /// ignored (the table is calibration data, not runtime state).
    pub fn set_measured_costs(&self, costs: MeasuredCosts) {
        let _ = self.costs.set(costs);
    }

    /// Apply a [`BudgetPlan`]'s per-dataset T1 partitioning as the
    /// eviction preference: each shard remembers every dataset's planned
    /// share of its slice, and a shard over-share dataset's blocks are
    /// preferred victims (bounded-lookahead scan; see module docs). The
    /// per-tier *totals* stay whatever this cache was constructed with —
    /// the plan informs who gets evicted first, it does not resize the
    /// tiers.
    pub fn apply_plan(&self, plan: &BudgetPlan) {
        let shards = self.shards.len() as u64;
        for shard in &self.shards {
            let mut s = shard.lock().expect("cache shard poisoned");
            s.t1_share = plan
                .datasets
                .iter()
                .map(|d| (d.id, d.t1_bytes / shards))
                .collect();
        }
    }

    /// Stable id for the dataset at `canonical_dir` on storage medium
    /// `medium`: the same `(medium, dir)` always maps to the same id
    /// within this cache, so independent readers of one dataset share
    /// blocks while distinct datasets never collide.
    pub fn dataset_id(&self, medium: usize, canonical_dir: &Path) -> u64 {
        let mut map = self.datasets.lock().expect("dataset map poisoned");
        let next = map.len() as u64;
        *map.entry((medium, canonical_dir.to_path_buf())).or_insert(next)
    }

    fn shard_of(&self, key: &BlockKey) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Claim `key`: a hit, a loader token, or a waiter (see [`Claim`]).
    ///
    /// A T1 hit refreshes recency and — on a probationary block —
    /// promotes it to the protected queue (the 2Q "second touch").
    /// An absent key consults T2 in the same shard under the same lock:
    /// a hit there removes the encoded entry (tiers are exclusive) and
    /// hands it to the loader via [`LoadToken::take_encoded`].
    ///
    /// Every claim emits a `cache_claim` trace point tagged with its
    /// outcome (`hit_t1` / `hit_t2` / `miss` / `inflight`) and bumps the
    /// matching `cache.claim.*` registry counter — both outside the
    /// shard lock (DESIGN.md §14).
    pub fn claim(&self, key: BlockKey) -> Claim<'_> {
        let claim = self.claim_inner(key);
        let (outcome, counter) = match &claim {
            Claim::Hit(_) => ("hit_t1", &self.obs.hit_t1),
            Claim::InFlight(_) => ("inflight", &self.obs.inflight),
            Claim::Miss(token) if token.encoded.is_some() => ("hit_t2", &self.obs.hit_t2),
            Claim::Miss(_) => ("miss", &self.obs.miss),
        };
        counter.inc();
        trace::point("cache_claim", &[("outcome", Tag::S(outcome))]);
        claim
    }

    fn claim_inner(&self, key: BlockKey) -> Claim<'_> {
        let mut shard = self.shards[self.shard_of(&key)]
            .lock()
            .expect("cache shard poisoned");
        match shard.slots.get(&key) {
            Some(Slot::Resident {
                block,
                tick,
                protected,
            }) => {
                let block = Arc::clone(block);
                let old_tick = *tick;
                let was_protected = *protected;
                let new_tick = self.next_tick();
                // Update the slot *before* any queue surgery: if the
                // promotion below overflows the protected cap and
                // `shrink_protected` demotes this very block straight
                // back (bytes > cap), the demotion must be the last
                // writer of the slot's tick/flag or the indexes and the
                // slot disagree.
                if let Some(Slot::Resident {
                    tick, protected, ..
                }) = shard.slots.get_mut(&key)
                {
                    *tick = new_tick;
                    *protected = true;
                }
                if was_protected {
                    shard.protected.remove(&old_tick);
                    shard.protected.insert(new_tick, key);
                } else {
                    // Second touch: promote out of probation.
                    let bytes = block.bytes;
                    shard.probation.remove(&old_tick);
                    shard.probation_bytes -= bytes;
                    shard.protected.insert(new_tick, key);
                    shard.protected_bytes += bytes;
                    self.promotions.fetch_add(1, Ordering::Relaxed);
                    self.shrink_protected(&mut shard);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                shard.note_traffic(key.dataset, |t| t.hits += 1);
                Claim::Hit(block)
            }
            Some(Slot::InFlight(flight)) => {
                let flight = Arc::clone(flight);
                self.coalesced_waits.fetch_add(1, Ordering::Relaxed);
                Claim::InFlight(FlightWaiter { flight })
            }
            None => {
                let flight = Arc::new(Flight::new());
                shard.slots.insert(key, Slot::InFlight(Arc::clone(&flight)));
                let encoded = shard.t2.remove(&key).map(|entry| {
                    shard.t2_lru.remove(&entry.tick);
                    let charge = t2_charge(&entry.enc);
                    shard.t2_bytes -= charge;
                    if let Some(b) = shard.t2_by_dataset.get_mut(&key.dataset) {
                        *b = b.saturating_sub(charge);
                    }
                    entry.enc
                });
                if let Some(enc) = &encoded {
                    self.decode_saves.fetch_add(1, Ordering::Relaxed);
                    shard.note_traffic(key.dataset, |t| t.decode_saves += 1);
                    if let Some(costs) = self.costs.get() {
                        let g = enc.geom();
                        self.decode_save_ps
                            .fetch_add(costs.cost_ps(enc.scheme(), g.s, g.zeta), Ordering::Relaxed);
                    }
                } else {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    shard.note_traffic(key.dataset, |t| t.misses += 1);
                }
                Claim::Miss(LoadToken {
                    cache: self,
                    key,
                    flight,
                    encoded,
                    resolved: false,
                })
            }
        }
    }

    /// Demote protected-LRU blocks back to probation until the protected
    /// queue fits its cap — the SLRU pressure valve that keeps the
    /// protected set from starving admissions.
    fn shrink_protected(&self, shard: &mut Shard) {
        while shard.protected_bytes > self.protected_shard_cap {
            let Some((&oldest, &key)) = shard.protected.iter().next() else {
                break;
            };
            shard.protected.remove(&oldest);
            let new_tick = self.next_tick();
            shard.probation.insert(new_tick, key);
            if let Some(Slot::Resident {
                block,
                tick,
                protected,
            }) = shard.slots.get_mut(&key)
            {
                let bytes = block.bytes;
                *tick = new_tick;
                *protected = false;
                shard.protected_bytes -= bytes;
                shard.probation_bytes += bytes;
            }
        }
    }

    /// Pick the next T1 victim: probation before protected; within the
    /// queue, prefer (within [`EVICT_LOOKAHEAD`]) a block from a dataset
    /// over its planned shard share, falling back to the absolute
    /// oldest. Returns `(tick, key, from_protected)`.
    fn pick_victim(shard: &Shard) -> Option<(u64, BlockKey, bool)> {
        let from_protected = shard.probation.is_empty();
        let queue = if from_protected {
            &shard.protected
        } else {
            &shard.probation
        };
        if !shard.t1_share.is_empty() {
            for (&tick, &key) in queue.iter().take(EVICT_LOOKAHEAD) {
                let used = shard.t1_by_dataset.get(&key.dataset).copied().unwrap_or(0);
                // A dataset absent from the plan has no planned share:
                // any residency is over-share.
                let over = match shard.t1_share.get(&key.dataset) {
                    Some(&share) => used > share,
                    None => true,
                };
                if over {
                    return Some((tick, key, from_protected));
                }
            }
        }
        queue
            .iter()
            .next()
            .map(|(&tick, &key)| (tick, key, from_protected))
    }

    fn publish_inner(
        &self,
        key: BlockKey,
        flight: &Arc<Flight>,
        block: DecodedBlock,
    ) -> Arc<CachedBlock> {
        let bytes = block.decoded_bytes();
        let _span = trace::span("cache_publish", &[("bytes", Tag::U(bytes))]);
        self.claimed.fetch_add(bytes, Ordering::Relaxed);
        let block = Arc::new(CachedBlock {
            block,
            bytes,
            claimed: Arc::clone(&self.claimed),
        });
        {
            let mut shard = self.shards[self.shard_of(&key)]
                .lock()
                .expect("cache shard poisoned");
            // The slot is still this flight's (in-flight slots are never
            // evicted and only its loader resolves it). New admissions
            // enter probation — including T2 revivals, so a sweep that
            // cycles through T2 still cannot reach the protected queue.
            let tick = self.next_tick();
            shard.slots.insert(
                key,
                Slot::Resident {
                    block: Arc::clone(&block),
                    tick,
                    protected: false,
                },
            );
            shard.probation.insert(tick, key);
            shard.probation_bytes += bytes;
            *shard.t1_by_dataset.entry(key.dataset).or_insert(0) += bytes;
            self.inserted_bytes.fetch_add(bytes, Ordering::Relaxed);
            while shard.t1_bytes() > self.t1_shard_budget {
                let Some((tick, victim, from_protected)) = Self::pick_victim(&shard) else {
                    break;
                };
                if from_protected {
                    shard.protected.remove(&tick);
                } else {
                    shard.probation.remove(&tick);
                }
                if let Some(Slot::Resident { block: b, .. }) = shard.slots.remove(&victim) {
                    let vbytes = b.bytes;
                    if from_protected {
                        shard.protected_bytes -= vbytes;
                    } else {
                        shard.probation_bytes -= vbytes;
                    }
                    if let Some(d) = shard.t1_by_dataset.get_mut(&victim.dataset) {
                        *d = d.saturating_sub(vbytes);
                    }
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                    self.demote(&mut shard, victim, &b);
                }
            }
        }
        // Wake waiters outside the shard lock.
        flight.resolve(Ok(Arc::clone(&block)));
        block
    }

    /// Capture a T1 eviction victim into T2 (re-encode; skip when T2 is
    /// disabled or the entry alone exceeds the shard's T2 slice), then
    /// shed T2-LRU entries until T2 fits its slice.
    fn demote(&self, shard: &mut Shard, key: BlockKey, block: &CachedBlock) {
        if self.t2_shard_budget == 0 {
            return;
        }
        let enc = block.block().encode();
        let charge = t2_charge(&enc);
        if charge > self.t2_shard_budget {
            return;
        }
        let tick = self.next_tick();
        shard.t2.insert(key, T2Entry { enc, tick });
        shard.t2_lru.insert(tick, key);
        shard.t2_bytes += charge;
        *shard.t2_by_dataset.entry(key.dataset).or_insert(0) += charge;
        self.demotions.fetch_add(1, Ordering::Relaxed);
        while shard.t2_bytes > self.t2_shard_budget {
            let Some((&oldest, &victim)) = shard.t2_lru.iter().next() else {
                break;
            };
            shard.t2_lru.remove(&oldest);
            if let Some(entry) = shard.t2.remove(&victim) {
                let vcharge = t2_charge(&entry.enc);
                shard.t2_bytes -= vcharge;
                if let Some(d) = shard.t2_by_dataset.get_mut(&victim.dataset) {
                    *d = d.saturating_sub(vcharge);
                }
                self.t2_evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn fail_inner(&self, key: BlockKey, flight: &Arc<Flight>, error: String) {
        {
            let mut shard = self.shards[self.shard_of(&key)]
                .lock()
                .expect("cache shard poisoned");
            // Remove the slot only if it still belongs to this flight —
            // a racing retry may have claimed a fresh one.
            let same_flight = matches!(
                shard.slots.get(&key),
                Some(Slot::InFlight(f)) if Arc::ptr_eq(f, flight)
            );
            if same_flight {
                shard.slots.remove(&key);
            }
        }
        flight.resolve(Err(error));
    }

    /// Snapshot the counters and the current residency.
    pub fn stats(&self) -> CacheStats {
        let mut resident_bytes = 0u64;
        let mut resident_blocks = 0u64;
        let mut protected_bytes = 0u64;
        let mut protected_blocks = 0u64;
        let mut t2_resident_bytes = 0u64;
        let mut t2_resident_blocks = 0u64;
        for shard in &self.shards {
            let s = shard.lock().expect("cache shard poisoned");
            resident_bytes += s.t1_bytes();
            resident_blocks += (s.probation.len() + s.protected.len()) as u64;
            protected_bytes += s.protected_bytes;
            protected_blocks += s.protected.len() as u64;
            t2_resident_bytes += s.t2_bytes;
            t2_resident_blocks += s.t2.len() as u64;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            decode_saves: self.decode_saves.load(Ordering::Relaxed),
            decode_save_ps: self.decode_save_ps.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            demotions: self.demotions.load(Ordering::Relaxed),
            promotions: self.promotions.load(Ordering::Relaxed),
            t2_evictions: self.t2_evictions.load(Ordering::Relaxed),
            coalesced_waits: self.coalesced_waits.load(Ordering::Relaxed),
            inserted_bytes: self.inserted_bytes.load(Ordering::Relaxed),
            resident_bytes,
            resident_blocks,
            protected_bytes,
            protected_blocks,
            t2_resident_bytes,
            t2_resident_blocks,
            claimed_bytes: self.claimed.load(Ordering::Relaxed),
        }
    }

    /// This dataset's slice of the counters (see [`DatasetStats`]).
    pub fn dataset_stats(&self, dataset: u64) -> DatasetStats {
        let mut out = DatasetStats::default();
        for shard in &self.shards {
            let s = shard.lock().expect("cache shard poisoned");
            if let Some(t) = s.traffic.get(&dataset) {
                out.hits += t.hits;
                out.decode_saves += t.decode_saves;
                out.misses += t.misses;
            }
            out.resident_bytes += s.t1_by_dataset.get(&dataset).copied().unwrap_or(0);
            out.t2_resident_bytes += s.t2_by_dataset.get(&dataset).copied().unwrap_or(0);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: u32) -> BlockKey {
        BlockKey {
            dataset: 0,
            file: 0,
            brow: b,
            bcol: 0,
        }
    }

    /// A COO block with `n` diagonal elements (payload 12 B each).
    fn blk(n: usize) -> DecodedBlock {
        let idx: Vec<u16> = (0..n as u16).collect();
        DecodedBlock::coo(0, 0, 1 << 12, idx.clone(), idx, vec![1.0; n]).unwrap()
    }

    /// Publish `k` as a fresh miss (panics if it is not one).
    fn force_publish(cache: &BlockCache, k: BlockKey, n: usize) -> Arc<CachedBlock> {
        let Claim::Miss(tok) = cache.claim(k) else {
            panic!("claim of {k:?} must miss");
        };
        tok.publish(blk(n))
    }

    #[test]
    fn miss_then_hit() {
        let cache = BlockCache::with_budget(1 << 20);
        let Claim::Miss(tok) = cache.claim(key(1)) else {
            panic!("first claim must miss");
        };
        let block = tok.publish(blk(10));
        assert_eq!(block.zeta(), 10);
        let Claim::Hit(b) = cache.claim(key(1)) else {
            panic!("second claim must hit");
        };
        assert!(Arc::ptr_eq(&b, &block));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert_eq!(st.resident_blocks, 1);
        assert_eq!(st.resident_bytes, block.decoded_bytes());
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    /// Recency order under a budget: the least recently *used* (not
    /// inserted) block is evicted first — here the touched block is
    /// protected (second touch) and the untouched one is the probation
    /// victim.
    #[test]
    fn lru_eviction_under_budget() {
        let one = blk(10).decoded_bytes();
        // Room for exactly two blocks in a single shard.
        let cache = BlockCache::with_budget_sharded(2 * one, 1);
        for b in [1u32, 2] {
            force_publish(&cache, key(b), 10);
        }
        assert_eq!(cache.stats().evictions, 0);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(matches!(cache.claim(key(1)), Claim::Hit(_)));
        force_publish(&cache, key(3), 10);
        let st = cache.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.resident_blocks, 2);
        assert!(matches!(cache.claim(key(1)), Claim::Hit(_)), "1 was touched");
        assert!(matches!(cache.claim(key(3)), Claim::Hit(_)), "3 is fresh");
        assert!(matches!(cache.claim(key(2)), Claim::Miss(_)), "2 evicted");
    }

    /// A block bigger than the whole budget is still served (the Arc
    /// stays valid) but does not stay resident — and `claimed_bytes`
    /// keeps tracking it while the caller holds the Arc, dropping to the
    /// resident total once released.
    #[test]
    fn oversized_block_served_but_not_retained() {
        let cache = BlockCache::with_budget_sharded(64, 1);
        let Claim::Miss(tok) = cache.claim(key(1)) else {
            panic!("miss expected");
        };
        let block = tok.publish(blk(1000));
        assert_eq!(block.zeta(), 1000);
        let st = cache.stats();
        assert_eq!(st.resident_blocks, 0);
        assert_eq!(st.resident_bytes, 0);
        assert_eq!(st.evictions, 1);
        // Evicted from residency, still alive through our Arc.
        assert_eq!(st.claimed_bytes, block.decoded_bytes());
        let bytes = block.decoded_bytes();
        drop(block);
        let _ = bytes;
        assert_eq!(cache.stats().claimed_bytes, 0, "last Arc drop releases the claim");
        assert!(matches!(cache.claim(key(1)), Claim::Miss(_)));
    }

    /// Concurrent claims of one absent key: exactly one loader; everyone
    /// else coalesces onto its flight and sees the same block.
    #[test]
    fn single_flight_coalesces() {
        let cache = Arc::new(BlockCache::with_budget(1 << 20));
        let threads = 8;
        let barrier = Arc::new(std::sync::Barrier::new(threads));
        let mut handles = Vec::new();
        for _ in 0..threads {
            let cache = Arc::clone(&cache);
            let barrier = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                match cache.claim(key(7)) {
                    Claim::Hit(b) => b,
                    Claim::InFlight(w) => w.wait().unwrap(),
                    Claim::Miss(tok) => {
                        // Slow decode: give peers time to coalesce.
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        tok.publish(blk(5))
                    }
                }
            }));
        }
        let blocks: Vec<Arc<CachedBlock>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for b in &blocks {
            assert!(Arc::ptr_eq(b, &blocks[0]), "all threads share one decode");
        }
        let st = cache.stats();
        assert_eq!(st.misses, 1, "exactly one loader: {st:?}");
        assert_eq!(
            st.hits + st.coalesced_waits,
            threads as u64 - 1,
            "everyone else hit or coalesced: {st:?}"
        );
    }

    /// A dropped (unresolved) loader fails its waiters instead of
    /// hanging them, and a retry claims a fresh miss.
    #[test]
    fn dropped_loader_fails_waiters() {
        let cache = BlockCache::with_budget(1 << 20);
        let waiter = {
            let Claim::Miss(tok) = cache.claim(key(9)) else {
                panic!("miss expected");
            };
            let Claim::InFlight(w) = cache.claim(key(9)) else {
                panic!("in-flight expected");
            };
            drop(tok);
            w
        };
        assert!(waiter.wait().is_err());
        assert!(matches!(cache.claim(key(9)), Claim::Miss(_)), "retry is a fresh miss");
    }

    /// An explicit `fail` behaves like a drop, with the caller's error.
    #[test]
    fn failed_loader_reports_error() {
        let cache = BlockCache::with_budget(1 << 20);
        let Claim::Miss(tok) = cache.claim(key(3)) else {
            panic!("miss expected");
        };
        let Claim::InFlight(w) = cache.claim(key(3)) else {
            panic!("in-flight expected");
        };
        tok.fail("decode exploded".into());
        assert_eq!(w.wait().unwrap_err(), "decode exploded");
    }

    #[test]
    fn dataset_ids_are_stable_and_distinct() {
        let cache = BlockCache::with_budget(1 << 20);
        let a = cache.dataset_id(0, Path::new("/data/a"));
        let b = cache.dataset_id(0, Path::new("/data/b"));
        let a2 = cache.dataset_id(0, Path::new("/data/a"));
        let a_other_medium = cache.dataset_id(1, Path::new("/data/a"));
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_ne!(a, a_other_medium);
    }

    /// The 2Q guarantee: blocks claimed exactly once never enter the
    /// protected queue, no matter how many stream past — and a
    /// twice-touched block survives an arbitrarily long single-touch
    /// stream because the stream fights only over probation.
    #[test]
    fn single_touch_blocks_never_enter_protected() {
        let one = blk(10).decoded_bytes();
        let cache = BlockCache::with_budget_sharded(8 * one, 1);
        // A long single-touch stream: everything lives and dies in
        // probation.
        for b in 0..100u32 {
            force_publish(&cache, key(b), 10);
        }
        let st = cache.stats();
        assert_eq!(st.promotions, 0, "single-touch must not promote: {st:?}");
        assert_eq!(st.protected_blocks, 0, "protected queue must stay empty: {st:?}");
        assert!(st.evictions > 0, "the stream must have churned probation");
        // Second touch on a still-resident block promotes it.
        let resident = (0..100u32)
            .rev()
            .find(|&b| matches!(cache.claim(key(b)), Claim::Hit(_)))
            .expect("some stream block is still probation-resident");
        let st = cache.stats();
        assert_eq!(st.promotions, 1);
        assert_eq!(st.protected_blocks, 1);
        // Another long single-touch stream cannot displace it.
        for b in 1000..1100u32 {
            force_publish(&cache, key(b), 10);
        }
        assert!(
            matches!(cache.claim(key(resident)), Claim::Hit(_)),
            "protected block must survive the sweep"
        );
        let st = cache.stats();
        assert_eq!(st.protected_blocks, 1, "sweep must not grow protected: {st:?}");
    }

    /// Two-tier round trip: a block evicted from T1 is demoted into T2;
    /// the next claim is a loader *carrying the encoded payload* (a
    /// decode-save, not a storage miss), and publishing its decode makes
    /// the block T1-resident again.
    #[test]
    fn demoted_block_revives_from_t2_without_storage() {
        let one = blk(10).decoded_bytes();
        let cache = BlockCache::with_tiered_budget_sharded(2 * one, 1 << 16, 1);
        for b in [1u32, 2, 3] {
            force_publish(&cache, key(b), 10);
        }
        let st = cache.stats();
        assert_eq!(st.evictions, 1, "{st:?}");
        assert_eq!(st.demotions, 1, "the eviction must demote into T2: {st:?}");
        assert_eq!(st.t2_resident_blocks, 1);
        assert!(st.t2_resident_bytes > 0);
        assert_eq!(st.misses, 3, "all three first claims were storage misses");
        // Block 1 was the probation-LRU victim. Claim it back: a miss in
        // shape (the caller must decode+publish) but T2-fed in substance.
        let Claim::Miss(mut tok) = cache.claim(key(1)) else {
            panic!("revival claim must be a loader");
        };
        let enc = tok.take_encoded().expect("loader must carry the T2 payload");
        let decoded = enc.decode().unwrap();
        assert_eq!(decoded, blk(10), "T2 revival must reproduce the block exactly");
        let block = tok.publish(decoded);
        assert_eq!(block.zeta(), 10);
        let st = cache.stats();
        assert_eq!(st.decode_saves, 1, "{st:?}");
        assert_eq!(st.misses, 3, "a T2 revival is not a storage miss: {st:?}");
        assert_eq!(st.t2_resident_blocks, 0, "tiers are exclusive: {st:?}");
        assert!(matches!(cache.claim(key(1)), Claim::Hit(_)), "revived block is T1-resident");
    }

    /// With a measured kernel table loaded, every T2 revival accumulates
    /// its modeled decode cost.
    #[test]
    fn decode_saves_are_priced_from_measured_costs() {
        use crate::abhsf::cost::{MeasuredCosts, MeasuredEntry};
        use crate::abhsf::Scheme;
        let entries = Scheme::ALL
            .iter()
            .map(|&scheme| MeasuredEntry {
                s: 1 << 12,
                scheme,
                base_ps: 1000,
                per_elem_ps: 10,
            })
            .collect();
        let costs = MeasuredCosts::new(entries).unwrap();
        let one = blk(10).decoded_bytes();
        let cache = BlockCache::with_tiered_budget_sharded(2 * one, 1 << 16, 1);
        cache.set_measured_costs(costs);
        for b in [1u32, 2, 3] {
            force_publish(&cache, key(b), 10);
        }
        let Claim::Miss(mut tok) = cache.claim(key(1)) else {
            panic!("revival claim must be a loader");
        };
        let enc = tok.take_encoded().unwrap();
        tok.publish(enc.decode().unwrap());
        let st = cache.stats();
        assert_eq!(st.decode_saves, 1);
        assert_eq!(st.decode_save_ps, 1000 + 10 * 10, "base + per_elem * zeta");
    }

    /// T2 disabled (every single-tier constructor): evictions never
    /// demote and revivals never happen — the old single-tier contract
    /// is a strict special case.
    #[test]
    fn zero_t2_budget_never_demotes() {
        let one = blk(10).decoded_bytes();
        let cache = BlockCache::with_budget_sharded(2 * one, 1);
        for b in [1u32, 2, 3] {
            force_publish(&cache, key(b), 10);
        }
        let st = cache.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.demotions, 0);
        assert_eq!(st.t2_resident_blocks, 0);
        let Claim::Miss(mut tok) = cache.claim(key(1)) else {
            panic!("re-claim of the evicted block must miss");
        };
        assert!(tok.take_encoded().is_none(), "no T2, no carried payload");
        tok.fail("not loading".into());
    }

    /// `claimed_bytes` counts live Arcs, `resident_bytes` counts budget
    /// charges; they diverge exactly while evicted blocks are still
    /// held.
    #[test]
    fn claimed_bytes_tracks_live_arcs() {
        let one = blk(10).decoded_bytes();
        let cache = BlockCache::with_budget_sharded(2 * one, 1);
        let b1 = force_publish(&cache, key(1), 10);
        let b2 = force_publish(&cache, key(2), 10);
        let st = cache.stats();
        assert_eq!(st.resident_bytes, 2 * one);
        assert_eq!(st.claimed_bytes, 2 * one);
        // Evict 1 and 2 by streaming two more blocks past the budget
        // while still holding their Arcs.
        let _b3 = force_publish(&cache, key(3), 10);
        let _b4 = force_publish(&cache, key(4), 10);
        let st = cache.stats();
        assert_eq!(st.resident_bytes, 2 * one, "budget still bounds residency");
        assert_eq!(
            st.claimed_bytes,
            4 * one,
            "evicted-but-held blocks stay claimed: {st:?}"
        );
        drop(b1);
        drop(b2);
        let st = cache.stats();
        assert_eq!(
            st.claimed_bytes, st.resident_bytes,
            "after release only cache-held Arcs remain: {st:?}"
        );
    }

    /// Per-dataset counters split cleanly and the plan's eviction
    /// preference targets the over-share dataset.
    #[test]
    fn dataset_stats_split_and_plan_prefers_over_share_victims() {
        let one = blk(10).decoded_bytes();
        let cache = BlockCache::with_budget_sharded(4 * one, 1);
        let k = |ds: u64, b: u32| BlockKey {
            dataset: ds,
            file: 0,
            brow: b,
            bcol: 0,
        };
        // Dataset 0 gets three resident blocks, dataset 1 gets one.
        for b in 0..3u32 {
            let Claim::Miss(tok) = cache.claim(k(0, b)) else {
                panic!()
            };
            tok.publish(blk(10));
        }
        let Claim::Miss(tok) = cache.claim(k(1, 0)) else {
            panic!()
        };
        tok.publish(blk(10));
        assert!(matches!(cache.claim(k(1, 0)), Claim::Hit(_)));
        let d0 = cache.dataset_stats(0);
        let d1 = cache.dataset_stats(1);
        assert_eq!((d0.hits, d0.misses), (0, 3));
        assert_eq!((d1.hits, d1.misses), (1, 1));
        assert_eq!(d0.resident_bytes, 3 * one);
        assert_eq!(d1.resident_bytes, one);
        // Plan: dataset 0 deserves one block's worth, dataset 1 the
        // rest. Dataset 0 is over-share, so the next eviction must take
        // dataset 0's oldest block even though dataset 1's block 0 was
        // published earlier than dataset 0's block 2... (it was touched,
        // but more to the point: victims come from dataset 0).
        let plan = BudgetPlan {
            total_bytes: 4 * one,
            datasets: vec![
                DatasetBudget {
                    id: 0,
                    label: "a".into(),
                    t1_bytes: one,
                    t2_bytes: 0,
                },
                DatasetBudget {
                    id: 1,
                    label: "b".into(),
                    t1_bytes: 3 * one,
                    t2_bytes: 0,
                },
            ],
        };
        cache.apply_plan(&plan);
        // Push two more dataset-0 blocks: every eviction should hit
        // dataset 0 (over its 1-block share), leaving dataset 1 intact.
        for b in 3..5u32 {
            let Claim::Miss(tok) = cache.claim(k(0, b)) else {
                panic!()
            };
            tok.publish(blk(10));
        }
        assert!(
            matches!(cache.claim(k(1, 0)), Claim::Hit(_)),
            "under-share dataset must keep its block"
        );
        assert!(cache.dataset_stats(0).resident_bytes <= 3 * one);
    }
}
