//! Concurrent decoded-block cache — the resident working set behind the
//! serving layer (`crate::serve`).
//!
//! Every load path before this module was one-shot batch: each
//! [`LoadPlan`](crate::coordinator::LoadPlan) re-reads and re-decodes
//! every surviving ABHSF block, even when the same dataset is queried
//! repeatedly. A [`BlockCache`] keeps blocks resident in their
//! **scheme-native decoded form** ([`DecodedBlock`]) so repeated
//! queries against the same dataset never touch storage for blocks
//! already seen — and the per-scheme SpMV kernels
//! (`crate::spmv::kernels`) execute the cached payloads directly:
//!
//! * **Sharded**: keys hash to one of N shards, each behind its own
//!   mutex, so concurrent serving threads contend only when they touch
//!   the same slice of the key space.
//! * **Byte-budgeted LRU**: the cache holds at most a configured number
//!   of *decoded* bytes, accounted per scheme as the block's compact
//!   payload ([`DecodedBlock::payload_bytes`] — COO 12 B/nnz, CSR
//!   10 B/nnz + 4 B/rowptr, bitmap s²/8 bits + 8 B/nnz, dense 8 B/cell)
//!   plus a fixed per-block overhead. That is what the blocks actually
//!   cost in RAM now that nothing expands them to 24 B triplets, so a
//!   given budget holds strictly more blocks than the triplet cache
//!   did. The budget is partitioned evenly across shards
//!   (slab-style); a shard over its slice evicts its least-recently-used
//!   resident blocks even if the global total is under budget.
//! * **Single-flight**: concurrent requests for the same absent block
//!   decode it once. The first requester becomes the *loader* (its
//!   [`Claim::Miss`] carries a [`LoadToken`] it must resolve);
//!   latecomers receive a [`Claim::InFlight`] waiter parked on the
//!   in-flight slot until the loader publishes or fails.
//!
//! Eviction removes a block from the map only — `Arc` hand-outs keep
//! already-claimed blocks alive for their holders, so a query never
//! observes a block disappearing under it.
//!
//! See DESIGN.md §10 for the key/invariant contract.

use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Identity of one decoded block: which dataset, which stored file,
/// which cell of that file's block grid.
///
/// `dataset` comes from [`BlockCache::dataset_id`], which canonicalizes
/// `(storage medium, dataset directory)` — two readers over the same
/// stored dataset share ids (and therefore blocks), readers over
/// distinct datasets never collide. Block coordinates are file-local:
/// two files of one dataset cover disjoint submatrix windows, so
/// `(file, brow, bcol)` is unambiguous within a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockKey {
    /// Cache-assigned dataset id (see [`BlockCache::dataset_id`]).
    pub dataset: u64,
    /// Stored file index (`matrix-<file>.h5spm`).
    pub file: u32,
    /// Block row in the file's grid.
    pub brow: u32,
    /// Block column in the file's grid.
    pub bcol: u32,
}

/// Fixed per-block bookkeeping charge (map entry, Arc, payload Vec
/// headers) added to the scheme-native payload when accounting a block
/// against the budget — keeps a pathological all-tiny-blocks working
/// set from looking free.
pub const BLOCK_FIXED_BYTES: u64 = 96;

pub use crate::abhsf::load::{BlockGeom, DecodedBlock};

impl DecodedBlock {
    /// Bytes this block is charged against the cache budget: the
    /// scheme-native payload ([`payload_bytes`](Self::payload_bytes))
    /// plus [`BLOCK_FIXED_BYTES`]. This is the budget-accounting policy
    /// of the cache, so it lives here rather than with the decoder.
    pub fn decoded_bytes(&self) -> u64 {
        BLOCK_FIXED_BYTES + self.payload_bytes()
    }
}

/// Result of one in-flight decode, shared between the loader and any
/// coalesced waiters.
#[derive(Debug)]
enum FlightState {
    Pending,
    Done(Arc<DecodedBlock>),
    Failed(String),
}

#[derive(Debug)]
struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Self {
        Self {
            state: Mutex::new(FlightState::Pending),
            cv: Condvar::new(),
        }
    }

    fn resolve(&self, outcome: Result<Arc<DecodedBlock>, String>) {
        let mut st = self.state.lock().expect("flight poisoned");
        *st = match outcome {
            Ok(b) => FlightState::Done(b),
            Err(e) => FlightState::Failed(e),
        };
        self.cv.notify_all();
    }
}

/// One shard slot: a resident block or a decode in flight. In-flight
/// slots are never in the LRU index and are therefore never evicted —
/// eviction only forgets bytes that are actually resident.
#[derive(Debug)]
enum Slot {
    Resident { block: Arc<DecodedBlock>, tick: u64 },
    InFlight(Arc<Flight>),
}

#[derive(Debug, Default)]
struct Shard {
    slots: HashMap<BlockKey, Slot>,
    /// Recency index over resident slots: tick → key, oldest first.
    lru: BTreeMap<u64, BlockKey>,
    resident_bytes: u64,
}

/// Outcome of [`BlockCache::claim`].
pub enum Claim<'c> {
    /// The block is resident; use it.
    Hit(Arc<DecodedBlock>),
    /// The block is absent and the caller just became its loader: decode
    /// it and resolve the token with [`LoadToken::publish`] (or
    /// [`LoadToken::fail`]). Dropping the token unresolved fails the
    /// flight so coalesced waiters never hang.
    Miss(LoadToken<'c>),
    /// Another thread is already decoding this block; park on
    /// [`FlightWaiter::wait`] for its result.
    InFlight(FlightWaiter),
}

/// The loader side of a single-flight slot (see [`Claim::Miss`]).
pub struct LoadToken<'c> {
    cache: &'c BlockCache,
    key: BlockKey,
    flight: Arc<Flight>,
    resolved: bool,
}

impl LoadToken<'_> {
    /// The block this token is responsible for.
    pub fn key(&self) -> BlockKey {
        self.key
    }

    /// Install the decoded block, wake every coalesced waiter, and
    /// return the shared block. May immediately evict older blocks (or,
    /// if this block alone exceeds the shard budget, the block itself —
    /// the returned `Arc` stays valid either way).
    pub fn publish(mut self, block: DecodedBlock) -> Arc<DecodedBlock> {
        self.resolved = true;
        self.cache.publish_inner(self.key, &self.flight, block)
    }

    /// Abandon the flight with an error: the slot is removed (a retry
    /// will claim a fresh miss) and waiters receive the error.
    pub fn fail(mut self, error: String) {
        self.resolved = true;
        self.cache.fail_inner(self.key, &self.flight, error);
    }
}

impl Drop for LoadToken<'_> {
    fn drop(&mut self) {
        if !self.resolved {
            self.cache.fail_inner(
                self.key,
                &self.flight,
                "block loader dropped without publishing".to_string(),
            );
        }
    }
}

/// The waiter side of a single-flight slot (see [`Claim::InFlight`]).
pub struct FlightWaiter {
    flight: Arc<Flight>,
}

impl FlightWaiter {
    /// Block until the loader resolves the flight; returns its block or
    /// its error message.
    pub fn wait(&self) -> Result<Arc<DecodedBlock>, String> {
        let mut st = self.flight.state.lock().expect("flight poisoned");
        while matches!(*st, FlightState::Pending) {
            st = self.flight.cv.wait(st).expect("flight poisoned");
        }
        match &*st {
            FlightState::Done(b) => Ok(Arc::clone(b)),
            FlightState::Failed(e) => Err(e.clone()),
            FlightState::Pending => unreachable!("loop exits only when resolved"),
        }
    }
}

/// Monotonic counters of one cache, plus the current residency. All
/// counters are lifetime totals; snapshot via [`BlockCache::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Claims answered from a resident block.
    pub hits: u64,
    /// Claims that became loaders (each corresponds to one decode,
    /// successful or not).
    pub misses: u64,
    /// Resident blocks evicted under budget pressure.
    pub evictions: u64,
    /// Claims that found a decode already in flight and waited on it
    /// instead of decoding again.
    pub coalesced_waits: u64,
    /// Decoded bytes ever inserted (publishes).
    pub inserted_bytes: u64,
    /// Decoded bytes currently resident.
    pub resident_bytes: u64,
    /// Blocks currently resident.
    pub resident_blocks: u64,
}

impl CacheStats {
    /// Fraction of hit-or-miss claims answered from residency
    /// (coalesced waits count toward neither side: they are misses whose
    /// decode someone else paid for).
    pub fn hit_rate(&self) -> f64 {
        let denom = self.hits + self.misses;
        if denom == 0 {
            0.0
        } else {
            self.hits as f64 / denom as f64
        }
    }
}

/// Default shard count (see [`BlockCache::with_budget`]).
const DEFAULT_SHARDS: usize = 16;

/// A concurrent, byte-budgeted cache of decoded ABHSF blocks (module
/// docs for the full contract).
#[derive(Debug)]
pub struct BlockCache {
    shards: Vec<Mutex<Shard>>,
    shard_budget: u64,
    budget: u64,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    coalesced_waits: AtomicU64,
    inserted_bytes: AtomicU64,
    /// `(storage medium, canonical dataset dir)` → assigned dataset id.
    datasets: Mutex<HashMap<(usize, PathBuf), u64>>,
}

impl BlockCache {
    /// Cache with the given decoded-byte budget and [`DEFAULT_SHARDS`]
    /// shards.
    pub fn with_budget(budget_bytes: u64) -> Self {
        Self::with_budget_sharded(budget_bytes, DEFAULT_SHARDS)
    }

    /// Cache with an explicit shard count (tests use 1 shard to make LRU
    /// order globally observable). The budget is split evenly across
    /// shards.
    pub fn with_budget_sharded(budget_bytes: u64, shards: usize) -> Self {
        let shards = shards.max(1);
        Self {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: budget_bytes / shards as u64,
            budget: budget_bytes,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            coalesced_waits: AtomicU64::new(0),
            inserted_bytes: AtomicU64::new(0),
            datasets: Mutex::new(HashMap::new()),
        }
    }

    /// The configured decoded-byte budget.
    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    /// Stable id for the dataset at `canonical_dir` on storage medium
    /// `medium`: the same `(medium, dir)` always maps to the same id
    /// within this cache, so independent readers of one dataset share
    /// blocks while distinct datasets never collide.
    pub fn dataset_id(&self, medium: usize, canonical_dir: &Path) -> u64 {
        let mut map = self.datasets.lock().expect("dataset map poisoned");
        let next = map.len() as u64;
        *map.entry((medium, canonical_dir.to_path_buf())).or_insert(next)
    }

    fn shard_of(&self, key: &BlockKey) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Claim `key`: a hit, a loader token, or a waiter (see [`Claim`]).
    pub fn claim(&self, key: BlockKey) -> Claim<'_> {
        let mut shard = self.shards[self.shard_of(&key)]
            .lock()
            .expect("cache shard poisoned");
        match shard.slots.get(&key) {
            Some(Slot::Resident { block, tick }) => {
                let block = Arc::clone(block);
                let old_tick = *tick;
                let new_tick = self.next_tick();
                shard.lru.remove(&old_tick);
                shard.lru.insert(new_tick, key);
                if let Some(Slot::Resident { tick, .. }) = shard.slots.get_mut(&key) {
                    *tick = new_tick;
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Claim::Hit(block)
            }
            Some(Slot::InFlight(flight)) => {
                let flight = Arc::clone(flight);
                self.coalesced_waits.fetch_add(1, Ordering::Relaxed);
                Claim::InFlight(FlightWaiter { flight })
            }
            None => {
                let flight = Arc::new(Flight::new());
                shard.slots.insert(key, Slot::InFlight(Arc::clone(&flight)));
                self.misses.fetch_add(1, Ordering::Relaxed);
                Claim::Miss(LoadToken {
                    cache: self,
                    key,
                    flight,
                    resolved: false,
                })
            }
        }
    }

    fn publish_inner(
        &self,
        key: BlockKey,
        flight: &Arc<Flight>,
        block: DecodedBlock,
    ) -> Arc<DecodedBlock> {
        let block = Arc::new(block);
        let bytes = block.decoded_bytes();
        {
            let mut shard = self.shards[self.shard_of(&key)]
                .lock()
                .expect("cache shard poisoned");
            // The slot is still this flight's (in-flight slots are never
            // evicted and only its loader resolves it).
            let tick = self.next_tick();
            shard.slots.insert(
                key,
                Slot::Resident {
                    block: Arc::clone(&block),
                    tick,
                },
            );
            shard.lru.insert(tick, key);
            shard.resident_bytes += bytes;
            self.inserted_bytes.fetch_add(bytes, Ordering::Relaxed);
            while shard.resident_bytes > self.shard_budget {
                let Some((&oldest, &victim)) = shard.lru.iter().next() else {
                    break;
                };
                shard.lru.remove(&oldest);
                if let Some(Slot::Resident { block: b, .. }) = shard.slots.remove(&victim) {
                    shard.resident_bytes -= b.decoded_bytes();
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        // Wake waiters outside the shard lock.
        flight.resolve(Ok(Arc::clone(&block)));
        block
    }

    fn fail_inner(&self, key: BlockKey, flight: &Arc<Flight>, error: String) {
        {
            let mut shard = self.shards[self.shard_of(&key)]
                .lock()
                .expect("cache shard poisoned");
            // Remove the slot only if it still belongs to this flight —
            // a racing retry may have claimed a fresh one.
            let same_flight = matches!(
                shard.slots.get(&key),
                Some(Slot::InFlight(f)) if Arc::ptr_eq(f, flight)
            );
            if same_flight {
                shard.slots.remove(&key);
            }
        }
        flight.resolve(Err(error));
    }

    /// Snapshot the counters and the current residency.
    pub fn stats(&self) -> CacheStats {
        let mut resident_bytes = 0u64;
        let mut resident_blocks = 0u64;
        for shard in &self.shards {
            let s = shard.lock().expect("cache shard poisoned");
            resident_bytes += s.resident_bytes;
            resident_blocks += s.lru.len() as u64;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            coalesced_waits: self.coalesced_waits.load(Ordering::Relaxed),
            inserted_bytes: self.inserted_bytes.load(Ordering::Relaxed),
            resident_bytes,
            resident_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: u32) -> BlockKey {
        BlockKey {
            dataset: 0,
            file: 0,
            brow: b,
            bcol: 0,
        }
    }

    /// A COO block with `n` diagonal elements (payload 12 B each).
    fn blk(n: usize) -> DecodedBlock {
        let idx: Vec<u16> = (0..n as u16).collect();
        DecodedBlock::coo(0, 0, 1 << 12, idx.clone(), idx, vec![1.0; n]).unwrap()
    }

    #[test]
    fn miss_then_hit() {
        let cache = BlockCache::with_budget(1 << 20);
        let Claim::Miss(tok) = cache.claim(key(1)) else {
            panic!("first claim must miss");
        };
        let block = tok.publish(blk(10));
        assert_eq!(block.zeta(), 10);
        let Claim::Hit(b) = cache.claim(key(1)) else {
            panic!("second claim must hit");
        };
        assert!(Arc::ptr_eq(&b, &block));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert_eq!(st.resident_blocks, 1);
        assert_eq!(st.resident_bytes, block.decoded_bytes());
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    /// LRU order under a budget: the least recently *used* (not
    /// inserted) block is evicted first.
    #[test]
    fn lru_eviction_under_budget() {
        let one = blk(10).decoded_bytes();
        // Room for exactly two blocks in a single shard.
        let cache = BlockCache::with_budget_sharded(2 * one, 1);
        for b in [1u32, 2] {
            let Claim::Miss(tok) = cache.claim(key(b)) else {
                panic!("miss expected");
            };
            tok.publish(blk(10));
        }
        assert_eq!(cache.stats().evictions, 0);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(matches!(cache.claim(key(1)), Claim::Hit(_)));
        let Claim::Miss(tok) = cache.claim(key(3)) else {
            panic!("miss expected");
        };
        tok.publish(blk(10));
        let st = cache.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.resident_blocks, 2);
        assert!(matches!(cache.claim(key(1)), Claim::Hit(_)), "1 was touched");
        assert!(matches!(cache.claim(key(3)), Claim::Hit(_)), "3 is fresh");
        assert!(matches!(cache.claim(key(2)), Claim::Miss(_)), "2 evicted");
    }

    /// A block bigger than the whole budget is still served (the Arc
    /// stays valid) but does not stay resident.
    #[test]
    fn oversized_block_served_but_not_retained() {
        let cache = BlockCache::with_budget_sharded(64, 1);
        let Claim::Miss(tok) = cache.claim(key(1)) else {
            panic!("miss expected");
        };
        let block = tok.publish(blk(1000));
        assert_eq!(block.zeta(), 1000);
        let st = cache.stats();
        assert_eq!(st.resident_blocks, 0);
        assert_eq!(st.resident_bytes, 0);
        assert_eq!(st.evictions, 1);
        assert!(matches!(cache.claim(key(1)), Claim::Miss(_)));
    }

    /// Concurrent claims of one absent key: exactly one loader; everyone
    /// else coalesces onto its flight and sees the same block.
    #[test]
    fn single_flight_coalesces() {
        let cache = Arc::new(BlockCache::with_budget(1 << 20));
        let threads = 8;
        let barrier = Arc::new(std::sync::Barrier::new(threads));
        let mut handles = Vec::new();
        for _ in 0..threads {
            let cache = Arc::clone(&cache);
            let barrier = Arc::clone(&barrier);
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                match cache.claim(key(7)) {
                    Claim::Hit(b) => b,
                    Claim::InFlight(w) => w.wait().unwrap(),
                    Claim::Miss(tok) => {
                        // Slow decode: give peers time to coalesce.
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        tok.publish(blk(5))
                    }
                }
            }));
        }
        let blocks: Vec<Arc<DecodedBlock>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for b in &blocks {
            assert!(Arc::ptr_eq(b, &blocks[0]), "all threads share one decode");
        }
        let st = cache.stats();
        assert_eq!(st.misses, 1, "exactly one loader: {st:?}");
        assert_eq!(
            st.hits + st.coalesced_waits,
            threads as u64 - 1,
            "everyone else hit or coalesced: {st:?}"
        );
    }

    /// A dropped (unresolved) loader fails its waiters instead of
    /// hanging them, and a retry claims a fresh miss.
    #[test]
    fn dropped_loader_fails_waiters() {
        let cache = BlockCache::with_budget(1 << 20);
        let waiter = {
            let Claim::Miss(tok) = cache.claim(key(9)) else {
                panic!("miss expected");
            };
            let Claim::InFlight(w) = cache.claim(key(9)) else {
                panic!("in-flight expected");
            };
            drop(tok);
            w
        };
        assert!(waiter.wait().is_err());
        assert!(matches!(cache.claim(key(9)), Claim::Miss(_)), "retry is a fresh miss");
    }

    /// An explicit `fail` behaves like a drop, with the caller's error.
    #[test]
    fn failed_loader_reports_error() {
        let cache = BlockCache::with_budget(1 << 20);
        let Claim::Miss(tok) = cache.claim(key(3)) else {
            panic!("miss expected");
        };
        let Claim::InFlight(w) = cache.claim(key(3)) else {
            panic!("in-flight expected");
        };
        tok.fail("decode exploded".into());
        assert_eq!(w.wait().unwrap_err(), "decode exploded");
    }

    #[test]
    fn dataset_ids_are_stable_and_distinct() {
        let cache = BlockCache::with_budget(1 << 20);
        let a = cache.dataset_id(0, Path::new("/data/a"));
        let b = cache.dataset_id(0, Path::new("/data/b"));
        let a2 = cache.dataset_id(0, Path::new("/data/a"));
        let a_other_medium = cache.dataset_id(1, Path::new("/data/a"));
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_ne!(a, a_other_medium);
    }
}
