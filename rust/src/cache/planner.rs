//! Footprint-budgeted partitioning of the cache byte budget across
//! datasets and tiers.
//!
//! The store-time cost model knows, per block, exactly how many bytes
//! each scheme occupies on disk — and the decoded-block cache charges
//! the *same* scheme-native payload plus a fixed overhead
//! ([`BLOCK_FIXED_BYTES`] for T1, [`T2_FIXED_BYTES`] for T2). So a
//! dataset's full cache footprint is computable from its block
//! directories alone, **without fetching any payload**:
//! [`DatasetFootprint::measure`] walks the directories (already the
//! cheap part of opening a reader) and sums both tiers' worst-case
//! charges.
//!
//! [`BudgetPlanner`] turns those footprints plus per-dataset traffic
//! weights into a [`BudgetPlan`]: a weighted waterfill grants each
//! dataset its share of the total budget — capped at its footprint, so
//! a small hot dataset can never soak up bytes it cannot use, with the
//! overflow re-granted to the datasets that can — and then splits each
//! grant across tiers (T1 first up to `t1_fraction`, T2 next, spill
//! back to T1). With ample budget every dataset ends fully resident:
//! `t1 = decoded footprint`, `t2 = encoded footprint`.
//!
//! The plan is applied with [`BlockCache::apply_plan`]
//! (see the module docs): per-dataset shares steer *victim selection*,
//! they do not resize the tiers — partitioning is a soft preference,
//! not a hard reservation, so one idle dataset never pins budget that
//! a busy one could use.

use std::path::Path;

use crate::abhsf::load::BlockDirectory;
use crate::abhsf::matrix_file_path;
use crate::coordinator::error::DatasetError;
use crate::coordinator::Dataset;
use crate::h5::H5Reader;

use super::{BLOCK_FIXED_BYTES, T2_FIXED_BYTES};

#[allow(unused_imports)] // doc links
use super::BlockCache;

/// Worst-case cache charges of one dataset, per tier, measured from its
/// block directories (no payload fetched).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DatasetFootprint {
    /// Blocks across all stored files.
    pub blocks: u64,
    /// Bytes if every block were T1-resident (decoded):
    /// Σ ([`BLOCK_FIXED_BYTES`] + scheme-native payload).
    pub decoded_bytes: u64,
    /// Bytes if every block were T2-resident (encoded):
    /// Σ ([`T2_FIXED_BYTES`] + scheme-native payload).
    pub encoded_bytes: u64,
}

impl DatasetFootprint {
    /// Measure a stored dataset: open every file, parse its block
    /// directory, sum the per-block charges. Costs one directory read
    /// per file — the same work a [`DatasetReader`](crate::serve::DatasetReader)
    /// does at open — and no payload I/O.
    pub fn measure(dataset: &Dataset) -> Result<Self, DatasetError> {
        let storage = dataset.storage();
        let mut out = Self::default();
        for k in 0..dataset.nprocs() {
            let path = matrix_file_path(dataset.dir(), k);
            let reader = H5Reader::open_on(storage.as_ref(), &path)
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            let dir = BlockDirectory::read(&reader)
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            for i in 0..dir.entries.len() {
                let payload = dir.payload_bytes(i);
                out.blocks += 1;
                out.decoded_bytes += BLOCK_FIXED_BYTES + payload;
                out.encoded_bytes += T2_FIXED_BYTES + payload;
            }
        }
        Ok(out)
    }

    /// Bytes to hold every block in *some* tier at once — the waterfill
    /// cap: granting more than this to the dataset is waste.
    pub fn total_bytes(&self) -> u64 {
        self.decoded_bytes + self.encoded_bytes
    }
}

/// One dataset's granted slice of the budget (see [`BudgetPlan`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetBudget {
    /// Cache dataset id ([`BlockCache::dataset_id`]).
    pub id: u64,
    /// Human-readable label (the dataset directory, in the CLI).
    pub label: String,
    /// Planned T1 (decoded) bytes.
    pub t1_bytes: u64,
    /// Planned T2 (encoded) bytes.
    pub t2_bytes: u64,
}

/// A budget partitioning: per-dataset, per-tier byte grants summing to
/// at most the total (strictly less when the combined footprints fit —
/// the plan never grants bytes a dataset cannot use).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BudgetPlan {
    /// The budget the plan partitioned.
    pub total_bytes: u64,
    /// Per-dataset grants, in the order the planner saw the datasets.
    pub datasets: Vec<DatasetBudget>,
}

impl BudgetPlan {
    /// Planned T1 bytes across datasets.
    pub fn t1_total(&self) -> u64 {
        self.datasets.iter().map(|d| d.t1_bytes).sum()
    }

    /// Planned T2 bytes across datasets.
    pub fn t2_total(&self) -> u64 {
        self.datasets.iter().map(|d| d.t2_bytes).sum()
    }
}

/// Builder for a [`BudgetPlan`] (module docs for the algorithm).
#[derive(Debug, Clone)]
pub struct BudgetPlanner {
    total: u64,
    t1_fraction: f64,
    datasets: Vec<(u64, String, DatasetFootprint, f64)>,
}

impl BudgetPlanner {
    /// Start a plan over `total_bytes` of combined T1+T2 budget.
    pub fn new(total_bytes: u64) -> Self {
        Self {
            total: total_bytes,
            t1_fraction: 0.5,
            datasets: Vec::new(),
        }
    }

    /// Fraction of each dataset's grant offered to T1 first (clamped to
    /// `[0, 1]`; default 0.5). T1 is capped at the decoded footprint and
    /// T2 at the encoded one, with overflow spilling to the other tier,
    /// so the fraction only matters under scarcity.
    pub fn t1_fraction(mut self, f: f64) -> Self {
        self.t1_fraction = if f.is_finite() { f.clamp(0.0, 1.0) } else { 0.5 };
        self
    }

    /// Add a dataset: its cache id, display label, measured footprint,
    /// and traffic weight (relative — e.g. observed hits+misses from
    /// [`BlockCache::dataset_stats`], or 1.0 each when no traffic has
    /// been observed yet). Non-finite or negative weights count as 0.
    pub fn dataset(
        mut self,
        id: u64,
        label: impl Into<String>,
        footprint: DatasetFootprint,
        weight: f64,
    ) -> Self {
        let weight = if weight.is_finite() { weight.max(0.0) } else { 0.0 };
        self.datasets.push((id, label.into(), footprint, weight));
        self
    }

    /// Compute the plan: weighted waterfill with footprint caps, then a
    /// per-dataset tier split.
    pub fn plan(&self) -> BudgetPlan {
        let n = self.datasets.len();
        // All-zero weights (no traffic observed) degrade to uniform.
        let uniform = self.datasets.iter().all(|(_, _, _, w)| *w == 0.0);
        let weights: Vec<f64> = self
            .datasets
            .iter()
            .map(|(_, _, _, w)| if uniform { 1.0 } else { *w })
            .collect();
        let caps: Vec<f64> = self
            .datasets
            .iter()
            .map(|(_, _, fp, _)| fp.total_bytes() as f64)
            .collect();
        let mut grants = vec![0.0f64; n];
        let mut done = vec![false; n];
        let mut remaining = self.total as f64;
        // Waterfill: each round offers every still-open dataset its
        // weight-proportional share of what is left; datasets whose
        // share exceeds their footprint cap are clipped to it and
        // closed, and the next round re-offers the reclaimed bytes to
        // the rest. Terminates in ≤ n+1 rounds (every capping round
        // closes at least one dataset; a cap-free round closes all).
        for _ in 0..=n {
            let wsum: f64 = (0..n).filter(|&i| !done[i]).map(|i| weights[i]).sum();
            if wsum <= 0.0 || remaining <= 0.0 {
                break;
            }
            let offer = remaining;
            let mut capped_any = false;
            for i in 0..n {
                if done[i] {
                    continue;
                }
                let share = offer * weights[i] / wsum;
                if share >= caps[i] {
                    grants[i] = caps[i];
                    remaining -= caps[i];
                    done[i] = true;
                    capped_any = true;
                }
            }
            if !capped_any {
                for i in 0..n {
                    if done[i] {
                        continue;
                    }
                    let share = offer * weights[i] / wsum;
                    grants[i] = share;
                    remaining -= share;
                    done[i] = true;
                }
                break;
            }
        }
        let datasets = self
            .datasets
            .iter()
            .zip(&grants)
            .map(|((id, label, fp, _), &grant)| {
                let dec = fp.decoded_bytes as f64;
                let enc = fp.encoded_bytes as f64;
                // T1 takes its fraction up to the decoded footprint; T2
                // takes the remainder up to the encoded one; anything T2
                // cannot use spills back to T1.
                let mut t1 = (grant * self.t1_fraction).min(dec);
                let rem = grant - t1;
                let t2 = rem.min(enc);
                t1 = (t1 + (rem - t2)).min(dec);
                DatasetBudget {
                    id: *id,
                    label: label.clone(),
                    t1_bytes: t1 as u64,
                    t2_bytes: t2 as u64,
                }
            })
            .collect();
        BudgetPlan {
            total_bytes: self.total,
            datasets,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(blocks: u64, payload_per_block: u64) -> DatasetFootprint {
        DatasetFootprint {
            blocks,
            decoded_bytes: blocks * (BLOCK_FIXED_BYTES + payload_per_block),
            encoded_bytes: blocks * (T2_FIXED_BYTES + payload_per_block),
        }
    }

    /// Budget beyond the combined footprints: every dataset gets its
    /// full decoded footprint in T1 and full encoded footprint in T2 —
    /// nothing is granted that cannot be used.
    #[test]
    fn ample_budget_grants_full_footprints() {
        let a = fp(10, 120);
        let b = fp(4, 500);
        let plan = BudgetPlanner::new(1 << 30)
            .dataset(0, "a", a, 1.0)
            .dataset(1, "b", b, 7.0)
            .plan();
        assert_eq!(plan.datasets[0].t1_bytes, a.decoded_bytes);
        assert_eq!(plan.datasets[0].t2_bytes, a.encoded_bytes);
        assert_eq!(plan.datasets[1].t1_bytes, b.decoded_bytes);
        assert_eq!(plan.datasets[1].t2_bytes, b.encoded_bytes);
        assert!(plan.t1_total() + plan.t2_total() <= plan.total_bytes);
    }

    /// Scarce budget: grants follow the traffic weights and never exceed
    /// either the per-dataset footprint or the total.
    #[test]
    fn scarce_budget_follows_weights_within_caps() {
        let a = fp(100, 120);
        let b = fp(100, 120);
        let total = a.total_bytes() / 2; // room for ~a quarter of each
        let plan = BudgetPlanner::new(total)
            .dataset(0, "cold", a, 1.0)
            .dataset(1, "hot", b, 3.0)
            .plan();
        let ga = plan.datasets[0].t1_bytes + plan.datasets[0].t2_bytes;
        let gb = plan.datasets[1].t1_bytes + plan.datasets[1].t2_bytes;
        assert!(gb > ga * 2, "3:1 weights must skew the grants: {plan:?}");
        assert!(ga + gb <= total);
        for (d, f) in plan.datasets.iter().zip([a, b]) {
            assert!(d.t1_bytes <= f.decoded_bytes);
            assert!(d.t2_bytes <= f.encoded_bytes);
        }
    }

    /// A small hot dataset cannot soak up bytes beyond its footprint:
    /// the overflow waterfalls to the dataset that can use it.
    #[test]
    fn caps_redistribute_to_uncapped_datasets() {
        let small = fp(2, 120);
        let big = fp(1000, 120);
        let total = small.total_bytes() * 10;
        let plan = BudgetPlanner::new(total)
            .dataset(0, "small-hot", small, 100.0)
            .dataset(1, "big-cold", big, 1.0)
            .plan();
        let gs = plan.datasets[0].t1_bytes + plan.datasets[0].t2_bytes;
        let gb = plan.datasets[1].t1_bytes + plan.datasets[1].t2_bytes;
        assert_eq!(gs, small.total_bytes(), "hot dataset capped at its footprint");
        assert!(
            gb >= total - gs - 1,
            "everything past the cap flows to the big dataset: {plan:?}"
        );
    }

    /// No observed traffic (all weights zero) degrades to a uniform
    /// split rather than granting nothing.
    #[test]
    fn zero_weights_degrade_to_uniform() {
        let a = fp(100, 120);
        let total = a.total_bytes(); // half of the combined footprint
        let plan = BudgetPlanner::new(total)
            .dataset(0, "a", a, 0.0)
            .dataset(1, "b", a, 0.0)
            .plan();
        let ga = plan.datasets[0].t1_bytes + plan.datasets[0].t2_bytes;
        let gb = plan.datasets[1].t1_bytes + plan.datasets[1].t2_bytes;
        assert!(ga > 0 && gb > 0);
        assert!((ga as i64 - gb as i64).unsigned_abs() <= 1, "{plan:?}");
    }

    /// The tier split honors `t1_fraction` under scarcity and spills
    /// unusable T2 bytes back to T1.
    #[test]
    fn tier_split_honors_fraction_and_spills() {
        let a = fp(100, 120);
        let total = a.decoded_bytes / 2;
        // Pure T1 preference: everything lands in T1.
        let plan = BudgetPlanner::new(total)
            .t1_fraction(1.0)
            .dataset(0, "a", a, 1.0)
            .plan();
        assert_eq!(plan.datasets[0].t1_bytes, total);
        assert_eq!(plan.datasets[0].t2_bytes, 0);
        // Even split under scarcity: half the grant per tier.
        let plan = BudgetPlanner::new(total)
            .t1_fraction(0.5)
            .dataset(0, "a", a, 1.0)
            .plan();
        let d = &plan.datasets[0];
        assert!(d.t1_bytes > 0 && d.t2_bytes > 0);
        assert!((d.t1_bytes as i64 - d.t2_bytes as i64).unsigned_abs() <= 1, "{plan:?}");
        // Zero T1 preference but a grant beyond the encoded footprint:
        // the surplus must spill back into T1, not vanish.
        let plan = BudgetPlanner::new(a.total_bytes())
            .t1_fraction(0.0)
            .dataset(0, "a", a, 1.0)
            .plan();
        let d = &plan.datasets[0];
        assert_eq!(d.t2_bytes, a.encoded_bytes);
        assert_eq!(d.t1_bytes, a.total_bytes() - a.encoded_bytes);
    }
}
