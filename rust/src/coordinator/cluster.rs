//! MPI-like leader/worker runtime on OS threads.
//!
//! A [`Cluster`] owns `P` persistent worker threads. The leader broadcasts
//! a job closure; every worker runs it against its private [`WorkerCtx`]
//! (rank, barrier, point-to-point channels) and sends one result back.
//! Workers keep no shared mutable state — all cross-rank communication
//! goes through the bounded element channels, which is what makes the
//! exchange loader's backpressure measurable.

use std::any::Any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;

/// A global element in transit between ranks: `(row, col, value)`.
pub type GlobalElement = (u64, u64, f64);

/// Message on the inter-worker element channels.
///
/// The first two variants carry the exchange *loader*'s traffic
/// (element batches and end-of-stream markers); the rest carry the
/// distributed SpMV engine's traffic ([`crate::dist`]): vector halo
/// segments, windowed partial results, the one-time data-window
/// announcement, and scalar reduction contributions. All of them obey
/// the same discipline — bounded channels, [`WorkerCtx::send_draining`]
/// under pressure — so the two protocols share one mesh.
pub enum Msg {
    /// A batch of elements routed to the receiving rank.
    Elements(Vec<GlobalElement>),
    /// Sender `rank` has finished producing for the receiver.
    Done(usize),
    /// A contiguous halo segment of the distributed vector `x` owned by
    /// rank `from`: global entries `start .. start + vals.len()`.
    XSegment {
        /// Owning (sending) rank.
        from: usize,
        /// Global index of `vals[0]`.
        start: u64,
        /// The segment payload.
        vals: Vec<f64>,
    },
    /// A window-complete partial of the distributed vector `y` computed
    /// by rank `from`, to be folded by the receiving owner in ascending
    /// `from` order (the fixed-order reduction that makes distributed
    /// SpMV bit-deterministic).
    YPartial {
        /// Computing (sending) rank.
        from: usize,
        /// Global index of `vals[0]`.
        start: u64,
        /// The partial payload (includes explicit zeros for empty rows).
        vals: Vec<f64>,
    },
    /// Rank `from`'s data windows, announced once when a distributed
    /// engine is built: half-open global `rows`/`cols` ranges its local
    /// matrix part touches. Every halo plan is derived symmetrically
    /// from these, so senders and receivers always agree.
    Window {
        /// Announcing rank.
        from: usize,
        /// Row window `[start, end)` of the local part.
        rows: (u64, u64),
        /// Column window `[start, end)` of the local part.
        cols: (u64, u64),
    },
    /// Rank `from`'s local contribution to a deterministic all-reduce:
    /// every rank folds all `P` values in ascending rank order, so the
    /// reduced scalar is bit-identical on every rank.
    Scalar {
        /// Contributing rank.
        from: usize,
        /// The local value.
        value: f64,
    },
}

type Job = Box<dyn FnOnce(&WorkerCtx) -> Box<dyn Any + Send> + Send>;

/// Per-worker context handed to every job.
pub struct WorkerCtx {
    /// This worker's rank `k ∈ [0, P)`.
    pub rank: usize,
    /// Worker count `P`.
    pub nprocs: usize,
    barrier: Arc<Barrier>,
    peer_senders: Vec<SyncSender<Msg>>,
    inbox: Mutex<Receiver<Msg>>,
    /// Nanoseconds this worker spent blocked on full peer channels
    /// (backpressure) during the current job.
    pub send_blocked_ns: AtomicU64,
}

impl WorkerCtx {
    /// Synchronize all workers (an MPI_Barrier equivalent).
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    /// Send a message to `dest`, blocking when the channel is full and
    /// accounting the blocked time (credit-based backpressure).
    pub fn send(&self, dest: usize, msg: Msg) {
        match self.peer_senders[dest].try_send(msg) {
            Ok(()) => {}
            Err(TrySendError::Full(m)) => {
                let t0 = std::time::Instant::now();
                // Fall back to a blocking send and record the wait.
                self.peer_senders[dest]
                    .send(m)
                    .unwrap_or_else(|_| panic!("worker {dest} channel closed"));
                self.send_blocked_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            Err(TrySendError::Disconnected(_)) => panic!("worker {dest} channel closed"),
        }
    }

    /// Receive the next message destined to this rank (blocking).
    pub fn recv(&self) -> Msg {
        self.inbox
            .lock()
            .expect("inbox poisoned")
            .recv()
            .expect("inbox closed")
    }

    /// Non-blocking send; on a full channel the message is handed back.
    pub fn try_send(&self, dest: usize, msg: Msg) -> std::result::Result<(), Msg> {
        match self.peer_senders[dest].try_send(msg) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(m)) => Err(m),
            Err(TrySendError::Disconnected(_)) => panic!("worker {dest} channel closed"),
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<Msg> {
        self.inbox.lock().expect("inbox poisoned").try_recv().ok()
    }

    /// Deadlock-free send for all-to-all exchanges: when `dest`'s inbox is
    /// full, drain our own inbox through `on_msg` instead of blocking (a
    /// cycle of ranks all blocked on full channels would otherwise
    /// deadlock at small capacities). Blocked-and-draining time is
    /// accounted as backpressure.
    pub fn send_draining<F: FnMut(Msg)>(&self, dest: usize, msg: Msg, mut on_msg: F) {
        let mut pending = msg;
        let mut t0: Option<std::time::Instant> = None;
        loop {
            match self.try_send(dest, pending) {
                Ok(()) => {
                    if let Some(t) = t0 {
                        self.send_blocked_ns
                            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    return;
                }
                Err(m) => {
                    t0.get_or_insert_with(std::time::Instant::now);
                    pending = m;
                    // Make progress on our own inbox, then retry.
                    let mut drained = false;
                    while let Some(incoming) = self.try_recv() {
                        on_msg(incoming);
                        drained = true;
                    }
                    if !drained {
                        std::thread::yield_now();
                    }
                }
            }
        }
    }
}

enum Command {
    Run(Job),
    Shutdown,
}

/// Fixed pool of `P` workers with private contexts.
pub struct Cluster {
    nprocs: usize,
    cmd_txs: Vec<Sender<Command>>,
    result_rx: Receiver<(usize, Box<dyn Any + Send>)>,
    handles: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn `P` workers. `channel_capacity` bounds each rank's inbox
    /// (messages, not elements) — the backpressure knob.
    pub fn new(nprocs: usize, channel_capacity: usize) -> Self {
        assert!(nprocs > 0, "cluster needs at least one worker");
        let barrier = Arc::new(Barrier::new(nprocs));
        // Build the P x P mesh: one bounded inbox per rank, senders cloned
        // to every rank.
        let mut inbox_txs = Vec::with_capacity(nprocs);
        let mut inbox_rxs = Vec::with_capacity(nprocs);
        for _ in 0..nprocs {
            let (tx, rx) = sync_channel::<Msg>(channel_capacity);
            inbox_txs.push(tx);
            inbox_rxs.push(rx);
        }
        let (result_tx, result_rx) = std::sync::mpsc::channel();

        let mut cmd_txs = Vec::with_capacity(nprocs);
        let mut handles = Vec::with_capacity(nprocs);
        for (rank, inbox) in inbox_rxs.into_iter().enumerate() {
            let (cmd_tx, cmd_rx) = std::sync::mpsc::channel::<Command>();
            cmd_txs.push(cmd_tx);
            let ctx = WorkerCtx {
                rank,
                nprocs,
                barrier: Arc::clone(&barrier),
                peer_senders: inbox_txs.clone(),
                inbox: Mutex::new(inbox),
                send_blocked_ns: AtomicU64::new(0),
            };
            let result_tx = result_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("abhsf-worker-{rank}"))
                    .spawn(move || {
                        let ctx = ctx;
                        while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Command::Run(job) => {
                                    let out = job(&ctx);
                                    if result_tx.send((ctx.rank, out)).is_err() {
                                        return;
                                    }
                                }
                                Command::Shutdown => return,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        Self {
            nprocs,
            cmd_txs,
            result_rx,
            handles,
        }
    }

    /// Worker count.
    pub fn nprocs(&self) -> usize {
        self.nprocs
    }

    /// Run `job` on every worker; returns the results indexed by rank.
    ///
    /// The closure receives the worker's context; its return value is sent
    /// back to the leader. Panics in workers propagate as a leader panic.
    pub fn run<R, F>(&self, job: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(&WorkerCtx) -> R + Send + Sync + 'static,
    {
        let job = Arc::new(job);
        for tx in &self.cmd_txs {
            let job = Arc::clone(&job);
            tx.send(Command::Run(Box::new(move |ctx| Box::new(job(ctx)))))
                .expect("worker command channel closed");
        }
        let mut slots: Vec<Option<R>> = (0..self.nprocs).map(|_| None).collect();
        for _ in 0..self.nprocs {
            let (rank, boxed) = self
                .result_rx
                .recv()
                .expect("a worker died (panicked) during the job");
            let value = boxed
                .downcast::<R>()
                .expect("worker returned unexpected type");
            slots[rank] = Some(*value);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Command::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_jobs_on_all_ranks() {
        let cluster = Cluster::new(4, 16);
        let out = cluster.run(|ctx| ctx.rank * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
        // Reusable for a second job.
        let out2 = cluster.run(|ctx| ctx.nprocs);
        assert_eq!(out2, vec![4; 4]);
    }

    #[test]
    fn barrier_synchronizes() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cluster = Cluster::new(4, 16);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let out = cluster.run(move |ctx| {
            c2.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
            // After the barrier every rank must observe all increments.
            c2.load(Ordering::SeqCst)
        });
        assert_eq!(out, vec![4; 4]);
    }

    #[test]
    fn point_to_point_exchange() {
        let cluster = Cluster::new(3, 8);
        // Every rank sends (rank -> dest) batches to all peers, then
        // receives Done markers from everyone.
        let out = cluster.run(|ctx| {
            for dest in 0..ctx.nprocs {
                ctx.send(
                    dest,
                    Msg::Elements(vec![(ctx.rank as u64, dest as u64, 1.0)]),
                );
                ctx.send(dest, Msg::Done(ctx.rank));
            }
            let mut got = Vec::new();
            let mut done = 0;
            while done < ctx.nprocs {
                match ctx.recv() {
                    Msg::Elements(batch) => got.extend(batch),
                    Msg::Done(_) => done += 1,
                    _ => unreachable!("loader test received a dist-engine message"),
                }
            }
            got.sort_by_key(|&(s, _, _)| s);
            got
        });
        for (rank, msgs) in out.iter().enumerate() {
            assert_eq!(msgs.len(), 3, "rank {rank}");
            for (s, d, _) in msgs {
                assert_eq!(*d as usize, rank);
                assert!((*s as usize) < 3);
            }
        }
    }

    /// The dist-engine message kinds survive a point-to-point hop with
    /// their payloads intact (shape only; the full halo protocol is
    /// exercised by `rust/tests/dist.rs`).
    #[test]
    fn dist_message_variants_roundtrip() {
        let cluster = Cluster::new(2, 4);
        let out = cluster.run(|ctx| {
            let peer = 1 - ctx.rank;
            ctx.send(
                peer,
                Msg::XSegment {
                    from: ctx.rank,
                    start: 3,
                    vals: vec![1.0, 2.0],
                },
            );
            ctx.send(
                peer,
                Msg::Window {
                    from: ctx.rank,
                    rows: (0, 4),
                    cols: (2, 6),
                },
            );
            ctx.send(
                peer,
                Msg::Scalar {
                    from: ctx.rank,
                    value: 0.5 + ctx.rank as f64,
                },
            );
            let mut seen = Vec::new();
            for _ in 0..3 {
                match ctx.recv() {
                    Msg::XSegment { from, start, vals } => {
                        assert_eq!(from, peer);
                        assert_eq!(start, 3);
                        assert_eq!(vals, vec![1.0, 2.0]);
                        seen.push("x");
                    }
                    Msg::Window { from, rows, cols } => {
                        assert_eq!(from, peer);
                        assert_eq!((rows, cols), ((0, 4), (2, 6)));
                        seen.push("w");
                    }
                    Msg::Scalar { from, value } => {
                        assert_eq!(from, peer);
                        assert_eq!(value, 0.5 + peer as f64);
                        seen.push("s");
                    }
                    _ => unreachable!("unexpected loader message"),
                }
            }
            seen.sort_unstable();
            seen
        });
        assert_eq!(out[0], vec!["s", "w", "x"]);
        assert_eq!(out[1], vec!["s", "w", "x"]);
    }

    #[test]
    fn backpressure_accounted_under_tiny_capacity() {
        let cluster = Cluster::new(2, 1);
        let out = cluster.run(|ctx| {
            if ctx.rank == 0 {
                // Flood rank 1 with more messages than its inbox holds;
                // rank 1 drains slowly.
                for i in 0..64u64 {
                    ctx.send(1, Msg::Elements(vec![(i, 0, 0.0)]));
                }
                ctx.send(1, Msg::Done(0));
                ctx.send_blocked_ns.load(Ordering::Relaxed)
            } else {
                let mut n = 0u64;
                loop {
                    match ctx.recv() {
                        Msg::Elements(_) => {
                            n += 1;
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Msg::Done(_) => break,
                        _ => unreachable!("loader test received a dist-engine message"),
                    }
                }
                n
            }
        });
        assert_eq!(out[1], 64);
        assert!(out[0] > 0, "sender never blocked despite capacity 1");
    }
}
