//! Self-describing datasets and load planning — the crate's public
//! store/load API.
//!
//! A stored ABHSF matrix directory is now a **dataset**: the per-process
//! `matrix-<k>.h5spm` containers plus a `dataset.json` manifest recording
//! the storing configuration (process count, mapping descriptor, global
//! dims/nnz, block size) and per-file byte/nonzero counts. Loading starts
//! from [`Dataset::open`], which *discovers* everything the old free
//! functions had to be told (`stored_files`, the old mapping, file
//! sizes), and goes through a [`LoadPlan`] builder:
//!
//! ```no_run
//! # use abhsf::coordinator::{Cluster, Dataset, InMemFormat, Strategy};
//! # fn demo() -> Result<(), abhsf::coordinator::DatasetError> {
//! let cluster = Cluster::new(4, 64);
//! let dataset = Dataset::open("matrix")?;
//! let (parts, report) = dataset
//!     .load()
//!     .nprocs(4)
//!     .format(InMemFormat::Csr)
//!     .strategy(Strategy::Auto)
//!     .run(&cluster)?;
//! # Ok(()) }
//! ```
//!
//! [`Strategy::Auto`] detects the same-configuration fast path (stored and
//! requested configurations provably equal — Algorithm 1 per rank on its
//! own file, the paper's headline result) and otherwise consults the
//! [`crate::parfs`] cost model over the manifest's file sizes to choose
//! between the all-read-all strategies (independent/collective, §4) and
//! the exchange loader (the paper's future-work direction). The decision
//! and the per-candidate predictions are recorded in
//! [`LoadReport::auto`](crate::coordinator::LoadReport).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::abhsf::matrix_file_path;
use crate::coordinator::cluster::Cluster;
use crate::coordinator::error::DatasetError;
use crate::coordinator::loader::{
    different_config_impl, exchange_impl, same_config_impl, DiffLoadOptions, LoadedMatrix,
};
use crate::coordinator::metrics::{AutoDecision, LoadReport, StoreReport};
use crate::coordinator::storer::{store_distributed_impl, store_parts_impl, StoreOptions};
use crate::coordinator::InMemFormat;
use crate::formats::Coo;
use crate::gen::KroneckerGen;
use crate::mapping::{MappingDesc, ProcessMapping};
use crate::parfs::{FsModel, IoStrategy, RankLoadProfile};
use crate::util::json::Json;
use crate::vfs::Storage;

/// Manifest file name inside a dataset directory.
pub const MANIFEST_FILE: &str = "dataset.json";

/// Current manifest format version.
const MANIFEST_VERSION: u64 = 1;

/// Loading strategy requested from a [`LoadPlan`]. `Auto` is the default:
/// same-config fast path when the configurations match, cost-model
/// selection among the rest otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Strategy {
    /// Pick automatically (fast path detection + cost model).
    #[default]
    Auto,
    /// All-read-all with independent I/O (paper §3, `H5FD_MPIO_INDEPENDENT`).
    Independent,
    /// All-read-all with collective I/O (paper §3, `H5FD_MPIO_COLLECTIVE`).
    Collective,
    /// Read each file once and route elements to their new owners
    /// (the paper's future-work extension).
    Exchange,
}

impl Strategy {
    /// Label for tables, reports and the CLI.
    pub fn label(self) -> &'static str {
        match self {
            Strategy::Auto => "auto",
            Strategy::Independent => "independent",
            Strategy::Collective => "collective",
            Strategy::Exchange => "exchange",
        }
    }
}

impl std::str::FromStr for Strategy {
    type Err = DatasetError;

    fn from_str(s: &str) -> Result<Self, DatasetError> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Strategy::Auto,
            "independent" => Strategy::Independent,
            "collective" => Strategy::Collective,
            "exchange" => Strategy::Exchange,
            _ => return Err(DatasetError::UnknownStrategy(s.to_string())),
        })
    }
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Per-file record in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoredFile {
    /// On-disk container size, bytes.
    pub bytes: u64,
    /// Nonzeros stored in this file.
    pub nnz: u64,
}

/// The dataset-level manifest: everything a loader needs to plan without
/// being told how the data was stored.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetManifest {
    /// Storing process count (= number of `matrix-<k>.h5spm` files).
    pub nprocs: usize,
    /// Descriptor of the storing mapping.
    pub mapping: MappingDesc,
    /// Global rows.
    pub m: u64,
    /// Global columns.
    pub n: u64,
    /// Global nonzeros.
    pub z: u64,
    /// ABHSF block size `s`.
    pub block_size: u64,
    /// Which cost table chose the per-block schemes: `"analytic"` (the
    /// byte-count model) or a measured-table label such as
    /// `"measured(s=8,16)"` (see
    /// [`CostModel::table_id`](crate::abhsf::CostModel::table_id)).
    pub cost_table: String,
    /// Per-file sizes and nonzero counts, indexed by rank.
    pub files: Vec<StoredFile>,
}

impl DatasetManifest {
    /// Total on-disk bytes across all stored files (the `unique_bytes` of
    /// the cost model: each distinct byte leaves the disks once).
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|f| f.bytes).sum()
    }

    fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("format".to_string(), Json::str("abhsf-dataset"));
        obj.insert("version".to_string(), Json::num(MANIFEST_VERSION));
        obj.insert("nprocs".to_string(), Json::num(self.nprocs as u64));
        obj.insert("mapping".to_string(), self.mapping.to_json());
        obj.insert("m".to_string(), Json::num(self.m));
        obj.insert("n".to_string(), Json::num(self.n));
        obj.insert("z".to_string(), Json::num(self.z));
        obj.insert("block_size".to_string(), Json::num(self.block_size));
        obj.insert("cost_table".to_string(), Json::str(self.cost_table.as_str()));
        obj.insert(
            "files".to_string(),
            Json::Arr(
                self.files
                    .iter()
                    .map(|f| {
                        let mut e = std::collections::BTreeMap::new();
                        e.insert("bytes".to_string(), Json::num(f.bytes));
                        e.insert("nnz".to_string(), Json::num(f.nnz));
                        Json::Obj(e)
                    })
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }

    fn from_json(v: &Json) -> Result<Self, String> {
        if v.get("format").and_then(Json::as_str) != Some("abhsf-dataset") {
            return Err("missing \"format\": \"abhsf-dataset\"".into());
        }
        let version = v
            .get("version")
            .and_then(Json::as_u64)
            .ok_or("missing version")?;
        if version > MANIFEST_VERSION {
            return Err(format!(
                "manifest version {version} is newer than supported {MANIFEST_VERSION}"
            ));
        }
        let num = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("missing numeric {key:?}"))
        };
        let mapping = MappingDesc::from_json(v.get("mapping").ok_or("missing mapping")?)?;
        let files: Vec<StoredFile> = v
            .get("files")
            .and_then(Json::as_arr)
            .ok_or("missing files")?
            .iter()
            .map(|e| -> Result<StoredFile, String> {
                Ok(StoredFile {
                    bytes: e
                        .get("bytes")
                        .and_then(Json::as_u64)
                        .ok_or("file entry missing bytes")?,
                    nnz: e
                        .get("nnz")
                        .and_then(Json::as_u64)
                        .ok_or("file entry missing nnz")?,
                })
            })
            .collect::<Result<_, _>>()?;
        let nprocs = num("nprocs")? as usize;
        if files.len() != nprocs {
            return Err(format!(
                "{} file entries but nprocs = {nprocs}",
                files.len()
            ));
        }
        if nprocs == 0 {
            return Err("nprocs = 0".into());
        }
        if mapping.nprocs() != nprocs {
            return Err(format!(
                "mapping descriptor declares {} processes but nprocs = {nprocs}",
                mapping.nprocs()
            ));
        }
        Ok(DatasetManifest {
            nprocs,
            mapping,
            m: num("m")?,
            n: num("n")?,
            z: num("z")?,
            block_size: num("block_size")?,
            // Absent in manifests written before calibration existed:
            // every such dataset used the analytic byte-count model.
            cost_table: v
                .get("cost_table")
                .and_then(Json::as_str)
                .unwrap_or("analytic")
                .to_string(),
            files,
        })
    }
}

/// A handle to a stored ABHSF dataset: directory + manifest + the
/// storage backend the directory lives on. Obtained from
/// [`Dataset::store`] / [`Dataset::store_parts`] (which write the
/// manifest) or [`Dataset::open`] (which reads or reconstructs it); the
/// `_on` variants of each take an explicit [`Storage`] backend, the plain
/// forms default to the local filesystem.
#[derive(Debug, Clone)]
pub struct Dataset {
    dir: PathBuf,
    manifest: DatasetManifest,
    storage: Arc<dyn Storage>,
}

impl Dataset {
    /// Store a generated matrix under `mapping` on the local filesystem
    /// and write the manifest; returns the dataset handle and the
    /// per-rank store report.
    pub fn store(
        cluster: &Cluster,
        gen: &Arc<KroneckerGen>,
        mapping: &Arc<dyn ProcessMapping>,
        dir: impl AsRef<Path>,
        opts: StoreOptions,
    ) -> Result<(Dataset, StoreReport), DatasetError> {
        Self::store_on(crate::vfs::local(), cluster, gen, mapping, dir, opts)
    }

    /// [`Dataset::store`] on an arbitrary storage backend.
    pub fn store_on(
        storage: Arc<dyn Storage>,
        cluster: &Cluster,
        gen: &Arc<KroneckerGen>,
        mapping: &Arc<dyn ProcessMapping>,
        dir: impl AsRef<Path>,
        opts: StoreOptions,
    ) -> Result<(Dataset, StoreReport), DatasetError> {
        let dir = dir.as_ref();
        let block_size = opts.block_size;
        let cost_table = opts.cost_model.table_id();
        let report = store_distributed_impl(cluster, &storage, gen, mapping, dir, opts)?;
        let dataset = Self::write_manifest(
            storage,
            dir,
            mapping.descriptor(),
            gen.dim(),
            gen.dim(),
            &report,
            block_size,
            cost_table,
        )?;
        Ok((dataset, report))
    }

    /// Store pre-built local parts (one COO per rank, partitioned by
    /// `mapping` — the caller guarantees the parts actually follow it)
    /// on the local filesystem and write the manifest.
    pub fn store_parts(
        cluster: &Cluster,
        parts: Vec<Coo>,
        mapping: &Arc<dyn ProcessMapping>,
        dir: impl AsRef<Path>,
        opts: StoreOptions,
    ) -> Result<(Dataset, StoreReport), DatasetError> {
        Self::store_parts_on(crate::vfs::local(), cluster, parts, mapping, dir, opts)
    }

    /// [`Dataset::store_parts`] on an arbitrary storage backend.
    pub fn store_parts_on(
        storage: Arc<dyn Storage>,
        cluster: &Cluster,
        parts: Vec<Coo>,
        mapping: &Arc<dyn ProcessMapping>,
        dir: impl AsRef<Path>,
        opts: StoreOptions,
    ) -> Result<(Dataset, StoreReport), DatasetError> {
        if cluster.nprocs() != mapping.nprocs() {
            return Err(DatasetError::ClusterMismatch {
                cluster: cluster.nprocs(),
                required: mapping.nprocs(),
                what: "the storage mapping",
            });
        }
        let dir = dir.as_ref();
        let (m, n) = parts
            .first()
            .map(|c| (c.info.m, c.info.n))
            .unwrap_or((0, 0));
        let block_size = opts.block_size;
        let cost_table = opts.cost_model.table_id();
        let report = store_parts_impl(cluster, &storage, parts, dir, opts)?;
        let dataset = Self::write_manifest(
            storage,
            dir,
            mapping.descriptor(),
            m,
            n,
            &report,
            block_size,
            cost_table,
        )?;
        Ok((dataset, report))
    }

    /// Scan the freshly written containers and persist the manifest.
    /// Shared by the store entry points above and the repack subsystem
    /// (which writes its containers rank-by-rank before describing them).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn write_manifest(
        storage: Arc<dyn Storage>,
        dir: &Path,
        mapping: MappingDesc,
        m: u64,
        n: u64,
        report: &StoreReport,
        block_size: u64,
        cost_table: String,
    ) -> Result<Dataset, DatasetError> {
        let nprocs = report.per_rank_nnz.len();
        let sizes = stored_file_sizes(storage.as_ref(), dir, nprocs)?;
        let files: Vec<StoredFile> = report
            .per_rank_nnz
            .iter()
            .zip(sizes)
            .map(|(&nnz, bytes)| StoredFile { bytes, nnz })
            .collect();
        let manifest = DatasetManifest {
            nprocs,
            mapping,
            m,
            n,
            z: report.total_nnz(),
            block_size,
            cost_table,
            files,
        };
        let text = format!("{}\n", manifest.to_json());
        storage.write_file(&dir.join(MANIFEST_FILE), text.as_bytes())?;
        Ok(Dataset {
            dir: dir.to_path_buf(),
            manifest,
            storage,
        })
    }

    /// Open a dataset directory on the local filesystem: parse
    /// `dataset.json`, or — for legacy directories written before the
    /// manifest existed — reconstruct what can be reconstructed by
    /// scanning `matrix-<k>.h5spm` headers (the mapping then stays
    /// opaque, disabling only the same-config fast-path *detection*, not
    /// any load path).
    pub fn open(dir: impl AsRef<Path>) -> Result<Dataset, DatasetError> {
        Self::open_on(crate::vfs::local(), dir)
    }

    /// [`Dataset::open`] on an arbitrary storage backend.
    pub fn open_on(
        storage: Arc<dyn Storage>,
        dir: impl AsRef<Path>,
    ) -> Result<Dataset, DatasetError> {
        let dir = dir.as_ref();
        let path = dir.join(MANIFEST_FILE);
        match storage.read_file(&path) {
            Ok(bytes) => {
                let text = String::from_utf8(bytes).map_err(|_| DatasetError::BadManifest {
                    path: path.clone(),
                    reason: "not UTF-8".into(),
                })?;
                let json = Json::parse(&text).map_err(|reason| DatasetError::BadManifest {
                    path: path.clone(),
                    reason,
                })?;
                let manifest = DatasetManifest::from_json(&json).map_err(|reason| {
                    DatasetError::BadManifest {
                        path: path.clone(),
                        reason,
                    }
                })?;
                Ok(Dataset {
                    dir: dir.to_path_buf(),
                    manifest,
                    storage,
                })
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                Self::open_legacy(storage, dir)
            }
            Err(e) => Err(DatasetError::BadManifest {
                path,
                reason: format!("unreadable: {e}"),
            }),
        }
    }

    fn open_legacy(storage: Arc<dyn Storage>, dir: &Path) -> Result<Dataset, DatasetError> {
        let mut files = Vec::new();
        let mut header = None;
        loop {
            let path = matrix_file_path(dir, files.len());
            let bytes = match storage.len(&path) {
                Ok(bytes) => bytes,
                // A gap in the matrix-<k> sequence ends the scan; any
                // other failure (e.g. EACCES) is an I/O problem on a file
                // that *exists* and must not masquerade as end-of-data.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => break,
                Err(source) => return Err(DatasetError::MissingFile { path, source }),
            };
            let reader = crate::h5::H5Reader::open_on(storage.as_ref(), &path)
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            let hdr = crate::abhsf::load::read_header(&reader)
                .map_err(|e| DatasetError::Internal(Box::new(e)))?;
            files.push(StoredFile {
                bytes,
                nnz: hdr.info.z_local,
            });
            header.get_or_insert(hdr);
        }
        let Some(hdr) = header else {
            return Err(DatasetError::NotADataset {
                dir: dir.to_path_buf(),
                reason: format!("no {MANIFEST_FILE} and no matrix-*.h5spm files"),
            });
        };
        // The scan stops at the first gap in the matrix-<k> sequence, so a
        // partially deleted directory would otherwise open as a smaller
        // "valid" dataset and silently load a subset of the matrix. The
        // headers expose the inconsistency for free: per-file local
        // nonzero counts must add up to the recorded global count.
        let local_sum: u64 = files.iter().map(|f| f.nnz).sum();
        if local_sum != hdr.info.z {
            return Err(DatasetError::NotADataset {
                dir: dir.to_path_buf(),
                reason: format!(
                    "incomplete legacy dataset: {} files hold {local_sum} nonzeros \
                     but headers record a global count of {}",
                    files.len(),
                    hdr.info.z
                ),
            });
        }
        let nprocs = files.len();
        Ok(Dataset {
            dir: dir.to_path_buf(),
            manifest: DatasetManifest {
                nprocs,
                mapping: MappingDesc::Opaque {
                    label: "legacy (stored without a manifest)".to_string(),
                    p: nprocs,
                },
                m: hdr.info.m,
                n: hdr.info.n,
                z: hdr.info.z,
                block_size: hdr.block_size,
                cost_table: "analytic".to_string(),
                files,
            },
            storage,
        })
    }

    /// Open a random-access cached reader over this dataset: queries
    /// (`rect` / `row_slice` / `nnz_in` / `spmv`) walk the per-file
    /// block directories, serve resident blocks from `cache` without
    /// touching storage, and fetch only the missing blocks through the
    /// read-ahead pipeline (see [`crate::serve::DatasetReader`]).
    ///
    /// Readers are per-thread; concurrent serving threads each open
    /// their own reader against the same shared cache.
    pub fn reader<'c>(
        &self,
        cache: &'c crate::cache::BlockCache,
    ) -> Result<crate::serve::DatasetReader<'c>, DatasetError> {
        crate::serve::DatasetReader::open(self, cache)
    }

    /// Begin planning a load of this dataset.
    pub fn load(&self) -> LoadPlan<'_> {
        LoadPlan {
            dataset: self,
            nprocs: None,
            mapping: None,
            format: InMemFormat::Csr,
            strategy: Strategy::Auto,
            model: FsModel::anselm_lustre(),
            prune: true,
            storage: Arc::clone(&self.storage),
        }
    }

    /// Dataset directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The storage backend this dataset lives on.
    pub fn storage(&self) -> &Arc<dyn Storage> {
        &self.storage
    }

    /// The manifest (discovered storing configuration).
    pub fn manifest(&self) -> &DatasetManifest {
        &self.manifest
    }

    /// Storing process count (= stored file count).
    pub fn nprocs(&self) -> usize {
        self.manifest.nprocs
    }

    /// Descriptor of the storing mapping.
    pub fn mapping(&self) -> &MappingDesc {
        &self.manifest.mapping
    }

    /// Global shape `(m, n)`.
    pub fn dims(&self) -> (u64, u64) {
        (self.manifest.m, self.manifest.n)
    }

    /// Global nonzero count.
    pub fn nnz(&self) -> u64 {
        self.manifest.z
    }

    /// ABHSF block size `s`.
    pub fn block_size(&self) -> u64 {
        self.manifest.block_size
    }

    /// Verify every stored file named by the manifest is present and
    /// readable (typed [`DatasetError::MissingFile`] otherwise).
    pub fn verify_files(&self) -> Result<(), DatasetError> {
        stored_file_sizes(self.storage.as_ref(), &self.dir, self.manifest.nprocs).map(|_| ())
    }

    /// Test-only constructor: a dataset handle over a synthetic manifest
    /// of `nprocs` identical files (no disk behind it) — lets cost-model
    /// tests in other modules price manifests without storing anything.
    #[cfg(test)]
    pub(crate) fn synthetic_for_tests(
        nprocs: usize,
        m: u64,
        n: u64,
        z: u64,
        block_size: u64,
        file_bytes: u64,
        file_nnz: u64,
    ) -> Dataset {
        Dataset {
            dir: PathBuf::from("/nonexistent"),
            manifest: DatasetManifest {
                nprocs,
                mapping: MappingDesc::Rowwise {
                    m,
                    n,
                    starts: crate::mapping::even_starts(m, nprocs),
                },
                m,
                n,
                z,
                block_size,
                cost_table: "analytic".to_string(),
                files: vec![
                    StoredFile {
                        bytes: file_bytes,
                        nnz: file_nnz,
                    };
                    nprocs
                ],
            },
            storage: crate::vfs::local(),
        }
    }

    /// Predicted makespan of the same-configuration fast path (rank `k`
    /// reads only `matrix-<k>.h5spm`), from the manifest's file sizes.
    pub fn predict_same_config(&self, model: &FsModel) -> f64 {
        let profiles: Vec<RankLoadProfile> = self
            .manifest
            .files
            .iter()
            .map(|f| RankLoadProfile {
                opens: 1,
                ops: ops_estimate(f.bytes),
                bytes: f.bytes,
            })
            .collect();
        model
            .simulate(&profiles, self.manifest.total_bytes(), IoStrategy::Independent)
            .makespan_s
    }

    /// Cost-model candidates for a different-configuration load with `p`
    /// processes, assuming unpruned all-read-all; see
    /// [`Dataset::predict_load`] for the pruning-aware form this
    /// delegates to.
    pub fn predict(&self, p: usize, model: &FsModel) -> Vec<(Strategy, f64)> {
        self.predict_load(p, model, None, false)
    }

    /// Cost-model candidates for a different-configuration load with `p`
    /// processes: strategy → predicted makespan. I/O footprints come from
    /// the manifest's per-file byte sizes; operation counts are estimated
    /// at container chunk granularity (~512 KiB per read op plus a fixed
    /// per-dataset floor), which is coarse but strategy selection only
    /// needs the §4 *orderings*, which are byte-volume driven.
    ///
    /// With `prune` and a target `mapping`, the all-read-all candidates
    /// shrink: rank `r` only fetches the fraction of each stored file
    /// whose window overlaps `mapping.rank_rect(r)` (area ratio — blocks
    /// follow the stored window's geometry closely enough for strategy
    /// *ordering* purposes). Irregular target mappings (no `rank_rect`)
    /// and opaque stored windows fall back conservatively: the missing
    /// rectangle is taken as the whole matrix. This is what moves the
    /// [`Strategy::Auto`] decision between all-read-all and exchange once
    /// pruning exists: pruned independent reads ~unique bytes in total
    /// instead of `P x unique`, without exchange's element routing.
    pub fn predict_load(
        &self,
        p: usize,
        model: &FsModel,
        mapping: Option<&dyn ProcessMapping>,
        prune: bool,
    ) -> Vec<(Strategy, f64)> {
        let ops_of = ops_estimate;
        let files = &self.manifest.files;
        let total_bytes = self.manifest.total_bytes();
        let unique = total_bytes;
        let mut out = Vec::new();

        let (m, n) = (self.manifest.m.max(1), self.manifest.n.max(1));
        let whole = (0u64, 0u64, m, n);
        // Fraction of stored file `k` that loading rank `r` must fetch.
        let overlap_frac = |k: usize, r: usize| -> f64 {
            if !prune {
                return 1.0;
            }
            let rect = mapping.and_then(|mp| mp.rank_rect(r)).unwrap_or(whole);
            let window = self.manifest.mapping.rank_rect(k).unwrap_or(whole);
            let (wr, wc, wm, wn) = window;
            if wm == 0 || wn == 0 {
                return 0.0;
            }
            let (rr, rc, rm, rn) = rect;
            let rows = (wr + wm).min(rr + rm).saturating_sub(wr.max(rr));
            let cols = (wc + wn).min(rc + rn).saturating_sub(wc.max(rc));
            (rows * cols) as f64 / (wm * wn) as f64
        };

        let all_read_all: Vec<RankLoadProfile> = (0..p)
            .map(|r| {
                let mut prof = RankLoadProfile {
                    opens: files.len() as u64,
                    ..RankLoadProfile::default()
                };
                for (k, f) in files.iter().enumerate() {
                    let bytes = (f.bytes as f64 * overlap_frac(k, r)) as u64;
                    prof.bytes += bytes;
                    prof.ops += ops_of(bytes);
                }
                prof
            })
            .collect();
        let indep = model
            .simulate(&all_read_all, unique, IoStrategy::Independent)
            .makespan_s;
        let coll = model
            .simulate(&all_read_all, unique, IoStrategy::Collective)
            .makespan_s;

        // Exchange: each file is read once (round-robin over loaders); the
        // decoded elements that change owners cross the fabric once more
        // as (i, j, v) triplets (24 bytes each).
        let exchange_profiles: Vec<RankLoadProfile> = (0..p)
            .map(|r| {
                let mut prof = RankLoadProfile::default();
                let mut k = r;
                while k < files.len() {
                    prof.opens += 1;
                    prof.ops += ops_of(files[k].bytes);
                    prof.bytes += files[k].bytes;
                    k += p;
                }
                prof
            })
            .collect();
        let moved_bytes = self.manifest.z as f64 * 24.0 * (p.saturating_sub(1) as f64 / p as f64);
        let exchange_extra = moved_bytes / model.net_agg_bps.min(model.client_bps * p as f64);
        let exch = model
            .simulate(&exchange_profiles, unique, IoStrategy::Independent)
            .makespan_s
            + exchange_extra;

        out.push((Strategy::Independent, indep));
        out.push((Strategy::Collective, coll));
        out.push((Strategy::Exchange, exch));
        out
    }
}

/// Read-operation estimate for one container: chunk-granular payload
/// reads plus a fixed floor for the directory and small datasets. Shared
/// with the repack forecast (`crate::repack`).
pub(crate) fn ops_estimate(bytes: u64) -> u64 {
    20 + bytes / (512 * 1024)
}

/// Stored sizes of `matrix-<k>.h5spm` for `k` in `0..count`, with a
/// typed [`DatasetError::MissingFile`] for any absent or unreadable
/// container. Shared by manifest writing and plan validation.
pub(crate) fn stored_file_sizes(
    storage: &dyn Storage,
    dir: &Path,
    count: usize,
) -> Result<Vec<u64>, DatasetError> {
    (0..count)
        .map(|k| {
            let path = matrix_file_path(dir, k);
            storage
                .len(&path)
                .map_err(|source| DatasetError::MissingFile { path, source })
        })
        .collect()
}

/// Builder for one load of a [`Dataset`]: requested process count,
/// target mapping, in-memory format and strategy, validated as a whole
/// by [`LoadPlan::run`].
#[derive(Clone)]
pub struct LoadPlan<'d> {
    dataset: &'d Dataset,
    nprocs: Option<usize>,
    mapping: Option<Arc<dyn ProcessMapping>>,
    format: InMemFormat,
    strategy: Strategy,
    model: FsModel,
    prune: bool,
    storage: Arc<dyn Storage>,
}

impl<'d> LoadPlan<'d> {
    /// Request a loading process count (defaults to the cluster's size
    /// at [`LoadPlan::run`]; stating it here adds an early consistency
    /// check against the cluster).
    pub fn nprocs(mut self, p: usize) -> Self {
        self.nprocs = Some(p);
        self
    }

    /// Target mapping `M(i, j)` for the loaded distribution. Optional
    /// when loading with the stored process count: the stored mapping is
    /// reused (the same-configuration case).
    pub fn mapping(mut self, mapping: &Arc<dyn ProcessMapping>) -> Self {
        self.mapping = Some(Arc::clone(mapping));
        self
    }

    /// Requested in-memory format (default CSR).
    pub fn format(mut self, format: InMemFormat) -> Self {
        self.format = format;
        self
    }

    /// Loading strategy (default [`Strategy::Auto`]).
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// File-system model used for `Auto` predictions (default: the
    /// paper-calibrated Anselm/Lustre constants).
    pub fn fs_model(mut self, model: FsModel) -> Self {
        self.model = model;
        self
    }

    /// Block-pruned different-configuration reading (default `true`):
    /// each rank consults the per-file block directories and fetches only
    /// blocks whose rectangle may intersect its mapping region. Exact for
    /// rectangular target mappings, a conservative no-op for irregular
    /// ones; `prune(false)` restores the paper's literal decode-everything
    /// §3 loop (useful for A/B measurements, see `benches/pruning.rs`).
    pub fn prune(mut self, prune: bool) -> Self {
        self.prune = prune;
        self
    }

    /// Storage backend to read through (default: the backend the dataset
    /// was opened on). Overriding is mainly useful to wrap the dataset's
    /// backend in a [`crate::vfs::SimFs`] for cost emulation or fault
    /// injection without reopening the dataset.
    pub fn storage(mut self, storage: Arc<dyn Storage>) -> Self {
        self.storage = storage;
        self
    }

    /// Validate the plan against the cluster and the manifest, pick the
    /// strategy (for [`Strategy::Auto`]), and execute the load.
    pub fn run(&self, cluster: &Cluster) -> Result<(Vec<LoadedMatrix>, LoadReport), DatasetError> {
        let p = self.nprocs.unwrap_or_else(|| cluster.nprocs());
        if cluster.nprocs() != p {
            return Err(DatasetError::ClusterMismatch {
                cluster: cluster.nprocs(),
                required: p,
                what: "the plan's loading process count",
            });
        }
        if let Some(mapping) = &self.mapping {
            if mapping.nprocs() != p {
                return Err(DatasetError::MappingMismatch {
                    mapping: mapping.nprocs(),
                    nprocs: p,
                });
            }
        }
        let stored = self.dataset.nprocs();
        // One metadata pass doubles as the missing-file check and the
        // load-time `unique_bytes` measurement (files may have changed
        // since the manifest was written; the backend is the truth here).
        let unique: u64 = stored_file_sizes(self.storage.as_ref(), &self.dataset.dir, stored)?
            .iter()
            .sum();
        // Same configuration ⇔ same process count and provably the same
        // mapping (no mapping requested means "as stored").
        let same_config = p == stored
            && match &self.mapping {
                None => true,
                Some(mapping) => mapping
                    .descriptor()
                    .same_mapping(self.dataset.mapping()),
            };

        match self.strategy {
            Strategy::Auto => {
                let predicted =
                    self.dataset
                        .predict_load(p, &self.model, self.mapping.as_deref(), self.prune);
                let mut labeled: Vec<(String, f64)> = Vec::with_capacity(predicted.len() + 1);
                if same_config {
                    labeled.push((
                        "same-config".to_string(),
                        self.dataset.predict_same_config(&self.model),
                    ));
                }
                labeled.extend(
                    predicted
                        .iter()
                        .map(|(s, t)| (s.label().to_string(), *t)),
                );
                let (mats, mut report, chosen_label) = if same_config {
                    // The fast path is both predicted-fastest and exact:
                    // prefer it unconditionally when eligible (paper §4).
                    let out = same_config_impl(
                        cluster,
                        &self.storage,
                        &self.dataset.dir,
                        self.format,
                        unique,
                    )?;
                    (out.0, out.1, "same-config".to_string())
                } else {
                    let (chosen, _) = predicted
                        .iter()
                        .copied()
                        .min_by(|a, b| a.1.total_cmp(&b.1))
                        .expect("at least one candidate");
                    let out = self.run_explicit(cluster, p, chosen, unique)?;
                    (out.0, out.1, chosen.label().to_string())
                };
                report.auto = Some(AutoDecision {
                    same_config,
                    predicted: labeled,
                    chosen: chosen_label,
                });
                Ok((mats, report))
            }
            explicit => self.run_explicit(cluster, p, explicit, unique),
        }
    }

    /// Execute a concrete (non-auto) strategy. `unique` is the freshly
    /// measured on-disk byte total from [`LoadPlan::run`].
    fn run_explicit(
        &self,
        cluster: &Cluster,
        p: usize,
        strategy: Strategy,
        unique: u64,
    ) -> Result<(Vec<LoadedMatrix>, LoadReport), DatasetError> {
        let mapping = self.resolve_mapping(p)?;
        let stored_files = self.dataset.nprocs();
        let out = match strategy {
            Strategy::Auto => unreachable!("Auto is resolved in run()"),
            Strategy::Independent | Strategy::Collective => different_config_impl(
                cluster,
                &self.storage,
                &self.dataset.dir,
                &mapping,
                &DiffLoadOptions {
                    stored_files,
                    strategy: if strategy == Strategy::Collective {
                        IoStrategy::Collective
                    } else {
                        IoStrategy::Independent
                    },
                    format: self.format,
                    prune: self.prune,
                },
                unique,
            )?,
            Strategy::Exchange => exchange_impl(
                cluster,
                &self.storage,
                &self.dataset.dir,
                &mapping,
                stored_files,
                self.format,
                unique,
                (self.dataset.manifest.m, self.dataset.manifest.n, self.dataset.manifest.z),
            )?,
        };
        Ok(out)
    }

    /// The target mapping: the explicit one, or the stored mapping
    /// rebuilt from its descriptor when loading with the stored process
    /// count.
    fn resolve_mapping(&self, p: usize) -> Result<Arc<dyn ProcessMapping>, DatasetError> {
        if let Some(mapping) = &self.mapping {
            return Ok(Arc::clone(mapping));
        }
        let stored = self.dataset.nprocs();
        if p != stored {
            return Err(DatasetError::MappingRequired { nprocs: p, stored });
        }
        self.dataset.mapping().build().ok_or_else(|| {
            DatasetError::MappingNotReconstructible {
                label: match self.dataset.mapping() {
                    MappingDesc::Opaque { label, .. } => label.clone(),
                    other => other.kind().to_string(),
                },
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parses_and_prints() {
        for (text, want) in [
            ("auto", Strategy::Auto),
            ("independent", Strategy::Independent),
            ("Collective", Strategy::Collective),
            (" exchange ", Strategy::Exchange),
        ] {
            assert_eq!(text.parse::<Strategy>().unwrap(), want, "{text}");
        }
        assert_eq!(Strategy::Exchange.to_string(), "exchange");
        assert!(matches!(
            "mpiio".parse::<Strategy>(),
            Err(DatasetError::UnknownStrategy(_))
        ));
        assert_eq!(Strategy::default(), Strategy::Auto);
    }

    fn sample_manifest() -> DatasetManifest {
        DatasetManifest {
            nprocs: 3,
            mapping: MappingDesc::Rowwise {
                m: 30,
                n: 30,
                starts: vec![0, 10, 20, 30],
            },
            m: 30,
            n: 30,
            z: 120,
            block_size: 8,
            cost_table: "analytic".to_string(),
            files: vec![
                StoredFile { bytes: 1000, nnz: 40 },
                StoredFile { bytes: 1200, nnz: 50 },
                StoredFile { bytes: 800, nnz: 30 },
            ],
        }
    }

    #[test]
    fn manifest_json_roundtrip() {
        let mut m = sample_manifest();
        m.cost_table = "measured(s=8,16)".to_string();
        let text = m.to_json().to_string();
        let back = DatasetManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.total_bytes(), 3000);
    }

    /// Manifests written before calibration existed carry no
    /// `cost_table`; they parse as `"analytic"`.
    #[test]
    fn manifest_without_cost_table_defaults_to_analytic() {
        let m = sample_manifest();
        let text = m
            .to_json()
            .to_string()
            .replace("\"cost_table\":\"analytic\",", "");
        let back = DatasetManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cost_table, "analytic");
        assert_eq!(back, m);
    }

    #[test]
    fn manifest_rejects_inconsistencies() {
        let m = sample_manifest();
        // files/nprocs disagreement.
        let mut bad = m.clone();
        bad.files.pop();
        let text = bad.to_json().to_string();
        assert!(DatasetManifest::from_json(&Json::parse(&text).unwrap()).is_err());
        // future version.
        let text = m
            .to_json()
            .to_string()
            .replace("\"version\":1", "\"version\":99");
        assert!(DatasetManifest::from_json(&Json::parse(&text).unwrap()).is_err());
        // wrong format tag.
        let text = m
            .to_json()
            .to_string()
            .replace("abhsf-dataset", "parquet");
        assert!(DatasetManifest::from_json(&Json::parse(&text).unwrap()).is_err());
        // mapping descriptor P disagrees with nprocs.
        let mut bad = m.clone();
        bad.mapping = MappingDesc::Rowwise {
            m: 30,
            n: 30,
            starts: vec![0, 15, 30],
        };
        let text = bad.to_json().to_string();
        assert!(DatasetManifest::from_json(&Json::parse(&text).unwrap()).is_err());
    }

    /// Pruned all-read-all predictions shrink with a rectangular target
    /// mapping — the input that can flip Auto between all-read-all and
    /// exchange — and degrade gracefully to the unpruned figures when
    /// the mapping offers no rectangles.
    #[test]
    fn predict_load_accounts_for_pruning() {
        let files: Vec<StoredFile> = (0..8)
            .map(|_| StoredFile {
                bytes: 1 << 30,
                nnz: 50_000_000,
            })
            .collect();
        let m = 1u64 << 20;
        let ds = Dataset {
            dir: PathBuf::from("/nonexistent"),
            manifest: DatasetManifest {
                nprocs: 8,
                mapping: MappingDesc::Rowwise {
                    m,
                    n: m,
                    starts: (0..=8).map(|k| k * (m / 8)).collect(),
                },
                m,
                n: m,
                z: 8 * 50_000_000,
                block_size: 64,
                cost_table: "analytic".to_string(),
                files,
            },
            storage: crate::vfs::local(),
        };
        let model = FsModel::anselm_lustre();
        let p = 16;
        let unpruned = ds.predict(p, &model);
        let colwise: crate::mapping::Colwise = crate::mapping::Colwise::regular(m, m, p);
        let pruned = ds.predict_load(p, &model, Some(&colwise), true);
        let find = |v: &[(Strategy, f64)], s: Strategy| {
            v.iter().find(|(c, _)| *c == s).map(|(_, t)| *t).unwrap()
        };
        // Pruning strictly cheapens the all-read-all candidates...
        assert!(find(&pruned, Strategy::Independent) < find(&unpruned, Strategy::Independent));
        assert!(find(&pruned, Strategy::Collective) < find(&unpruned, Strategy::Collective));
        // ...and leaves exchange alone (it already reads each byte once).
        let e0 = find(&unpruned, Strategy::Exchange);
        let e1 = find(&pruned, Strategy::Exchange);
        assert!((e0 - e1).abs() < 1e-12);
        // Unpruned, Auto preferred exchange; pruned all-read-all reads
        // ~the same unique bytes without routing, so the decision flips.
        assert!(e0 < find(&unpruned, Strategy::Independent));
        assert!(find(&pruned, Strategy::Independent) < e1);
        // Irregular target mapping: conservative fallback = unpruned.
        let cyclic = crate::mapping::CyclicRows { m, n: m, p };
        let fallback = ds.predict_load(p, &model, Some(&cyclic), true);
        for &(s, t) in &fallback {
            assert!(
                (t - find(&unpruned, s)).abs() < 1e-9,
                "{s:?} fallback diverged"
            );
        }
    }

    #[test]
    fn predictions_follow_paper_orderings() {
        // Figure-1 scale: 60 stored files of 4 GiB each.
        let files: Vec<StoredFile> = (0..60)
            .map(|_| StoredFile {
                bytes: 4 << 30,
                nnz: 200_000_000,
            })
            .collect();
        let ds = Dataset {
            dir: PathBuf::from("/nonexistent"),
            manifest: DatasetManifest {
                nprocs: 60,
                mapping: MappingDesc::Rowwise {
                    m: 1 << 22,
                    n: 1 << 22,
                    starts: (0..=60).map(|k| k * ((1u64 << 22) / 60)).collect(),
                },
                m: 1 << 22,
                n: 1 << 22,
                z: 60 * 200_000_000,
                block_size: 64,
                cost_table: "analytic".to_string(),
                files,
            },
            storage: crate::vfs::local(),
        };
        let model = FsModel::anselm_lustre();
        let t_same = ds.predict_same_config(&model);
        for p in [15usize, 30, 60] {
            let diff = ds.predict(p, &model);
            let find = |s: Strategy| {
                diff.iter()
                    .find(|(c, _)| *c == s)
                    .map(|(_, t)| *t)
                    .unwrap()
            };
            let (ti, tc) = (find(Strategy::Independent), find(Strategy::Collective));
            assert!(t_same < ti, "P={p}: same {t_same} !< indep {ti}");
            assert!(ti < tc, "P={p}: indep {ti} !< coll {tc}");
            // Exchange reads each byte once: cheaper I/O than all-read-all.
            let te = find(Strategy::Exchange);
            assert!(te < ti, "P={p}: exchange {te} !< indep {ti}");
        }
    }
}
