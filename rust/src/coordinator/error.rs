//! Typed errors for the [`crate::coordinator::Dataset`] /
//! [`crate::coordinator::LoadPlan`] API.
//!
//! These replace the `assert_eq!` panics and stringly `anyhow!` errors of
//! the original free-function API: misconfigurations (wrong cluster size,
//! mismatched mapping, missing files) are recoverable caller mistakes and
//! surface as matchable variants instead of aborting worker threads.

use std::path::PathBuf;

/// Errors raised by dataset storing, opening, planning and loading.
#[derive(Debug, thiserror::Error)]
pub enum DatasetError {
    /// The directory holds neither a `dataset.json` manifest nor any
    /// `matrix-<k>.h5spm` files.
    #[error("no ABHSF dataset in {dir}: {reason}")]
    NotADataset {
        /// Directory probed.
        dir: PathBuf,
        /// What was missing.
        reason: String,
    },

    /// `dataset.json` exists but cannot be read, parsed, or is
    /// self-inconsistent.
    #[error("corrupt dataset manifest {path}: {reason}")]
    BadManifest {
        /// Manifest path.
        path: PathBuf,
        /// Parse/validation failure.
        reason: String,
    },

    /// A stored file named by the manifest is missing or unreadable.
    /// (Previously this was silently treated as a zero-byte file when
    /// accounting `unique_bytes`.)
    #[error("stored file {path} is missing or unreadable: {source}")]
    MissingFile {
        /// The absent file.
        path: PathBuf,
        /// Underlying filesystem error.
        #[source]
        source: std::io::Error,
    },

    /// The cluster passed to `LoadPlan::run` / `Dataset::store` has a
    /// different worker count than the plan requires.
    #[error("cluster has {cluster} workers but {what} requires {required}")]
    ClusterMismatch {
        /// Workers in the supplied cluster.
        cluster: usize,
        /// Workers the plan/mapping requires.
        required: usize,
        /// Which constraint mismatched (for the message).
        what: &'static str,
    },

    /// The supplied mapping's process count disagrees with the plan's.
    #[error("mapping declares {mapping} processes but the plan loads with {nprocs}")]
    MappingMismatch {
        /// `mapping.nprocs()`.
        mapping: usize,
        /// The plan's loading process count.
        nprocs: usize,
    },

    /// Loading with a different process count than stored requires an
    /// explicit target mapping.
    #[error(
        "loading with {nprocs} processes differs from the stored {stored} \
         and no target mapping was given; supply LoadPlan::mapping(...)"
    )]
    MappingRequired {
        /// Requested loading process count.
        nprocs: usize,
        /// Stored process count.
        stored: usize,
    },

    /// The stored mapping descriptor is opaque, so the requested implicit
    /// reconstruction is impossible.
    #[error(
        "stored mapping {label:?} cannot be reconstructed from the manifest; \
         supply LoadPlan::mapping(...)"
    )]
    MappingNotReconstructible {
        /// The stored mapping's label.
        label: String,
    },

    /// `store_parts` was given a different number of parts than workers.
    #[error("{parts} parts supplied for {cluster} workers (need exactly one each)")]
    PartsMismatch {
        /// Parts supplied.
        parts: usize,
        /// Cluster workers.
        cluster: usize,
    },

    /// A repack was asked to write into the directory it reads from —
    /// source containers would be clobbered mid-stream.
    #[error(
        "repack destination {dir} is the source dataset directory; \
         choose a different output directory"
    )]
    RepackIntoSource {
        /// The offending directory.
        dir: PathBuf,
    },

    /// A repack requested an ABHSF block size outside the format's range
    /// (in-block indexes are u16, so `1 ≤ s ≤ 65536`).
    #[error("block size {0} out of range (expected 1..=65536)")]
    InvalidBlockSize(u64),

    /// A repack requested a container chunk size of zero elements.
    #[error("container chunk size must be positive (got 0 elements)")]
    InvalidChunkSize,

    /// Unparsable strategy name (CLI / `FromStr`).
    #[error("unknown strategy {0:?} (expected auto|independent|collective|exchange)")]
    UnknownStrategy(String),

    /// Unparsable in-memory format name (CLI / `FromStr`).
    #[error("unknown in-memory format {0:?} (expected csr|coo)")]
    UnknownFormat(String),

    /// Failure inside the parallel store/load machinery (container I/O,
    /// decode errors, worker failures).
    #[error("load/store failed: {0}")]
    Internal(#[source] Box<dyn std::error::Error + Send + Sync>),
}

impl From<anyhow::Error> for DatasetError {
    fn from(e: anyhow::Error) -> Self {
        // Keep already-typed dataset errors intact when they bubble back
        // out of anyhow-typed internals.
        match e.downcast::<DatasetError>() {
            Ok(d) => d,
            Err(e) => DatasetError::Internal(e.into()),
        }
    }
}

impl From<std::io::Error> for DatasetError {
    fn from(e: std::io::Error) -> Self {
        DatasetError::Internal(Box::new(e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anyhow_roundtrip_preserves_typed_variants() {
        let typed = DatasetError::MissingFile {
            path: PathBuf::from("/x/matrix-3.h5spm"),
            source: std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        };
        let through: anyhow::Error = typed.into();
        let back: DatasetError = through.into();
        assert!(matches!(back, DatasetError::MissingFile { .. }), "{back}");
    }

    #[test]
    fn messages_name_the_ingredients() {
        let e = DatasetError::ClusterMismatch {
            cluster: 3,
            required: 5,
            what: "the stored configuration",
        };
        let msg = format!("{e}");
        assert!(msg.contains('3') && msg.contains('5'), "{msg}");

        let e = DatasetError::MappingRequired {
            nprocs: 7,
            stored: 4,
        };
        assert!(format!("{e}").contains("LoadPlan::mapping"));
    }
}
