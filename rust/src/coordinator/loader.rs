//! Parallel loading orchestration — the paper's §3 in executable form.
//!
//! Three scenarios, all reached through
//! [`crate::coordinator::LoadPlan::run`], and all reading through the
//! plan's [`crate::vfs::Storage`] backend:
//!
//! * same-configuration — the storing and loading configurations match:
//!   rank `k` streams its own `matrix-<k>.h5spm` through Algorithm 1.
//! * different-configuration — the general case: *all* ranks read *all*
//!   files and keep only elements with `M(i, j) = k` under the new
//!   mapping; with [`IoStrategy::Collective`], ranks advance file by file
//!   in lockstep (each read is a synchronizing collective), with
//!   [`IoStrategy::Independent`] each rank streams at its own pace.
//! * exchange — the paper's future-work direction, implemented as an
//!   ablation: stored files are assigned round-robin to loading ranks,
//!   each file is read *once*, and decoded elements are routed to their
//!   new owners over the bounded (backpressured) element channels.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::abhsf::{load_coo, load_csr, matrix_file_path, visit_elements, visit_elements_pruned};
use crate::coordinator::cluster::{Cluster, Msg};
use crate::coordinator::error::DatasetError;
use crate::coordinator::metrics::LoadReport;
use crate::coordinator::InMemFormat;
use crate::formats::element::window_or_tight;
use crate::formats::{Coo, Csr, LocalInfo};
use crate::h5::{H5Reader, IoStats};
use crate::mapping::ProcessMapping;
use crate::parfs::IoStrategy;
use crate::vfs::Storage;

/// A loaded local submatrix in the requested in-memory format.
#[derive(Debug, Clone)]
pub enum LoadedMatrix {
    /// CSR output (Algorithm 1's native form).
    Csr(Csr),
    /// COO output.
    Coo(Coo),
}

impl LoadedMatrix {
    /// Nonzero count.
    pub fn nnz(&self) -> usize {
        match self {
            LoadedMatrix::Csr(c) => c.nnz(),
            LoadedMatrix::Coo(c) => c.nnz(),
        }
    }

    /// Borrow metadata.
    pub fn info(&self) -> &LocalInfo {
        match self {
            LoadedMatrix::Csr(c) => &c.info,
            LoadedMatrix::Coo(c) => &c.info,
        }
    }

    /// Convert to CSR (no-op if already CSR).
    pub fn into_csr(self) -> Csr {
        match self {
            LoadedMatrix::Csr(c) => c,
            LoadedMatrix::Coo(c) => Csr::from_coo(&c),
        }
    }

    /// Convert to COO (no-op if already COO).
    pub fn into_coo(self) -> Coo {
        match self {
            LoadedMatrix::Csr(c) => c.to_coo(),
            LoadedMatrix::Coo(c) => c,
        }
    }

    /// Validate the contained structure.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            LoadedMatrix::Csr(c) => c.validate(),
            LoadedMatrix::Coo(c) => c.validate(),
        }
    }
}

/// Options for different-configuration loading.
#[derive(Clone)]
pub struct DiffLoadOptions {
    /// Number of stored files (storing-side process count).
    pub stored_files: usize,
    /// I/O strategy (paper §4 measures both).
    pub strategy: IoStrategy,
    /// Requested in-memory format.
    pub format: InMemFormat,
    /// Block-pruned reading: consult the block directory first and fetch
    /// only blocks whose rectangle may intersect this rank's region
    /// (exact for rectangular mappings, conservative no-op for irregular
    /// ones). Decodes strictly fewer elements whenever the target mapping
    /// localizes ranks; `false` restores the paper's literal
    /// decode-everything §3 loop.
    pub prune: bool,
}

type RankLoad = anyhow::Result<(LoadedMatrix, IoStats, f64)>;

/// Same-configuration load: rank `k` runs Algorithm 1 on its own file.
/// The cluster size must equal the storing process count. `unique` is
/// the sum of the stored files' sizes, measured by the planner — passing
/// it in keeps metadata round-trips out of the timed region.
pub(crate) fn same_config_impl(
    cluster: &Cluster,
    storage: &Arc<dyn Storage>,
    dir: &Path,
    format: InMemFormat,
    unique: u64,
) -> anyhow::Result<(Vec<LoadedMatrix>, LoadReport)> {
    let dirb = dir.to_path_buf();
    let storage = Arc::clone(storage);
    let t0 = Instant::now();
    let results: Vec<RankLoad> = cluster.run(move |ctx| {
        let t = Instant::now();
        let path = matrix_file_path(&dirb, ctx.rank);
        let reader = H5Reader::open_on(storage.as_ref(), &path)?;
        let loaded = match format {
            InMemFormat::Csr => LoadedMatrix::Csr(load_csr(&reader)?),
            InMemFormat::Coo => LoadedMatrix::Coo(load_coo(&reader)?),
        };
        Ok((loaded, reader.stats(), t.elapsed().as_secs_f64()))
    });
    assemble(
        "same-config",
        cluster.nprocs(),
        results,
        unique,
        IoStrategy::Independent,
        t0,
    )
}

/// Different-configuration load (paper §3): every rank reads every stored
/// file and keeps the elements the new `mapping` assigns to it. See
/// [`same_config_impl`] for the `unique` contract.
pub(crate) fn different_config_impl(
    cluster: &Cluster,
    storage: &Arc<dyn Storage>,
    dir: &Path,
    mapping: &Arc<dyn ProcessMapping>,
    opts: &DiffLoadOptions,
    unique: u64,
) -> anyhow::Result<(Vec<LoadedMatrix>, LoadReport)> {
    if cluster.nprocs() != mapping.nprocs() {
        return Err(DatasetError::MappingMismatch {
            mapping: mapping.nprocs(),
            nprocs: cluster.nprocs(),
        }
        .into());
    }
    let dirb = dir.to_path_buf();
    let storage = Arc::clone(storage);
    let mapping = Arc::clone(mapping);
    let opts_c = opts.clone();
    let t0 = Instant::now();
    let results: Vec<RankLoad> = cluster.run(move |ctx| {
        let t = Instant::now();
        let mut io = IoStats::default();
        let mut mine: Vec<(u64, u64, f64)> = Vec::new();
        let mut global: Option<(u64, u64, u64)> = None;
        // The outer loop over *all* stored files (paper §3 step 1).
        for file in 0..opts_c.stored_files {
            if opts_c.strategy == IoStrategy::Collective {
                // Collective I/O: every read is a collective operation, so
                // ranks advance through the shared file sequence together.
                ctx.barrier();
            }
            let path = matrix_file_path(&dirb, file);
            let reader = H5Reader::open_on(storage.as_ref(), &path)?;
            let hdr = crate::abhsf::load::read_header(&reader)?;
            global.get_or_insert((hdr.info.m, hdr.info.n, hdr.info.z));
            let rank = ctx.rank;
            let map = mapping.as_ref();
            if opts_c.prune {
                // Block-pruned §3: skip whole blocks whose rectangle
                // cannot map anything to this rank, then filter the
                // surviving elements exactly as below (intersection is
                // necessary, not sufficient, for ownership).
                let ps = visit_elements_pruned(
                    &reader,
                    |r0, c0, rows, cols| map.intersects(rank, (r0, c0, rows, cols)),
                    |i, j, v| {
                        if map.owner(i, j) == rank {
                            mine.push((i, j, v));
                        }
                    },
                )?;
                io.blocks_total += ps.blocks_total;
                io.blocks_skipped += ps.blocks_skipped;
                io.bytes_skipped += ps.bytes_skipped;
            } else {
                // Keep only elements mapped to this rank (paper §3 step 2).
                visit_elements(&reader, |i, j, v| {
                    if map.owner(i, j) == rank {
                        mine.push((i, j, v));
                    }
                })?;
            }
            io.add(reader.stats());
        }
        let (m, n, z) = global.ok_or_else(|| anyhow::anyhow!("no stored files"))?;
        let loaded = build_local(
            mine,
            mapping.as_ref(),
            ctx.rank,
            m,
            n,
            z,
            opts_c.format,
        );
        Ok((loaded, io, t.elapsed().as_secs_f64()))
    });
    assemble(
        &format!("diff-config/{}", opts.strategy.label()),
        cluster.nprocs(),
        results,
        unique,
        opts.strategy,
        t0,
    )
}

/// Exchange-based different-configuration load (ablation / future-work):
/// stored files are read once each (round-robin over loading ranks) and
/// elements are routed to their new owners through the bounded channels.
///
/// See [`same_config_impl`] for the `unique` contract. `dims` is the
/// global `(m, n, z)` from the dataset manifest: a rank that reads no file
/// (P_load > P_store) must not open a container just for the dims — that
/// open would either go uncounted or skew the per-rank I/O trace.
#[allow(clippy::too_many_arguments)]
pub(crate) fn exchange_impl(
    cluster: &Cluster,
    storage: &Arc<dyn Storage>,
    dir: &Path,
    mapping: &Arc<dyn ProcessMapping>,
    stored_files: usize,
    format: InMemFormat,
    unique: u64,
    dims: (u64, u64, u64),
) -> anyhow::Result<(Vec<LoadedMatrix>, LoadReport)> {
    if cluster.nprocs() != mapping.nprocs() {
        return Err(DatasetError::MappingMismatch {
            mapping: mapping.nprocs(),
            nprocs: cluster.nprocs(),
        }
        .into());
    }
    const BATCH: usize = 4096;
    let dirb = dir.to_path_buf();
    let storage = Arc::clone(storage);
    let mapping = Arc::clone(mapping);
    let t0 = Instant::now();
    type ExchangeOut = anyhow::Result<(LoadedMatrix, IoStats, f64, u64)>;
    let results: Vec<ExchangeOut> = cluster.run(move |ctx| {
        let t = Instant::now();
        ctx.send_blocked_ns
            .store(0, std::sync::atomic::Ordering::Relaxed);
        let p = ctx.nprocs;
        let rank = ctx.rank;
        let map = mapping.as_ref();
        let mut io = IoStats::default();
        // Reader half: stream my assigned files, batch per destination.
        // `mine`/`done` live in cells so the inbox can be drained while a
        // send is blocked (see `send_draining`: a cycle of ranks blocked
        // on full channels would otherwise deadlock).
        let mut outboxes: Vec<Vec<(u64, u64, f64)>> = vec![Vec::with_capacity(BATCH); p];
        let mine: std::cell::RefCell<Vec<(u64, u64, f64)>> =
            std::cell::RefCell::new(Vec::new());
        let done = std::cell::Cell::new(1usize); // counts self
        let handle = |msg: Msg| match msg {
            Msg::Elements(batch) => mine.borrow_mut().extend(batch),
            Msg::Done(_) => done.set(done.get() + 1),
            // Dist-engine messages (x halos, y partials, …) never fly
            // during a load phase — ranks are inside this loader, not an
            // engine exchange.
            _ => unreachable!("loader received a dist-engine message"),
        };
        let mut file = rank;
        while file < stored_files {
            let path = matrix_file_path(&dirb, file);
            let reader = H5Reader::open_on(storage.as_ref(), &path)?;
            visit_elements(&reader, |i, j, v| {
                let owner = map.owner(i, j);
                if owner == rank {
                    mine.borrow_mut().push((i, j, v));
                } else {
                    let out = &mut outboxes[owner];
                    out.push((i, j, v));
                    if out.len() >= BATCH {
                        ctx.send_draining(owner, Msg::Elements(std::mem::take(out)), &handle);
                    }
                }
            })?;
            io.add(reader.stats());
            file += p;
        }
        // Flush tails and signal completion to every peer.
        for dest in 0..p {
            if dest != rank {
                if !outboxes[dest].is_empty() {
                    ctx.send_draining(
                        dest,
                        Msg::Elements(std::mem::take(&mut outboxes[dest])),
                        &handle,
                    );
                }
                ctx.send_draining(dest, Msg::Done(rank), &handle);
            }
        }
        // Receiver half: collect until every peer is done.
        while done.get() < p {
            handle(ctx.recv());
        }
        let mine = mine.into_inner();
        // Global dims come from the dataset manifest — a rank that read
        // no file must not open one just for the header (it used to, and
        // the open went uncounted in its IoStats).
        let (m, n, z) = dims;
        let loaded = build_local(mine, map, rank, m, n, z, format);
        let blocked = ctx
            .send_blocked_ns
            .load(std::sync::atomic::Ordering::Relaxed);
        Ok((loaded, io, t.elapsed().as_secs_f64(), blocked))
    });
    let mut plain: Vec<RankLoad> = Vec::with_capacity(results.len());
    let mut blocked = Vec::with_capacity(results.len());
    for r in results {
        match r {
            Ok((lm, io, wall, b)) => {
                blocked.push(b);
                plain.push(Ok((lm, io, wall)));
            }
            Err(e) => {
                blocked.push(0);
                plain.push(Err(e));
            }
        }
    }
    let (matrices, mut report) = assemble(
        "diff-config/exchange",
        cluster.nprocs(),
        plain,
        unique,
        IoStrategy::Independent,
        t0,
    )?;
    report.send_blocked_ns = blocked;
    Ok((matrices, report))
}

/// Build a rank's local matrix from its collected global elements.
fn build_local(
    mut elems: Vec<(u64, u64, f64)>,
    mapping: &dyn ProcessMapping,
    rank: usize,
    m: u64,
    n: u64,
    z: u64,
    format: InMemFormat,
) -> LoadedMatrix {
    // Window: the mapping's declared region, tightened to the actual
    // bounding box when the mapping declares the whole matrix (paper §2
    // defines the window as min/max over owned nonzeros).
    let (ro, co, ml, nl) = window_or_tight(mapping.window(rank), m, n, &elems);
    let info = LocalInfo {
        m,
        n,
        z,
        m_local: ml,
        n_local: nl,
        z_local: 0,
        m_offset: ro,
        n_offset: co,
    };
    elems.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut coo = Coo::with_info(info);
    for (i, j, v) in elems {
        coo.push(i - ro, j - co, v);
    }
    match format {
        InMemFormat::Coo => LoadedMatrix::Coo(coo),
        InMemFormat::Csr => LoadedMatrix::Csr(Csr::from_coo(&coo)),
    }
}

fn assemble(
    scenario: &str,
    nprocs: usize,
    results: Vec<RankLoad>,
    unique_bytes: u64,
    strategy: IoStrategy,
    t0: Instant,
) -> anyhow::Result<(Vec<LoadedMatrix>, LoadReport)> {
    let wall_s = t0.elapsed().as_secs_f64();
    let mut matrices = Vec::with_capacity(nprocs);
    let mut per_rank_io = Vec::with_capacity(nprocs);
    let mut per_rank_wall = Vec::with_capacity(nprocs);
    let mut per_rank_nnz = Vec::with_capacity(nprocs);
    for r in results {
        let (lm, io, rank_wall) = r?;
        per_rank_nnz.push(lm.nnz() as u64);
        per_rank_io.push(io);
        per_rank_wall.push(rank_wall);
        matrices.push(lm);
    }
    let report = LoadReport {
        scenario: scenario.to_string(),
        nprocs,
        wall_s,
        per_rank_wall_s: per_rank_wall,
        per_rank_io,
        per_rank_nnz,
        unique_bytes,
        send_blocked_ns: vec![0; nprocs],
        strategy,
        auto: None,
    };
    Ok((matrices, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    use crate::coordinator::dataset::{Dataset, Strategy};
    use crate::coordinator::storer::StoreOptions;
    use crate::gen::{KroneckerGen, SeedMatrix};
    use crate::mapping::{Block2d, Colwise, Rowwise};
    use crate::spmv::{max_abs_diff, SpmvParts};

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("abhsf-loader-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Store a cage-like Kronecker matrix with `p_store` ranks row-wise.
    fn setup(name: &str, p_store: usize) -> (PathBuf, Arc<KroneckerGen>, u64) {
        let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 42), 2));
        let n = gen.dim();
        let mapping: Arc<dyn ProcessMapping> =
            Arc::new(Rowwise::regular(n, n, p_store));
        let cluster = Cluster::new(p_store, 64);
        let dir = tmpdir(name);
        Dataset::store(
            &cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: 8,
                ..Default::default()
            },
        )
        .unwrap();
        (dir, gen, n)
    }

    /// Reference y = A x via direct generation.
    fn reference_spmv(gen: &KroneckerGen, x: &[f64]) -> Vec<f64> {
        let n = gen.dim() as usize;
        let mut y = vec![0.0; n];
        gen.visit_row_range(0, n as u64, |i, j, v| {
            y[i as usize] += v * x[j as usize];
        });
        y
    }

    fn test_vector(n: u64) -> Vec<f64> {
        (0..n).map(|i| ((i % 17) as f64) * 0.25 + 1.0).collect()
    }

    #[test]
    fn same_config_load_reconstructs_matrix() {
        let p = 4;
        let (dir, gen, n) = setup("same", p);
        let cluster = Cluster::new(p, 64);
        let dataset = Dataset::open(&dir).unwrap();
        let (mats, report) = dataset
            .load()
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
        let x = test_vector(n);
        let y = SpmvParts::Csr(&parts).spmv(&x);
        assert!(max_abs_diff(&y, &reference_spmv(&gen, &x)) < 1e-9);
        assert!(report.unique_bytes > 0);
        assert_eq!(report.per_rank_io.len(), p);
        for io in &report.per_rank_io {
            assert_eq!(io.opens, 1, "same-config rank must open exactly 1 file");
        }
    }

    #[test]
    fn diff_config_colwise_independent() {
        let p_store = 4;
        let (dir, gen, n) = setup("diff-ind", p_store);
        let dataset = Dataset::open(&dir).unwrap();
        for p_load in [2usize, 3, 6] {
            let cluster = Cluster::new(p_load, 64);
            let mapping: Arc<dyn ProcessMapping> =
                Arc::new(Colwise::regular(n, n, p_load));
            let (mats, report) = dataset
                .load()
                .mapping(&mapping)
                .strategy(Strategy::Independent)
                .format(InMemFormat::Csr)
                .run(&cluster)
                .unwrap();
            assert_eq!(report.total_nnz(), gen.nnz(), "P={p_load}");
            // Every rank reads all files.
            for io in &report.per_rank_io {
                assert_eq!(io.opens as usize, p_store);
            }
            let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
            let x = test_vector(n);
            let y = SpmvParts::Csr(&parts).spmv(&x);
            assert!(max_abs_diff(&y, &reference_spmv(&gen, &x)) < 1e-9);
        }
    }

    #[test]
    fn diff_config_collective_matches_independent() {
        let p_store = 3;
        let (dir, gen, n) = setup("diff-coll", p_store);
        let p_load = 4;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 64);
        let (mats, report) = Dataset::open(&dir)
            .unwrap()
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Collective)
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        assert_eq!(report.strategy, IoStrategy::Collective);
        for m in &mats {
            m.validate().unwrap();
        }
    }

    #[test]
    fn diff_config_2d_mapping() {
        let p_store = 4;
        let (dir, gen, n) = setup("diff-2d", p_store);
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Block2d::regular(n, n, 2, 3));
        let cluster = Cluster::new(6, 64);
        let (mats, report) = Dataset::open(&dir)
            .unwrap()
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Independent)
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
        let x = test_vector(n);
        let y = SpmvParts::Csr(&parts).spmv(&x);
        assert!(max_abs_diff(&y, &reference_spmv(&gen, &x)) < 1e-9);
    }

    #[test]
    fn exchange_loader_equivalent_to_all_read_all() {
        let p_store = 4;
        let (dir, gen, n) = setup("exch", p_store);
        let p_load = 4;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 8);
        let (mats, report) = Dataset::open(&dir)
            .unwrap()
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        // Each file was opened exactly once across all ranks.
        let opens: u64 = report.per_rank_io.iter().map(|s| s.opens).sum();
        assert_eq!(opens as usize, p_store);
        let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
        let x = test_vector(n);
        let y = SpmvParts::Csr(&parts).spmv(&x);
        assert!(max_abs_diff(&y, &reference_spmv(&gen, &x)) < 1e-9);
    }

    #[test]
    fn exchange_with_fewer_loaders_than_files() {
        let p_store = 6;
        let (dir, gen, n) = setup("exch-few", p_store);
        let p_load = 2;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 8);
        let (mats, report) = Dataset::open(&dir)
            .unwrap()
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        for m in &mats {
            m.validate().unwrap();
        }
    }

    #[test]
    fn diff_config_reads_p_times_the_bytes() {
        // The central quantitative fact behind Figure 1: *unpruned*
        // all-read-all moves P_load x unique bytes, same-config moves
        // them once. Pruning can only lower the all-read-all side.
        let p_store = 3;
        let (dir, _gen, n) = setup("bytes", p_store);
        let dataset = Dataset::open(&dir).unwrap();
        let same_cluster = Cluster::new(p_store, 64);
        let (_, same) = dataset
            .load()
            .format(InMemFormat::Csr)
            .run(&same_cluster)
            .unwrap();
        let p_load = 5;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 64);
        let (_, diff) = dataset
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Independent)
            .prune(false)
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(same.unique_bytes, diff.unique_bytes);
        // Same-config readers touch roughly the unique bytes (payload +
        // directory); unpruned diff-config touches ~P_load times as much.
        let ratio = diff.total_read_bytes() as f64 / same.total_read_bytes() as f64;
        assert!(
            (ratio - p_load as f64).abs() < 0.2 * p_load as f64,
            "ratio {ratio} expected ~{p_load}"
        );
        let (_, pruned) = dataset
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Independent)
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert!(
            pruned.total_read_bytes() <= diff.total_read_bytes(),
            "pruned {} > unpruned {}",
            pruned.total_read_bytes(),
            diff.total_read_bytes()
        );
    }

    /// Acceptance: a Rowwise-stored → Colwise-loaded remap prunes — the
    /// skip counters are nonzero (every stored block is nonzero, so a
    /// skipped block is strictly fewer decoded elements) while the loaded
    /// matrix is identical to the unpruned load's.
    #[test]
    fn pruned_remap_skips_blocks_and_matches_unpruned() {
        let p_store = 4;
        let (dir, gen, n) = setup("prune-remap", p_store);
        let dataset = Dataset::open(&dir).unwrap();
        let p_load = 4;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 64);
        let mut loads = Vec::new();
        for (prune, strategy) in [
            (true, Strategy::Independent),
            (false, Strategy::Independent),
            (true, Strategy::Collective),
        ] {
            let (mats, report) = dataset
                .load()
                .mapping(&mapping)
                .strategy(strategy)
                .prune(prune)
                .format(InMemFormat::Coo)
                .run(&cluster)
                .unwrap();
            assert_eq!(report.total_nnz(), gen.nnz());
            if prune {
                assert!(
                    report.blocks_skipped() > 0,
                    "remap must skip blocks: {:?}",
                    report.prune_ratio()
                );
                assert!(report.bytes_skipped() > 0);
                assert!(report.blocks_total() > report.blocks_skipped());
                for io in &report.per_rank_io {
                    assert_eq!(io.opens as usize, p_store, "pruning keeps all opens");
                }
            } else {
                assert_eq!(report.blocks_total(), 0, "unpruned loads don't count blocks");
                assert_eq!(report.blocks_skipped(), 0);
            }
            let mut elems: Vec<(u64, u64, f64)> = Vec::new();
            for m in mats {
                let coo = m.into_coo();
                let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
                for (i, j, v) in coo.iter() {
                    elems.push((i + ro, j + co, v));
                }
            }
            elems.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
            loads.push(elems);
        }
        assert_eq!(loads[0], loads[1], "pruned != unpruned (independent)");
        assert_eq!(loads[0], loads[2], "independent != collective (pruned)");
    }

    /// Regression (exchange): total opens stay exactly `p_store` even
    /// when `p_load > p_store` — idle ranks used to open `matrix-0` for
    /// the global dims without counting it.
    #[test]
    fn exchange_opens_exactly_p_store_files_with_idle_ranks() {
        let p_store = 2;
        let (dir, gen, n) = setup("exch-idle", p_store);
        let p_load = 5; // ranks 2..5 read no file
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 8);
        let (mats, report) = Dataset::open(&dir)
            .unwrap()
            .load()
            .mapping(&mapping)
            .strategy(Strategy::Exchange)
            .format(InMemFormat::Csr)
            .run(&cluster)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        let opens: u64 = report.per_rank_io.iter().map(|s| s.opens).sum();
        assert_eq!(opens as usize, p_store, "every file opened exactly once");
        for (rank, io) in report.per_rank_io.iter().enumerate().skip(p_store) {
            assert_eq!(io.opens, 0, "idle rank {rank} must not open files");
            assert_eq!(io.bytes, 0, "idle rank {rank} must not read");
        }
        // Idle ranks still produce valid (column-strip) submatrices.
        for m in &mats {
            m.validate().unwrap();
        }
    }
}
