//! Reports produced by the parallel store/load orchestration and the
//! serving harness, and the bridge from measured I/O traces into the
//! [`crate::parfs`] cost model.

use crate::cache::CacheStats;
use crate::h5::IoStats;
use crate::parfs::{FsModel, IoStrategy, RankLoadProfile, SimReport};

/// Outcome of a parallel store.
#[derive(Debug, Clone)]
pub struct StoreReport {
    /// Wall time of the whole store (leader-observed), s.
    pub wall_s: f64,
    /// Per-rank writer I/O statistics.
    pub per_rank_io: Vec<IoStats>,
    /// Per-rank nonzeros stored.
    pub per_rank_nnz: Vec<u64>,
    /// Per-rank file payload bytes (ABHSF datasets).
    pub per_rank_bytes: Vec<u64>,
}

impl StoreReport {
    /// Total stored nonzeros.
    pub fn total_nnz(&self) -> u64 {
        self.per_rank_nnz.iter().sum()
    }

    /// Total file bytes.
    pub fn total_bytes(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.bytes).sum()
    }
}

/// Outcome of a distributed solve/SpMV run (`solve` and `spmv` CLI):
/// the solver's convergence record plus every rank's halo-exchange
/// counters ([`crate::dist::DistStats`]), printable against the
/// [`crate::dist::predict_spmv_comm`] model.
#[derive(Debug, Clone)]
pub struct DistReport {
    /// Solver label (`power`, `cg`, `lanczos`, or `spmv`).
    pub alg: String,
    /// Cluster size.
    pub nprocs: usize,
    /// Wall time of the whole run (leader-observed), s.
    pub wall_s: f64,
    /// Iterations (matrix applications) executed.
    pub iterations: usize,
    /// Whether the convergence criterion was met.
    pub converged: bool,
    /// Headline scalar (eigenvalue estimate or final residual norm).
    pub value: f64,
    /// Residual trajectory, one entry per iteration.
    pub residuals: Vec<f64>,
    /// Per-rank engine counters.
    pub per_rank: Vec<crate::dist::DistStats>,
}

impl DistReport {
    /// Total halo bytes sent across all ranks (equals total received).
    pub fn halo_bytes_sent(&self) -> u64 {
        self.per_rank.iter().map(|s| s.halo_bytes_sent).sum()
    }

    /// Total halo bytes received across all ranks.
    pub fn halo_bytes_recv(&self) -> u64 {
        self.per_rank.iter().map(|s| s.halo_bytes_recv).sum()
    }

    /// Distributed SpMVs executed per rank (identical on all ranks for
    /// collective solvers; 0 when the run never applied the matrix).
    pub fn spmvs(&self) -> u64 {
        self.per_rank.first().map_or(0, |s| s.spmvs)
    }

    /// Average halo bytes sent per SpMV across the whole cluster.
    pub fn bytes_per_spmv(&self) -> u64 {
        let spmvs = self.spmvs();
        if spmvs == 0 {
            0
        } else {
            self.halo_bytes_sent() / spmvs
        }
    }
}

/// How `Strategy::Auto` arrived at its choice: the per-candidate
/// cost-model predictions and the winner. Attached to [`LoadReport`] by
/// [`crate::coordinator::LoadPlan`] so experiments can audit the
/// selection against the measured outcome.
#[derive(Debug, Clone)]
pub struct AutoDecision {
    /// Whether the same-configuration fast path was eligible (stored and
    /// requested configurations provably match).
    pub same_config: bool,
    /// Candidate strategies with their predicted makespans, s
    /// (label → predicted seconds under the plan's [`FsModel`]).
    pub predicted: Vec<(String, f64)>,
    /// Label of the strategy actually executed.
    pub chosen: String,
}

/// Outcome of a parallel load.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Scenario label (`same-config`, `diff-config/independent`, …).
    pub scenario: String,
    /// Loading process count.
    pub nprocs: usize,
    /// Wall time of the whole load (leader-observed), s.
    pub wall_s: f64,
    /// Per-rank wall times, s.
    pub per_rank_wall_s: Vec<f64>,
    /// Per-rank reader I/O statistics.
    pub per_rank_io: Vec<IoStats>,
    /// Per-rank loaded nonzeros.
    pub per_rank_nnz: Vec<u64>,
    /// Distinct file bytes touched by the job (counted once).
    pub unique_bytes: u64,
    /// Per-rank nanoseconds blocked on backpressure (exchange loader).
    pub send_blocked_ns: Vec<u64>,
    /// I/O strategy used.
    pub strategy: IoStrategy,
    /// The `Strategy::Auto` decision record, when the load was planned
    /// with auto-selection (`None` for explicitly chosen strategies and
    /// for the deprecated free-function entry points).
    pub auto: Option<AutoDecision>,
}

impl LoadReport {
    /// Total loaded nonzeros.
    pub fn total_nnz(&self) -> u64 {
        self.per_rank_nnz.iter().sum()
    }

    /// Total bytes transferred to readers (with re-reads).
    pub fn total_read_bytes(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.bytes).sum()
    }

    /// Blocks examined across all ranks (block-pruned loads only; zero
    /// for the same-config fast path and unpruned loads).
    pub fn blocks_total(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.blocks_total).sum()
    }

    /// Blocks skipped across all ranks without fetching their payload.
    pub fn blocks_skipped(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.blocks_skipped).sum()
    }

    /// Payload bytes of the skipped blocks across all ranks.
    pub fn bytes_skipped(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.bytes_skipped).sum()
    }

    /// Fraction of examined blocks that were skipped, `None` when the
    /// load did not go through the pruned decoder.
    pub fn prune_ratio(&self) -> Option<f64> {
        let total = self.blocks_total();
        (total > 0).then(|| self.blocks_skipped() as f64 / total as f64)
    }

    /// Read-ahead batches across all ranks that were already fetched when
    /// the decoder asked for them (block-pruned loads only).
    pub fn prefetch_hits(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.prefetch_hits).sum()
    }

    /// Total seconds decoders spent blocked waiting on the read-ahead
    /// fetcher, across all ranks (block-pruned loads only).
    pub fn prefetch_stall_s(&self) -> f64 {
        self.per_rank_io
            .iter()
            .map(|s| s.prefetch_stall_ns)
            .sum::<u64>() as f64
            / 1e9
    }

    /// Extract the per-rank footprints for the cost model.
    pub fn profiles(&self) -> Vec<RankLoadProfile> {
        self.per_rank_io
            .iter()
            .map(|s| RankLoadProfile {
                opens: s.opens,
                ops: s.ops,
                bytes: s.bytes,
            })
            .collect()
    }

    /// Run the parallel-FS cost model over this load's measured I/O trace.
    pub fn simulate(&self, model: &FsModel) -> SimReport {
        model.simulate(&self.profiles(), self.unique_bytes, self.strategy)
    }
}

/// Outcome of one closed-loop serving run
/// ([`crate::serve::run_closed_loop`]): N worker threads issuing seeded
/// random rect/row-slice/nnz/SpMV queries against one or more datasets
/// through a shared [`crate::cache::BlockCache`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Worker threads.
    pub threads: usize,
    /// Queries completed across all threads (SpMV queries included).
    pub queries: u64,
    /// How many of those were whole-matrix SpMV queries.
    pub spmv_queries: u64,
    /// Wall time of the whole run (leader-observed), s.
    pub wall_s: f64,
    /// Per-query latency percentiles across all threads, ms. Computed
    /// from the bounded-memory serving histogram
    /// ([`crate::obs::metrics::LogHistogram`]), so each percentile is
    /// within ~2% relative error of the exact order statistic.
    pub p50_ms: f64,
    /// 90th percentile latency, ms.
    pub p90_ms: f64,
    /// 99th percentile latency, ms.
    pub p99_ms: f64,
    /// 99.9th percentile latency, ms.
    pub p999_ms: f64,
    /// Slowest single query, ms (exact — the histogram tracks the true
    /// maximum, not a bucket midpoint).
    pub max_ms: f64,
    /// Elements returned by rect/row-slice queries plus elements counted
    /// by nnz queries (a work proxy; an SpMV query contributes its output
    /// vector length `m`).
    pub elements_returned: u64,
    /// Aggregate reader I/O across every worker's readers — what
    /// actually reached storage (cache hits contribute nothing here).
    pub io: IoStats,
    /// Cache counters at the end of the run (both tiers; see
    /// [`CacheStats`] for the T1/T2 breakdown).
    pub cache: CacheStats,
    /// Per-dataset traffic and residency, `(label, stats)` in serving
    /// order — how the budget partitioning actually played out.
    pub per_dataset: Vec<(String, crate::cache::DatasetStats)>,
}

impl ServeReport {
    /// Query throughput, queries/s.
    pub fn qps(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.queries as f64 / self.wall_s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy_report() -> LoadReport {
        LoadReport {
            scenario: "test".into(),
            nprocs: 2,
            wall_s: 0.5,
            per_rank_wall_s: vec![0.4, 0.5],
            per_rank_io: vec![
                IoStats {
                    bytes: 1000,
                    ops: 10,
                    opens: 1,
                    ..IoStats::default()
                },
                IoStats {
                    bytes: 2000,
                    ops: 20,
                    opens: 1,
                    blocks_total: 8,
                    blocks_skipped: 6,
                    bytes_skipped: 500,
                    prefetch_hits: 3,
                    prefetch_stall_ns: 1_500_000_000,
                },
            ],
            per_rank_nnz: vec![50, 70],
            unique_bytes: 3000,
            send_blocked_ns: vec![0, 0],
            strategy: IoStrategy::Independent,
            auto: None,
        }
    }

    #[test]
    fn totals() {
        let r = dummy_report();
        assert_eq!(r.total_nnz(), 120);
        assert_eq!(r.total_read_bytes(), 3000);
        assert_eq!(r.blocks_total(), 8);
        assert_eq!(r.blocks_skipped(), 6);
        assert_eq!(r.bytes_skipped(), 500);
        assert_eq!(r.prune_ratio(), Some(0.75));
        assert_eq!(r.prefetch_hits(), 3);
        assert!((r.prefetch_stall_s() - 1.5).abs() < 1e-12);
        let mut unpruned = dummy_report();
        for io in &mut unpruned.per_rank_io {
            io.blocks_total = 0;
            io.blocks_skipped = 0;
        }
        assert_eq!(unpruned.prune_ratio(), None);
    }

    #[test]
    fn profiles_match_io() {
        let r = dummy_report();
        let p = r.profiles();
        assert_eq!(p.len(), 2);
        assert_eq!(p[1].bytes, 2000);
        assert_eq!(p[1].ops, 20);
    }

    #[test]
    fn simulate_runs() {
        let r = dummy_report();
        let sim = r.simulate(&FsModel::anselm_lustre());
        assert!(sim.makespan_s > 0.0);
        assert_eq!(sim.per_rank_s.len(), 2);
    }

    #[test]
    fn serve_report_qps() {
        let r = ServeReport {
            threads: 2,
            queries: 100,
            spmv_queries: 5,
            wall_s: 2.0,
            p50_ms: 1.0,
            p90_ms: 1.5,
            p99_ms: 2.0,
            p999_ms: 2.5,
            max_ms: 3.0,
            elements_returned: 10,
            io: IoStats::default(),
            cache: CacheStats::default(),
            per_dataset: Vec::new(),
        };
        assert!((r.qps() - 50.0).abs() < 1e-12);
        let idle = ServeReport { wall_s: 0.0, ..r };
        assert_eq!(idle.qps(), 0.0);
    }
}
