//! Layer-3 coordinator: leader/worker runtime and the parallel
//! store/load orchestration — the paper's system contribution.
//!
//! [`cluster`] provides the MPI-like substrate the paper assumes: a fixed
//! set of worker threads with private state ("address spaces"), a
//! broadcastable job primitive, barriers, and point-to-point element
//! channels with bounded capacity (backpressure).
//!
//! On top of it:
//! * [`dataset`] — **the public store/load API**: a [`Dataset`] handle
//!   whose `dataset.json` manifest makes stored matrices self-describing
//!   (stored process count, mapping descriptor, dims/nnz, block size,
//!   per-file sizes are *discovered, never passed*), and a [`LoadPlan`]
//!   builder with typed validation ([`DatasetError`]) and cost-model
//!   strategy auto-selection ([`Strategy::Auto`]);
//! * [`storer`] — parallel matrix storage: every rank builds its local
//!   submatrix (from a generator or provided parts), converts it to ABHSF
//!   on the fly and writes `matrix-<k>.h5spm` (single-file-per-process);
//! * [`loader`] — the paper's loading algorithms: same-configuration
//!   (Algorithm 1 per rank on its own file), different-configuration
//!   (all-read-all with `M(i,j)` filtering, independent or collective
//!   I/O), and the exchange-based extension (each rank reads its own file
//!   and routes elements to their new owners — the paper's "future
//!   research" direction);
//! * [`metrics`] — per-rank I/O traces, wall times, the
//!   [`Strategy::Auto`] decision record, and the bridge into the
//!   [`crate::parfs`] cost model.
//!
//! Every layer reads and writes through a pluggable
//! [`crate::vfs::Storage`] backend carried by the [`Dataset`] (default:
//! the local filesystem; see `Dataset::open_on` / `Dataset::store_on` and
//! the `LoadPlan::storage` / `RepackPlan::storage` hooks). The pre-0.2
//! deprecated free functions were removed in 0.3; use the
//! [`Dataset`] / [`LoadPlan`] API.

pub mod cluster;
pub mod dataset;
pub mod error;
pub mod loader;
pub mod metrics;
pub mod storer;

pub use cluster::{Cluster, WorkerCtx};
pub use dataset::{Dataset, DatasetManifest, LoadPlan, StoredFile, Strategy, MANIFEST_FILE};
pub use error::DatasetError;
pub use loader::{DiffLoadOptions, LoadedMatrix};
pub use metrics::{AutoDecision, DistReport, LoadReport, StoreReport};
pub use storer::StoreOptions;
// The repack subsystem lives in `crate::repack` (it is the first
// store-path-at-load-scale subsystem and owns its own module tree), but
// its planning types are part of the coordinator-facing API surface.
pub use crate::repack::{PhaseStats, RepackForecast, RepackPlan, RepackReport};

/// In-memory format requested for loaded submatrices (third leg of the
/// paper's "configuration" triple).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum InMemFormat {
    /// Compressed sparse rows (Algorithm 1's native output).
    #[default]
    Csr,
    /// Coordinate list.
    Coo,
}

impl InMemFormat {
    /// Label for tables, reports and the CLI.
    pub fn label(self) -> &'static str {
        match self {
            InMemFormat::Csr => "csr",
            InMemFormat::Coo => "coo",
        }
    }
}

impl std::str::FromStr for InMemFormat {
    type Err = DatasetError;

    fn from_str(s: &str) -> Result<Self, DatasetError> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "csr" => InMemFormat::Csr,
            "coo" => InMemFormat::Coo,
            _ => return Err(DatasetError::UnknownFormat(s.to_string())),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_parses() {
        assert_eq!("csr".parse::<InMemFormat>().unwrap(), InMemFormat::Csr);
        assert_eq!(" COO ".parse::<InMemFormat>().unwrap(), InMemFormat::Coo);
        assert!(matches!(
            "dense".parse::<InMemFormat>(),
            Err(DatasetError::UnknownFormat(_))
        ));
        assert_eq!(InMemFormat::default().label(), "csr");
    }
}
