//! Layer-3 coordinator: leader/worker runtime and the parallel
//! store/load orchestration — the paper's system contribution.
//!
//! [`cluster`] provides the MPI-like substrate the paper assumes: a fixed
//! set of worker threads with private state ("address spaces"), a
//! broadcastable job primitive, barriers, and point-to-point element
//! channels with bounded capacity (backpressure).
//!
//! On top of it:
//! * [`storer`] — parallel matrix storage: every rank builds its local
//!   submatrix (from a generator or provided parts), converts it to ABHSF
//!   on the fly and writes `matrix-<k>.h5spm` (single-file-per-process);
//! * [`loader`] — the paper's loading algorithms: same-configuration
//!   (Algorithm 1 per rank on its own file), different-configuration
//!   (all-read-all with `M(i,j)` filtering, independent or collective
//!   I/O), and the exchange-based extension (each rank reads its own file
//!   and routes elements to their new owners — the paper's "future
//!   research" direction);
//! * [`metrics`] — per-rank I/O traces, wall times, and the bridge into
//!   the [`crate::parfs`] cost model.

pub mod cluster;
pub mod loader;
pub mod metrics;
pub mod storer;

pub use cluster::{Cluster, WorkerCtx};
pub use loader::{
    load_different_config, load_exchange, load_same_config, DiffLoadOptions, LoadedMatrix,
};
pub use metrics::{LoadReport, StoreReport};
pub use storer::{store_distributed, store_parts};

/// In-memory format requested for loaded submatrices (third leg of the
/// paper's "configuration" triple).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InMemFormat {
    /// Compressed sparse rows (Algorithm 1's native output).
    Csr,
    /// Coordinate list.
    Coo,
}
