//! Parallel matrix storage: each rank converts its local submatrix to
//! ABHSF on the fly and writes one `matrix-<k>.h5spm` file
//! (single-file-per-process strategy; storage side of refs [1, 3]).

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::abhsf::cost::CostModel;
use crate::abhsf::{matrix_file_path, store::store_data_chunked_on, AbhsfData};
use crate::coordinator::cluster::Cluster;
use crate::coordinator::error::DatasetError;
use crate::coordinator::metrics::StoreReport;
use crate::formats::Coo;
use crate::gen::KroneckerGen;
use crate::mapping::ProcessMapping;
use crate::vfs::Storage;

/// Options controlling the storage conversion.
#[derive(Debug, Clone)]
pub struct StoreOptions {
    /// ABHSF block size `s`.
    pub block_size: u64,
    /// Container dataset chunk size (elements).
    pub chunk_elems: u64,
    /// Scheme-selection cost model.
    pub cost_model: CostModel,
}

impl Default for StoreOptions {
    fn default() -> Self {
        Self {
            block_size: 64,
            chunk_elems: crate::h5::DEFAULT_CHUNK_ELEMS,
            cost_model: CostModel::default(),
        }
    }
}

/// Store a generated matrix: every rank of `cluster` lazily generates its
/// own portion under `mapping` (no rank ever holds the global matrix),
/// converts it to ABHSF and writes its file into `dir` on `storage`.
pub(crate) fn store_distributed_impl(
    cluster: &Cluster,
    storage: &Arc<dyn Storage>,
    gen: &Arc<KroneckerGen>,
    mapping: &Arc<dyn ProcessMapping>,
    dir: &Path,
    opts: StoreOptions,
) -> Result<StoreReport, DatasetError> {
    if cluster.nprocs() != mapping.nprocs() {
        return Err(DatasetError::ClusterMismatch {
            cluster: cluster.nprocs(),
            required: mapping.nprocs(),
            what: "the storage mapping",
        });
    }
    storage.create_dir_all(dir)?;
    let dir = dir.to_path_buf();
    let storage = Arc::clone(storage);
    let gen = Arc::clone(gen);
    let mapping = Arc::clone(mapping);
    let t0 = Instant::now();
    let results = cluster.run(move |ctx| {
        let coo = gen.local_coo(mapping.as_ref(), ctx.rank);
        store_local(storage.as_ref(), &coo, &dir, ctx.rank, &opts)
    });
    finish_report(results, t0)
}

/// Store pre-built local parts (one COO per rank).
pub(crate) fn store_parts_impl(
    cluster: &Cluster,
    storage: &Arc<dyn Storage>,
    parts: Vec<Coo>,
    dir: &Path,
    opts: StoreOptions,
) -> Result<StoreReport, DatasetError> {
    if cluster.nprocs() != parts.len() {
        return Err(DatasetError::PartsMismatch {
            parts: parts.len(),
            cluster: cluster.nprocs(),
        });
    }
    storage.create_dir_all(dir)?;
    let dir = dir.to_path_buf();
    let storage = Arc::clone(storage);
    let parts = Arc::new(parts);
    let t0 = Instant::now();
    let results = cluster.run(move |ctx| {
        let coo = &parts[ctx.rank];
        store_local(storage.as_ref(), coo, &dir, ctx.rank, &opts)
    });
    finish_report(results, t0)
}

type RankStoreResult = anyhow::Result<(crate::h5::IoStats, u64, u64)>;

fn store_local(
    storage: &dyn Storage,
    coo: &Coo,
    dir: &Path,
    rank: usize,
    opts: &StoreOptions,
) -> RankStoreResult {
    let data = AbhsfData::from_coo(coo, opts.block_size, &opts.cost_model)?;
    let path = matrix_file_path(dir, rank);
    let io = store_data_chunked_on(storage, &path, &data, opts.chunk_elems)?;
    Ok((io, coo.nnz() as u64, data.payload_bytes()))
}

fn finish_report(results: Vec<RankStoreResult>, t0: Instant) -> Result<StoreReport, DatasetError> {
    let mut per_rank_io = Vec::new();
    let mut per_rank_nnz = Vec::new();
    let mut per_rank_bytes = Vec::new();
    for r in results {
        let (io, nnz, bytes) = r.map_err(DatasetError::from)?;
        per_rank_io.push(io);
        per_rank_nnz.push(nnz);
        per_rank_bytes.push(bytes);
    }
    Ok(StoreReport {
        wall_s: t0.elapsed().as_secs_f64(),
        per_rank_io,
        per_rank_nnz,
        per_rank_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dataset::Dataset;
    use crate::gen::SeedMatrix;
    use crate::mapping::Rowwise;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("abhsf-storer-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn distributed_store_writes_all_files() {
        let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 1), 2));
        let n = gen.dim();
        let p = 4;
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p));
        let cluster = Cluster::new(p, 64);
        let dir = tmpdir("dist");
        let (dataset, report) = Dataset::store(
            &cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: 8,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        assert_eq!(dataset.nprocs(), p);
        for k in 0..p {
            assert!(matrix_file_path(&dir, k).exists(), "missing file {k}");
        }
        assert!(report.wall_s > 0.0);
        assert!(report.total_bytes() > 0);
    }

    #[test]
    fn cluster_mapping_size_mismatch_is_typed_error() {
        let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 1), 2));
        let n = gen.dim();
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, 3));
        let cluster = Cluster::new(2, 64);
        let dir = tmpdir("mismatch");
        let err = Dataset::store(&cluster, &gen, &mapping, &dir, StoreOptions::default())
            .expect_err("size mismatch must not panic");
        assert!(
            matches!(err, DatasetError::ClusterMismatch { cluster: 2, required: 3, .. }),
            "{err}"
        );
    }

    #[test]
    fn store_parts_rejects_mapping_arity_mismatch() {
        // A mapping that disagrees with the cluster must be rejected
        // before anything hits disk — otherwise the manifest would
        // record a descriptor with the wrong process count.
        let gen = KroneckerGen::new(SeedMatrix::cage_like(6, 3), 2);
        let n = gen.dim();
        let rw = Rowwise::regular(n, n, 2);
        let parts: Vec<Coo> = (0..2).map(|k| gen.local_coo(&rw, k)).collect();
        let cluster = Cluster::new(2, 64);
        let wrong: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, 7));
        let dir = tmpdir("parts-mismatch");
        let err = Dataset::store_parts(&cluster, parts, &wrong, &dir, StoreOptions::default())
            .expect_err("mapping arity mismatch must be rejected");
        assert!(
            matches!(err, DatasetError::ClusterMismatch { cluster: 2, required: 7, .. }),
            "{err}"
        );
        assert!(!dir.join(crate::coordinator::MANIFEST_FILE).exists());
    }

    #[test]
    fn store_parts_roundtrips_via_reader() {
        let gen = KroneckerGen::new(SeedMatrix::cage_like(6, 3), 2);
        let n = gen.dim();
        let p = 3;
        let mapping = Rowwise::regular(n, n, p);
        let parts: Vec<Coo> = (0..p).map(|k| gen.local_coo(&mapping, k)).collect();
        let want_nnz: u64 = parts.iter().map(|c| c.nnz() as u64).sum();
        let cluster = Cluster::new(p, 64);
        let dir = tmpdir("parts");
        let mapping: Arc<dyn ProcessMapping> = Arc::new(mapping);
        let (dataset, report) = Dataset::store_parts(
            &cluster,
            parts,
            &mapping,
            &dir,
            StoreOptions {
                block_size: 4,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.total_nnz(), want_nnz);
        assert_eq!(dataset.nnz(), want_nnz);
        // Spot-check one file loads back.
        let r = crate::h5::H5Reader::open(matrix_file_path(&dir, 1)).unwrap();
        let csr = crate::abhsf::load_csr(&r).unwrap();
        assert!(csr.nnz() > 0);
    }
}
