//! Distributed SpMV engine with halo exchange — the compute subsystem
//! the loaded data feeds (DESIGN.md §13).
//!
//! Every `spmv` path before this module multiplied against a fully
//! resident `x` on each rank, so nothing actually scaled past one node's
//! memory. Here `x` and `y` are *partitioned* across ranks by the
//! dataset's [`MappingDesc`] ([`spmv_partitions`]; owner-computes for
//! `y`), and each [`RankEngine`] computes from the announced per-rank
//! windows exactly which `x` segments its local submatrix touches and
//! halo-exchanges **only those segments** over the existing
//! [`Cluster`](crate::coordinator::Cluster)/[`WorkerCtx`] channel mesh
//! ([`Msg::XSegment`]/[`Msg::YPartial`]), deadlock-free via the
//! `send_draining` discipline (a rank blocked on a full peer channel
//! drains its own inbox into the [`RankEngine`]'s mailbox).
//!
//! **Bit-determinism.** Partial `y` contributions are reduced to their
//! owners in a *fixed ascending rank order*, with the owner's own
//! partial folded at its own rank position. Combined with windowed
//! kernels whose per-element accumulation order is identical to the
//! global-vector kernels ([`Csr::spmv_windowed_into`],
//! [`spmv_block_windowed_into`](crate::spmv::kernels)), the distributed
//! result is bit-identical to the single-rank
//! [`SpmvParts`](crate::spmv::SpmvParts) fold over the same parts in
//! rank order — `rust/tests/dist.rs` asserts `==`, not `≈`.
//!
//! **Comm/compute overlap.** An engine posts all of its outgoing `x`
//! halo segments *before* asking the local operator to
//! [`prefetch`](LocalOperator::prefetch) (block fetch + decode through
//! the serve layer's read-ahead pipeline), and only then waits for
//! incoming segments — decode runs while halos are in flight. This is
//! safe: our sends are already posted, so a peer spinning in
//! `send_draining` against our full inbox makes progress the moment we
//! start receiving.
//!
//! **Comm model.** [`predict_spmv_comm`] computes per-rank halo bytes
//! from the mapping descriptor alone — *exactly* for rectangular
//! mappings (row-wise / column-wise / 2D block keep their declared
//! windows through `window_or_tight`), and as an upper bound for
//! irregular ones (cyclic rows declare the whole matrix; the stored
//! tight windows can only shrink the traffic). The measured
//! [`DistStats`] halo counters are validated against it in tests and
//! printed by the `solve`/`spmv` CLI.
//!
//! Iterative solvers (power iteration, CG, Lanczos) with distributed
//! dot/norm reductions live in [`solvers`].

pub mod solvers;

use std::collections::VecDeque;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::cache::CachedBlock;
use crate::coordinator::cluster::{Msg, WorkerCtx};
use crate::coordinator::error::DatasetError;
use crate::formats::Csr;
use crate::mapping::{even_starts, MappingDesc};
use crate::obs::metrics::LogHistogram;
use crate::obs::trace::{self, Tag};
use crate::serve::DatasetReader;
use crate::spmv::kernels::spmv_block_windowed_into;

/// Global-registry handles for the per-SpMV phase histograms
/// (`dist.exchange_s` / `dist.compute_s`), resolved once so the SpMV
/// hot path never touches the registry lock.
fn dist_histograms() -> &'static (Arc<LogHistogram>, Arc<LogHistogram>) {
    static HANDLES: OnceLock<(Arc<LogHistogram>, Arc<LogHistogram>)> = OnceLock::new();
    HANDLES.get_or_init(|| {
        let reg = crate::obs::metrics::global();
        (
            reg.histogram("dist.exchange_s"),
            reg.histogram("dist.compute_s"),
        )
    })
}

/// Contiguous partition of a global vector across `P` ranks: rank `k`
/// owns entries `[starts[k], starts[k+1])`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VectorPartition {
    /// Chunk starts, `P + 1` entries, ascending, `starts[0] = 0`.
    pub starts: Vec<u64>,
}

impl VectorPartition {
    /// Even split of `total` entries over `parts` ranks.
    pub fn even(total: u64, parts: usize) -> Self {
        Self {
            starts: even_starts(total, parts),
        }
    }

    /// Partition from explicit chunk starts (`P + 1` entries).
    pub fn from_starts(starts: Vec<u64>) -> Self {
        assert!(starts.len() >= 2, "need at least one chunk");
        Self { starts }
    }

    /// Number of ranks.
    pub fn nprocs(&self) -> usize {
        self.starts.len() - 1
    }

    /// Owned half-open range of `rank`.
    pub fn range(&self, rank: usize) -> (u64, u64) {
        (self.starts[rank], self.starts[rank + 1])
    }

    /// Owned entry count of `rank`.
    pub fn len_of(&self, rank: usize) -> usize {
        (self.starts[rank + 1] - self.starts[rank]) as usize
    }

    /// Total vector length.
    pub fn total(&self) -> u64 {
        *self.starts.last().unwrap()
    }
}

/// The `x`/`y` partitioning contract: how the input and output vectors
/// of `y = A x` are split across ranks for a given mapping.
///
/// * Row-wise: `y` follows the row chunks (owner-computes: each rank
///   fully owns its rows' results); for square matrices `x` uses the
///   same boundaries so solvers can alias iterate and product.
/// * Column-wise: `x` follows the column chunks (each rank holds the
///   `x` entries its columns multiply); `y` mirrors them when square.
/// * 2D block / cyclic / opaque: even splits of both vectors.
///
/// Returns `(x_partition, y_partition)`. For square matrices the two
/// are always equal — the invariant the iterative solvers rely on.
pub fn spmv_partitions(desc: &MappingDesc, m: u64, n: u64) -> (VectorPartition, VectorPartition) {
    let p = desc.nprocs();
    match desc {
        MappingDesc::Rowwise { starts, .. } => {
            let y = VectorPartition::from_starts(starts.clone());
            let x = if n == m {
                y.clone()
            } else {
                VectorPartition::even(n, p)
            };
            (x, y)
        }
        MappingDesc::Colwise { starts, .. } => {
            let x = VectorPartition::from_starts(starts.clone());
            let y = if m == n {
                x.clone()
            } else {
                VectorPartition::even(m, p)
            };
            (x, y)
        }
        _ => (VectorPartition::even(n, p), VectorPartition::even(m, p)),
    }
}

/// Intersection of two half-open intervals, normalized so empty results
/// have `hi == lo`.
pub fn overlap(a: (u64, u64), b: (u64, u64)) -> (u64, u64) {
    let lo = a.0.max(b.0);
    let hi = a.1.min(b.1);
    if hi <= lo {
        (lo, lo)
    } else {
        (lo, hi)
    }
}

/// `parfs`-style prediction of one distributed SpMV's halo traffic.
#[derive(Debug, Clone)]
pub struct CommPrediction {
    /// Halo payload bytes each rank sends per SpMV (x segments + y
    /// partials; 8 B per `f64`, scalar reductions excluded).
    pub per_rank_sent: Vec<u64>,
    /// Halo payload bytes each rank receives per SpMV.
    pub per_rank_recv: Vec<u64>,
    /// `true` when the mapping's ownership is rectangular, in which case
    /// the engine's measured byte counters match this prediction
    /// *exactly*; irregular mappings make it an upper bound (their
    /// stored windows are tightened to actual elements).
    pub exact: bool,
    /// The naive alternative this engine replaces: every rank holding
    /// the full input vector (`P × n × 8` bytes moved per SpMV).
    pub broadcast_bytes: u64,
}

impl CommPrediction {
    /// Total bytes sent across all ranks (equals total received).
    pub fn total_bytes(&self) -> u64 {
        self.per_rank_sent.iter().sum()
    }
}

/// Predict per-rank halo bytes for one SpMV under `desc` on an `m × n`
/// matrix, from the mapping descriptor alone (module docs for the
/// exactness contract). Mirrors the engine's plan derivation: rank `s`
/// sends to `r ≠ s` the overlap of `s`'s owned `x` range with `r`'s
/// column window, and the overlap of `s`'s row window with `r`'s owned
/// `y` range; zero-length segments are skipped on both sides.
pub fn predict_spmv_comm(desc: &MappingDesc, m: u64, n: u64) -> CommPrediction {
    let p = desc.nprocs();
    let (x_part, y_part) = spmv_partitions(desc, m, n);
    let mut exact = true;
    let windows: Vec<((u64, u64), (u64, u64))> = (0..p)
        .map(|r| match desc.rank_rect(r) {
            Some((r0, c0, rm, cn)) => ((r0, r0 + rm), (c0, c0 + cn)),
            None => {
                exact = false;
                ((0, m), (0, n))
            }
        })
        .collect();
    let mut sent = vec![0u64; p];
    let mut recv = vec![0u64; p];
    for s in 0..p {
        for r in 0..p {
            if s == r {
                continue;
            }
            let x = overlap(x_part.range(s), windows[r].1);
            let xb = 8 * (x.1 - x.0);
            sent[s] += xb;
            recv[r] += xb;
            let y = overlap(windows[s].0, y_part.range(r));
            let yb = 8 * (y.1 - y.0);
            sent[s] += yb;
            recv[r] += yb;
        }
    }
    CommPrediction {
        per_rank_sent: sent,
        per_rank_recv: recv,
        exact,
        broadcast_bytes: p as u64 * n * 8,
    }
}

/// Per-rank counters of one engine's lifetime: halo traffic and the
/// exchange/compute/decode time split. Halo bytes count the `f64`
/// payloads of [`Msg::XSegment`]/[`Msg::YPartial`] only (8 B per
/// element); scalar reductions and window announcements are excluded.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DistStats {
    /// Halo payload bytes sent.
    pub halo_bytes_sent: u64,
    /// Halo payload bytes received.
    pub halo_bytes_recv: u64,
    /// Halo messages sent.
    pub halo_msgs_sent: u64,
    /// Halo messages received.
    pub halo_msgs_recv: u64,
    /// Distributed SpMVs executed.
    pub spmvs: u64,
    /// Seconds posting halo sends and waiting on halo receives.
    pub exchange_s: f64,
    /// Seconds inside the local operator's windowed apply.
    pub compute_s: f64,
    /// Seconds inside the local operator's prefetch (block fetch +
    /// decode; zero for resident CSR operators).
    pub decode_s: f64,
}

/// Per-source FIFO queues for every dist message kind. The channel mesh
/// delivers one interleaved stream; the mailbox reorders it so waits
/// can target "the next `x` segment *from rank 3*" while queueing
/// whatever else arrives (including next-iteration traffic from ranks
/// that are already ahead — per-sender channel FIFO keeps each queue in
/// iteration order).
struct Mailbox {
    x: Vec<VecDeque<(u64, Vec<f64>)>>,
    y: Vec<VecDeque<(u64, Vec<f64>)>>,
    windows: Vec<VecDeque<((u64, u64), (u64, u64))>>,
    scalars: Vec<VecDeque<f64>>,
}

impl Mailbox {
    fn new(p: usize) -> Self {
        Self {
            x: (0..p).map(|_| VecDeque::new()).collect(),
            y: (0..p).map(|_| VecDeque::new()).collect(),
            windows: (0..p).map(|_| VecDeque::new()).collect(),
            scalars: (0..p).map(|_| VecDeque::new()).collect(),
        }
    }

    fn put(&mut self, msg: Msg) {
        match msg {
            Msg::XSegment { from, start, vals } => self.x[from].push_back((start, vals)),
            Msg::YPartial { from, start, vals } => self.y[from].push_back((start, vals)),
            Msg::Window { from, rows, cols } => self.windows[from].push_back((rows, cols)),
            Msg::Scalar { from, value } => self.scalars[from].push_back(value),
            Msg::Elements(_) | Msg::Done(_) => {
                unreachable!("loader message during a dist exchange")
            }
        }
    }

    fn wait_x(&mut self, ctx: &WorkerCtx, src: usize) -> (u64, Vec<f64>) {
        loop {
            if let Some(seg) = self.x[src].pop_front() {
                return seg;
            }
            self.put(ctx.recv());
        }
    }

    fn wait_y(&mut self, ctx: &WorkerCtx, src: usize) -> (u64, Vec<f64>) {
        loop {
            if let Some(seg) = self.y[src].pop_front() {
                return seg;
            }
            self.put(ctx.recv());
        }
    }

    fn wait_window(&mut self, ctx: &WorkerCtx, src: usize) -> ((u64, u64), (u64, u64)) {
        loop {
            if let Some(w) = self.windows[src].pop_front() {
                return w;
            }
            self.put(ctx.recv());
        }
    }

    fn wait_scalar(&mut self, ctx: &WorkerCtx, src: usize) -> f64 {
        loop {
            if let Some(v) = self.scalars[src].pop_front() {
                return v;
            }
            self.put(ctx.recv());
        }
    }
}

/// One rank's local piece of the matrix, as the engine drives it: a
/// row/column window declaration, an optional prefetch (block fetch +
/// decode, overlapped with halo exchange), and a windowed apply.
pub trait LocalOperator {
    /// Half-open global row range this rank's elements fall in.
    fn row_window(&self) -> (u64, u64);

    /// Half-open global column range this rank's elements fall in.
    fn col_window(&self) -> (u64, u64);

    /// Materialize whatever `apply` needs (fetch + decode blocks through
    /// the cache); returns the seconds spent doing so. Called once per
    /// SpMV *between* posting halo sends and waiting on receives, so
    /// decode overlaps communication; cheap no-op after the first call
    /// for operators that cache their blocks.
    fn prefetch(&mut self) -> Result<f64, DatasetError> {
        Ok(0.0)
    }

    /// Accumulate `y += A_local x` against windowed vectors: `x_win`
    /// holds global entries `[x_off, x_off + x_win.len())`, `y_win`
    /// global entries `[y_off, ...)`; both windows cover the declared
    /// ones. Must make exactly the same f64 operations in the same
    /// order as the resident global-vector kernel.
    fn apply(&mut self, x_win: &[f64], x_off: u64, y_win: &mut [f64], y_off: u64);
}

/// Resident CSR parts as a [`LocalOperator`] — the shape `LoadPlan`
/// hands back, windows straight from the parts' [`LocalInfo`]
/// (tightened at store time by `window_or_tight`).
pub struct CsrOperator<'a> {
    parts: &'a [Csr],
}

impl<'a> CsrOperator<'a> {
    /// Wrap this rank's loaded CSR parts (usually exactly one).
    pub fn new(parts: &'a [Csr]) -> Self {
        Self { parts }
    }

    fn union(&self, f: impl Fn(&Csr) -> (u64, u64)) -> (u64, u64) {
        let mut win: Option<(u64, u64)> = None;
        for p in self.parts {
            let (lo, hi) = f(p);
            if hi <= lo {
                continue;
            }
            win = Some(match win {
                None => (lo, hi),
                Some((a, b)) => (a.min(lo), b.max(hi)),
            });
        }
        win.unwrap_or((0, 0))
    }
}

impl LocalOperator for CsrOperator<'_> {
    fn row_window(&self) -> (u64, u64) {
        self.union(|p| (p.info.m_offset, p.info.m_offset + p.info.m_local))
    }

    fn col_window(&self) -> (u64, u64) {
        self.union(|p| (p.info.n_offset, p.info.n_offset + p.info.n_local))
    }

    fn apply(&mut self, x_win: &[f64], x_off: u64, y_win: &mut [f64], y_off: u64) {
        for p in self.parts {
            p.spmv_windowed_into(x_win, x_off, y_win, y_off);
        }
    }
}

/// One stored file's decoded blocks as a [`LocalOperator`]: windows from
/// the block directory (no payload read), blocks fetched through the
/// serving cache on first [`prefetch`](LocalOperator::prefetch) and
/// applied **in directory order** every iteration
/// ([`DatasetReader::file_blocks`]) — reproducible bits regardless of
/// cache state.
pub struct BlockOperator<'r, 'c> {
    reader: &'r DatasetReader<'c>,
    file: usize,
    blocks: Option<Vec<Arc<CachedBlock>>>,
    row_win: (u64, u64),
    col_win: (u64, u64),
}

impl<'r, 'c> BlockOperator<'r, 'c> {
    /// Operator over stored file `file` of `reader`'s dataset.
    pub fn new(reader: &'r DatasetReader<'c>, file: usize) -> Self {
        let (row_win, col_win) = reader.file_window(file);
        Self {
            reader,
            file,
            blocks: None,
            row_win,
            col_win,
        }
    }
}

impl LocalOperator for BlockOperator<'_, '_> {
    fn row_window(&self) -> (u64, u64) {
        self.row_win
    }

    fn col_window(&self) -> (u64, u64) {
        self.col_win
    }

    fn prefetch(&mut self) -> Result<f64, DatasetError> {
        if self.blocks.is_none() {
            let t0 = Instant::now();
            self.blocks = Some(self.reader.file_blocks(self.file)?);
            return Ok(t0.elapsed().as_secs_f64());
        }
        Ok(0.0)
    }

    fn apply(&mut self, x_win: &[f64], x_off: u64, y_win: &mut [f64], y_off: u64) {
        let blocks = self.blocks.as_ref().expect("prefetch() before apply()");
        for block in blocks {
            spmv_block_windowed_into(block, x_win, x_off, y_win, y_off);
        }
    }
}

/// One rank's half of the distributed SpMV engine (module docs for the
/// protocol). Construction performs a one-time all-to-all window
/// announcement and derives all four exchange plans symmetrically, so
/// both sides of every pair agree on exactly which segments fly.
pub struct RankEngine<'a> {
    ctx: &'a WorkerCtx,
    x_part: VectorPartition,
    y_part: VectorPartition,
    row_win: (u64, u64),
    col_win: (u64, u64),
    /// `(dest, start, len)`: my owned `x` entries `dest`'s columns touch.
    x_send: Vec<(usize, u64, u64)>,
    /// `(src, start, len)`: `x` segments my columns need from `src`.
    x_recv: Vec<(usize, u64, u64)>,
    /// `(owner, start, len)`: partial `y` rows I computed for `owner`.
    y_send: Vec<(usize, u64, u64)>,
    /// `(src, start, len)`: partials folded into my owned `y`, ascending
    /// `src` **including myself** — the fixed fold order that makes the
    /// reduction bit-deterministic.
    y_fold: Vec<(usize, u64, u64)>,
    mailbox: Mailbox,
    x_buf: Vec<f64>,
    y_buf: Vec<f64>,
    stats: DistStats,
}

impl<'a> RankEngine<'a> {
    /// Build this rank's engine: announce `(row_win, col_win)` (the
    /// local operator's declared windows) to every peer, collect
    /// theirs, and derive the exchange plans. Collective: every rank of
    /// the cluster must construct its engine with the same partitions.
    pub fn new(
        ctx: &'a WorkerCtx,
        x_part: VectorPartition,
        y_part: VectorPartition,
        row_win: (u64, u64),
        col_win: (u64, u64),
    ) -> Self {
        let p = ctx.nprocs;
        let me = ctx.rank;
        assert_eq!(x_part.nprocs(), p, "x partition has wrong rank count");
        assert_eq!(y_part.nprocs(), p, "y partition has wrong rank count");
        let mut mailbox = Mailbox::new(p);
        for r in 0..p {
            if r != me {
                ctx.send_draining(
                    r,
                    Msg::Window {
                        from: me,
                        rows: row_win,
                        cols: col_win,
                    },
                    |m| mailbox.put(m),
                );
            }
        }
        let mut windows = vec![((0, 0), (0, 0)); p];
        windows[me] = (row_win, col_win);
        for src in 0..p {
            if src != me {
                windows[src] = mailbox.wait_window(ctx, src);
            }
        }
        let seg = |a: (u64, u64), b: (u64, u64)| {
            let (lo, hi) = overlap(a, b);
            (hi > lo).then_some((lo, hi - lo))
        };
        let mut x_send = Vec::new();
        let mut x_recv = Vec::new();
        let mut y_send = Vec::new();
        let mut y_fold = Vec::new();
        for r in 0..p {
            if r != me {
                if let Some((start, len)) = seg(x_part.range(me), windows[r].1) {
                    x_send.push((r, start, len));
                }
                if let Some((start, len)) = seg(col_win, x_part.range(r)) {
                    x_recv.push((r, start, len));
                }
                if let Some((start, len)) = seg(row_win, y_part.range(r)) {
                    y_send.push((r, start, len));
                }
            }
            if let Some((start, len)) = seg(windows[r].0, y_part.range(me)) {
                y_fold.push((r, start, len));
            }
        }
        let x_buf = vec![0.0; (col_win.1 - col_win.0) as usize];
        let y_buf = vec![0.0; (row_win.1 - row_win.0) as usize];
        Self {
            ctx,
            x_part,
            y_part,
            row_win,
            col_win,
            x_send,
            x_recv,
            y_send,
            y_fold,
            mailbox,
            x_buf,
            y_buf,
            stats: DistStats::default(),
        }
    }

    /// This rank's owned half-open range of the input vector.
    pub fn x_owned_range(&self) -> (u64, u64) {
        self.x_part.range(self.ctx.rank)
    }

    /// This rank's owned half-open range of the output vector.
    pub fn y_owned_range(&self) -> (u64, u64) {
        self.y_part.range(self.ctx.rank)
    }

    /// Global input-vector length `n`.
    pub fn x_total(&self) -> u64 {
        self.x_part.total()
    }

    /// Global output-vector length `m`.
    pub fn y_total(&self) -> u64 {
        self.y_part.total()
    }

    /// This rank.
    pub fn rank(&self) -> usize {
        self.ctx.rank
    }

    /// Cluster size `P`.
    pub fn nprocs(&self) -> usize {
        self.ctx.nprocs
    }

    /// Counters accumulated so far.
    pub fn stats(&self) -> &DistStats {
        &self.stats
    }

    /// One distributed `y = A x`: `x_local`/`y_local` are this rank's
    /// owned segments ([`x_owned_range`](Self::x_owned_range) /
    /// [`y_owned_range`](Self::y_owned_range)). Collective — every rank
    /// must call with its own engine and operator. `y_local` is
    /// overwritten.
    pub fn spmv<O: LocalOperator + ?Sized>(
        &mut self,
        op: &mut O,
        x_local: &[f64],
        y_local: &mut [f64],
    ) -> Result<(), DatasetError> {
        let me = self.ctx.rank;
        let (x0, x1) = self.x_part.range(me);
        let (y0, y1) = self.y_part.range(me);
        assert_eq!(x_local.len() as u64, x1 - x0, "x_local != owned x range");
        assert_eq!(y_local.len() as u64, y1 - y0, "y_local != owned y range");

        // 1. Post x halo sends (ascending dest). Draining keeps this
        //    deadlock-free under any channel capacity.
        let ctx = self.ctx;
        let te = Instant::now();
        {
            let _span = trace::span(
                "halo_exchange",
                &[("phase", Tag::S("x_send")), ("rank", Tag::U(me as u64))],
            );
            let mailbox = &mut self.mailbox;
            for &(dest, start, len) in &self.x_send {
                let lo = (start - x0) as usize;
                let vals = x_local[lo..lo + len as usize].to_vec();
                self.stats.halo_bytes_sent += 8 * len;
                self.stats.halo_msgs_sent += 1;
                ctx.send_draining(
                    dest,
                    Msg::XSegment {
                        from: me,
                        start,
                        vals,
                    },
                    |m| mailbox.put(m),
                );
            }
        }
        let te_s = te.elapsed().as_secs_f64();
        self.stats.exchange_s += te_s;

        // 2. Overlap: fetch + decode local blocks while halos fly.
        self.stats.decode_s += op.prefetch()?;

        // 3. Assemble the column-window view of x: own overlap copied
        //    in place, every expected remote segment awaited.
        let tw = Instant::now();
        let span_wait = trace::span(
            "halo_exchange",
            &[("phase", Tag::S("x_wait")), ("rank", Tag::U(me as u64))],
        );
        let (c0, _) = self.col_win;
        self.x_buf.fill(0.0);
        let own = overlap((x0, x1), self.col_win);
        if own.1 > own.0 {
            let src = &x_local[(own.0 - x0) as usize..(own.1 - x0) as usize];
            self.x_buf[(own.0 - c0) as usize..(own.1 - c0) as usize].copy_from_slice(src);
        }
        for &(src, start, len) in &self.x_recv {
            let (got_start, vals) = self.mailbox.wait_x(ctx, src);
            assert_eq!(got_start, start, "x segment from {src} misaligned");
            assert_eq!(vals.len() as u64, len, "x segment from {src} wrong length");
            self.stats.halo_bytes_recv += 8 * len;
            self.stats.halo_msgs_recv += 1;
            let lo = (start - c0) as usize;
            self.x_buf[lo..lo + len as usize].copy_from_slice(&vals);
        }
        drop(span_wait);
        let tw_s = tw.elapsed().as_secs_f64();
        self.stats.exchange_s += tw_s;

        // 4. Local windowed apply.
        let tc = Instant::now();
        let span_apply = trace::span("kernel_exec", &[("rank", Tag::U(me as u64))]);
        let (r0, _) = self.row_win;
        self.y_buf.fill(0.0);
        op.apply(&self.x_buf, c0, &mut self.y_buf, r0);
        drop(span_apply);
        let tc_s = tc.elapsed().as_secs_f64();
        self.stats.compute_s += tc_s;

        // 5. Reduce partials to owners, then fold my owned y in fixed
        //    ascending source order (own partial at own rank position).
        let tr = Instant::now();
        let span_reduce = trace::span(
            "halo_exchange",
            &[("phase", Tag::S("y_reduce")), ("rank", Tag::U(me as u64))],
        );
        {
            let mailbox = &mut self.mailbox;
            for &(owner, start, len) in &self.y_send {
                let lo = (start - r0) as usize;
                let vals = self.y_buf[lo..lo + len as usize].to_vec();
                self.stats.halo_bytes_sent += 8 * len;
                self.stats.halo_msgs_sent += 1;
                ctx.send_draining(
                    owner,
                    Msg::YPartial {
                        from: me,
                        start,
                        vals,
                    },
                    |m| mailbox.put(m),
                );
            }
        }
        y_local.fill(0.0);
        for &(src, start, len) in &self.y_fold {
            if src == me {
                for i in 0..len as usize {
                    y_local[(start - y0) as usize + i] += self.y_buf[(start - r0) as usize + i];
                }
            } else {
                let (got_start, vals) = self.mailbox.wait_y(ctx, src);
                assert_eq!(got_start, start, "y partial from {src} misaligned");
                assert_eq!(vals.len() as u64, len, "y partial from {src} wrong length");
                self.stats.halo_bytes_recv += 8 * len;
                self.stats.halo_msgs_recv += 1;
                for (i, v) in vals.into_iter().enumerate() {
                    y_local[(start - y0) as usize + i] += v;
                }
            }
        }
        drop(span_reduce);
        let tr_s = tr.elapsed().as_secs_f64();
        self.stats.exchange_s += tr_s;
        self.stats.spmvs += 1;
        let (exchange, compute) = dist_histograms();
        exchange.record(te_s + tw_s + tr_s);
        compute.record(tc_s);
        Ok(())
    }

    /// Deterministic all-reduce sum: every rank sends its local value to
    /// every peer and folds all `P` values in ascending rank order (own
    /// value at own position) — identical f64 bits on every rank, every
    /// run. Collective.
    pub fn allreduce_sum(&mut self, value: f64) -> f64 {
        let me = self.ctx.rank;
        let p = self.ctx.nprocs;
        let ctx = self.ctx;
        {
            let mailbox = &mut self.mailbox;
            for r in 0..p {
                if r != me {
                    ctx.send_draining(r, Msg::Scalar { from: me, value }, |m| mailbox.put(m));
                }
            }
        }
        let mut total = 0.0;
        for r in 0..p {
            total += if r == me {
                value
            } else {
                self.mailbox.wait_scalar(ctx, r)
            };
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Cluster;
    use crate::formats::{Coo, LocalInfo};

    #[test]
    fn partitions_follow_the_mapping() {
        let desc = MappingDesc::Rowwise {
            m: 10,
            n: 10,
            starts: vec![0, 3, 6, 8, 10],
        };
        let (x, y) = spmv_partitions(&desc, 10, 10);
        assert_eq!(y.starts, vec![0, 3, 6, 8, 10]);
        assert_eq!(x, y, "square row-wise aliases x to the row chunks");
        let desc = MappingDesc::Colwise {
            m: 10,
            n: 10,
            starts: vec![0, 5, 10],
        };
        let (x, y) = spmv_partitions(&desc, 10, 10);
        assert_eq!(x.starts, vec![0, 5, 10]);
        assert_eq!(x, y);
        let desc = MappingDesc::Block2d {
            m: 9,
            n: 9,
            pr: 2,
            pc: 2,
        };
        let (x, y) = spmv_partitions(&desc, 9, 9);
        assert_eq!(x.starts, even_starts(9, 4));
        assert_eq!(y.starts, even_starts(9, 4));
        // Rectangular (non-square) row-wise: x falls back to even.
        let desc = MappingDesc::Rowwise {
            m: 6,
            n: 9,
            starts: vec![0, 2, 6],
        };
        let (x, y) = spmv_partitions(&desc, 6, 9);
        assert_eq!(y.starts, vec![0, 2, 6]);
        assert_eq!(x.starts, even_starts(9, 2));
    }

    #[test]
    fn overlap_normalizes_empty() {
        assert_eq!(overlap((0, 5), (3, 9)), (3, 5));
        assert_eq!(overlap((0, 5), (5, 9)), (5, 5));
        let (lo, hi) = overlap((7, 9), (0, 3));
        assert_eq!(hi, lo, "disjoint intervals are empty");
    }

    /// Row-wise square: every rank broadcasts its x chunk to all peers
    /// (their column windows span everything), y traffic is zero (rows
    /// are owner-computed). Exact, and strictly below the resident
    /// broadcast for P ≥ 2.
    #[test]
    fn predict_rowwise_is_exact_x_only() {
        let desc = MappingDesc::Rowwise {
            m: 10,
            n: 10,
            starts: vec![0, 3, 6, 8, 10],
        };
        let pred = predict_spmv_comm(&desc, 10, 10);
        assert!(pred.exact);
        assert_eq!(pred.per_rank_sent, vec![3 * 3 * 8, 3 * 3 * 8, 2 * 3 * 8, 2 * 3 * 8]);
        assert_eq!(pred.per_rank_recv, vec![7 * 8, 7 * 8, 8 * 8, 8 * 8]);
        assert_eq!(pred.total_bytes(), (4 - 1) * 10 * 8);
        assert_eq!(pred.broadcast_bytes, 4 * 10 * 8);
        assert!(pred.total_bytes() < pred.broadcast_bytes);
    }

    /// Column-wise is the mirror image: x traffic zero, y partials
    /// reduced to owners.
    #[test]
    fn predict_colwise_mirrors_rowwise() {
        let desc = MappingDesc::Colwise {
            m: 10,
            n: 10,
            starts: vec![0, 3, 6, 8, 10],
        };
        let pred = predict_spmv_comm(&desc, 10, 10);
        assert!(pred.exact);
        assert_eq!(pred.total_bytes(), (4 - 1) * 10 * 8);
    }

    /// Irregular mappings predict with whole-matrix windows and say so.
    #[test]
    fn predict_cyclic_is_upper_bound() {
        let desc = MappingDesc::CyclicRows { m: 12, n: 12, p: 3 };
        let pred = predict_spmv_comm(&desc, 12, 12);
        assert!(!pred.exact);
        // Every rank ships its whole x chunk and a partial for every
        // other rank's whole y chunk.
        assert_eq!(pred.total_bytes(), 2 * (3 - 1) * 12 * 8);
    }

    #[test]
    fn predict_single_rank_is_silent() {
        let desc = MappingDesc::Rowwise {
            m: 8,
            n: 8,
            starts: vec![0, 8],
        };
        let pred = predict_spmv_comm(&desc, 8, 8);
        assert!(pred.exact);
        assert_eq!(pred.total_bytes(), 0);
    }

    fn two_rank_rowwise_parts() -> (Vec<Csr>, Vec<f64>, Vec<f64>) {
        // 4x4 matrix split into two row bands of 2; dense reference.
        let entries = [
            (0u64, 0u64, 2.0),
            (0, 3, 1.0),
            (1, 1, -1.0),
            (2, 0, 4.0),
            (2, 2, 0.5),
            (3, 3, 3.0),
        ];
        let mut parts = Vec::new();
        for rank in 0..2u64 {
            let (r0, r1) = (rank * 2, rank * 2 + 2);
            let info = LocalInfo {
                m: 4,
                n: 4,
                z: entries.len() as u64,
                m_local: 2,
                n_local: 4,
                z_local: 0,
                m_offset: r0,
                n_offset: 0,
            };
            let mut coo = Coo::with_info(info);
            for &(i, j, v) in &entries {
                if i >= r0 && i < r1 {
                    coo.push(i - r0, j, v);
                }
            }
            parts.push(Csr::from_coo(&coo));
        }
        let x = vec![1.0, -2.0, 0.5, 3.0];
        let mut want = vec![0.0; 4];
        for &(i, j, v) in &entries {
            want[i as usize] += v * x[j as usize];
        }
        (parts, x, want)
    }

    /// End-to-end engine on a 2-rank row-wise split: distributed y is
    /// bit-identical to the single-rank fold, and measured halo bytes
    /// match the prediction exactly.
    #[test]
    fn engine_matches_single_rank_bitwise() {
        let (parts, x, want) = two_rank_rowwise_parts();
        let reference = crate::spmv::SpmvParts::Csr(&parts).spmv(&x);
        assert_eq!(reference, want);
        let desc = MappingDesc::Rowwise {
            m: 4,
            n: 4,
            starts: vec![0, 2, 4],
        };
        let pred = predict_spmv_comm(&desc, 4, 4);
        let parts = Arc::new(parts);
        let x = Arc::new(x);
        let desc = Arc::new(desc);
        let cluster = Cluster::new(2, 1);
        let out = cluster.run(move |ctx| {
            let (xp, yp) = spmv_partitions(&desc, 4, 4);
            let mine = std::slice::from_ref(&parts[ctx.rank]);
            let mut op = CsrOperator::new(mine);
            let mut engine = RankEngine::new(
                ctx,
                xp,
                yp,
                op.row_window(),
                op.col_window(),
            );
            let (x0, x1) = engine.x_owned_range();
            let x_local = x[x0 as usize..x1 as usize].to_vec();
            let (y0, y1) = engine.y_owned_range();
            let mut y_local = vec![0.0; (y1 - y0) as usize];
            engine.spmv(&mut op, &x_local, &mut y_local).unwrap();
            (y_local, engine.stats().clone())
        });
        let mut y = Vec::new();
        for (rank, (y_local, stats)) in out.iter().enumerate() {
            y.extend_from_slice(y_local);
            assert_eq!(stats.halo_bytes_sent, pred.per_rank_sent[rank]);
            assert_eq!(stats.halo_bytes_recv, pred.per_rank_recv[rank]);
            assert_eq!(stats.spmvs, 1);
        }
        assert_eq!(y, reference);
    }

    /// The fixed-order scalar all-reduce lands on identical bits on
    /// every rank, equal to the sequential ascending fold.
    #[test]
    fn allreduce_is_rank_order_deterministic() {
        let p = 4;
        let vals: Vec<f64> = (0..p).map(|r| 0.1 + r as f64 * 0.3).collect();
        let want = vals.iter().fold(0.0, |acc, v| acc + v);
        let vals = Arc::new(vals);
        let cluster = Cluster::new(p, 1);
        let out = cluster.run(move |ctx| {
            let xp = VectorPartition::even(4, ctx.nprocs);
            let yp = xp.clone();
            let mut engine = RankEngine::new(ctx, xp, yp, (0, 0), (0, 0));
            engine.allreduce_sum(vals[ctx.rank])
        });
        for got in out {
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }
}
