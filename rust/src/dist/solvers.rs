//! Distributed iterative solvers over a [`RankEngine`]: power
//! iteration, Conjugate Gradient and Lanczos, with every dot/norm
//! reduced through the engine's fixed-rank-order
//! [`allreduce_sum`](RankEngine::allreduce_sum) so all ranks iterate on
//! identical f64 bits (no rank can diverge on a convergence test).
//!
//! All three are collective: every rank of the cluster runs the same
//! solver with its own engine and [`LocalOperator`], holding only its
//! owned vector segments. They require a square operand with
//! `x`-partition == `y`-partition — what [`spmv_partitions`] produces
//! for square matrices — so iterates can feed straight back into the
//! next product.
//!
//! [`spmv_partitions`]: super::spmv_partitions

use crate::coordinator::error::DatasetError;

use super::{LocalOperator, RankEngine};

/// What one rank gets back from a solver run. The scalar fields
/// (`iterations`, `converged`, `residuals`, `value`, `extremal`) are
/// identical on every rank by the all-reduce determinism contract;
/// `x_local` is the rank's owned segment of the final iterate/solution.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Solver name (`"power"`, `"cg"`, `"lanczos"`).
    pub alg: &'static str,
    /// Iterations (matrix applications) executed.
    pub iterations: usize,
    /// Whether the convergence criterion was met within the budget.
    pub converged: bool,
    /// Residual trajectory, one entry per iteration: relative λ change
    /// for power iteration, ‖r‖₂ for CG (including the initial one),
    /// off-diagonal β for Lanczos.
    pub residuals: Vec<f64>,
    /// Headline scalar: dominant-eigenvalue estimate (power, Lanczos
    /// λ_max) or final residual norm (CG).
    pub value: f64,
    /// Lanczos only: Ritz estimates of the extremal eigenvalues
    /// `(λ_min, λ_max)` of the tridiagonal projection.
    pub extremal: Option<(f64, f64)>,
    /// This rank's owned segment of the final vector (eigenvector
    /// iterate for power/Lanczos, solution for CG).
    pub x_local: Vec<f64>,
}

fn local_dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn assert_square(engine: &RankEngine<'_>) {
    assert_eq!(
        engine.x_owned_range(),
        engine.y_owned_range(),
        "solvers need x partition == y partition (square operand)"
    );
}

/// Distributed power iteration: `x ← A x / ‖A x‖₂` until the relative
/// change of `‖A x‖₂` (the dominant-eigenvalue estimate) drops to
/// `tol`. Starts from the deterministic uniform unit vector.
pub fn power_iteration<O: LocalOperator + ?Sized>(
    engine: &mut RankEngine<'_>,
    op: &mut O,
    tol: f64,
    max_iters: usize,
) -> Result<SolveOutcome, DatasetError> {
    assert_square(engine);
    let n = engine.x_total();
    let len = {
        let (lo, hi) = engine.x_owned_range();
        (hi - lo) as usize
    };
    let mut x = vec![1.0 / (n as f64).sqrt(); len];
    let mut y = vec![0.0; len];
    let mut residuals = Vec::new();
    let mut lambda = 0.0f64;
    let mut converged = false;
    let mut iterations = 0;
    for _ in 0..max_iters {
        engine.spmv(op, &x, &mut y)?;
        iterations += 1;
        let norm = engine.allreduce_sum(local_dot(&y, &y)).sqrt();
        if norm == 0.0 {
            // A x = 0: the iterate is in the null space; report it.
            lambda = 0.0;
            converged = true;
            residuals.push(0.0);
            x.clone_from(&y);
            break;
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
        let rel = ((norm - lambda) / norm).abs();
        residuals.push(rel);
        lambda = norm;
        if rel <= tol {
            converged = true;
            break;
        }
    }
    Ok(SolveOutcome {
        alg: "power",
        iterations,
        converged,
        residuals,
        value: lambda,
        extremal: None,
        x_local: x,
    })
}

/// Distributed Conjugate Gradient for `A x = b`, `A` symmetric positive
/// definite. `b_local` is this rank's owned segment of the right-hand
/// side; starts from `x₀ = 0` and converges when
/// `‖r‖₂ ≤ tol · max(‖b‖₂, 1)`. Bails out (converged = false) on
/// `pᵀA p ≤ 0`, the tell of a non-SPD operand or fatal roundoff.
pub fn conjugate_gradient<O: LocalOperator + ?Sized>(
    engine: &mut RankEngine<'_>,
    op: &mut O,
    b_local: &[f64],
    tol: f64,
    max_iters: usize,
) -> Result<SolveOutcome, DatasetError> {
    assert_square(engine);
    let len = b_local.len();
    let mut x = vec![0.0f64; len];
    let mut r = b_local.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0f64; len];
    let mut rr = engine.allreduce_sum(local_dot(&r, &r));
    let stop = tol * rr.sqrt().max(1.0);
    let mut residuals = vec![rr.sqrt()];
    let mut converged = rr.sqrt() <= stop;
    let mut iterations = 0;
    while !converged && iterations < max_iters {
        engine.spmv(op, &p, &mut ap)?;
        iterations += 1;
        let pap = engine.allreduce_sum(local_dot(&p, &ap));
        if pap <= 0.0 {
            break;
        }
        let alpha = rr / pap;
        for i in 0..len {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rr_next = engine.allreduce_sum(local_dot(&r, &r));
        residuals.push(rr_next.sqrt());
        if rr_next.sqrt() <= stop {
            converged = true;
            rr = rr_next;
            break;
        }
        let beta = rr_next / rr;
        for i in 0..len {
            p[i] = r[i] + beta * p[i];
        }
        rr = rr_next;
    }
    Ok(SolveOutcome {
        alg: "cg",
        iterations,
        converged,
        residuals,
        value: rr.sqrt(),
        extremal: None,
        x_local: x,
    })
}

/// Distributed Lanczos: `steps` three-term-recurrence iterations from
/// the deterministic uniform unit vector (no reorthogonalization),
/// yielding the tridiagonal projection `T = tridiag(β, α, β)` and Ritz
/// estimates of the extremal eigenvalues via [`tridiag_extremal_eigs`].
/// Stops early on Lanczos breakdown (β ≈ 0: an exact invariant
/// subspace was found, which only makes the estimates exact).
pub fn lanczos<O: LocalOperator + ?Sized>(
    engine: &mut RankEngine<'_>,
    op: &mut O,
    steps: usize,
) -> Result<SolveOutcome, DatasetError> {
    assert_square(engine);
    assert!(steps > 0, "lanczos needs at least one step");
    let n = engine.x_total();
    let len = {
        let (lo, hi) = engine.x_owned_range();
        (hi - lo) as usize
    };
    let mut v = vec![1.0 / (n as f64).sqrt(); len];
    let mut v_prev = vec![0.0f64; len];
    let mut w = vec![0.0f64; len];
    let mut beta_prev = 0.0f64;
    let mut alphas: Vec<f64> = Vec::with_capacity(steps);
    let mut betas: Vec<f64> = Vec::with_capacity(steps.saturating_sub(1));
    let mut residuals = Vec::with_capacity(steps);
    let mut broke_down = false;
    for _ in 0..steps {
        engine.spmv(op, &v, &mut w)?;
        if beta_prev != 0.0 {
            for (wi, vp) in w.iter_mut().zip(&v_prev) {
                *wi -= beta_prev * vp;
            }
        }
        let alpha = engine.allreduce_sum(local_dot(&w, &v));
        for (wi, vi) in w.iter_mut().zip(&v) {
            *wi -= alpha * vi;
        }
        alphas.push(alpha);
        let beta = engine.allreduce_sum(local_dot(&w, &w)).sqrt();
        residuals.push(beta);
        if alphas.len() == steps {
            break;
        }
        // Breakdown test relative to the spectrum scale seen so far.
        let scale = alphas.iter().fold(beta, |m, a| m.max(a.abs()));
        if beta <= 1e-12 * scale.max(1.0) {
            broke_down = true;
            break;
        }
        betas.push(beta);
        std::mem::swap(&mut v_prev, &mut v);
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / beta;
        }
        beta_prev = beta;
    }
    let extremal = tridiag_extremal_eigs(&alphas, &betas);
    Ok(SolveOutcome {
        alg: "lanczos",
        iterations: alphas.len(),
        converged: broke_down || alphas.len() == steps,
        residuals,
        value: extremal.1,
        extremal: Some(extremal),
        x_local: v,
    })
}

/// Extremal eigenvalues `(λ_min, λ_max)` of the symmetric tridiagonal
/// matrix with diagonal `alphas` and off-diagonal `betas`
/// (`betas.len() == alphas.len() - 1`), via Gershgorin bracketing and
/// Sturm-sequence bisection (the `LDLᵀ` negative-pivot count of
/// `T - x I` equals the number of eigenvalues below `x`). Deterministic
/// and ~80 bisection steps per end — exact to f64 resolution.
pub fn tridiag_extremal_eigs(alphas: &[f64], betas: &[f64]) -> (f64, f64) {
    let n = alphas.len();
    assert!(n > 0, "empty tridiagonal");
    assert_eq!(betas.len(), n - 1, "need one off-diagonal per gap");
    if n == 1 {
        return (alphas[0], alphas[0]);
    }
    let radius = |i: usize| {
        let left = if i > 0 { betas[i - 1].abs() } else { 0.0 };
        let right = if i < n - 1 { betas[i].abs() } else { 0.0 };
        left + right
    };
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (i, &a) in alphas.iter().enumerate() {
        lo = lo.min(a - radius(i));
        hi = hi.max(a + radius(i));
    }
    // Eigenvalues of T strictly below x = negative pivots of the LDL^T
    // factorization of T - x I.
    let count_below = |x: f64| {
        let mut count = 0usize;
        let mut d = alphas[0] - x;
        if d < 0.0 {
            count += 1;
        }
        for i in 1..n {
            if d == 0.0 {
                // Exact zero pivot: perturb infinitesimally (standard
                // Sturm safeguard; bisection absorbs the off-by-one).
                d = -f64::MIN_POSITIVE;
            }
            d = alphas[i] - x - betas[i - 1] * betas[i - 1] / d;
            if d < 0.0 {
                count += 1;
            }
        }
        count
    };
    let bisect = |want: usize| {
        // Smallest x in [lo, hi] with count_below(x) >= want.
        let (mut a, mut b) = (lo, hi + (hi - lo).abs() * 1e-12 + f64::MIN_POSITIVE);
        for _ in 0..80 {
            let mid = 0.5 * (a + b);
            if count_below(mid) >= want {
                b = mid;
            } else {
                a = mid;
            }
        }
        b
    };
    (bisect(1), bisect(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Cluster;
    use crate::dist::{spmv_partitions, CsrOperator, RankEngine};
    use crate::formats::{Coo, Csr, LocalInfo};
    use crate::mapping::MappingDesc;
    use std::sync::Arc;

    /// tridiag(-1, 2, -1) of order 10 has eigenvalues
    /// `2 - 2 cos(kπ/11)`, k = 1..10.
    #[test]
    fn sturm_bisection_nails_known_spectrum() {
        let n = 10;
        let alphas = vec![2.0; n];
        let betas = vec![-1.0; n - 1];
        let (lmin, lmax) = tridiag_extremal_eigs(&alphas, &betas);
        let pi = std::f64::consts::PI;
        let want_min = 2.0 - 2.0 * (pi / 11.0).cos();
        let want_max = 2.0 - 2.0 * (10.0 * pi / 11.0).cos();
        assert!((lmin - want_min).abs() < 1e-9, "λ_min {lmin} vs {want_min}");
        assert!((lmax - want_max).abs() < 1e-9, "λ_max {lmax} vs {want_max}");
    }

    #[test]
    fn tridiag_degenerate_orders() {
        assert_eq!(tridiag_extremal_eigs(&[3.5], &[]), (3.5, 3.5));
        let (lmin, lmax) = tridiag_extremal_eigs(&[1.0, 1.0], &[0.0]);
        assert!((lmin - 1.0).abs() < 1e-9 && (lmax - 1.0).abs() < 1e-9);
    }

    /// CG on a tiny SPD system, single rank: the engine path must find
    /// the exact algebraic solution.
    #[test]
    fn cg_solves_small_spd_single_rank() {
        // [[4, 1], [1, 3]] x = [1, 2] → x = (1/11, 7/11).
        let info = LocalInfo::whole(2, 2, 4);
        let mut coo = Coo::with_info(info);
        coo.push(0, 0, 4.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 3.0);
        let parts = Arc::new(vec![Csr::from_coo(&coo)]);
        let cluster = Cluster::new(1, 4);
        let out = cluster.run(move |ctx| {
            let desc = MappingDesc::Rowwise {
                m: 2,
                n: 2,
                starts: vec![0, 2],
            };
            let (xp, yp) = spmv_partitions(&desc, 2, 2);
            let mut op = CsrOperator::new(&parts);
            let mut engine =
                RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            conjugate_gradient(&mut engine, &mut op, &[1.0, 2.0], 1e-12, 100).unwrap()
        });
        let got = &out[0];
        assert!(got.converged, "residuals: {:?}", got.residuals);
        assert!((got.x_local[0] - 1.0 / 11.0).abs() < 1e-10);
        assert!((got.x_local[1] - 7.0 / 11.0).abs() < 1e-10);
        assert!(got.iterations <= 2, "2x2 CG converges in ≤ 2 steps");
    }

    /// Power iteration on a diagonal matrix finds the dominant entry.
    #[test]
    fn power_finds_dominant_eigenvalue() {
        let info = LocalInfo::whole(3, 3, 3);
        let mut coo = Coo::with_info(info);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 5.0);
        coo.push(2, 2, 2.0);
        let parts = Arc::new(vec![Csr::from_coo(&coo)]);
        let cluster = Cluster::new(1, 4);
        let out = cluster.run(move |ctx| {
            let desc = MappingDesc::Rowwise {
                m: 3,
                n: 3,
                starts: vec![0, 3],
            };
            let (xp, yp) = spmv_partitions(&desc, 3, 3);
            let mut op = CsrOperator::new(&parts);
            let mut engine =
                RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            power_iteration(&mut engine, &mut op, 1e-10, 500).unwrap()
        });
        let got = &out[0];
        assert!(got.converged);
        assert!((got.value - 5.0).abs() < 1e-6, "λ = {}", got.value);
    }
}
