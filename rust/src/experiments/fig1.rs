//! Figure 1 experiment: "Measured time of the process of loading matrices
//! from the file system to memory for different configurations."
//!
//! Reproduces the paper's §4 protocol, scaled to this testbed:
//!
//! * workload — cage-like seed enlarged by a Kronecker product (the
//!   paper's cage12-based generator, ref [4]);
//! * storage — `P_store` processes, **balanced row-wise** mapping (equal
//!   amortized nonzeros per process), ABHSF files;
//! * case 1 — loading with the same configuration;
//! * case 2 — loading with `P_load` processes and a **regular
//!   column-wise** mapping, for both HDF5-style I/O strategies
//!   (independent / collective), sweeping `P_load`;
//! * extension — the exchange loader (paper's future-work) as a third
//!   series.
//!
//! Each case reports the measured wall time on the local FS and the
//! simulated Anselm/Lustre makespan from the calibrated cost model fed
//! with the *measured* per-rank I/O traces.

use std::sync::Arc;

use crate::coordinator::{Cluster, Dataset, InMemFormat, StoreOptions, Strategy};
use crate::gen::{KroneckerGen, SeedMatrix};
use crate::mapping::{Colwise, ProcessMapping};
use crate::parfs::FsModel;
use crate::util::bench::Table;
use crate::util::human;

/// Configuration for one Figure-1 run.
#[derive(Debug, Clone)]
pub struct Fig1Config {
    /// Cage-like seed dimension.
    pub seed_n: u64,
    /// Kronecker order.
    pub order: u32,
    /// Storing process count (the paper used 60).
    pub p_store: usize,
    /// Loading process counts to sweep (the paper used 15..60).
    pub p_loads: Vec<usize>,
    /// ABHSF block size.
    pub block_size: u64,
    /// RNG seed for the matrix.
    pub rng_seed: u64,
    /// Repetitions per point (wall-clock median).
    pub reps: usize,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Self {
            seed_n: 12,
            order: 2,
            p_store: 6,
            p_loads: vec![2, 3, 4, 6, 8],
            block_size: 32,
            rng_seed: 42,
            reps: 3,
        }
    }
}

/// One row of the Figure-1 table.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    /// Scenario label.
    pub scenario: String,
    /// Loading process count.
    pub p_load: usize,
    /// Median measured wall time, s.
    pub wall_s: f64,
    /// Simulated Lustre makespan, s.
    pub sim_s: f64,
    /// Bytes read (sum over ranks).
    pub read_bytes: u64,
    /// Loaded nonzeros.
    pub nnz: u64,
    /// Blocks examined across ranks (block-pruned scenarios; 0 otherwise).
    pub blocks_total: u64,
    /// Blocks skipped across ranks without fetching payload.
    pub blocks_skipped: u64,
}

impl Fig1Row {
    /// `skipped/total` as a percentage string, `-` for unpruned paths.
    pub fn prune_label(&self) -> String {
        if self.blocks_total == 0 {
            "-".into()
        } else {
            format!(
                "{:.1}%",
                self.blocks_skipped as f64 / self.blocks_total as f64 * 100.0
            )
        }
    }
}

/// Run the experiment; returns all rows (and prints them when `verbose`).
pub fn run_fig1(cfg: &Fig1Config, verbose: bool) -> anyhow::Result<Vec<Fig1Row>> {
    let model = FsModel::anselm_lustre();
    let gen = Arc::new(KroneckerGen::new(
        SeedMatrix::cage_like(cfg.seed_n, cfg.rng_seed),
        cfg.order,
    ));
    let n = gen.dim();
    let dir = std::env::temp_dir().join(format!(
        "abhsf-fig1-{}-{}-{}",
        std::process::id(),
        cfg.seed_n,
        cfg.p_store
    ));
    let _ = std::fs::remove_dir_all(&dir);

    // Store once with the paper's configuration: balanced row-wise.
    let store_map: Arc<dyn ProcessMapping> = Arc::new(gen.balanced_rowwise(cfg.p_store));
    let store_cluster = Cluster::new(cfg.p_store, 64);
    let (dataset, sreport) = Dataset::store(
        &store_cluster,
        &gen,
        &store_map,
        &dir,
        StoreOptions {
            block_size: cfg.block_size,
            ..Default::default()
        },
    )?;
    if verbose {
        println!(
            "workload: {} x {}, {} nnz, {} ABHSF payload in {} files\n",
            human::count(n),
            human::count(n),
            human::count(gen.nnz()),
            human::bytes(sreport.total_bytes()),
            cfg.p_store,
        );
    }

    let mut rows = Vec::new();

    // Case 1: same configuration — `Strategy::Auto` on a matching
    // configuration must take the fast path.
    {
        let cluster = Cluster::new(cfg.p_store, 64);
        let mut walls = Vec::new();
        let mut last = None;
        for _ in 0..cfg.reps {
            let (_, report) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
            debug_assert!(report.auto.as_ref().is_some_and(|a| a.same_config));
            walls.push(report.wall_s);
            last = Some(report);
        }
        let report = last.unwrap();
        rows.push(Fig1Row {
            scenario: "same-config".into(),
            p_load: cfg.p_store,
            wall_s: median(&mut walls),
            sim_s: report.simulate(&model).makespan_s,
            read_bytes: report.total_read_bytes(),
            nnz: report.total_nnz(),
            blocks_total: report.blocks_total(),
            blocks_skipped: report.blocks_skipped(),
        });
    }

    // Case 2: different configuration (column-wise regular), both
    // strategies, plus the exchange extension. The three paper-literal
    // series run with pruning OFF — Figure 1's shape claims (independent
    // ~flat, P x unique bytes) describe the decode-everything §3 loop;
    // a fourth series shows what block pruning does to the same remap.
    for &p_load in &cfg.p_loads {
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let cluster = Cluster::new(p_load, 64);
        let series = [
            (Strategy::Independent, false, "diff/independent".to_string()),
            (Strategy::Collective, false, "diff/collective".to_string()),
            (Strategy::Exchange, false, "diff/exchange".to_string()),
            (Strategy::Independent, true, "diff/independent+prune".to_string()),
        ];
        for (strategy, prune, scenario) in series {
            let mut walls = Vec::new();
            let mut last = None;
            for _ in 0..cfg.reps {
                let (_, report) = dataset
                    .load()
                    .nprocs(p_load)
                    .mapping(&mapping)
                    .strategy(strategy)
                    .prune(prune)
                    .format(InMemFormat::Csr)
                    .run(&cluster)?;
                walls.push(report.wall_s);
                last = Some(report);
            }
            let report = last.unwrap();
            rows.push(Fig1Row {
                scenario,
                p_load,
                wall_s: median(&mut walls),
                sim_s: report.simulate(&model).makespan_s,
                read_bytes: report.total_read_bytes(),
                nnz: report.total_nnz(),
                blocks_total: report.blocks_total(),
                blocks_skipped: report.blocks_skipped(),
            });
        }
    }

    if verbose {
        let mut t = Table::new(&[
            "scenario",
            "P_load",
            "wall [s]",
            "sim Lustre [s]",
            "read",
            "blk skip",
            "nnz",
        ]);
        for r in &rows {
            t.row(&[
                r.scenario.clone(),
                r.p_load.to_string(),
                format!("{:.4}", r.wall_s),
                format!("{:.3}", r.sim_s),
                human::bytes(r.read_bytes),
                r.prune_label(),
                human::count(r.nnz),
            ]);
        }
        t.print();
        let same = rows.iter().find(|r| r.scenario == "same-config").unwrap();
        println!(
            "\npaper shape checks: same-config fastest (sim {:.3}s); \
             independent ~flat and << T_same x P; collective slowest",
            same.sim_s
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
    Ok(rows)
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_small_run_has_expected_shape() {
        let cfg = Fig1Config {
            seed_n: 8,
            order: 2,
            p_store: 3,
            p_loads: vec![2, 4],
            block_size: 16,
            rng_seed: 7,
            reps: 1,
        };
        let rows = run_fig1(&cfg, false).unwrap();
        // 1 same-config + 4 scenarios x 2 loader counts.
        assert_eq!(rows.len(), 1 + 4 * 2);
        let same = rows.iter().find(|r| r.scenario == "same-config").unwrap();
        let nnz = same.nnz;
        for r in &rows {
            assert_eq!(r.nnz, nnz, "{}: loaded nnz differs", r.scenario);
        }
        // Simulated ordering (the paper's headline): same < indep < coll,
        // and the pruned series must skip blocks without reading more.
        for &p in &[2usize, 4] {
            let indep = rows
                .iter()
                .find(|r| r.scenario == "diff/independent" && r.p_load == p)
                .unwrap();
            let coll = rows
                .iter()
                .find(|r| r.scenario == "diff/collective" && r.p_load == p)
                .unwrap();
            assert!(same.sim_s < indep.sim_s, "P={p}");
            assert!(indep.sim_s < coll.sim_s, "P={p}");
            let pruned = rows
                .iter()
                .find(|r| r.scenario == "diff/independent+prune" && r.p_load == p)
                .unwrap();
            assert!(pruned.blocks_skipped > 0, "P={p}: remap must prune");
            assert!(pruned.blocks_total > pruned.blocks_skipped, "P={p}");
            assert!(pruned.read_bytes <= indep.read_bytes, "P={p}");
            assert_eq!(indep.blocks_total, 0, "P={p}: unpruned counts no blocks");
        }
    }
}
