//! Shared experiment drivers: the code that regenerates the paper's
//! figure and the ablation tables. Used by both the CLI (`abhsf fig1`)
//! and the bench binaries (`cargo bench`), so numbers in either path come
//! from the same implementation.

pub mod fig1;

pub use fig1::{run_fig1, Fig1Config, Fig1Row};
