//! Coordinate (COO) in-memory sparse format.
//!
//! Stores the local submatrix of one process as parallel `rows/cols/vals`
//! arrays in *local* coordinates. This is one of the two in-memory formats
//! the paper's store/load pipeline converts from/to (refs [1, 6]).

use crate::formats::element::{Element, LocalInfo};

/// COO storage of a local submatrix.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Coo {
    /// Shared matrix/submatrix metadata.
    pub info: LocalInfo,
    /// Local row indices of nonzeros.
    pub rows: Vec<u64>,
    /// Local column indices of nonzeros.
    pub cols: Vec<u64>,
    /// Values of nonzeros.
    pub vals: Vec<f64>,
}

impl Coo {
    /// Empty COO with the given metadata (z_local is updated as elements
    /// are pushed).
    pub fn with_info(info: LocalInfo) -> Self {
        Self {
            info,
            rows: Vec::new(),
            cols: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Append a nonzero in local coordinates.
    pub fn push(&mut self, row: u64, col: u64, val: f64) {
        debug_assert!(row < self.info.m_local, "row {row} >= m_local {}", self.info.m_local);
        debug_assert!(col < self.info.n_local, "col {col} >= n_local {}", self.info.n_local);
        self.rows.push(row);
        self.cols.push(col);
        self.vals.push(val);
        self.info.z_local = self.vals.len() as u64;
    }

    /// Build from a list of elements (local coordinates).
    pub fn from_elements(info: LocalInfo, elements: &[Element]) -> Self {
        let mut coo = Self::with_info(info);
        coo.rows.reserve(elements.len());
        coo.cols.reserve(elements.len());
        coo.vals.reserve(elements.len());
        for e in elements {
            coo.push(e.row, e.col, e.val);
        }
        coo
    }

    /// View as a vector of elements (local coordinates).
    pub fn to_elements(&self) -> Vec<Element> {
        (0..self.nnz())
            .map(|i| Element::new(self.rows[i], self.cols[i], self.vals[i]))
            .collect()
    }

    /// Iterate `(local_row, local_col, val)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64, f64)> + '_ {
        (0..self.nnz()).map(move |i| (self.rows[i], self.cols[i], self.vals[i]))
    }

    /// Sort in place lexicographically by (row, col).
    pub fn sort(&mut self) {
        let mut perm: Vec<usize> = (0..self.nnz()).collect();
        perm.sort_unstable_by_key(|&i| (self.rows[i], self.cols[i]));
        self.rows = perm.iter().map(|&i| self.rows[i]).collect();
        self.cols = perm.iter().map(|&i| self.cols[i]).collect();
        self.vals = perm.iter().map(|&i| self.vals[i]).collect();
    }

    /// Sort and sum duplicate coordinates.
    pub fn sort_dedup(&mut self) {
        self.sort();
        let n = self.nnz();
        if n == 0 {
            return;
        }
        let mut w = 0usize;
        for r in 1..n {
            if self.rows[r] == self.rows[w] && self.cols[r] == self.cols[w] {
                self.vals[w] += self.vals[r];
            } else {
                w += 1;
                self.rows[w] = self.rows[r];
                self.cols[w] = self.cols[r];
                self.vals[w] = self.vals[r];
            }
        }
        self.rows.truncate(w + 1);
        self.cols.truncate(w + 1);
        self.vals.truncate(w + 1);
        self.info.z_local = self.vals.len() as u64;
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        self.info.validate()?;
        if self.rows.len() != self.vals.len() || self.cols.len() != self.vals.len() {
            return Err("rows/cols/vals length mismatch".into());
        }
        if self.info.z_local as usize != self.vals.len() {
            return Err(format!(
                "z_local={} but {} stored elements",
                self.info.z_local,
                self.vals.len()
            ));
        }
        for i in 0..self.nnz() {
            if self.rows[i] >= self.info.m_local {
                return Err(format!("element {i}: row {} >= m_local {}", self.rows[i], self.info.m_local));
            }
            if self.cols[i] >= self.info.n_local {
                return Err(format!("element {i}: col {} >= n_local {}", self.cols[i], self.info.n_local));
            }
        }
        Ok(())
    }

    /// Local SpMV contribution: `y[global_i] += val * x[global_j]` for every
    /// stored nonzero. `x` has global length `n`, `y` global length `m`.
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len() as u64, self.info.n, "x length != n");
        assert_eq!(y.len() as u64, self.info.m, "y length != m");
        let ro = self.info.m_offset as usize;
        let co = self.info.n_offset as usize;
        for i in 0..self.nnz() {
            y[ro + self.rows[i] as usize] += self.vals[i] * x[co + self.cols[i] as usize];
        }
    }

    /// In-memory size in bytes of the payload arrays, using the paper's
    /// experimental representation (f64 values, 32-bit indexes).
    pub fn payload_bytes_paper(&self) -> u64 {
        (self.nnz() as u64) * (8 + 4 + 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        // 4x5 local window at global offset (2, 1) of a 10x10 matrix.
        let info = LocalInfo {
            m: 10,
            n: 10,
            z: 4,
            m_local: 4,
            n_local: 5,
            z_local: 0,
            m_offset: 2,
            n_offset: 1,
        };
        let mut coo = Coo::with_info(info);
        coo.push(3, 0, 1.0);
        coo.push(0, 4, 2.0);
        coo.push(0, 1, 3.0);
        coo.push(2, 2, 4.0);
        coo
    }

    #[test]
    fn push_and_validate() {
        let coo = sample();
        assert_eq!(coo.nnz(), 4);
        assert!(coo.validate().is_ok());
    }

    #[test]
    fn sort_orders_lexicographically() {
        let mut coo = sample();
        coo.sort();
        let order: Vec<(u64, u64)> = coo.iter().map(|(r, c, _)| (r, c)).collect();
        assert_eq!(order, vec![(0, 1), (0, 4), (2, 2), (3, 0)]);
        assert!(coo.validate().is_ok());
    }

    #[test]
    fn dedup_sums_duplicates() {
        let info = LocalInfo::whole(3, 3, 0);
        let mut coo = Coo::with_info(info);
        coo.push(1, 1, 2.0);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 3.0);
        coo.sort_dedup();
        assert_eq!(coo.nnz(), 2);
        assert_eq!(coo.iter().collect::<Vec<_>>(), vec![(0, 0, 1.0), (1, 1, 5.0)]);
    }

    #[test]
    fn element_roundtrip() {
        let coo = sample();
        let elems = coo.to_elements();
        let coo2 = Coo::from_elements(coo.info, &elems);
        assert_eq!(coo, coo2);
    }

    #[test]
    fn spmv_offsets_respected() {
        let coo = sample();
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut y = vec![0.0; 10];
        coo.spmv_into(&x, &mut y);
        // element (3,0,1.0) -> y[5] += 1.0 * x[1] = 1
        // element (0,4,2.0) -> y[2] += 2.0 * x[5] = 10
        // element (0,1,3.0) -> y[2] += 3.0 * x[2] = 6
        // element (2,2,4.0) -> y[4] += 4.0 * x[3] = 12
        assert_eq!(y[5], 1.0);
        assert_eq!(y[2], 16.0);
        assert_eq!(y[4], 12.0);
        assert_eq!(y.iter().sum::<f64>(), 29.0);
    }

    #[test]
    fn validate_rejects_out_of_window() {
        let mut coo = sample();
        coo.rows.push(99);
        coo.cols.push(0);
        coo.vals.push(1.0);
        coo.info.z_local += 1;
        assert!(coo.validate().is_err());
    }

    #[test]
    fn paper_payload_bytes() {
        let coo = sample();
        assert_eq!(coo.payload_bytes_paper(), 4 * 16);
    }
}
