//! Compressed Sparse Rows (CSR) in-memory format.
//!
//! Mirrors the paper's `csr` structure: shared metadata plus
//! `vals[] / colinds[] / rowptrs[]` in local coordinates. This is the output
//! format of the loading Algorithms 1–6.

use crate::formats::coo::Coo;
use crate::formats::element::{Element, LocalInfo};

/// CSR storage of a local submatrix.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Csr {
    /// Shared matrix/submatrix metadata.
    pub info: LocalInfo,
    /// Values of nonzero elements, row-major.
    pub vals: Vec<f64>,
    /// Local column indexes of nonzero elements.
    pub colinds: Vec<u64>,
    /// Row pointers: `rowptrs[i]..rowptrs[i+1]` indexes row i's data.
    /// Length `m_local + 1` when complete.
    pub rowptrs: Vec<u64>,
}

impl Csr {
    /// Empty CSR (no rows finalized yet) with given metadata.
    pub fn with_info(info: LocalInfo) -> Self {
        Self {
            info,
            vals: Vec::new(),
            colinds: Vec::new(),
            rowptrs: Vec::new(),
        }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Build from a COO (sorted + deduplicated internally; the input is not
    /// required to be sorted).
    pub fn from_coo(coo: &Coo) -> Self {
        let mut sorted = coo.clone();
        sorted.sort_dedup();
        let mut csr = Csr::with_info(sorted.info);
        csr.vals.reserve(sorted.nnz());
        csr.colinds.reserve(sorted.nnz());
        csr.rowptrs.reserve(sorted.info.m_local as usize + 1);
        let mut row = 0u64;
        csr.rowptrs.push(0);
        for (r, c, v) in sorted.iter() {
            while row < r {
                csr.rowptrs.push(csr.vals.len() as u64);
                row += 1;
            }
            csr.colinds.push(c);
            csr.vals.push(v);
        }
        while row < sorted.info.m_local {
            csr.rowptrs.push(csr.vals.len() as u64);
            row += 1;
        }
        csr.info.z_local = csr.vals.len() as u64;
        csr
    }

    /// Convert to COO (sorted by construction).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_info(self.info);
        for r in 0..self.info.m_local as usize {
            let (lo, hi) = self.row_range(r);
            for k in lo..hi {
                coo.push(r as u64, self.colinds[k], self.vals[k]);
            }
        }
        coo
    }

    /// Elements in lexicographic order.
    pub fn to_elements(&self) -> Vec<Element> {
        self.to_coo().to_elements()
    }

    /// Index range of row `r`'s data.
    pub fn row_range(&self, r: usize) -> (usize, usize) {
        (self.rowptrs[r] as usize, self.rowptrs[r + 1] as usize)
    }

    /// Iterate one row's `(local_col, val)` pairs.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (u64, f64)> + '_ {
        let (lo, hi) = self.row_range(r);
        (lo..hi).map(move |k| (self.colinds[k], self.vals[k]))
    }

    /// Validate the CSR invariants: monotone rowptrs of full length,
    /// column indexes within the window, columns sorted within rows.
    pub fn validate(&self) -> Result<(), String> {
        self.info.validate()?;
        if self.rowptrs.len() != self.info.m_local as usize + 1 {
            return Err(format!(
                "rowptrs length {} != m_local+1 = {}",
                self.rowptrs.len(),
                self.info.m_local + 1
            ));
        }
        if self.rowptrs[0] != 0 {
            return Err("rowptrs[0] != 0".into());
        }
        if *self.rowptrs.last().unwrap() as usize != self.vals.len() {
            return Err(format!(
                "rowptrs last {} != nnz {}",
                self.rowptrs.last().unwrap(),
                self.vals.len()
            ));
        }
        if self.colinds.len() != self.vals.len() {
            return Err("colinds/vals length mismatch".into());
        }
        if self.info.z_local as usize != self.vals.len() {
            return Err(format!(
                "z_local={} but {} stored elements",
                self.info.z_local,
                self.vals.len()
            ));
        }
        for r in 0..self.info.m_local as usize {
            let (lo, hi) = self.row_range(r);
            if lo > hi {
                return Err(format!("rowptrs not monotone at row {r}"));
            }
            for k in lo..hi {
                if self.colinds[k] >= self.info.n_local {
                    return Err(format!(
                        "row {r}: col {} >= n_local {}",
                        self.colinds[k], self.info.n_local
                    ));
                }
                if k > lo && self.colinds[k] <= self.colinds[k - 1] {
                    return Err(format!("row {r}: columns not strictly increasing at {k}"));
                }
            }
        }
        Ok(())
    }

    /// Local SpMV contribution into global vectors (see [`Coo::spmv_into`]).
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len() as u64, self.info.n, "x length != n");
        assert_eq!(y.len() as u64, self.info.m, "y length != m");
        self.spmv_windowed_into(x, 0, y, 0);
    }

    /// Local SpMV contribution into *windowed* vectors: `x_win` holds the
    /// global entries `[x_off, x_off + x_win.len())` of `x`, `y_win` the
    /// global entries `[y_off, y_off + y_win.len())` of `y`. Both windows
    /// must cover this part's local column/row span. The accumulation
    /// order per row is identical to [`spmv_into`] (one accumulator per
    /// row, one add into `y` per row), so a windowed apply is bitwise
    /// equal to the global one — the distributed engine's determinism
    /// contract (DESIGN.md §13) rests on this.
    pub fn spmv_windowed_into(&self, x_win: &[f64], x_off: u64, y_win: &mut [f64], y_off: u64) {
        assert!(
            x_off <= self.info.n_offset
                && self.info.n_offset + self.info.n_local <= x_off + x_win.len() as u64,
            "x window [{x_off}, +{}) does not cover columns [{}, +{})",
            x_win.len(),
            self.info.n_offset,
            self.info.n_local
        );
        assert!(
            y_off <= self.info.m_offset
                && self.info.m_offset + self.info.m_local <= y_off + y_win.len() as u64,
            "y window [{y_off}, +{}) does not cover rows [{}, +{})",
            y_win.len(),
            self.info.m_offset,
            self.info.m_local
        );
        let ro = (self.info.m_offset - y_off) as usize;
        let co = (self.info.n_offset - x_off) as usize;
        for r in 0..self.info.m_local as usize {
            let (lo, hi) = self.row_range(r);
            let mut acc = 0.0;
            for k in lo..hi {
                acc += self.vals[k] * x_win[co + self.colinds[k] as usize];
            }
            y_win[ro + r] += acc;
        }
    }

    /// In-memory payload bytes with the paper's representation
    /// (f64 values, 32-bit column indexes and row pointers).
    pub fn payload_bytes_paper(&self) -> u64 {
        self.nnz() as u64 * (8 + 4) + self.rowptrs.len() as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_coo() -> Coo {
        let info = LocalInfo {
            m: 8,
            n: 8,
            z: 5,
            m_local: 4,
            n_local: 4,
            z_local: 0,
            m_offset: 4,
            n_offset: 4,
        };
        let mut coo = Coo::with_info(info);
        coo.push(2, 3, 5.0);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(3, 1, 4.0);
        coo.push(2, 0, 3.0);
        coo
    }

    #[test]
    fn from_coo_structure() {
        let csr = Csr::from_coo(&sample_coo());
        assert!(csr.validate().is_ok());
        assert_eq!(csr.rowptrs, vec![0, 2, 2, 4, 5]);
        assert_eq!(csr.colinds, vec![0, 2, 0, 3, 1]);
        assert_eq!(csr.vals, vec![1.0, 2.0, 3.0, 5.0, 4.0]);
    }

    #[test]
    fn coo_roundtrip_canonical() {
        let mut coo = sample_coo();
        let csr = Csr::from_coo(&coo);
        let back = csr.to_coo();
        coo.sort_dedup();
        assert_eq!(coo, back);
    }

    /// A windowed apply over exactly the local span is bitwise equal to
    /// the global-vector apply (same per-row accumulation order).
    #[test]
    fn windowed_spmv_bitwise_matches_global() {
        let csr = Csr::from_coo(&sample_coo());
        let x: Vec<f64> = (0..8).map(|i| 1.0 + i as f64 * 0.375).collect();
        let mut y_global = vec![0.0f64; 8];
        csr.spmv_into(&x, &mut y_global);

        // Tight windows: columns [4, 8), rows [4, 8).
        let x_win = &x[4..8];
        let mut y_win = vec![0.0f64; 4];
        csr.spmv_windowed_into(x_win, 4, &mut y_win, 4);
        assert_eq!(&y_global[4..8], y_win.as_slice());

        // A wider-than-tight window lands on the same bits too.
        let mut y_wide = vec![0.0f64; 6];
        csr.spmv_windowed_into(&x[2..8], 2, &mut y_wide, 2);
        assert_eq!(&y_global[4..8], &y_wide[2..6]);
    }

    #[test]
    fn spmv_matches_coo() {
        let coo = sample_coo();
        let csr = Csr::from_coo(&coo);
        let x: Vec<f64> = (0..8).map(|i| (i as f64) * 0.5 + 1.0).collect();
        let mut y1 = vec![0.0; 8];
        let mut y2 = vec![0.0; 8];
        coo.spmv_into(&x, &mut y1);
        csr.spmv_into(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn empty_rows_handled() {
        let info = LocalInfo::whole(5, 5, 0);
        let coo = Coo::with_info(info);
        let csr = Csr::from_coo(&coo);
        assert!(csr.validate().is_ok());
        assert_eq!(csr.rowptrs, vec![0; 6]);
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn row_iteration() {
        let csr = Csr::from_coo(&sample_coo());
        let row2: Vec<(u64, f64)> = csr.row(2).collect();
        assert_eq!(row2, vec![(0, 3.0), (3, 5.0)]);
        let row1: Vec<(u64, f64)> = csr.row(1).collect();
        assert!(row1.is_empty());
    }

    #[test]
    fn validate_catches_unsorted_columns() {
        let mut csr = Csr::from_coo(&sample_coo());
        csr.colinds.swap(0, 1);
        assert!(csr.validate().is_err());
    }

    #[test]
    fn dedup_in_from_coo() {
        let info = LocalInfo::whole(2, 2, 0);
        let mut coo = Coo::with_info(info);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.nnz(), 1);
        assert_eq!(csr.vals[0], 3.0);
    }
}
