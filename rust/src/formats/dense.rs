//! Small dense matrix — used as the correctness oracle in tests and as the
//! decoded form of ABHSF dense blocks.

use crate::formats::coo::Coo;
use crate::formats::element::LocalInfo;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    /// Rows.
    pub nrows: usize,
    /// Columns.
    pub ncols: usize,
    /// Row-major data, `nrows * ncols` entries.
    pub data: Vec<f64>,
}

impl Dense {
    /// Zero matrix.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Self {
            nrows,
            ncols,
            data: vec![0.0; nrows * ncols],
        }
    }

    /// Entry accessor.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.ncols + j]
    }

    /// Entry mutator.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.ncols + j] = v;
    }

    /// Densify a local COO (local window only).
    pub fn from_coo(coo: &Coo) -> Self {
        let mut d = Self::zeros(coo.info.m_local as usize, coo.info.n_local as usize);
        for (r, c, v) in coo.iter() {
            let cell = &mut d.data[r as usize * d.ncols + c as usize];
            *cell += v;
        }
        d
    }

    /// Sparsify into COO with the given metadata (z_local recomputed).
    pub fn to_coo(&self, mut info: LocalInfo) -> Coo {
        assert_eq!(info.m_local as usize, self.nrows);
        assert_eq!(info.n_local as usize, self.ncols);
        info.z_local = 0;
        let mut coo = Coo::with_info(info);
        for i in 0..self.nrows {
            for j in 0..self.ncols {
                let v = self.get(i, j);
                if v != 0.0 {
                    coo.push(i as u64, j as u64, v);
                }
            }
        }
        coo
    }

    /// Count of nonzero entries.
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    /// Dense mat-vec: `y = A x` over the local window.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for i in 0..self.nrows {
            let row = &self.data[i * self.ncols..(i + 1) * self.ncols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_accessors() {
        let mut d = Dense::zeros(2, 3);
        assert_eq!(d.nnz(), 0);
        d.set(1, 2, 4.5);
        assert_eq!(d.get(1, 2), 4.5);
        assert_eq!(d.nnz(), 1);
    }

    #[test]
    fn coo_roundtrip() {
        let info = LocalInfo::whole(3, 3, 0);
        let mut coo = Coo::with_info(info);
        coo.push(0, 1, 2.0);
        coo.push(2, 2, -1.0);
        coo.push(1, 0, 3.5);
        let d = Dense::from_coo(&coo);
        assert_eq!(d.get(0, 1), 2.0);
        assert_eq!(d.get(2, 2), -1.0);
        let mut back = d.to_coo(info);
        back.sort();
        let mut orig = coo.clone();
        orig.sort();
        assert_eq!(orig, back);
    }

    #[test]
    fn matvec_oracle() {
        let mut d = Dense::zeros(2, 2);
        d.set(0, 0, 1.0);
        d.set(0, 1, 2.0);
        d.set(1, 1, 3.0);
        let y = d.matvec(&[10.0, 100.0]);
        assert_eq!(y, vec![210.0, 300.0]);
    }

    #[test]
    fn from_coo_sums_duplicates() {
        let info = LocalInfo::whole(1, 1, 0);
        let mut coo = Coo::with_info(info);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 2.0);
        let d = Dense::from_coo(&coo);
        assert_eq!(d.get(0, 0), 3.0);
    }
}
