//! Single nonzero element and local-submatrix metadata shared by all
//! in-memory and on-disk formats (`element_t` and the common header fields
//! of the paper's `csr` / `abhsf` structures).

use std::cmp::Ordering;

/// One nonzero element in *local* coordinates (0-based, relative to the
/// owning process's submatrix origin `(m_offset, n_offset)`).
///
/// Mirrors the paper's `element_t { row; col; val; }`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Element {
    /// Local row index.
    pub row: u64,
    /// Local column index.
    pub col: u64,
    /// Value.
    pub val: f64,
}

impl Element {
    /// Construct an element.
    pub fn new(row: u64, col: u64, val: f64) -> Self {
        Self { row, col, val }
    }

    /// Lexicographic (row, col) comparison — the sort order Algorithm 1
    /// applies to the per-block-row `elements` buffer.
    pub fn cmp_lex(&self, other: &Self) -> Ordering {
        (self.row, self.col).cmp(&(other.row, other.col))
    }
}

/// Sort a buffer of elements lexicographically by (row, col).
pub fn sort_lex(elements: &mut [Element]) {
    elements.sort_unstable_by(|a, b| a.cmp_lex(b));
}

/// Shared matrix/submatrix metadata: the global shape plus the local
/// window this process owns. Corresponds to the common attribute prefix of
/// the paper's `abhsf` and `csr` structures (`m, n, z, m_local, n_local,
/// z_local, m_offset, n_offset`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LocalInfo {
    /// Global number of rows `m`.
    pub m: u64,
    /// Global number of columns `n`.
    pub n: u64,
    /// Global number of nonzero elements `nnz`.
    pub z: u64,
    /// Local rows `m^(k)`.
    pub m_local: u64,
    /// Local columns `n^(k)`.
    pub n_local: u64,
    /// Local nonzeros `nnz^(k)`.
    pub z_local: u64,
    /// First row of the local submatrix `r^(k)` (0-based).
    pub m_offset: u64,
    /// First column of the local submatrix `c^(k)` (0-based).
    pub n_offset: u64,
}

impl LocalInfo {
    /// Metadata for a single-process (whole-matrix) view.
    pub fn whole(m: u64, n: u64, z: u64) -> Self {
        Self {
            m,
            n,
            z,
            m_local: m,
            n_local: n,
            z_local: z,
            m_offset: 0,
            n_offset: 0,
        }
    }

    /// Check internal consistency (window within global bounds).
    pub fn validate(&self) -> Result<(), String> {
        if self.m_offset + self.m_local > self.m {
            return Err(format!(
                "row window [{}, {}) exceeds m={}",
                self.m_offset,
                self.m_offset + self.m_local,
                self.m
            ));
        }
        if self.n_offset + self.n_local > self.n {
            return Err(format!(
                "col window [{}, {}) exceeds n={}",
                self.n_offset,
                self.n_offset + self.n_local,
                self.n
            ));
        }
        if self.z_local > self.z {
            return Err(format!("z_local={} exceeds z={}", self.z_local, self.z));
        }
        Ok(())
    }

    /// Whether a *global* coordinate falls inside this local window.
    pub fn contains_global(&self, i: u64, j: u64) -> bool {
        i >= self.m_offset
            && i < self.m_offset + self.m_local
            && j >= self.n_offset
            && j < self.n_offset + self.n_local
    }
}

/// Compute the tight bounding window of a set of *global* elements, as the
/// paper defines `r^(k), c^(k), m^(k), n^(k)` (min/max over owned nonzeros).
/// Returns `None` for an empty set.
pub fn tight_window(global_elems: &[(u64, u64, f64)]) -> Option<(u64, u64, u64, u64)> {
    if global_elems.is_empty() {
        return None;
    }
    let mut rmin = u64::MAX;
    let mut rmax = 0u64;
    let mut cmin = u64::MAX;
    let mut cmax = 0u64;
    for &(i, j, _) in global_elems {
        rmin = rmin.min(i);
        rmax = rmax.max(i);
        cmin = cmin.min(j);
        cmax = cmax.max(j);
    }
    Some((rmin, cmin, rmax - rmin + 1, cmax - cmin + 1))
}

/// The effective submatrix window for a set of owned *global* elements:
/// the mapping's `declared` window, tightened to the elements' bounding
/// box when the declaration spans the whole `m × n` matrix (mappings
/// with non-contiguous ownership declare the whole matrix; the paper §2
/// defines the window as min/max over owned nonzeros). An empty element
/// set keeps the declared window. Shared by the generator, the loaders
/// and the repack pipeline so the windowing rule cannot drift between
/// them.
pub fn window_or_tight(
    declared: (u64, u64, u64, u64),
    m: u64,
    n: u64,
    elems: &[(u64, u64, f64)],
) -> (u64, u64, u64, u64) {
    let (ro, co, ml, nl) = declared;
    if ro == 0 && co == 0 && ml == m && nl == n {
        tight_window(elems).unwrap_or(declared)
    } else {
        declared
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_lex_order() {
        let mut v = vec![
            Element::new(1, 2, 0.5),
            Element::new(0, 9, 1.0),
            Element::new(1, 0, 2.0),
            Element::new(0, 0, 3.0),
        ];
        sort_lex(&mut v);
        let order: Vec<(u64, u64)> = v.iter().map(|e| (e.row, e.col)).collect();
        assert_eq!(order, vec![(0, 0), (0, 9), (1, 0), (1, 2)]);
    }

    #[test]
    fn local_info_validate() {
        let ok = LocalInfo {
            m: 10,
            n: 10,
            z: 5,
            m_local: 4,
            n_local: 10,
            z_local: 5,
            m_offset: 6,
            n_offset: 0,
        };
        assert!(ok.validate().is_ok());
        let bad = LocalInfo {
            m_offset: 7,
            ..ok
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn contains_global_window() {
        let w = LocalInfo {
            m: 100,
            n: 100,
            z: 0,
            m_local: 10,
            n_local: 20,
            z_local: 0,
            m_offset: 30,
            n_offset: 40,
        };
        assert!(w.contains_global(30, 40));
        assert!(w.contains_global(39, 59));
        assert!(!w.contains_global(40, 40));
        assert!(!w.contains_global(30, 60));
        assert!(!w.contains_global(29, 40));
    }

    #[test]
    fn tight_window_matches_paper_definition() {
        let elems = vec![(5u64, 7u64, 1.0), (9, 3, 2.0), (5, 3, 3.0)];
        let (r, c, m, n) = tight_window(&elems).unwrap();
        assert_eq!((r, c, m, n), (5, 3, 5, 5));
        assert!(tight_window(&[]).is_none());
    }

    #[test]
    fn window_or_tight_rules() {
        let elems = vec![(5u64, 7u64, 1.0), (9, 3, 2.0)];
        // Whole-matrix declaration: tighten to the bounding box.
        assert_eq!(window_or_tight((0, 0, 16, 16), 16, 16, &elems), (5, 3, 5, 5));
        // Partial declaration: kept verbatim.
        assert_eq!(window_or_tight((4, 0, 8, 16), 16, 16, &elems), (4, 0, 8, 16));
        // Whole-matrix declaration, no elements: kept verbatim.
        assert_eq!(window_or_tight((0, 0, 16, 16), 16, 16, &[]), (0, 0, 16, 16));
    }

    #[test]
    fn whole_info() {
        let w = LocalInfo::whole(8, 9, 17);
        assert!(w.validate().is_ok());
        assert_eq!(w.m_local, 8);
        assert_eq!(w.n_local, 9);
        assert_eq!(w.z_local, 17);
        assert!(w.contains_global(7, 8));
    }
}
