//! In-memory sparse matrix formats: COO, CSR, and a small dense oracle,
//! plus the shared element/metadata types.
//!
//! All formats store *local* coordinates relative to the owning process's
//! submatrix window (`m_offset`, `n_offset`); see [`element::LocalInfo`].

pub mod coo;
pub mod csr;
pub mod dense;
pub mod element;

pub use coo::Coo;
pub use csr::Csr;
pub use dense::Dense;
pub use element::{Element, LocalInfo};

/// Canonical (sorted, deduplicated) element list of a local matrix in any
/// format — the equality notion used by roundtrip tests.
pub fn canonical_elements(coo: &Coo) -> Vec<Element> {
    let mut c = coo.clone();
    c.sort_dedup();
    c.to_elements()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_is_order_independent() {
        let info = LocalInfo::whole(4, 4, 0);
        let mut a = Coo::with_info(info);
        a.push(1, 1, 2.0);
        a.push(0, 3, 1.0);
        let mut b = Coo::with_info(info);
        b.push(0, 3, 1.0);
        b.push(1, 1, 2.0);
        assert_eq!(canonical_elements(&a), canonical_elements(&b));
    }
}
