//! Lazy Kronecker-product enlargement of seed matrices (ref [4]).
//!
//! For a seed `S` of dimension `n` with `z` nonzeros, the order-`d` power
//! `A = S ⊗ S ⊗ … ⊗ S` has dimension `n^d` and `z^d` nonzeros:
//!
//! ```text
//! A[i, j] = Π_t S[i_t, j_t]   where i = Σ i_t n^(d-1-t), j likewise.
//! ```
//!
//! Row `i` of `A` therefore factors into the per-digit seed rows, and any
//! row range — hence any rank's row-wise portion — can be generated
//! independently in `O(output)` time without materializing the global
//! matrix, which is exactly how the cited scalable generator distributes
//! work across MPI processes.

use crate::formats::{Coo, LocalInfo};
use crate::gen::seed::SeedMatrix;
use crate::mapping::ProcessMapping;

/// Generator for `seed^{⊗order}`.
#[derive(Debug, Clone)]
pub struct KroneckerGen {
    /// The seed matrix `S`.
    pub seed: SeedMatrix,
    /// Kronecker order `d ≥ 1`.
    pub order: u32,
    /// Cached per-row nonzero counts of the seed.
    seed_row_counts: Vec<u64>,
}

impl KroneckerGen {
    /// Create a generator; panics if `n^order` or `z^order` overflows u64.
    pub fn new(seed: SeedMatrix, order: u32) -> Self {
        assert!(order >= 1, "order must be >= 1");
        let _ = checked_pow(seed.n, order).expect("n^order overflows u64");
        let _ = checked_pow(seed.nnz(), order).expect("nnz^order overflows u64");
        let seed_row_counts = seed.row_counts();
        Self {
            seed,
            order,
            seed_row_counts,
        }
    }

    /// Dimension `n^d` of the expanded (square) matrix.
    pub fn dim(&self) -> u64 {
        checked_pow(self.seed.n, self.order).unwrap()
    }

    /// Total nonzeros `z^d`.
    pub fn nnz(&self) -> u64 {
        checked_pow(self.seed.nnz(), self.order).unwrap()
    }

    /// Nonzeros in expanded row `i`: product of per-digit seed row counts.
    pub fn row_nnz(&self, i: u64) -> u64 {
        let mut rem = i;
        let mut count = 1u64;
        for _ in 0..self.order {
            let digit = rem % self.seed.n;
            rem /= self.seed.n;
            count *= self.seed_row_counts[digit as usize];
            if count == 0 {
                return 0;
            }
        }
        count
    }

    /// Stream every nonzero of expanded row `i` as `(col, val)`, in
    /// ascending column order.
    pub fn visit_row<F: FnMut(u64, f64)>(&self, i: u64, mut sink: F) {
        // Decompose i into digits, most significant first.
        let d = self.order as usize;
        let mut digits = vec![0u64; d];
        let mut rem = i;
        for t in (0..d).rev() {
            digits[t] = rem % self.seed.n;
            rem /= self.seed.n;
        }
        // Cartesian product over the d seed rows; odometer over element
        // indices. Most-significant digit varies slowest, so columns are
        // produced in ascending order (seed rows are column-sorted).
        let rows: Vec<&[(u64, u64, f64)]> = digits.iter().map(|&r| self.seed.row(r)).collect();
        if rows.iter().any(|r| r.is_empty()) {
            return;
        }
        let mut idx = vec![0usize; d];
        loop {
            let mut col = 0u64;
            let mut val = 1.0f64;
            for t in 0..d {
                let (_, c, v) = rows[t][idx[t]];
                col = col * self.seed.n + c;
                val *= v;
            }
            sink(col, val);
            // Advance odometer (least significant digit = last).
            let mut t = d;
            loop {
                if t == 0 {
                    return;
                }
                t -= 1;
                idx[t] += 1;
                if idx[t] < rows[t].len() {
                    break;
                }
                idx[t] = 0;
            }
        }
    }

    /// Stream every nonzero with global coordinates in row range
    /// `[r0, r1)`, rows ascending.
    pub fn visit_row_range<F: FnMut(u64, u64, f64)>(&self, r0: u64, r1: u64, mut sink: F) {
        for i in r0..r1 {
            self.visit_row(i, |j, v| sink(i, j, v));
        }
    }

    /// Build rank `k`'s local COO under `mapping`, with the window declared
    /// by the mapping (shrunk to the tight element window for
    /// non-contiguous mappings). Returns elements in local coordinates.
    pub fn local_coo(&self, mapping: &dyn ProcessMapping, rank: usize) -> Coo {
        let n = self.dim();
        let (ro, co, ml, nl) = mapping.window(rank);
        // Collect the rank's global elements.
        let mut elems: Vec<(u64, u64, f64)> = Vec::new();
        self.visit_row_range(ro, ro + ml, |i, j, v| {
            if j >= co && j < co + nl && mapping.owner(i, j) == rank {
                elems.push((i, j, v));
            }
        });
        // Non-contiguous mapping: tighten the declared window to the
        // actually-owned bounding box, as the paper's storage side does.
        let (ro, co, ml, nl) =
            crate::formats::element::window_or_tight((ro, co, ml, nl), n, n, &elems);
        let info = LocalInfo {
            m: n,
            n,
            z: self.nnz(),
            m_local: ml,
            n_local: nl,
            z_local: 0,
            m_offset: ro,
            n_offset: co,
        };
        let mut coo = Coo::with_info(info);
        for (i, j, v) in elems {
            coo.push(i - ro, j - co, v);
        }
        coo
    }

    /// Build the balanced row-wise mapping the paper stores with: row
    /// chunks with equal amortized nonzeros (uses [`Self::row_nnz`]).
    pub fn balanced_rowwise(&self, p: usize) -> crate::mapping::Rowwise {
        let n = self.dim();
        crate::mapping::Rowwise::balanced_by_nnz(n, n, p, |r| self.row_nnz(r))
    }
}

fn checked_pow(base: u64, exp: u32) -> Option<u64> {
    let mut acc = 1u64;
    for _ in 0..exp {
        acc = acc.checked_mul(base)?;
    }
    Some(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Dense;
    use crate::mapping::{Colwise, Rowwise};

    /// Dense oracle for small Kronecker powers.
    fn dense_kron(seed: &SeedMatrix, order: u32) -> Dense {
        let mut acc = Dense::zeros(1, 1);
        acc.set(0, 0, 1.0);
        for _ in 0..order {
            let s = seed;
            let mut next = Dense::zeros(acc.nrows * s.n as usize, acc.ncols * s.n as usize);
            for ar in 0..acc.nrows {
                for ac in 0..acc.ncols {
                    let av = acc.get(ar, ac);
                    if av == 0.0 {
                        continue;
                    }
                    for &(r, c, v) in &s.triplets {
                        next.set(
                            ar * s.n as usize + r as usize,
                            ac * s.n as usize + c as usize,
                            av * v,
                        );
                    }
                }
            }
            acc = next;
        }
        acc
    }

    #[test]
    fn matches_dense_oracle_order2() {
        let seed = SeedMatrix::new(
            "t",
            3,
            vec![(0, 0, 2.0), (0, 2, 1.0), (1, 1, -1.0), (2, 0, 0.5), (2, 2, 3.0)],
        );
        let gen = KroneckerGen::new(seed.clone(), 2);
        let oracle = dense_kron(&seed, 2);
        assert_eq!(gen.dim(), 9);
        assert_eq!(gen.nnz(), 25);
        let mut got = Dense::zeros(9, 9);
        gen.visit_row_range(0, 9, |i, j, v| got.set(i as usize, j as usize, v));
        assert_eq!(got.data, oracle.data);
    }

    #[test]
    fn matches_dense_oracle_order3_cagelike() {
        let seed = SeedMatrix::cage_like(4, 9);
        let gen = KroneckerGen::new(seed.clone(), 3);
        let oracle = dense_kron(&seed, 3);
        let mut got = Dense::zeros(64, 64);
        let mut count = 0u64;
        gen.visit_row_range(0, 64, |i, j, v| {
            got.set(i as usize, j as usize, v);
            count += 1;
        });
        assert_eq!(count, gen.nnz());
        for (a, b) in got.data.iter().zip(&oracle.data) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn row_nnz_matches_enumeration() {
        let seed = SeedMatrix::cage_like(8, 11);
        let gen = KroneckerGen::new(seed, 2);
        for i in 0..gen.dim() {
            let mut count = 0u64;
            gen.visit_row(i, |_, _| count += 1);
            assert_eq!(count, gen.row_nnz(i), "row {i}");
        }
    }

    #[test]
    fn columns_ascending_within_row() {
        let seed = SeedMatrix::cage_like(8, 3);
        let gen = KroneckerGen::new(seed, 2);
        for i in (0..gen.dim()).step_by(7) {
            let mut last: Option<u64> = None;
            gen.visit_row(i, |j, _| {
                if let Some(l) = last {
                    assert!(j > l, "row {i}: column {j} after {l}");
                }
                last = Some(j);
            });
        }
    }

    #[test]
    fn local_coo_partition_is_exact() {
        // Union of per-rank local parts == whole matrix, no overlap.
        let seed = SeedMatrix::cage_like(6, 5);
        let gen = KroneckerGen::new(seed, 2);
        let n = gen.dim();
        let map = Rowwise::regular(n, n, 4);
        let mut seen = std::collections::HashMap::new();
        for rank in 0..4 {
            let coo = gen.local_coo(&map, rank);
            coo.validate().unwrap();
            for (r, c, v) in coo.iter() {
                let key = (r + coo.info.m_offset, c + coo.info.n_offset);
                assert!(seen.insert(key, v).is_none(), "duplicate {key:?}");
            }
        }
        assert_eq!(seen.len() as u64, gen.nnz());
        // Cross-check a few values against direct enumeration.
        let mut expect = std::collections::HashMap::new();
        gen.visit_row_range(0, n, |i, j, v| {
            expect.insert((i, j), v);
        });
        assert_eq!(seen, expect);
    }

    #[test]
    fn colwise_partition_is_exact() {
        let seed = SeedMatrix::cage_like(5, 2);
        let gen = KroneckerGen::new(seed, 2);
        let n = gen.dim();
        let map = Colwise::regular(n, n, 3);
        let total: u64 = (0..3)
            .map(|rank| {
                let coo = gen.local_coo(&map, rank);
                coo.validate().unwrap();
                coo.nnz() as u64
            })
            .sum();
        assert_eq!(total, gen.nnz());
    }

    #[test]
    fn balanced_rowwise_evens_nnz() {
        let seed = SeedMatrix::rmat(4, 4, 17); // skewed 16x16 seed
        let gen = KroneckerGen::new(seed, 2);
        let p = 5;
        let map = gen.balanced_rowwise(p);
        let counts: Vec<u64> = (0..p).map(|k| gen.local_coo(&map, k).nnz() as u64).collect();
        let total: u64 = counts.iter().sum();
        assert_eq!(total, gen.nnz());
        let regular = Rowwise::regular(gen.dim(), gen.dim(), p);
        let reg_counts: Vec<u64> = (0..p)
            .map(|k| gen.local_coo(&regular, k).nnz() as u64)
            .collect();
        let spread = |c: &[u64]| c.iter().max().unwrap() - c.iter().min().unwrap();
        assert!(
            spread(&counts) <= spread(&reg_counts),
            "balanced {counts:?} not tighter than regular {reg_counts:?}"
        );
    }

    #[test]
    fn order_one_is_seed() {
        let seed = SeedMatrix::cage_like(16, 4);
        let gen = KroneckerGen::new(seed.clone(), 1);
        let mut got = Vec::new();
        gen.visit_row_range(0, 16, |i, j, v| got.push((i, j, v)));
        assert_eq!(got, seed.triplets);
    }
}
