//! Scalable sparse-matrix generation (stand-in for the paper's workload).
//!
//! The paper's experiments enlarge the `cage12` seed matrix (130k rows,
//! 2M nonzeros, ≈15.6 nnz/row) with Kronecker products until each process
//! holds 256 GB (ref [4], *Scalable parallel generation of very large
//! sparse matrices*). `cage12` itself is not redistributable data, so
//! [`seed`] provides a deterministic **cage-like** generator matching its
//! structural statistics (banded DNA-electrophoresis pattern, similar row
//! density), plus simpler seeds for tests and ablations; [`kronecker`]
//! implements the same lazy, per-process Kronecker enlargement as ref [4]
//! — any rank can materialize exactly its own portion without ever
//! building the global matrix.

pub mod kronecker;
pub mod seed;
pub mod spd;

pub use kronecker::KroneckerGen;
pub use seed::SeedMatrix;
pub use spd::spd_parts;
