//! Seed matrices for Kronecker enlargement.

use crate::formats::{Coo, LocalInfo};
use crate::util::rng::Xoshiro256;

/// A small square seed matrix held as sorted COO triplets.
#[derive(Debug, Clone, PartialEq)]
pub struct SeedMatrix {
    /// Dimension (square).
    pub n: u64,
    /// Sorted (row, col, val) triplets, duplicate-free.
    pub triplets: Vec<(u64, u64, f64)>,
    /// Label for logs/benches.
    pub name: String,
}

impl SeedMatrix {
    /// Build from raw triplets (sorted + deduplicated by summation).
    pub fn new(name: &str, n: u64, mut triplets: Vec<(u64, u64, f64)>) -> Self {
        triplets.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut out: Vec<(u64, u64, f64)> = Vec::with_capacity(triplets.len());
        for t in triplets {
            assert!(t.0 < n && t.1 < n, "seed triplet out of range");
            match out.last_mut() {
                Some(last) if last.0 == t.0 && last.1 == t.1 => last.2 += t.2,
                _ => out.push(t),
            }
        }
        Self {
            n,
            triplets: out,
            name: name.to_string(),
        }
    }

    /// Nonzero count.
    pub fn nnz(&self) -> u64 {
        self.triplets.len() as u64
    }

    /// Per-row nonzero counts.
    pub fn row_counts(&self) -> Vec<u64> {
        let mut counts = vec![0u64; self.n as usize];
        for &(r, _, _) in &self.triplets {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Triplets of one row (slice into the sorted array).
    pub fn row(&self, r: u64) -> &[(u64, u64, f64)] {
        let lo = self.triplets.partition_point(|t| t.0 < r);
        let hi = self.triplets.partition_point(|t| t.0 <= r);
        &self.triplets[lo..hi]
    }

    /// View as a whole-matrix COO.
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_info(LocalInfo::whole(self.n, self.n, self.nnz()));
        for &(r, c, v) in &self.triplets {
            coo.push(r, c, v);
        }
        coo
    }

    /// **Cage-like seed** — deterministic generator structurally similar to
    /// the `cage` DNA-electrophoresis matrices used by the paper: a real
    /// unsymmetric square matrix with full diagonal, a banded neighborhood
    /// (transition probabilities to nearby states) and a few long-range
    /// couplings, averaging ≈15 nnz/row for n ≥ 64.
    ///
    /// Fully determined by `(n, seed)`.
    pub fn cage_like(n: u64, seed: u64) -> Self {
        assert!(n >= 4, "cage-like seed needs n >= 4");
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0xCA6E);
        let mut triplets = Vec::new();
        // Band half-width chosen so diagonal + band gives ~13 nnz/row.
        let half = 6u64.min(n / 2 - 1).max(1);
        for i in 0..n {
            // Diagonal dominance (cage matrices are diagonally dominant).
            triplets.push((i, i, 1.0 + rng.next_f64()));
            let lo = i.saturating_sub(half);
            let hi = (i + half).min(n - 1);
            for j in lo..=hi {
                if j != i && rng.chance(0.85) {
                    triplets.push((i, j, rng.range_f64(-0.5, 0.5)));
                }
            }
            // ~2 long-range couplings per row (electrophoresis jump terms).
            for _ in 0..2 {
                if rng.chance(0.9) {
                    let j = rng.next_below(n);
                    if j != i {
                        triplets.push((i, j, rng.range_f64(-0.25, 0.25)));
                    }
                }
            }
        }
        Self::new(&format!("cage-like-{n}"), n, triplets)
    }

    /// Identity-like diagonal seed (Kronecker powers stay diagonal) —
    /// useful to make generator behaviour auditable in tests.
    pub fn diagonal(n: u64) -> Self {
        let triplets = (0..n).map(|i| (i, i, (i + 1) as f64)).collect();
        Self::new(&format!("diag-{n}"), n, triplets)
    }

    /// Uniform random seed with expected `density` fill.
    pub fn random(n: u64, density: f64, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5EED);
        let mut triplets = Vec::new();
        for i in 0..n {
            for j in 0..n {
                if rng.chance(density) {
                    triplets.push((i, j, rng.range_f64(-1.0, 1.0)));
                }
            }
        }
        // Guarantee no empty matrix.
        if triplets.is_empty() {
            triplets.push((0, 0, 1.0));
        }
        Self::new(&format!("random-{n}-{density}"), n, triplets)
    }

    /// R-MAT-style power-law seed (skewed degree distribution), the
    /// adversarial case for balanced partitioning.
    pub fn rmat(scale: u32, avg_nnz_per_row: u64, seed: u64) -> Self {
        let n = 1u64 << scale;
        let target = n * avg_nnz_per_row;
        let (a, b, c) = (0.57, 0.19, 0.19);
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x12A7);
        let mut triplets = Vec::with_capacity(target as usize);
        for _ in 0..target {
            let (mut r0, mut r1, mut c0, mut c1) = (0u64, n, 0u64, n);
            while r1 - r0 > 1 {
                let x = rng.next_f64();
                let (top, left) = if x < a {
                    (true, true)
                } else if x < a + b {
                    (true, false)
                } else if x < a + b + c {
                    (false, true)
                } else {
                    (false, false)
                };
                let rm = (r0 + r1) / 2;
                let cm = (c0 + c1) / 2;
                if top {
                    r1 = rm;
                } else {
                    r0 = rm;
                }
                if left {
                    c1 = cm;
                } else {
                    c0 = cm;
                }
            }
            triplets.push((r0, c0, rng.range_f64(0.1, 1.0)));
        }
        Self::new(&format!("rmat-{scale}"), n, triplets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cage_like_statistics() {
        let s = SeedMatrix::cage_like(128, 1);
        // Density: ~11-16 nnz/row.
        let per_row = s.nnz() as f64 / s.n as f64;
        assert!((9.0..18.0).contains(&per_row), "nnz/row = {per_row}");
        // Full diagonal.
        for i in 0..s.n {
            assert!(
                s.row(i).iter().any(|&(_, j, _)| j == i),
                "missing diagonal at {i}"
            );
        }
        // Deterministic.
        assert_eq!(s, SeedMatrix::cage_like(128, 1));
        assert_ne!(s, SeedMatrix::cage_like(128, 2));
    }

    #[test]
    fn seed_rows_sorted_and_unique() {
        let s = SeedMatrix::cage_like(64, 7);
        for w in s.triplets.windows(2) {
            assert!((w[0].0, w[0].1) < (w[1].0, w[1].1), "unsorted/duplicate");
        }
    }

    #[test]
    fn row_slicing() {
        let s = SeedMatrix::new("t", 4, vec![(0, 1, 1.0), (2, 0, 2.0), (2, 3, 3.0)]);
        assert_eq!(s.row(0), &[(0, 1, 1.0)]);
        assert!(s.row(1).is_empty());
        assert_eq!(s.row(2).len(), 2);
        assert_eq!(s.row_counts(), vec![1, 0, 2, 0]);
    }

    #[test]
    fn new_dedups_by_sum() {
        let s = SeedMatrix::new("t", 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 1.0)]);
        assert_eq!(s.triplets, vec![(0, 0, 3.0), (1, 1, 1.0)]);
    }

    #[test]
    fn diagonal_seed() {
        let s = SeedMatrix::diagonal(5);
        assert_eq!(s.nnz(), 5);
        assert_eq!(s.row(3), &[(3, 3, 4.0)]);
    }

    #[test]
    fn rmat_skewed() {
        let s = SeedMatrix::rmat(6, 8, 3);
        assert_eq!(s.n, 64);
        assert!(s.nnz() > 0 && s.nnz() <= 64 * 8);
        let counts = s.row_counts();
        let max = *counts.iter().max().unwrap();
        let mean = s.nnz() / s.n;
        assert!(max >= mean * 2, "rmat not skewed: max {max}, mean {mean}");
    }

    #[test]
    fn to_coo_valid() {
        let coo = SeedMatrix::cage_like(32, 5).to_coo();
        assert!(coo.validate().is_ok());
    }
}
