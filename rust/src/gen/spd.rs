//! Symmetric-positive-definite workload derivation for the iterative
//! solvers: `S = (A + Aᵀ)/2 + σ·I` from any square generated matrix
//! `A`, with `σ` chosen automatically so `S` is strictly diagonally
//! dominant with a positive diagonal — a sufficient (Gershgorin)
//! condition for positive definiteness, so CG provably converges on the
//! generated systems the `solve` CLI and CI smoke run.

use std::collections::BTreeMap;

use crate::formats::element::window_or_tight;
use crate::formats::{Coo, LocalInfo};
use crate::gen::KroneckerGen;
use crate::mapping::ProcessMapping;

/// Build per-rank COO parts of `S = (A + Aᵀ)/2 + σ·I` under `mapping`,
/// where `A` is the generated matrix. With `extra_shift ≥ 0` the
/// applied shift is `σ = σ_auto + extra_shift`, where `σ_auto` makes
/// `S` strictly diagonally dominant (`σ_auto = 1 + max(0, max_i(Σ_{j≠i}
/// |s_ij| − s_ii))` over the symmetrized entries). Returns the parts
/// (tight windows, exact-zero cancellations dropped) and the applied
/// `σ`.
///
/// The symmetrization materializes the global entry map once
/// (`BTreeMap` over `(i, j)`), which is fine at harness scale — the
/// solvers' matrices are generated small enough to check convergence,
/// not to stress memory.
pub fn spd_parts(
    gen: &KroneckerGen,
    mapping: &dyn ProcessMapping,
    extra_shift: f64,
) -> (Vec<Coo>, f64) {
    let n = gen.dim();
    assert!(extra_shift >= 0.0, "extra shift must be non-negative");
    let mut entries: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    gen.visit_row_range(0, n, |i, j, v| {
        *entries.entry((i, j)).or_insert(0.0) += v / 2.0;
        *entries.entry((j, i)).or_insert(0.0) += v / 2.0;
    });
    // Diagonal dominance deficit of the symmetrized matrix.
    let mut diag = vec![0.0f64; n as usize];
    let mut offdiag_abs = vec![0.0f64; n as usize];
    for (&(i, j), &v) in &entries {
        if i == j {
            diag[i as usize] += v;
        } else {
            offdiag_abs[i as usize] += v.abs();
        }
    }
    let deficit = diag
        .iter()
        .zip(&offdiag_abs)
        .map(|(d, o)| o - d)
        .fold(0.0f64, f64::max);
    let sigma = 1.0 + deficit.max(0.0) + extra_shift;
    for i in 0..n {
        *entries.entry((i, i)).or_insert(0.0) += sigma;
    }
    // Symmetrization can cancel exactly (v/2 + (-v/2)); zero entries are
    // not nonzeros.
    entries.retain(|_, v| *v != 0.0);

    let p = mapping.nprocs();
    let mut per_rank: Vec<Vec<(u64, u64, f64)>> = vec![Vec::new(); p];
    for (&(i, j), &v) in &entries {
        per_rank[mapping.owner(i, j)].push((i, j, v));
    }
    let total = entries.len() as u64;
    let parts = per_rank
        .into_iter()
        .enumerate()
        .map(|(rank, elems)| {
            let declared = mapping.window(rank);
            let (ro, co, ml, nl) = window_or_tight(declared, n, n, &elems);
            let info = LocalInfo {
                m: n,
                n,
                z: total,
                m_local: ml,
                n_local: nl,
                z_local: 0,
                m_offset: ro,
                n_offset: co,
            };
            let mut coo = Coo::with_info(info);
            for (i, j, v) in elems {
                coo.push(i - ro, j - co, v);
            }
            coo
        })
        .collect();
    (parts, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::SeedMatrix;
    use crate::mapping::Rowwise;

    fn collect_global(parts: &[Coo]) -> BTreeMap<(u64, u64), f64> {
        let mut out = BTreeMap::new();
        for part in parts {
            let (ro, co) = (part.info.m_offset, part.info.n_offset);
            for (i, j, v) in part.iter() {
                assert!(
                    out.insert((i + ro, j + co), v).is_none(),
                    "duplicate global entry"
                );
            }
        }
        out
    }

    #[test]
    fn spd_parts_are_symmetric_and_dominant() {
        let gen = KroneckerGen::new(SeedMatrix::cage_like(8, 42), 2);
        let n = gen.dim();
        let mapping = Rowwise::regular(n, n, 4);
        let (parts, sigma) = spd_parts(&gen, &mapping, 0.0);
        assert!(sigma >= 1.0);
        assert_eq!(parts.len(), 4);
        let s = collect_global(&parts);
        // Symmetry, exact.
        for (&(i, j), &v) in &s {
            assert_eq!(s.get(&(j, i)), Some(&v), "asymmetric at ({i},{j})");
        }
        // Strict diagonal dominance with positive diagonal.
        let mut diag = vec![0.0f64; n as usize];
        let mut off = vec![0.0f64; n as usize];
        for (&(i, j), &v) in &s {
            if i == j {
                diag[i as usize] = v;
            } else {
                off[i as usize] += v.abs();
            }
        }
        for i in 0..n as usize {
            assert!(
                diag[i] > off[i],
                "row {i} not dominant: diag {} vs off {}",
                diag[i],
                off[i]
            );
        }
    }

    #[test]
    fn extra_shift_adds_to_diagonal() {
        let gen = KroneckerGen::new(SeedMatrix::cage_like(8, 42), 1);
        let n = gen.dim();
        let mapping = Rowwise::regular(n, n, 2);
        let (_, sigma0) = spd_parts(&gen, &mapping, 0.0);
        let (_, sigma3) = spd_parts(&gen, &mapping, 3.0);
        assert!((sigma3 - sigma0 - 3.0).abs() < 1e-12);
    }
}
