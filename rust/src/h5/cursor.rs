//! Streaming dataset cursor — the "next value from `abhsf.xxx[]`" primitive
//! of the paper's pseudocode (Algorithms 1, 3–6).
//!
//! A cursor reads a dataset strictly forward, one chunk at a time, so the
//! loading algorithm streams each dataset with one buffered pass instead of
//! materializing it (important at the paper's 256 GB/process scale).

use crate::h5::dtype::Scalar;
use crate::h5::reader::H5Reader;
use crate::h5::{H5Error, Result};

/// Forward-only typed cursor over one dataset of an [`H5Reader`].
pub struct Cursor<'r, T: Scalar> {
    reader: &'r H5Reader,
    name: String,
    /// Decoded current chunk.
    buf: Vec<T>,
    /// Next index within `buf`.
    buf_pos: usize,
    /// Next chunk index to load.
    next_chunk: usize,
    /// Elements consumed so far.
    consumed: u64,
    /// Total elements in the dataset.
    total: u64,
}

impl<'r, T: Scalar> Cursor<'r, T> {
    /// Open a cursor at position 0 of `name`.
    pub fn new(reader: &'r H5Reader, name: &str) -> Result<Self> {
        let entry = reader.entry(name)?;
        if entry.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: entry.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(Self {
            reader,
            name: name.to_string(),
            buf: Vec::new(),
            buf_pos: 0,
            next_chunk: 0,
            consumed: 0,
            total: entry.total_elems,
        })
    }

    /// Total dataset length.
    pub fn len(&self) -> u64 {
        self.total
    }

    /// Whether the dataset is empty.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Elements consumed so far.
    pub fn position(&self) -> u64 {
        self.consumed
    }

    /// Elements remaining.
    pub fn remaining(&self) -> u64 {
        self.total - self.consumed
    }

    fn refill(&mut self) -> Result<bool> {
        let entry = self.reader.entry(&self.name)?.clone();
        while self.next_chunk < entry.chunks.len() {
            let idx = self.next_chunk;
            let chunk = entry.chunks[idx];
            self.next_chunk += 1;
            if chunk.elems == 0 {
                continue;
            }
            let bytes = self
                .reader
                .read_chunk_bytes(&self.name, idx, &chunk, T::DTYPE.size())?;
            crate::h5::dtype::decode_into::<T>(&bytes, &mut self.buf);
            self.buf_pos = 0;
            return Ok(true);
        }
        Ok(false)
    }

    /// Next value, or `None` at end of dataset.
    pub fn next(&mut self) -> Result<Option<T>> {
        if self.buf_pos >= self.buf.len() && !self.refill()? {
            return Ok(None);
        }
        let v = self.buf[self.buf_pos];
        self.buf_pos += 1;
        self.consumed += 1;
        Ok(Some(v))
    }

    /// Next value, erroring on premature end — the pseudocode's unchecked
    /// "next value from dataset" semantics with corruption detection.
    pub fn next_required(&mut self) -> Result<T> {
        self.next()?.ok_or_else(|| {
            H5Error::Corrupt(format!(
                "dataset {} exhausted after {} elements",
                self.name, self.consumed
            ))
        })
    }

    /// Read up to `count` values into a fresh vector (fewer at EOF).
    pub fn take(&mut self, count: usize) -> Result<Vec<T>> {
        let mut out = Vec::with_capacity(count.min(self.remaining() as usize));
        self.take_into(&mut out, count)?;
        Ok(out)
    }

    /// Append up to `count` values to `out`, copying whole buffered chunk
    /// slices at a time (the loader's bulk-decode fast path: ~10x fewer
    /// per-element calls than repeated [`Self::next`]).
    pub fn take_into(&mut self, out: &mut Vec<T>, count: usize) -> Result<usize> {
        let mut left = count;
        while left > 0 {
            if self.buf_pos >= self.buf.len() && !self.refill()? {
                break;
            }
            let n = left.min(self.buf.len() - self.buf_pos);
            out.extend_from_slice(&self.buf[self.buf_pos..self.buf_pos + n]);
            self.buf_pos += n;
            self.consumed += n as u64;
            left -= n;
        }
        Ok(count - left)
    }

    /// Exactly `count` values appended to `out`, erroring at premature EOF.
    pub fn take_exact_into(&mut self, out: &mut Vec<T>, count: usize) -> Result<()> {
        let got = self.take_into(out, count)?;
        if got != count {
            return Err(H5Error::Corrupt(format!(
                "dataset {} exhausted: wanted {count}, got {got} (position {})",
                self.name, self.consumed
            )));
        }
        Ok(())
    }

    /// Skip `count` values (erroring if fewer remain).
    pub fn skip(&mut self, count: u64) -> Result<()> {
        // Chunk-aware skip: fast-forward through buffered data; chunks that
        // are entirely skipped are still read (streaming semantics keep the
        // access pattern sequential, as HDF5 contiguous reads would).
        for _ in 0..count {
            self.next_required()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::h5::writer::H5Writer;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("abhsf-h5-cursor-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn cursor_streams_across_chunks() {
        let path = tmpfile("stream.h5spm");
        let data: Vec<u32> = (0..1000).collect();
        {
            let mut w = H5Writer::create(&path).unwrap();
            w.set_chunk_elems(64);
            w.write_dataset("d", &data).unwrap();
            w.finish().unwrap();
        }
        let r = H5Reader::open(&path).unwrap();
        let mut c = Cursor::<u32>::new(&r, "d").unwrap();
        assert_eq!(c.len(), 1000);
        let mut got = Vec::new();
        while let Some(v) = c.next().unwrap() {
            got.push(v);
        }
        assert_eq!(got, data);
        assert_eq!(c.remaining(), 0);
        assert!(c.next().unwrap().is_none());
    }

    #[test]
    fn next_required_errors_at_eof() {
        let path = tmpfile("eof.h5spm");
        {
            let mut w = H5Writer::create(&path).unwrap();
            w.write_dataset::<u64>("d", &[1, 2]).unwrap();
            w.finish().unwrap();
        }
        let r = H5Reader::open(&path).unwrap();
        let mut c = Cursor::<u64>::new(&r, "d").unwrap();
        assert_eq!(c.next_required().unwrap(), 1);
        assert_eq!(c.next_required().unwrap(), 2);
        assert!(c.next_required().is_err());
    }

    #[test]
    fn take_and_skip() {
        let path = tmpfile("take.h5spm");
        let data: Vec<f64> = (0..100).map(|i| i as f64).collect();
        {
            let mut w = H5Writer::create(&path).unwrap();
            w.set_chunk_elems(7);
            w.write_dataset("d", &data).unwrap();
            w.finish().unwrap();
        }
        let r = H5Reader::open(&path).unwrap();
        let mut c = Cursor::<f64>::new(&r, "d").unwrap();
        assert_eq!(c.take(5).unwrap(), vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        c.skip(90).unwrap();
        assert_eq!(c.take(10).unwrap(), vec![95.0, 96.0, 97.0, 98.0, 99.0]);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let path = tmpfile("mismatch.h5spm");
        {
            let mut w = H5Writer::create(&path).unwrap();
            w.write_dataset::<u32>("d", &[1]).unwrap();
            w.finish().unwrap();
        }
        let r = H5Reader::open(&path).unwrap();
        assert!(Cursor::<f64>::new(&r, "d").is_err());
        assert!(Cursor::<u32>::new(&r, "missing").is_err());
    }

    #[test]
    fn empty_dataset_cursor() {
        let path = tmpfile("empty.h5spm");
        {
            let mut w = H5Writer::create(&path).unwrap();
            w.write_dataset::<u32>("d", &[]).unwrap();
            w.finish().unwrap();
        }
        let r = H5Reader::open(&path).unwrap();
        let mut c = Cursor::<u32>::new(&r, "d").unwrap();
        assert!(c.is_empty());
        assert!(c.next().unwrap().is_none());
    }
}
