//! Scalar types storable in h5spm attributes and datasets.

/// Type tag for stored scalars.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Dtype {
    /// Unsigned 8-bit.
    U8 = 0,
    /// Unsigned 16-bit.
    U16 = 1,
    /// Unsigned 32-bit.
    U32 = 2,
    /// Unsigned 64-bit.
    U64 = 3,
    /// Signed 32-bit.
    I32 = 4,
    /// Signed 64-bit.
    I64 = 5,
    /// IEEE-754 single.
    F32 = 6,
    /// IEEE-754 double.
    F64 = 7,
}

impl Dtype {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::U16 => 2,
            Dtype::U32 | Dtype::I32 | Dtype::F32 => 4,
            Dtype::U64 | Dtype::I64 | Dtype::F64 => 8,
        }
    }

    /// Decode from its tag byte.
    pub fn from_tag(tag: u8) -> Option<Self> {
        Some(match tag {
            0 => Dtype::U8,
            1 => Dtype::U16,
            2 => Dtype::U32,
            3 => Dtype::U64,
            4 => Dtype::I32,
            5 => Dtype::I64,
            6 => Dtype::F32,
            7 => Dtype::F64,
            _ => return None,
        })
    }
}

/// A scalar that can live in an h5spm dataset or attribute.
///
/// Little-endian on disk throughout.
pub trait Scalar: Copy + Default + std::fmt::Debug + PartialEq + 'static {
    /// The dtype tag of this scalar.
    const DTYPE: Dtype;

    /// Serialize into `buf` (exactly `Self::DTYPE.size()` bytes).
    fn write_le(self, buf: &mut [u8]);

    /// Deserialize from `buf`.
    fn read_le(buf: &[u8]) -> Self;

    /// Widen to f64 for attribute storage.
    fn to_f64(self) -> f64;
}

macro_rules! impl_scalar {
    ($t:ty, $dt:expr) => {
        impl Scalar for $t {
            const DTYPE: Dtype = $dt;

            #[inline]
            fn write_le(self, buf: &mut [u8]) {
                buf.copy_from_slice(&self.to_le_bytes());
            }

            #[inline]
            fn read_le(buf: &[u8]) -> Self {
                <$t>::from_le_bytes(buf.try_into().expect("scalar width"))
            }

            #[inline]
            fn to_f64(self) -> f64 {
                self as f64
            }
        }
    };
}

impl_scalar!(u8, Dtype::U8);
impl_scalar!(u16, Dtype::U16);
impl_scalar!(u32, Dtype::U32);
impl_scalar!(u64, Dtype::U64);
impl_scalar!(i32, Dtype::I32);
impl_scalar!(i64, Dtype::I64);
impl_scalar!(f32, Dtype::F32);
impl_scalar!(f64, Dtype::F64);

/// Encode a slice of scalars into little-endian bytes.
pub fn encode_slice<T: Scalar>(xs: &[T]) -> Vec<u8> {
    let w = T::DTYPE.size();
    let mut out = vec![0u8; xs.len() * w];
    for (i, &x) in xs.iter().enumerate() {
        x.write_le(&mut out[i * w..(i + 1) * w]);
    }
    out
}

/// Decode little-endian bytes into scalars.
pub fn decode_slice<T: Scalar>(bytes: &[u8]) -> Vec<T> {
    let mut out = Vec::new();
    decode_into(bytes, &mut out);
    out
}

/// Decode little-endian bytes into a reused buffer (cleared first) —
/// avoids one allocation per chunk on the loader's hot path.
pub fn decode_into<T: Scalar>(bytes: &[u8], out: &mut Vec<T>) {
    let w = T::DTYPE.size();
    assert!(bytes.len() % w == 0, "byte length {} not multiple of {w}", bytes.len());
    out.clear();
    out.reserve(bytes.len() / w);
    out.extend(bytes.chunks_exact(w).map(T::read_le));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_tags() {
        for dt in [
            Dtype::U8,
            Dtype::U16,
            Dtype::U32,
            Dtype::U64,
            Dtype::I32,
            Dtype::I64,
            Dtype::F32,
            Dtype::F64,
        ] {
            assert_eq!(Dtype::from_tag(dt as u8), Some(dt));
        }
        assert_eq!(Dtype::from_tag(99), None);
        assert_eq!(Dtype::U8.size(), 1);
        assert_eq!(Dtype::F64.size(), 8);
    }

    #[test]
    fn encode_decode_roundtrip_u32() {
        let xs = vec![0u32, 1, 0xDEAD_BEEF, u32::MAX];
        let bytes = encode_slice(&xs);
        assert_eq!(bytes.len(), 16);
        assert_eq!(decode_slice::<u32>(&bytes), xs);
    }

    #[test]
    fn encode_decode_roundtrip_f64() {
        let xs = vec![0.0f64, -1.5, std::f64::consts::PI, f64::MIN_POSITIVE];
        let bytes = encode_slice(&xs);
        assert_eq!(decode_slice::<f64>(&bytes), xs);
    }

    #[test]
    fn encode_decode_u8() {
        let xs: Vec<u8> = (0..=255).collect();
        assert_eq!(decode_slice::<u8>(&encode_slice(&xs)), xs);
    }
}
