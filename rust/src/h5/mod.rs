//! `h5spm` — a small hierarchical container file format standing in for the
//! HDF5 library (which the paper uses; real HDF5 is unavailable offline).
//!
//! The model is a strict subset of what the ABHSF storage/loading algorithms
//! need from HDF5:
//!
//! * **attributes** — named typed scalars (the paper's `m`, `n_local`,
//!   `block_size`, …);
//! * **datasets** — named typed 1-D arrays (`schemes[]`, `coo_vals[]`, …),
//!   stored in CRC32-checksummed chunks and readable either wholesale, as an
//!   arbitrary slice (*hyperslab* in HDF5 terms), or through a streaming
//!   [`cursor::Cursor`] that mirrors the pseudocode's
//!   "next value from `abhsf.xxx[]`".
//!
//! On-disk layout (all little-endian):
//!
//! ```text
//! [superblock]  magic "H5SPM1\0\0" | dir_offset u64 | dir_len u64
//! [data]        chunk payloads, in write order
//! [directory]   attr count u32, per attr: name | dtype u8 | 8-byte value
//!               dataset count u32, per dataset: name | dtype u8 |
//!                 total elems u64 | chunk count u32 |
//!                 per chunk: file offset u64 | elems u64 | crc32 u32
//!               directory crc32 u32
//! ```
//!
//! The directory lives at the end so datasets stream straight to disk; the
//! superblock's `dir_offset` is patched on `finish()`. I/O byte/op counters
//! are exposed for the parallel-I/O cost simulator (`crate::parfs`).

pub mod cursor;
pub mod dtype;
pub mod reader;
pub mod writer;

pub use cursor::Cursor;
pub use dtype::{Dtype, Scalar};
pub use reader::H5Reader;
pub use writer::H5Writer;

/// Magic bytes at file start.
pub const MAGIC: &[u8; 8] = b"H5SPM1\0\0";

/// Default dataset chunk size in elements. 64 Ki elements keeps chunks in
/// the 64–512 KiB range for 1–8 byte scalars, similar to HDF5 defaults for
/// large 1-D datasets.
pub const DEFAULT_CHUNK_ELEMS: u64 = 64 * 1024;

/// Errors from container I/O.
#[derive(Debug, thiserror::Error)]
pub enum H5Error {
    /// Underlying filesystem error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// Bad magic / version.
    #[error("not an h5spm file: {0}")]
    BadMagic(String),
    /// Structural corruption.
    #[error("corrupt container: {0}")]
    Corrupt(String),
    /// Checksum failure.
    #[error("checksum mismatch in {0} (chunk {1})")]
    Checksum(String, usize),
    /// Missing attribute/dataset.
    #[error("no such {kind}: {name}")]
    NotFound {
        /// "attribute" or "dataset".
        kind: &'static str,
        /// Requested name.
        name: String,
    },
    /// Type mismatch on read.
    #[error("dtype mismatch for {name}: stored {stored:?}, requested {requested:?}")]
    DtypeMismatch {
        /// Object name.
        name: String,
        /// Stored dtype.
        stored: Dtype,
        /// Requested dtype.
        requested: Dtype,
    },
    /// Out-of-bounds slice read.
    #[error("slice [{start}, {start}+{count}) out of bounds for {name} (len {len})")]
    OutOfBounds {
        /// Dataset name.
        name: String,
        /// Slice start.
        start: u64,
        /// Slice length.
        count: u64,
        /// Dataset length.
        len: u64,
    },
    /// API misuse (e.g. writing after finish).
    #[error("usage error: {0}")]
    Usage(String),
}

/// Result alias for container operations.
pub type Result<T> = std::result::Result<T, H5Error>;

/// Byte/op counters for one reader or writer, consumed by the I/O cost
/// simulator.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoStats {
    /// Bytes transferred (payload, excluding directory).
    pub bytes: u64,
    /// Number of distinct read/write operations (chunk granularity).
    pub ops: u64,
    /// Number of file opens.
    pub opens: u64,
    /// ABHSF blocks listed in the directories of the files this counter
    /// set covers (block-pruned loading only; zero elsewhere).
    pub blocks_total: u64,
    /// ABHSF blocks whose payload was neither fetched nor decoded because
    /// the block rectangle cannot intersect the reading rank's region
    /// (block-pruned loading only; zero elsewhere).
    pub blocks_skipped: u64,
    /// Payload bytes of the skipped blocks (logical element-level bytes,
    /// independent of container chunk granularity; block-pruned loading
    /// only, zero elsewhere).
    pub bytes_skipped: u64,
    /// Read-ahead batches that were already fetched when the decoder
    /// asked for them — each hit is a fetch fully overlapped with decode
    /// (block-pruned loading only; zero elsewhere).
    pub prefetch_hits: u64,
    /// Nanoseconds the decoder spent blocked waiting for the read-ahead
    /// fetcher (block-pruned loading only; zero elsewhere). Zero stall
    /// with nonzero hits means the pipeline fully hid the fetch time.
    pub prefetch_stall_ns: u64,
}

impl IoStats {
    /// Accumulate another counter set.
    pub fn add(&mut self, other: IoStats) {
        self.bytes += other.bytes;
        self.ops += other.ops;
        self.opens += other.opens;
        self.blocks_total += other.blocks_total;
        self.blocks_skipped += other.blocks_skipped;
        self.bytes_skipped += other.bytes_skipped;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_stall_ns += other.prefetch_stall_ns;
    }
}
