//! h5spm container reader: directory parsing, attribute access, whole /
//! sliced (hyperslab) dataset reads, checksum verification, I/O counters.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use crate::h5::dtype::{decode_slice, Dtype, Scalar};
use crate::h5::writer::{AttrEntry, ChunkEntry, DatasetEntry};
use crate::h5::{H5Error, IoStats, Result, MAGIC};

/// Read-only view of one h5spm container.
pub struct H5Reader {
    pub(crate) file: RefCell<File>,
    path: PathBuf,
    attrs: BTreeMap<String, AttrEntry>,
    pub(crate) datasets: BTreeMap<String, DatasetEntry>,
    stats: RefCell<IoStats>,
    /// When false, chunk CRCs are not verified (perf mode).
    pub verify_checksums: bool,
}

impl H5Reader {
    /// Open and parse the directory.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut file = File::open(&path)?;
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)
            .map_err(|_| H5Error::BadMagic(format!("{}: too short", path.display())))?;
        if &magic != MAGIC {
            return Err(H5Error::BadMagic(format!(
                "{}: bad magic {:?}",
                path.display(),
                magic
            )));
        }
        let dir_offset = read_u64(&mut file)?;
        let dir_len = read_u64(&mut file)?;
        if dir_offset == 0 {
            return Err(H5Error::Corrupt(format!(
                "{}: unfinished file (no directory)",
                path.display()
            )));
        }
        file.seek(SeekFrom::Start(dir_offset))?;
        let mut dir = vec![0u8; dir_len as usize];
        file.read_exact(&mut dir)?;
        let mut crc_bytes = [0u8; 4];
        file.read_exact(&mut crc_bytes)?;
        if crc32fast::hash(&dir) != u32::from_le_bytes(crc_bytes) {
            return Err(H5Error::Corrupt(format!(
                "{}: directory checksum mismatch",
                path.display()
            )));
        }

        let mut p = Parser { buf: &dir, pos: 0 };
        let nattrs = p.u32()? as usize;
        let mut attrs = BTreeMap::new();
        for _ in 0..nattrs {
            let name = p.name()?;
            let dtype = Dtype::from_tag(p.u8()?)
                .ok_or_else(|| H5Error::Corrupt("bad attr dtype".into()))?;
            let mut raw = [0u8; 8];
            raw.copy_from_slice(p.bytes(8)?);
            attrs.insert(name, AttrEntry { dtype, raw });
        }
        let ndatasets = p.u32()? as usize;
        let mut datasets = BTreeMap::new();
        for _ in 0..ndatasets {
            let name = p.name()?;
            let dtype = Dtype::from_tag(p.u8()?)
                .ok_or_else(|| H5Error::Corrupt("bad dataset dtype".into()))?;
            let total_elems = p.u64()?;
            let nchunks = p.u32()? as usize;
            let mut chunks = Vec::with_capacity(nchunks);
            let mut sum = 0u64;
            for _ in 0..nchunks {
                let offset = p.u64()?;
                let elems = p.u64()?;
                let crc = p.u32()?;
                sum += elems;
                chunks.push(ChunkEntry { offset, elems, crc });
            }
            if sum != total_elems {
                return Err(H5Error::Corrupt(format!(
                    "dataset {name}: chunk sum {sum} != total {total_elems}"
                )));
            }
            datasets.insert(
                name,
                DatasetEntry {
                    dtype,
                    total_elems,
                    chunks,
                },
            );
        }

        Ok(Self {
            file: RefCell::new(file),
            path,
            attrs,
            datasets,
            stats: RefCell::new(IoStats {
                opens: 1,
                // Superblock + directory reads.
                bytes: 24 + dir_len + 4,
                ops: 2,
            }),
            verify_checksums: true,
        })
    }

    /// Path this reader was opened from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// List attribute names.
    pub fn attr_names(&self) -> Vec<String> {
        self.attrs.keys().cloned().collect()
    }

    /// List dataset names.
    pub fn dataset_names(&self) -> Vec<String> {
        self.datasets.keys().cloned().collect()
    }

    /// Typed attribute read.
    pub fn attr<T: Scalar>(&self, name: &str) -> Result<T> {
        let a = self.attrs.get(name).ok_or_else(|| H5Error::NotFound {
            kind: "attribute",
            name: name.into(),
        })?;
        if a.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: a.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(T::read_le(&a.raw[..T::DTYPE.size()]))
    }

    /// Does this dataset exist?
    pub fn has_dataset(&self, name: &str) -> bool {
        self.datasets.contains_key(name)
    }

    /// Dataset length in elements.
    pub fn dataset_len(&self, name: &str) -> Result<u64> {
        Ok(self.entry(name)?.total_elems)
    }

    /// Dataset dtype.
    pub fn dataset_dtype(&self, name: &str) -> Result<Dtype> {
        Ok(self.entry(name)?.dtype)
    }

    pub(crate) fn entry(&self, name: &str) -> Result<&DatasetEntry> {
        self.datasets.get(name).ok_or_else(|| H5Error::NotFound {
            kind: "dataset",
            name: name.into(),
        })
    }

    fn check_dtype<T: Scalar>(&self, name: &str) -> Result<&DatasetEntry> {
        let e = self.entry(name)?;
        if e.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: e.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(e)
    }

    /// Read one whole chunk's payload (with optional CRC verification).
    pub(crate) fn read_chunk_bytes(
        &self,
        name: &str,
        chunk_idx: usize,
        chunk: &ChunkEntry,
        width: usize,
    ) -> Result<Vec<u8>> {
        let nbytes = chunk.elems as usize * width;
        let mut buf = vec![0u8; nbytes];
        {
            let mut f = self.file.borrow_mut();
            f.seek(SeekFrom::Start(chunk.offset))?;
            f.read_exact(&mut buf)?;
        }
        let mut st = self.stats.borrow_mut();
        st.bytes += nbytes as u64;
        st.ops += 1;
        drop(st);
        if self.verify_checksums && crc32fast::hash(&buf) != chunk.crc {
            return Err(H5Error::Checksum(name.to_string(), chunk_idx));
        }
        Ok(buf)
    }

    /// Read an entire dataset.
    pub fn read_all<T: Scalar>(&self, name: &str) -> Result<Vec<T>> {
        let e = self.check_dtype::<T>(name)?.clone();
        let mut out = Vec::with_capacity(e.total_elems as usize);
        for (i, c) in e.chunks.iter().enumerate() {
            let bytes = self.read_chunk_bytes(name, i, c, T::DTYPE.size())?;
            out.extend(decode_slice::<T>(&bytes));
        }
        Ok(out)
    }

    /// Read the hyperslab `[start, start+count)` of a dataset, touching
    /// only the chunks that overlap it.
    pub fn read_slice<T: Scalar>(&self, name: &str, start: u64, count: u64) -> Result<Vec<T>> {
        let e = self.check_dtype::<T>(name)?.clone();
        if start + count > e.total_elems {
            return Err(H5Error::OutOfBounds {
                name: name.into(),
                start,
                count,
                len: e.total_elems,
            });
        }
        let mut out = Vec::with_capacity(count as usize);
        let mut chunk_start = 0u64;
        for (i, c) in e.chunks.iter().enumerate() {
            let chunk_end = chunk_start + c.elems;
            if chunk_end > start && chunk_start < start + count {
                let bytes = self.read_chunk_bytes(name, i, c, T::DTYPE.size())?;
                let all = decode_slice::<T>(&bytes);
                let lo = start.saturating_sub(chunk_start) as usize;
                let hi = ((start + count).min(chunk_end) - chunk_start) as usize;
                out.extend_from_slice(&all[lo..hi]);
            }
            if chunk_end >= start + count {
                break;
            }
            chunk_start = chunk_end;
        }
        Ok(out)
    }

    /// I/O counters accumulated by this reader.
    pub fn stats(&self) -> IoStats {
        *self.stats.borrow()
    }
}

struct Parser<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(H5Error::Corrupt("directory truncated".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String> {
        let len = u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()) as usize;
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| H5Error::Corrupt("non-utf8 name".into()))
    }
}

fn read_u64(f: &mut File) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
