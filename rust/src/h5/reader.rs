//! h5spm container reader: directory parsing, attribute access, whole /
//! sliced (hyperslab) dataset reads, checksum verification, I/O counters.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use crate::h5::dtype::{decode_slice, Dtype, Scalar};
use crate::h5::writer::{AttrEntry, ChunkEntry, DatasetEntry};
use crate::h5::{H5Error, IoStats, Result, MAGIC};

/// Read-only view of one h5spm container.
pub struct H5Reader {
    pub(crate) file: RefCell<File>,
    path: PathBuf,
    attrs: BTreeMap<String, AttrEntry>,
    pub(crate) datasets: BTreeMap<String, DatasetEntry>,
    stats: RefCell<IoStats>,
    /// When false, chunk CRCs are not verified (perf mode).
    pub verify_checksums: bool,
}

impl H5Reader {
    /// Open and parse the directory.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let mut file = File::open(&path)?;
        let mut magic = [0u8; 8];
        file.read_exact(&mut magic)
            .map_err(|_| H5Error::BadMagic(format!("{}: too short", path.display())))?;
        if &magic != MAGIC {
            return Err(H5Error::BadMagic(format!(
                "{}: bad magic {:?}",
                path.display(),
                magic
            )));
        }
        let dir_offset = read_u64(&mut file)?;
        let dir_len = read_u64(&mut file)?;
        if dir_offset == 0 {
            return Err(H5Error::Corrupt(format!(
                "{}: unfinished file (no directory)",
                path.display()
            )));
        }
        file.seek(SeekFrom::Start(dir_offset))?;
        let mut dir = vec![0u8; dir_len as usize];
        file.read_exact(&mut dir)?;
        let mut crc_bytes = [0u8; 4];
        file.read_exact(&mut crc_bytes)?;
        if crc32fast::hash(&dir) != u32::from_le_bytes(crc_bytes) {
            return Err(H5Error::Corrupt(format!(
                "{}: directory checksum mismatch",
                path.display()
            )));
        }

        let mut p = Parser { buf: &dir, pos: 0 };
        let nattrs = p.u32()? as usize;
        let mut attrs = BTreeMap::new();
        for _ in 0..nattrs {
            let name = p.name()?;
            let dtype = Dtype::from_tag(p.u8()?)
                .ok_or_else(|| H5Error::Corrupt("bad attr dtype".into()))?;
            let mut raw = [0u8; 8];
            raw.copy_from_slice(p.bytes(8)?);
            attrs.insert(name, AttrEntry { dtype, raw });
        }
        let ndatasets = p.u32()? as usize;
        let mut datasets = BTreeMap::new();
        for _ in 0..ndatasets {
            let name = p.name()?;
            let dtype = Dtype::from_tag(p.u8()?)
                .ok_or_else(|| H5Error::Corrupt("bad dataset dtype".into()))?;
            let total_elems = p.u64()?;
            let nchunks = p.u32()? as usize;
            let mut chunks = Vec::with_capacity(nchunks);
            let mut sum = 0u64;
            for _ in 0..nchunks {
                let offset = p.u64()?;
                let elems = p.u64()?;
                let crc = p.u32()?;
                sum += elems;
                chunks.push(ChunkEntry { offset, elems, crc });
            }
            if sum != total_elems {
                return Err(H5Error::Corrupt(format!(
                    "dataset {name}: chunk sum {sum} != total {total_elems}"
                )));
            }
            datasets.insert(
                name,
                DatasetEntry {
                    dtype,
                    total_elems,
                    chunks,
                },
            );
        }

        Ok(Self {
            file: RefCell::new(file),
            path,
            attrs,
            datasets,
            stats: RefCell::new(IoStats {
                opens: 1,
                // Superblock + directory reads.
                bytes: 24 + dir_len + 4,
                ops: 2,
                ..IoStats::default()
            }),
            verify_checksums: true,
        })
    }

    /// Path this reader was opened from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// List attribute names.
    pub fn attr_names(&self) -> Vec<String> {
        self.attrs.keys().cloned().collect()
    }

    /// List dataset names.
    pub fn dataset_names(&self) -> Vec<String> {
        self.datasets.keys().cloned().collect()
    }

    /// Typed attribute read.
    pub fn attr<T: Scalar>(&self, name: &str) -> Result<T> {
        let a = self.attrs.get(name).ok_or_else(|| H5Error::NotFound {
            kind: "attribute",
            name: name.into(),
        })?;
        if a.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: a.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(T::read_le(&a.raw[..T::DTYPE.size()]))
    }

    /// Does this dataset exist?
    pub fn has_dataset(&self, name: &str) -> bool {
        self.datasets.contains_key(name)
    }

    /// Dataset length in elements.
    pub fn dataset_len(&self, name: &str) -> Result<u64> {
        Ok(self.entry(name)?.total_elems)
    }

    /// Dataset dtype.
    pub fn dataset_dtype(&self, name: &str) -> Result<Dtype> {
        Ok(self.entry(name)?.dtype)
    }

    pub(crate) fn entry(&self, name: &str) -> Result<&DatasetEntry> {
        self.datasets.get(name).ok_or_else(|| H5Error::NotFound {
            kind: "dataset",
            name: name.into(),
        })
    }

    fn check_dtype<T: Scalar>(&self, name: &str) -> Result<&DatasetEntry> {
        let e = self.entry(name)?;
        if e.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: e.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(e)
    }

    /// Read one whole chunk's payload (with optional CRC verification).
    pub(crate) fn read_chunk_bytes(
        &self,
        name: &str,
        chunk_idx: usize,
        chunk: &ChunkEntry,
        width: usize,
    ) -> Result<Vec<u8>> {
        let nbytes = chunk.elems as usize * width;
        let mut buf = vec![0u8; nbytes];
        {
            let mut f = self.file.borrow_mut();
            f.seek(SeekFrom::Start(chunk.offset))?;
            f.read_exact(&mut buf)?;
        }
        let mut st = self.stats.borrow_mut();
        st.bytes += nbytes as u64;
        st.ops += 1;
        drop(st);
        if self.verify_checksums && crc32fast::hash(&buf) != chunk.crc {
            return Err(H5Error::Checksum(name.to_string(), chunk_idx));
        }
        Ok(buf)
    }

    /// Read an entire dataset.
    pub fn read_all<T: Scalar>(&self, name: &str) -> Result<Vec<T>> {
        let e = self.check_dtype::<T>(name)?.clone();
        let mut out = Vec::with_capacity(e.total_elems as usize);
        for (i, c) in e.chunks.iter().enumerate() {
            let bytes = self.read_chunk_bytes(name, i, c, T::DTYPE.size())?;
            out.extend(decode_slice::<T>(&bytes));
        }
        Ok(out)
    }

    /// Read the hyperslab `[start, start+count)` of a dataset, touching
    /// only the chunks that overlap it.
    pub fn read_slice<T: Scalar>(&self, name: &str, start: u64, count: u64) -> Result<Vec<T>> {
        let e = self.check_dtype::<T>(name)?.clone();
        if start + count > e.total_elems {
            return Err(H5Error::OutOfBounds {
                name: name.into(),
                start,
                count,
                len: e.total_elems,
            });
        }
        let mut out = Vec::with_capacity(count as usize);
        let mut chunk_start = 0u64;
        for (i, c) in e.chunks.iter().enumerate() {
            let chunk_end = chunk_start + c.elems;
            if chunk_end > start && chunk_start < start + count {
                let bytes = self.read_chunk_bytes(name, i, c, T::DTYPE.size())?;
                let all = decode_slice::<T>(&bytes);
                let lo = start.saturating_sub(chunk_start) as usize;
                let hi = ((start + count).min(chunk_end) - chunk_start) as usize;
                out.extend_from_slice(&all[lo..hi]);
            }
            if chunk_end >= start + count {
                break;
            }
            chunk_start = chunk_end;
        }
        Ok(out)
    }

    /// Read many hyperslabs of one dataset in a single forward pass.
    ///
    /// `ranges` must be ascending and non-overlapping `(start, count)`
    /// pairs (element units). Each chunk of the dataset is read **at most
    /// once** no matter how many ranges touch it, and chunks touched by no
    /// range are not read at all — this is the I/O primitive behind
    /// block-pruned loading, where per-block [`H5Reader::read_slice`]
    /// calls would re-fetch shared chunks once per block.
    ///
    /// Returns one vector per requested range, in order.
    pub fn read_ranges<T: Scalar>(
        &self,
        name: &str,
        ranges: &[(u64, u64)],
    ) -> Result<Vec<Vec<T>>> {
        let e = self.check_dtype::<T>(name)?.clone();
        let mut prev_end = 0u64;
        for &(start, count) in ranges {
            if start < prev_end {
                return Err(H5Error::Usage(format!(
                    "read_ranges({name}): ranges not ascending/disjoint at {start}"
                )));
            }
            if start + count > e.total_elems {
                return Err(H5Error::OutOfBounds {
                    name: name.into(),
                    start,
                    count,
                    len: e.total_elems,
                });
            }
            prev_end = start + count;
        }
        let mut out: Vec<Vec<T>> = ranges
            .iter()
            .map(|&(_, count)| Vec::with_capacity(count as usize))
            .collect();
        // Walk chunks and ranges in lockstep; `next` is the first range
        // not yet fully served.
        let mut next = 0usize;
        let mut chunk_start = 0u64;
        for (ci, c) in e.chunks.iter().enumerate() {
            let chunk_end = chunk_start + c.elems;
            // Skip ranges that end before this chunk (already served).
            while next < ranges.len() && ranges[next].0 + ranges[next].1 <= chunk_start {
                next += 1;
            }
            if next >= ranges.len() {
                break;
            }
            // Does any range overlap this chunk?
            let overlaps = ranges[next..]
                .iter()
                .take_while(|&&(start, _)| start < chunk_end)
                .any(|&(_, count)| count > 0);
            if !overlaps {
                chunk_start = chunk_end;
                continue;
            }
            let bytes = self.read_chunk_bytes(name, ci, c, T::DTYPE.size())?;
            let all = decode_slice::<T>(&bytes);
            for (k, &(start, count)) in ranges.iter().enumerate().skip(next) {
                if start >= chunk_end {
                    break;
                }
                let end = start + count;
                if end <= chunk_start || count == 0 {
                    continue;
                }
                let lo = start.max(chunk_start) - chunk_start;
                let hi = end.min(chunk_end) - chunk_start;
                out[k].extend_from_slice(&all[lo as usize..hi as usize]);
            }
            chunk_start = chunk_end;
        }
        Ok(out)
    }

    /// I/O counters accumulated by this reader.
    pub fn stats(&self) -> IoStats {
        *self.stats.borrow()
    }
}

struct Parser<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(H5Error::Corrupt("directory truncated".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String> {
        let len = u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()) as usize;
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| H5Error::Corrupt("non-utf8 name".into()))
    }
}

fn read_u64(f: &mut File) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::h5::writer::H5Writer;

    fn ranged_file(name: &str, len: u32, chunk: u64) -> PathBuf {
        let dir = std::env::temp_dir().join("abhsf-h5-reader-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let data: Vec<u32> = (0..len).collect();
        let mut w = H5Writer::create(&path).unwrap();
        w.set_chunk_elems(chunk);
        w.write_dataset("d", &data).unwrap();
        w.finish().unwrap();
        path
    }

    #[test]
    fn read_ranges_matches_read_slice() {
        let path = ranged_file("ranges.h5spm", 1000, 64);
        let r = H5Reader::open(&path).unwrap();
        let ranges = [(0u64, 10u64), (10, 5), (70, 200), (999, 1)];
        let got = r.read_ranges::<u32>("d", &ranges).unwrap();
        assert_eq!(got.len(), ranges.len());
        for (out, &(start, count)) in got.iter().zip(&ranges) {
            let want = r.read_slice::<u32>("d", start, count).unwrap();
            assert_eq!(*out, want, "range ({start},{count})");
        }
        // Empty ranges yield empty vectors.
        let got = r.read_ranges::<u32>("d", &[(5, 0), (42, 3)]).unwrap();
        assert!(got[0].is_empty());
        assert_eq!(got[1], vec![42, 43, 44]);
        assert!(r.read_ranges::<u32>("d", &[]).unwrap().is_empty());
    }

    /// Chunks shared by several ranges are fetched once, and untouched
    /// chunks are never fetched — the byte-saving contract of pruning.
    #[test]
    fn read_ranges_reads_each_needed_chunk_once() {
        let path = ranged_file("ranges-bytes.h5spm", 1000, 100);
        // Two ranges in chunk 0, nothing until a range in chunk 9.
        let r = H5Reader::open(&path).unwrap();
        let base = r.stats().bytes;
        let got = r
            .read_ranges::<u32>("d", &[(3, 4), (50, 10), (950, 20)])
            .unwrap();
        assert_eq!(got[0], vec![3, 4, 5, 6]);
        assert_eq!(got[2][0], 950);
        let payload = r.stats().bytes - base;
        // Exactly two 100-element u32 chunks.
        assert_eq!(payload, 2 * 100 * 4);
        assert_eq!(r.stats().ops, 2 + 2);
        // Reference: read_all touches all ten chunks.
        let r2 = H5Reader::open(&path).unwrap();
        let base2 = r2.stats().bytes;
        r2.read_all::<u32>("d").unwrap();
        assert_eq!(r2.stats().bytes - base2, 1000 * 4);
    }

    #[test]
    fn read_ranges_rejects_bad_input() {
        let path = ranged_file("ranges-bad.h5spm", 100, 10);
        let r = H5Reader::open(&path).unwrap();
        assert!(matches!(
            r.read_ranges::<u32>("d", &[(90, 20)]),
            Err(H5Error::OutOfBounds { .. })
        ));
        assert!(matches!(
            r.read_ranges::<u32>("d", &[(10, 10), (5, 2)]),
            Err(H5Error::Usage(_))
        ));
        // Overlap is also rejected.
        assert!(matches!(
            r.read_ranges::<u32>("d", &[(0, 10), (9, 2)]),
            Err(H5Error::Usage(_))
        ));
    }

    #[test]
    fn read_ranges_spanning_chunk_boundaries() {
        let path = ranged_file("ranges-span.h5spm", 300, 64);
        let r = H5Reader::open(&path).unwrap();
        let got = r.read_ranges::<u32>("d", &[(60, 80), (200, 100)]).unwrap();
        let want: Vec<u32> = (60..140).collect();
        assert_eq!(got[0], want);
        let want: Vec<u32> = (200..300).collect();
        assert_eq!(got[1], want);
    }
}
