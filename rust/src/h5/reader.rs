//! h5spm container reader: directory parsing, attribute access, whole /
//! sliced (hyperslab) dataset reads, checksum verification, I/O counters.
//!
//! Readers are backend-agnostic: [`H5Reader::open_on`] takes any
//! [`crate::vfs::Storage`] implementation ([`H5Reader::open`] is the
//! local-filesystem shorthand), and all positioned reads go through the
//! shared [`StorageRead`] handle — which also powers the crate-internal
//! double-buffered `PrefetchStream` used by block-pruned loading to
//! overlap payload fetching with decoding.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::h5::dtype::{decode_slice, Dtype, Scalar};
use crate::h5::writer::{AttrEntry, ChunkEntry, DatasetEntry};
use crate::h5::{H5Error, IoStats, Result, MAGIC};
use crate::obs::metrics::Counter;
use crate::obs::trace::{self, Tag};
use crate::vfs::{LocalFs, Storage, StorageRead};

/// Global-registry handles for the chunk-read counters, resolved once so
/// the per-chunk path pays two relaxed atomic adds, not a registry lock.
fn vfs_counters() -> &'static (Arc<Counter>, Arc<Counter>) {
    static HANDLES: OnceLock<(Arc<Counter>, Arc<Counter>)> = OnceLock::new();
    HANDLES.get_or_init(|| {
        let reg = crate::obs::metrics::global();
        (reg.counter("vfs.read_ops"), reg.counter("vfs.read_bytes"))
    })
}

/// Read-only view of one h5spm container.
pub struct H5Reader {
    pub(crate) file: Arc<dyn StorageRead>,
    path: PathBuf,
    attrs: BTreeMap<String, AttrEntry>,
    pub(crate) datasets: BTreeMap<String, DatasetEntry>,
    stats: RefCell<IoStats>,
    /// When false, chunk CRCs are not verified (perf mode).
    pub verify_checksums: bool,
}

impl H5Reader {
    /// Open and parse the directory on the local filesystem.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        Self::open_on(&LocalFs, path)
    }

    /// Open and parse the directory on an arbitrary storage backend.
    pub fn open_on<P: AsRef<Path>>(storage: &dyn Storage, path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = storage.open(&path)?;
        // Superblock: magic + directory offset/len.
        let mut superblock = [0u8; 24];
        file.read_exact_at(0, &mut superblock)
            .map_err(|_| H5Error::BadMagic(format!("{}: too short", path.display())))?;
        if &superblock[..8] != MAGIC {
            return Err(H5Error::BadMagic(format!(
                "{}: bad magic {:?}",
                path.display(),
                &superblock[..8]
            )));
        }
        let dir_offset = u64::from_le_bytes(superblock[8..16].try_into().unwrap());
        let dir_len = u64::from_le_bytes(superblock[16..24].try_into().unwrap());
        if dir_offset == 0 {
            return Err(H5Error::Corrupt(format!(
                "{}: unfinished file (no directory)",
                path.display()
            )));
        }
        // Never trust the stored directory extent: a corrupt superblock
        // must be a typed error, not a huge allocation or an overflow.
        let file_len = file.len()?;
        let dir_end = dir_offset
            .checked_add(dir_len)
            .and_then(|end| end.checked_add(4));
        match dir_end {
            Some(end) if end <= file_len => {}
            _ => {
                return Err(H5Error::Corrupt(format!(
                    "{}: directory [{dir_offset}, +{dir_len}+4) exceeds file size {file_len}",
                    path.display()
                )))
            }
        }
        let mut dir = vec![0u8; dir_len as usize + 4];
        file.read_exact_at(dir_offset, &mut dir)?;
        let crc_bytes: [u8; 4] = dir[dir_len as usize..].try_into().unwrap();
        dir.truncate(dir_len as usize);
        if crc32fast::hash(&dir) != u32::from_le_bytes(crc_bytes) {
            return Err(H5Error::Corrupt(format!(
                "{}: directory checksum mismatch",
                path.display()
            )));
        }

        let mut p = Parser { buf: &dir, pos: 0 };
        let nattrs = p.u32()? as usize;
        let mut attrs = BTreeMap::new();
        for _ in 0..nattrs {
            let name = p.name()?;
            let dtype = Dtype::from_tag(p.u8()?)
                .ok_or_else(|| H5Error::Corrupt("bad attr dtype".into()))?;
            let mut raw = [0u8; 8];
            raw.copy_from_slice(p.bytes(8)?);
            attrs.insert(name, AttrEntry { dtype, raw });
        }
        let ndatasets = p.u32()? as usize;
        let mut datasets = BTreeMap::new();
        for _ in 0..ndatasets {
            let name = p.name()?;
            let dtype = Dtype::from_tag(p.u8()?)
                .ok_or_else(|| H5Error::Corrupt("bad dataset dtype".into()))?;
            let total_elems = p.u64()?;
            let nchunks = p.u32()? as usize;
            let mut chunks = Vec::with_capacity(nchunks);
            let mut sum = 0u64;
            for _ in 0..nchunks {
                let offset = p.u64()?;
                let elems = p.u64()?;
                let crc = p.u32()?;
                sum += elems;
                chunks.push(ChunkEntry { offset, elems, crc });
            }
            if sum != total_elems {
                return Err(H5Error::Corrupt(format!(
                    "dataset {name}: chunk sum {sum} != total {total_elems}"
                )));
            }
            datasets.insert(
                name,
                DatasetEntry {
                    dtype,
                    total_elems,
                    chunks,
                },
            );
        }

        Ok(Self {
            file,
            path,
            attrs,
            datasets,
            stats: RefCell::new(IoStats {
                opens: 1,
                // Superblock + directory reads.
                bytes: 24 + dir_len + 4,
                ops: 2,
                ..IoStats::default()
            }),
            verify_checksums: true,
        })
    }

    /// Path this reader was opened from.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// List attribute names.
    pub fn attr_names(&self) -> Vec<String> {
        self.attrs.keys().cloned().collect()
    }

    /// List dataset names.
    pub fn dataset_names(&self) -> Vec<String> {
        self.datasets.keys().cloned().collect()
    }

    /// Typed attribute read.
    pub fn attr<T: Scalar>(&self, name: &str) -> Result<T> {
        let a = self.attrs.get(name).ok_or_else(|| H5Error::NotFound {
            kind: "attribute",
            name: name.into(),
        })?;
        if a.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: a.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(T::read_le(&a.raw[..T::DTYPE.size()]))
    }

    /// Does this dataset exist?
    pub fn has_dataset(&self, name: &str) -> bool {
        self.datasets.contains_key(name)
    }

    /// Dataset length in elements.
    pub fn dataset_len(&self, name: &str) -> Result<u64> {
        Ok(self.entry(name)?.total_elems)
    }

    /// Dataset dtype.
    pub fn dataset_dtype(&self, name: &str) -> Result<Dtype> {
        Ok(self.entry(name)?.dtype)
    }

    pub(crate) fn entry(&self, name: &str) -> Result<&DatasetEntry> {
        self.datasets.get(name).ok_or_else(|| H5Error::NotFound {
            kind: "dataset",
            name: name.into(),
        })
    }

    fn check_dtype<T: Scalar>(&self, name: &str) -> Result<&DatasetEntry> {
        let e = self.entry(name)?;
        if e.dtype != T::DTYPE {
            return Err(H5Error::DtypeMismatch {
                name: name.into(),
                stored: e.dtype,
                requested: T::DTYPE,
            });
        }
        Ok(e)
    }

    /// Read one whole chunk's payload (with optional CRC verification).
    pub(crate) fn read_chunk_bytes(
        &self,
        name: &str,
        chunk_idx: usize,
        chunk: &ChunkEntry,
        width: usize,
    ) -> Result<Vec<u8>> {
        let nbytes = chunk.elems as usize * width;
        let mut buf = vec![0u8; nbytes];
        {
            let _span = trace::span("vfs_read", &[("bytes", Tag::U(nbytes as u64))]);
            self.file.read_exact_at(chunk.offset, &mut buf)?;
        }
        let (ops, bytes) = vfs_counters();
        ops.inc();
        bytes.add(nbytes as u64);
        let mut st = self.stats.borrow_mut();
        st.bytes += nbytes as u64;
        st.ops += 1;
        drop(st);
        if self.verify_checksums && crc32fast::hash(&buf) != chunk.crc {
            return Err(H5Error::Checksum(name.to_string(), chunk_idx));
        }
        Ok(buf)
    }

    /// Read an entire dataset.
    pub fn read_all<T: Scalar>(&self, name: &str) -> Result<Vec<T>> {
        let e = self.check_dtype::<T>(name)?.clone();
        let mut out = Vec::with_capacity(e.total_elems as usize);
        for (i, c) in e.chunks.iter().enumerate() {
            let bytes = self.read_chunk_bytes(name, i, c, T::DTYPE.size())?;
            out.extend(decode_slice::<T>(&bytes));
        }
        Ok(out)
    }

    /// Read the hyperslab `[start, start+count)` of a dataset, touching
    /// only the chunks that overlap it.
    pub fn read_slice<T: Scalar>(&self, name: &str, start: u64, count: u64) -> Result<Vec<T>> {
        let e = self.check_dtype::<T>(name)?.clone();
        if start + count > e.total_elems {
            return Err(H5Error::OutOfBounds {
                name: name.into(),
                start,
                count,
                len: e.total_elems,
            });
        }
        let mut out = Vec::with_capacity(count as usize);
        let mut chunk_start = 0u64;
        for (i, c) in e.chunks.iter().enumerate() {
            let chunk_end = chunk_start + c.elems;
            if chunk_end > start && chunk_start < start + count {
                let bytes = self.read_chunk_bytes(name, i, c, T::DTYPE.size())?;
                let all = decode_slice::<T>(&bytes);
                let lo = start.saturating_sub(chunk_start) as usize;
                let hi = ((start + count).min(chunk_end) - chunk_start) as usize;
                out.extend_from_slice(&all[lo..hi]);
            }
            if chunk_end >= start + count {
                break;
            }
            chunk_start = chunk_end;
        }
        Ok(out)
    }

    /// Read many hyperslabs of one dataset in a single forward pass.
    ///
    /// `ranges` must be ascending and non-overlapping `(start, count)`
    /// pairs (element units). Each chunk of the dataset is read **at most
    /// once** no matter how many ranges touch it, and chunks touched by no
    /// range are not read at all — this is the I/O primitive behind
    /// block-pruned loading, where per-block [`H5Reader::read_slice`]
    /// calls would re-fetch shared chunks once per block.
    ///
    /// Returns one vector per requested range, in order.
    pub fn read_ranges<T: Scalar>(
        &self,
        name: &str,
        ranges: &[(u64, u64)],
    ) -> Result<Vec<Vec<T>>> {
        let e = self.check_dtype::<T>(name)?.clone();
        let (raw, io) = fetch_ranges_raw(
            self.file.as_ref(),
            name,
            &e,
            T::DTYPE.size(),
            ranges,
            self.verify_checksums,
        )?;
        self.stats.borrow_mut().add(io);
        Ok(raw.iter().map(|bytes| decode_slice::<T>(bytes)).collect())
    }

    /// Merge externally accumulated counters (the prefetch worker's) into
    /// this reader's statistics.
    pub(crate) fn merge_stats(&self, io: IoStats) {
        self.stats.borrow_mut().add(io);
    }

    /// Start a double-buffered background fetch over `datasets`.
    ///
    /// Each [`BatchRequest`] names, per dataset (aligned with the
    /// `datasets` slice), the ascending disjoint element ranges to fetch.
    /// A background thread fetches batches in order through the *same*
    /// storage handle (no extra open is charged) and hands them over a
    /// bounded channel, staying at most two batches ahead of the
    /// consumer — fetch of batch `i + 1` overlaps decode of batch `i`.
    /// Consume with [`PrefetchStream::next`].
    pub(crate) fn prefetch(
        &self,
        datasets: &[&str],
        batches: Vec<BatchRequest>,
    ) -> Result<PrefetchStream> {
        let entries: Vec<(String, DatasetEntry, usize)> = datasets
            .iter()
            .map(|name| {
                let e = self.entry(name)?.clone();
                let width = e.dtype.size();
                Ok((name.to_string(), e, width))
            })
            .collect::<Result<_>>()?;
        let file = Arc::clone(&self.file);
        let verify = self.verify_checksums;
        let (tx, rx) = mpsc::sync_channel::<Result<(BatchData, IoStats)>>(1);
        // The fetcher runs on its own thread: hand it the caller's current
        // span id so its `prefetch_batch` spans stay linked into the
        // claiming query's trace chain (DESIGN.md §14).
        let trace_parent = trace::current_id();
        let handle = std::thread::spawn(move || {
            trace::adopt_parent(trace_parent);
            for batch in batches {
                let _span = trace::span("prefetch_batch", &[]);
                let mut io = IoStats::default();
                let mut data = Vec::with_capacity(entries.len());
                let mut failed = None;
                for ((name, entry, width), ranges) in entries.iter().zip(&batch.ranges) {
                    match fetch_ranges_raw(file.as_ref(), name, entry, *width, ranges, verify) {
                        Ok((d, st)) => {
                            io.add(st);
                            data.push(d);
                        }
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
                match failed {
                    None => {
                        if tx.send(Ok((BatchData { data }, io))).is_err() {
                            return; // consumer gone
                        }
                    }
                    Some(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }
        });
        Ok(PrefetchStream {
            rx: Some(rx),
            handle: Some(handle),
            hits: 0,
            stall_ns: 0,
        })
    }

    /// I/O counters accumulated by this reader.
    pub fn stats(&self) -> IoStats {
        *self.stats.borrow()
    }
}

/// One prefetch batch: per dataset (aligned with the `datasets` slice
/// given to [`H5Reader::prefetch`]), ascending disjoint `(start, count)`
/// element ranges; an empty list skips that dataset for this batch.
pub(crate) struct BatchRequest {
    pub ranges: Vec<Vec<(u64, u64)>>,
}

/// One fetched batch: `data[d][r]` holds the raw little-endian bytes of
/// range `r` of dataset `d`, aligned with the request.
pub(crate) struct BatchData {
    pub data: Vec<Vec<Vec<u8>>>,
}

/// Consumer half of [`H5Reader::prefetch`]: yields batches in order and
/// accounts the overlap — a batch already fetched when asked for is a
/// *prefetch hit*, time spent waiting for the fetcher is *stall*.
pub(crate) struct PrefetchStream {
    rx: Option<mpsc::Receiver<Result<(BatchData, IoStats)>>>,
    handle: Option<std::thread::JoinHandle<()>>,
    hits: u64,
    stall_ns: u64,
}

impl PrefetchStream {
    /// The next batch, or `None` after the last. Fetch I/O counters are
    /// merged into `reader`'s statistics as batches arrive; the
    /// hit/stall counters land there when the stream finishes (including
    /// the error path).
    pub(crate) fn next(&mut self, reader: &H5Reader) -> Result<Option<BatchData>> {
        let Some(rx) = &self.rx else {
            return Ok(None);
        };
        let msg = match rx.try_recv() {
            Ok(m) => {
                self.hits += 1;
                m
            }
            Err(mpsc::TryRecvError::Empty) => {
                let t = Instant::now();
                match rx.recv() {
                    Ok(m) => {
                        self.stall_ns += t.elapsed().as_nanos() as u64;
                        m
                    }
                    Err(_) => {
                        self.finish(reader);
                        return Ok(None);
                    }
                }
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                self.finish(reader);
                return Ok(None);
            }
        };
        match msg {
            Ok((batch, io)) => {
                reader.merge_stats(io);
                Ok(Some(batch))
            }
            Err(e) => {
                self.finish(reader);
                Err(e)
            }
        }
    }

    /// Join the worker and flush hit/stall counters into the reader.
    fn finish(&mut self, reader: &H5Reader) {
        self.rx = None; // unblocks a worker waiting to send
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        reader.merge_stats(IoStats {
            prefetch_hits: self.hits,
            prefetch_stall_ns: self.stall_ns,
            ..IoStats::default()
        });
        self.hits = 0;
        self.stall_ns = 0;
    }
}

impl Drop for PrefetchStream {
    fn drop(&mut self) {
        // Abandoned mid-stream (error propagation): dropping the receiver
        // unblocks the worker; join it so no fetch outlives the reader.
        self.rx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Fetch many hyperslabs of one dataset as raw bytes in a single forward
/// pass (the chunk-coalescing walk behind [`H5Reader::read_ranges`] and
/// the prefetch worker). Within one call each needed chunk is read at
/// most once and untouched chunks never; CRCs are verified per chunk when
/// `verify` is set. Returns the per-range bytes and the I/O counters of
/// this pass (the caller owns merging them into reader statistics).
pub(crate) fn fetch_ranges_raw(
    file: &dyn StorageRead,
    name: &str,
    entry: &DatasetEntry,
    width: usize,
    ranges: &[(u64, u64)],
    verify: bool,
) -> Result<(Vec<Vec<u8>>, IoStats)> {
    let mut prev_end = 0u64;
    for &(start, count) in ranges {
        if start < prev_end {
            return Err(H5Error::Usage(format!(
                "read_ranges({name}): ranges not ascending/disjoint at {start}"
            )));
        }
        if start + count > entry.total_elems {
            return Err(H5Error::OutOfBounds {
                name: name.into(),
                start,
                count,
                len: entry.total_elems,
            });
        }
        prev_end = start + count;
    }
    let mut io = IoStats::default();
    let mut out: Vec<Vec<u8>> = ranges
        .iter()
        .map(|&(_, count)| Vec::with_capacity(count as usize * width))
        .collect();
    // Walk chunks and ranges in lockstep; `next` is the first range
    // not yet fully served.
    let mut next = 0usize;
    let mut chunk_start = 0u64;
    for (ci, c) in entry.chunks.iter().enumerate() {
        let chunk_end = chunk_start + c.elems;
        // Skip ranges that end before this chunk (already served).
        while next < ranges.len() && ranges[next].0 + ranges[next].1 <= chunk_start {
            next += 1;
        }
        if next >= ranges.len() {
            break;
        }
        // Does any range overlap this chunk?
        let overlaps = ranges[next..]
            .iter()
            .take_while(|&&(start, _)| start < chunk_end)
            .any(|&(_, count)| count > 0);
        if !overlaps {
            chunk_start = chunk_end;
            continue;
        }
        let nbytes = c.elems as usize * width;
        let mut buf = vec![0u8; nbytes];
        {
            let _span = trace::span("vfs_read", &[("bytes", Tag::U(nbytes as u64))]);
            file.read_exact_at(c.offset, &mut buf)?;
        }
        let (ops, bytes) = vfs_counters();
        ops.inc();
        bytes.add(nbytes as u64);
        io.bytes += nbytes as u64;
        io.ops += 1;
        if verify && crc32fast::hash(&buf) != c.crc {
            return Err(H5Error::Checksum(name.to_string(), ci));
        }
        for (k, &(start, count)) in ranges.iter().enumerate().skip(next) {
            if start >= chunk_end {
                break;
            }
            let end = start + count;
            if end <= chunk_start || count == 0 {
                continue;
            }
            let lo = (start.max(chunk_start) - chunk_start) as usize * width;
            let hi = (end.min(chunk_end) - chunk_start) as usize * width;
            out[k].extend_from_slice(&buf[lo..hi]);
        }
        chunk_start = chunk_end;
    }
    Ok((out, io))
}

struct Parser<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(H5Error::Corrupt("directory truncated".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn name(&mut self) -> Result<String> {
        let len = u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()) as usize;
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| H5Error::Corrupt("non-utf8 name".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::h5::writer::H5Writer;
    use crate::vfs::MemFs;

    fn ranged_file(name: &str, len: u32, chunk: u64) -> PathBuf {
        let dir = std::env::temp_dir().join("abhsf-h5-reader-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let data: Vec<u32> = (0..len).collect();
        let mut w = H5Writer::create(&path).unwrap();
        w.set_chunk_elems(chunk);
        w.write_dataset("d", &data).unwrap();
        w.finish().unwrap();
        path
    }

    #[test]
    fn read_ranges_matches_read_slice() {
        let path = ranged_file("ranges.h5spm", 1000, 64);
        let r = H5Reader::open(&path).unwrap();
        let ranges = [(0u64, 10u64), (10, 5), (70, 200), (999, 1)];
        let got = r.read_ranges::<u32>("d", &ranges).unwrap();
        assert_eq!(got.len(), ranges.len());
        for (out, &(start, count)) in got.iter().zip(&ranges) {
            let want = r.read_slice::<u32>("d", start, count).unwrap();
            assert_eq!(*out, want, "range ({start},{count})");
        }
        // Empty ranges yield empty vectors.
        let got = r.read_ranges::<u32>("d", &[(5, 0), (42, 3)]).unwrap();
        assert!(got[0].is_empty());
        assert_eq!(got[1], vec![42, 43, 44]);
        assert!(r.read_ranges::<u32>("d", &[]).unwrap().is_empty());
    }

    /// Chunks shared by several ranges are fetched once, and untouched
    /// chunks are never fetched — the byte-saving contract of pruning.
    #[test]
    fn read_ranges_reads_each_needed_chunk_once() {
        let path = ranged_file("ranges-bytes.h5spm", 1000, 100);
        // Two ranges in chunk 0, nothing until a range in chunk 9.
        let r = H5Reader::open(&path).unwrap();
        let base = r.stats().bytes;
        let got = r
            .read_ranges::<u32>("d", &[(3, 4), (50, 10), (950, 20)])
            .unwrap();
        assert_eq!(got[0], vec![3, 4, 5, 6]);
        assert_eq!(got[2][0], 950);
        let payload = r.stats().bytes - base;
        // Exactly two 100-element u32 chunks.
        assert_eq!(payload, 2 * 100 * 4);
        assert_eq!(r.stats().ops, 2 + 2);
        // Reference: read_all touches all ten chunks.
        let r2 = H5Reader::open(&path).unwrap();
        let base2 = r2.stats().bytes;
        r2.read_all::<u32>("d").unwrap();
        assert_eq!(r2.stats().bytes - base2, 1000 * 4);
    }

    #[test]
    fn read_ranges_rejects_bad_input() {
        let path = ranged_file("ranges-bad.h5spm", 100, 10);
        let r = H5Reader::open(&path).unwrap();
        assert!(matches!(
            r.read_ranges::<u32>("d", &[(90, 20)]),
            Err(H5Error::OutOfBounds { .. })
        ));
        assert!(matches!(
            r.read_ranges::<u32>("d", &[(10, 10), (5, 2)]),
            Err(H5Error::Usage(_))
        ));
        // Overlap is also rejected.
        assert!(matches!(
            r.read_ranges::<u32>("d", &[(0, 10), (9, 2)]),
            Err(H5Error::Usage(_))
        ));
    }

    #[test]
    fn read_ranges_spanning_chunk_boundaries() {
        let path = ranged_file("ranges-span.h5spm", 300, 64);
        let r = H5Reader::open(&path).unwrap();
        let got = r.read_ranges::<u32>("d", &[(60, 80), (200, 100)]).unwrap();
        let want: Vec<u32> = (60..140).collect();
        assert_eq!(got[0], want);
        let want: Vec<u32> = (200..300).collect();
        assert_eq!(got[1], want);
    }

    /// The container format round-trips bit-identically through MemFs.
    #[test]
    fn open_on_memfs_roundtrip() {
        let fs = MemFs::new();
        let path = Path::new("/mem/file.h5spm");
        let data: Vec<u32> = (0..500).collect();
        {
            let mut w = H5Writer::create_on(&fs, path).unwrap();
            w.set_chunk_elems(64);
            w.set_attr("answer", 42u64).unwrap();
            w.write_dataset("d", &data).unwrap();
            w.finish().unwrap();
        }
        let r = H5Reader::open_on(&fs, path).unwrap();
        assert_eq!(r.attr::<u64>("answer").unwrap(), 42);
        assert_eq!(r.read_all::<u32>("d").unwrap(), data);
        assert_eq!(
            r.read_ranges::<u32>("d", &[(10, 5), (400, 10)]).unwrap()[1][0],
            400
        );
    }

    /// The double-buffered prefetch stream delivers exactly the bytes the
    /// synchronous path would, merges its I/O into the reader's counters,
    /// and accounts hits/stalls.
    #[test]
    fn prefetch_stream_matches_synchronous_ranges() {
        let path = ranged_file("prefetch.h5spm", 4000, 64);
        let r = H5Reader::open(&path).unwrap();
        let batches: Vec<BatchRequest> = (0..8)
            .map(|b| BatchRequest {
                ranges: vec![vec![(b * 500, 300)]],
            })
            .collect();
        let mut stream = r.prefetch(&["d"], batches).unwrap();
        let mut got: Vec<u32> = Vec::new();
        let mut first = true;
        while let Some(batch) = stream.next(&r).unwrap() {
            assert_eq!(batch.data.len(), 1);
            for raw in &batch.data[0] {
                got.extend(decode_slice::<u32>(raw));
            }
            if first {
                // Give the worker ample time to stage the next batch, so
                // at least one delivery is a guaranteed prefetch hit.
                std::thread::sleep(std::time::Duration::from_millis(50));
                first = false;
            }
        }
        let want: Vec<u32> = (0..8u32)
            .flat_map(|b| (b * 500..b * 500 + 300))
            .collect();
        assert_eq!(got, want);
        let st = r.stats();
        assert!(st.bytes > 4000 * 2, "fetch I/O not merged: {st:?}");
        assert!(st.prefetch_hits >= 1, "no overlap accounting: {st:?}");
    }

    /// A fetch error (bad range) surfaces through the stream as Err, and
    /// the worker thread is joined cleanly.
    #[test]
    fn prefetch_stream_propagates_errors() {
        let path = ranged_file("prefetch-err.h5spm", 100, 10);
        let r = H5Reader::open(&path).unwrap();
        let batches = vec![
            BatchRequest {
                ranges: vec![vec![(0, 10)]],
            },
            BatchRequest {
                ranges: vec![vec![(90, 20)]], // out of bounds
            },
        ];
        let mut stream = r.prefetch(&["d"], batches).unwrap();
        let mut saw_err = false;
        loop {
            match stream.next(&r) {
                Ok(Some(_)) => {}
                Ok(None) => break,
                Err(e) => {
                    assert!(matches!(e, H5Error::OutOfBounds { .. }), "{e}");
                    saw_err = true;
                    break;
                }
            }
        }
        assert!(saw_err, "out-of-bounds batch must error");
    }
}
