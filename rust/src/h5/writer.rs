//! h5spm container writer.
//!
//! Writers are backend-agnostic: [`H5Writer::create_on`] streams through
//! any [`crate::vfs::Storage`] write handle ([`H5Writer::create`] is the
//! local-filesystem shorthand).

use std::collections::BTreeMap;
use std::path::Path;

use crate::h5::dtype::{encode_slice, Dtype, Scalar};
use crate::h5::{H5Error, IoStats, Result, DEFAULT_CHUNK_ELEMS, MAGIC};
use crate::vfs::{LocalFs, Storage, StorageWrite};

/// One chunk's directory entry.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ChunkEntry {
    pub offset: u64,
    pub elems: u64,
    pub crc: u32,
}

/// One dataset's directory entry.
#[derive(Debug, Clone)]
pub(crate) struct DatasetEntry {
    pub dtype: Dtype,
    pub total_elems: u64,
    pub chunks: Vec<ChunkEntry>,
}

/// Attribute value: dtype tag + 8-byte little-endian payload.
#[derive(Debug, Clone, Copy)]
pub(crate) struct AttrEntry {
    pub dtype: Dtype,
    pub raw: [u8; 8],
}

/// Streaming writer for one h5spm file.
///
/// Datasets are written through [`H5Writer::write_dataset`] (whole array)
/// or a [`DatasetAppender`] (streaming); attributes via `set_attr`.
/// Call [`H5Writer::finish`] to write the directory — dropping without
/// finishing leaves an unreadable file, mirroring HDF5's behaviour on
/// unclosed files.
pub struct H5Writer {
    file: Box<dyn StorageWrite>,
    pos: u64,
    attrs: BTreeMap<String, AttrEntry>,
    datasets: BTreeMap<String, DatasetEntry>,
    chunk_elems: u64,
    stats: IoStats,
    finished: bool,
}

impl H5Writer {
    /// Create (truncate) a container at `path` on the local filesystem.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        Self::create_on(&LocalFs, path)
    }

    /// Create (truncate) a container at `path` on an arbitrary storage
    /// backend.
    pub fn create_on<P: AsRef<Path>>(storage: &dyn Storage, path: P) -> Result<Self> {
        let mut file = storage.create(path.as_ref())?;
        // Superblock: magic + placeholder directory offset/len.
        file.append(MAGIC)?;
        file.append(&0u64.to_le_bytes())?;
        file.append(&0u64.to_le_bytes())?;
        Ok(Self {
            file,
            pos: (MAGIC.len() + 16) as u64,
            attrs: BTreeMap::new(),
            datasets: BTreeMap::new(),
            chunk_elems: DEFAULT_CHUNK_ELEMS,
            stats: IoStats {
                opens: 1,
                ..Default::default()
            },
            finished: false,
        })
    }

    /// Override the chunk size (elements per chunk) for subsequently
    /// written datasets.
    pub fn set_chunk_elems(&mut self, elems: u64) {
        assert!(elems > 0, "chunk_elems must be positive");
        self.chunk_elems = elems;
    }

    /// Set a typed scalar attribute (overwrites an existing one).
    pub fn set_attr<T: Scalar>(&mut self, name: &str, value: T) -> Result<()> {
        self.check_open()?;
        let mut raw = [0u8; 8];
        value.write_le(&mut raw[..T::DTYPE.size()]);
        self.attrs.insert(
            name.to_string(),
            AttrEntry {
                dtype: T::DTYPE,
                raw,
            },
        );
        Ok(())
    }

    /// Write a whole dataset at once (chunked internally).
    pub fn write_dataset<T: Scalar>(&mut self, name: &str, data: &[T]) -> Result<()> {
        let mut app = self.append_dataset::<T>(name)?;
        app.append(data)?;
        app.close()
    }

    /// Open a streaming appender for a new dataset. Only one appender may
    /// be active at a time (enforced by the borrow).
    pub fn append_dataset<T: Scalar>(&mut self, name: &str) -> Result<DatasetAppender<'_, T>> {
        self.check_open()?;
        if self.datasets.contains_key(name) {
            return Err(H5Error::Usage(format!("dataset {name} already written")));
        }
        Ok(DatasetAppender {
            name: name.to_string(),
            writer: self,
            buf: Vec::new(),
            entry: DatasetEntry {
                dtype: T::DTYPE,
                total_elems: 0,
                chunks: Vec::new(),
            },
            closed: false,
            _ty: std::marker::PhantomData,
        })
    }

    fn check_open(&self) -> Result<()> {
        if self.finished {
            Err(H5Error::Usage("writer already finished".into()))
        } else {
            Ok(())
        }
    }

    fn write_chunk_bytes(&mut self, bytes: &[u8]) -> Result<(u64, u32)> {
        let offset = self.pos;
        let crc = crc32fast::hash(bytes);
        self.file.append(bytes)?;
        self.pos += bytes.len() as u64;
        self.stats.bytes += bytes.len() as u64;
        self.stats.ops += 1;
        Ok((offset, crc))
    }

    /// Write the directory, patch the superblock, flush, and return I/O
    /// statistics.
    pub fn finish(mut self) -> Result<IoStats> {
        self.check_open()?;
        let dir_offset = self.pos;
        let mut dir = Vec::new();
        write_u32(&mut dir, self.attrs.len() as u32);
        for (name, a) in &self.attrs {
            write_name(&mut dir, name);
            dir.push(a.dtype as u8);
            dir.extend_from_slice(&a.raw);
        }
        write_u32(&mut dir, self.datasets.len() as u32);
        for (name, d) in &self.datasets {
            write_name(&mut dir, name);
            dir.push(d.dtype as u8);
            dir.extend_from_slice(&d.total_elems.to_le_bytes());
            write_u32(&mut dir, d.chunks.len() as u32);
            for c in &d.chunks {
                dir.extend_from_slice(&c.offset.to_le_bytes());
                dir.extend_from_slice(&c.elems.to_le_bytes());
                dir.extend_from_slice(&c.crc.to_le_bytes());
            }
        }
        let dir_crc = crc32fast::hash(&dir);
        self.file.append(&dir)?;
        self.file.append(&dir_crc.to_le_bytes())?;
        // Patch the superblock, then persist.
        let mut patch = [0u8; 16];
        patch[..8].copy_from_slice(&dir_offset.to_le_bytes());
        patch[8..].copy_from_slice(&(dir.len() as u64).to_le_bytes());
        self.file.patch_at(MAGIC.len() as u64, &patch)?;
        self.file.sync()?;
        self.finished = true;
        Ok(self.stats)
    }

    /// I/O counters so far.
    pub fn stats(&self) -> IoStats {
        self.stats
    }
}

fn write_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn write_name(out: &mut Vec<u8>, name: &str) {
    let bytes = name.as_bytes();
    assert!(bytes.len() <= u16::MAX as usize, "name too long");
    out.extend_from_slice(&(bytes.len() as u16).to_le_bytes());
    out.extend_from_slice(bytes);
}

/// Streaming appender for one dataset; buffers to the chunk size and
/// flushes full chunks to disk.
pub struct DatasetAppender<'w, T: Scalar> {
    name: String,
    writer: &'w mut H5Writer,
    buf: Vec<T>,
    entry: DatasetEntry,
    closed: bool,
    _ty: std::marker::PhantomData<T>,
}

impl<T: Scalar> DatasetAppender<'_, T> {
    /// Append elements.
    pub fn append(&mut self, data: &[T]) -> Result<()> {
        self.buf.extend_from_slice(data);
        let chunk = self.writer.chunk_elems as usize;
        while self.buf.len() >= chunk {
            let rest = self.buf.split_off(chunk);
            let full = std::mem::replace(&mut self.buf, rest);
            self.flush_chunk(&full)?;
        }
        Ok(())
    }

    /// Append one element.
    pub fn push(&mut self, x: T) -> Result<()> {
        self.append(std::slice::from_ref(&x))
    }

    fn flush_chunk(&mut self, data: &[T]) -> Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        let bytes = encode_slice(data);
        let (offset, crc) = self.writer.write_chunk_bytes(&bytes)?;
        self.entry.chunks.push(ChunkEntry {
            offset,
            elems: data.len() as u64,
            crc,
        });
        self.entry.total_elems += data.len() as u64;
        Ok(())
    }

    /// Flush the tail chunk and register the dataset in the directory.
    pub fn close(mut self) -> Result<()> {
        let tail = std::mem::take(&mut self.buf);
        self.flush_chunk(&tail)?;
        self.writer
            .datasets
            .insert(self.name.clone(), self.entry.clone());
        self.closed = true;
        Ok(())
    }
}

impl<T: Scalar> Drop for DatasetAppender<'_, T> {
    fn drop(&mut self) {
        // Losing data silently is worse than a loud panic in debug;
        // in release an unclosed appender simply omits the dataset.
        debug_assert!(
            self.closed || (self.buf.is_empty() && self.entry.total_elems == 0),
            "DatasetAppender for {:?} dropped without close()",
            self.name
        );
    }
}
