//! # abhsf — parallel loading of large sparse matrices in the ABHSF
//!
//! A production-style reproduction of *"Loading Large Sparse Matrices Stored
//! in Files in the Adaptive-Blocking Hierarchical Storage Format"* (Langr,
//! Šimeček, Tvrdík, 2014), built as a three-layer Rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: a
//!   leader/worker streaming orchestrator ([`coordinator`]) that stores and
//!   loads distributed sparse matrices through the space-efficient ABHSF
//!   ([`abhsf`]) in per-process [`h5`] container files, under same or
//!   different store/load *configurations* (process count × element→process
//!   [`mapping`] × in-memory [`formats`]), with a calibrated parallel-I/O
//!   cost model ([`parfs`]) reproducing the paper's Figure 1.
//!
//!   The public entry points are [`coordinator::Dataset`] (self-describing
//!   stored matrices: `Dataset::store` writes a `dataset.json` manifest,
//!   `Dataset::open` discovers the storing configuration from it) and
//!   [`coordinator::LoadPlan`] (`dataset.load().nprocs(p).mapping(m)
//!   .format(f).strategy(Strategy::Auto).run(&cluster)`), whose `Auto`
//!   strategy takes the same-configuration fast path when possible and
//!   otherwise picks the cheapest §4 strategy from the [`parfs`] cost
//!   model, recording the decision in the returned
//!   [`coordinator::LoadReport`]. Stored datasets are also *migratable*:
//!   [`repack`] stream-transcodes a dataset to a new process count,
//!   mapping and block size without materializing the full matrix
//!   anywhere (`dataset.repack().nprocs(p).mapping(m).block_size(s)
//!   .run(&cluster, out_dir)`).
//!   Every layer reads and writes through a pluggable storage backend
//!   ([`vfs`]): the real filesystem, an `Arc`-shared in-memory namespace,
//!   a [`vfs::SimFs`] decorator that emulates the [`parfs`] cost model
//!   and injects storage faults, or a [`net::RemoteFs`] TCP client to the
//!   `pallas-served` storage daemon ([`net`], DESIGN.md §11); block-pruned reads overlap fetch and
//!   decode through a double-buffered read-ahead pipeline
//!   (DESIGN.md §9). Repeated-query workloads are served through
//!   [`cache`] + [`serve`]: a sharded, byte-budgeted decoded-block cache
//!   with single-flight coalescing behind
//!   `Dataset::reader(&cache)`'s rect / row-slice / nnz / SpMV queries
//!   and a multi-threaded closed-loop harness (DESIGN.md §10). Loaded
//!   matrices are *computable at scale* through [`dist`]: a distributed
//!   SpMV engine with mapping-derived vector partitioning and
//!   halo-segment exchange, plus distributed iterative solvers (power /
//!   CG / Lanczos) behind the `solve` CLI subcommand (DESIGN.md §13).
//! * **Layer 2/1 (python/, build-time)** — a JAX blocked-SpMV consumer with
//!   Pallas kernels, AOT-lowered to HLO text and executed from Rust via the
//!   PJRT CPU client ([`runtime`]).
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod abhsf;
pub mod cache;
pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod formats;
pub mod gen;
pub mod h5;
pub mod mapping;
pub mod net;
pub mod obs;
pub mod parfs;
pub mod repack;
pub mod runtime;
pub mod serve;
pub mod spmv;
pub mod util;
pub mod vfs;
