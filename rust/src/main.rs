//! `abhsf` — command-line launcher for the ABHSF parallel store/load
//! system (leader entrypoint).
//!
//! Subcommands:
//!
//! * `generate`  — describe a Kronecker workload (dims, nnz, balance);
//! * `store`     — generate a matrix and store it in parallel as a
//!   self-describing dataset (ABHSF files + `dataset.json` manifest);
//! * `info`      — inspect a stored dataset directory;
//! * `load`      — load a stored dataset (the storing configuration is
//!   discovered from the manifest; `--strategy auto` picks the
//!   same-config fast path or the cheapest §4 strategy), with wall +
//!   simulated times;
//! * `roundtrip` — store, load, verify, report;
//! * `repack`    — stream-transcode a stored dataset to a new process
//!   count, mapping and block size (out-of-core; pruned read + fresh
//!   scheme selection), with the repack-vs-direct-load forecast;
//! * `spmv`      — load a dataset and run normalized power iteration on
//!   it (the end-to-end consumer), optionally cross-checking one SpMV
//!   against the PJRT engine;
//! * `serve`     — concurrent serving harness: N worker threads issue
//!   seeded random rect/row-slice/nnz/SpMV queries against one or more
//!   datasets through a shared byte-budgeted decoded-block cache,
//!   reporting throughput, p50/p99 latency and cache counters;
//! * `served`    — the `pallas-served` storage daemon: serve any VFS
//!   backend over TCP to `--backend remote:HOST:PORT` clients;
//! * `calibrate` — inspect a `BENCH_kernels.json` kernel calibration
//!   table: per calibrated block size, the measured scheme-decision map
//!   next to the analytic one and how many fills flip;
//! * `trace`     — summarize a `--trace PATH` JSONL span trace: per-kind
//!   totals, slowest spans, cache-claim outcomes, and one example query
//!   chain reconstructed from the parent links;
//! * `stats`     — query a live `pallas-served` daemon's lifetime
//!   counters over the wire `Stats` opcode;
//! * `fig1`      — regenerate the paper's Figure 1 table quickly.
//!
//! `load`/`repack`/`serve`/`solve`/`spmv` accept `--trace PATH` to emit
//! structured span events (DESIGN.md §14) for offline analysis with
//! `abhsf trace`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use abhsf::abhsf::load::read_header;
use abhsf::abhsf::{CostModel, MeasuredCosts, Scheme};
use abhsf::cache::{BlockCache, BudgetPlanner, DatasetFootprint};
use abhsf::coordinator::{Cluster, Dataset, DistReport, InMemFormat, StoreOptions, Strategy};
use abhsf::dist::solvers::{conjugate_gradient, lanczos, power_iteration, SolveOutcome};
use abhsf::dist::{
    predict_spmv_comm, spmv_partitions, BlockOperator, CommPrediction, CsrOperator, LocalOperator,
    RankEngine,
};
use abhsf::experiments::{run_fig1, Fig1Config};
use abhsf::formats::Csr;
use abhsf::gen::{KroneckerGen, SeedMatrix};
use abhsf::h5::H5Reader;
use abhsf::mapping::{Block2d, Colwise, CyclicRows, ProcessMapping, Rowwise};
use abhsf::net::{RemoteFs, RetryPolicy, ServeOptions};
use abhsf::parfs::FsModel;
use abhsf::serve::{ServeConfig, Workload};
use abhsf::spmv::SpmvParts;
use abhsf::util::args::Args;
use abhsf::util::bench::Table;
use abhsf::util::human;
use abhsf::vfs::{FaultSpec, MemFs, SimFs, Storage};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let result = match cmd.as_str() {
        "generate" => cmd_generate(argv),
        "store" => cmd_store(argv),
        "info" => cmd_info(argv),
        "load" => cmd_load(argv),
        "roundtrip" => cmd_roundtrip(argv),
        "repack" => cmd_repack(argv),
        "spmv" => cmd_spmv(argv),
        "solve" => cmd_solve(argv),
        "serve" => cmd_serve(argv),
        "served" => cmd_served(argv),
        "calibrate" => cmd_calibrate(argv),
        "trace" => cmd_trace(argv),
        "stats" => cmd_stats(argv),
        "fig1" => cmd_fig1(argv),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand: {other}\n");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        // Usage mistakes (bad flag syntax, unknown --backend, malformed
        // --fault) exit 2 with the usage text, like an unknown
        // subcommand; runtime failures (missing dataset, I/O, injected
        // faults) exit 1.
        if e.downcast_ref::<UsageError>().is_some()
            || e.downcast_ref::<abhsf::util::args::ArgError>().is_some()
        {
            eprintln!("usage error: {e:#}\n");
            print_usage();
            std::process::exit(2);
        }
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// A command-line mistake (as opposed to a runtime failure): reported
/// with the usage text and exit code 2.
#[derive(Debug)]
struct UsageError(String);

impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for UsageError {}

fn usage_error(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg.into()))
}

fn print_usage() {
    println!(
        "abhsf — parallel loading of sparse matrices in the ABHSF \
         (Langr, Simecek, Tvrdik, 2014 reproduction)\n\n\
         Usage: abhsf <subcommand> [options]\n\n\
         Subcommands:\n\
         \x20 generate   describe a Kronecker workload\n\
         \x20 store      generate + store a matrix in parallel (ABHSF dataset)\n\
         \x20 info       inspect a stored dataset directory\n\
         \x20 load       load a stored dataset (configuration discovered from \
         the manifest)\n\
         \x20 roundtrip  store, reload, verify\n\
         \x20 repack     stream-transcode a dataset to a new process count, \
         mapping, block size\n\
         \x20 spmv       distributed power iteration with halo exchange \
         (--resident for the\n\
         \x20            single-address-space path; optional PJRT cross-check)\n\
         \x20 solve      distributed iterative solver (cg | power | lanczos) \
         over the halo-\n\
         \x20            exchange SpMV engine, with per-rank comm stats\n\
         \x20 serve      concurrent random-access query harness over a \
         shared decoded-block cache\n\
         \x20 served     pallas-served storage daemon: serve a directory \
         over TCP to remote: clients\n\
         \x20 calibrate  inspect a kernel calibration table \
         (measured vs analytic scheme decisions)\n\
         \x20 trace      summarize a --trace JSONL span trace (per-kind \
         totals, slowest spans,\n\
         \x20            cache-claim outcomes, example query chain)\n\
         \x20 stats      query a live pallas-served daemon's counters \
         (--backend remote:HOST:PORT)\n\
         \x20 fig1       regenerate the paper's Figure 1 (quick profile)\n\n\
         Common options: --seed-size N --seed cage|diag|random|rmat --order D\n\
         \x20               --procs P --block-size S --dir PATH \
         --mapping rowwise|colwise|2d|cyclic\n\
         \x20               --strategy auto|independent|collective|exchange --format csr|coo\n\
         \x20               --no-prune (disable block-pruned diff-config reading)\n\
         \x20               --backend local|mem|sim|remote:HOST:PORT  storage \
         backend for store/info/load/roundtrip/repack/spmv/serve\n\
         \x20                 local = the real filesystem (default)\n\
         \x20                 mem   = a fresh in-memory namespace that dies with \
         this invocation — nothing\n\
         \x20                         persists, so only self-contained cycles \
         (roundtrip) are meaningful\n\
         \x20                 sim   = parfs-cost simulation over the local files, \
         with optional fault injection\n\
         \x20                 remote:HOST:PORT = a pallas-served daemon; dataset \
         paths resolve under its --root\n\
         Sim options:    --sim-scale X  sleep X real seconds per simulated second \
         (default 0: account only)\n\
         \x20               --fault kind:substr[,kind:substr...]  inject faults on \
         matching paths\n\
         \x20                 (kinds: missing | truncate | fail-writes)\n\
         Net options:    --net-timeout SECS (request timeout; default 10) \
         --net-retries N (default 4)\n\
         Obs options:    --trace PATH  emit JSONL span events \
         (load/repack/serve/solve/spmv; summarize\n\
         \x20               with `abhsf trace PATH`) --metrics  print the \
         metrics-registry snapshot (serve)\n\
         Served options: --listen ADDR (default 127.0.0.1:7311) --root DIR \
         (default .) --backend local|mem|sim\n\
         \x20               --drop-every N  hang up before every Nth request \
         (transient-fault injection; 0 = off)\n\
         \x20               --status-every SECS  print a periodic status \
         line with the live counters (0 = off)\n\
         Store options:  --calibrate PATH  choose block schemes by the measured \
         kernel-cost table\n\
         \x20               (BENCH_kernels.json from `cargo bench --bench \
         kernels`) instead of bytes\n\
         \x20               --spd SHIFT  symmetrize + diagonally shift the \
         generated matrix into an\n\
         \x20               SPD system (S = (A+At)/2 + sigma*I) before storing \
         — the CG workload\n\
         Repack options: --out PATH --nprocs P --mapping KIND --block-size S \
         --chunk-size C\n\
         Calibrate opts: --table PATH (default BENCH_kernels.json)\n\
         Spmv options:   --iters N --resident (old single-address-space path) \
         --pjrt-check (implies\n\
         \x20               --resident)\n\
         Solve options:  --alg cg|power|lanczos --tol T (default 1e-8) \
         --max-iters N (default 500)\n\
         \x20               --steps N (lanczos steps, default 50) --from-blocks \
         (apply straight from\n\
         \x20               decoded ABHSF blocks through the cache read-ahead \
         pipeline)\n\
         Serve options:  --dir A[,B,...] --threads N --queries Q --budget BYTES \
         (e.g. 1MiB)\n\
         \x20               --query-seed S --spmv-every K (0 = no SpMV queries) \
         --gen (store a generated\n\
         \x20               workload first when the directory holds no dataset; \
         implied on --backend mem)\n\
         \x20               --workload uniform|zipf:THETA|hotspot:K  query-key \
         distribution (default uniform)\n\
         \x20               --t2-budget auto|off|BYTES  encoded-tier slice of \
         --budget (default auto:\n\
         \x20               footprint-planned; T1+T2 always equals --budget) \
         --calibrate PATH (price T2\n\
         \x20               re-decodes from the measured kernel table)\n"
    );
}

/// `--trace PATH`: start JSONL span tracing for this invocation. The
/// returned guard flushes and closes the sink when the command returns —
/// success or error — so the emitted trace is always well formed.
fn start_trace(a: &Args) -> anyhow::Result<Option<TraceGuard>> {
    match a.get("trace") {
        None => Ok(None),
        Some(path) => {
            abhsf::obs::trace::enable(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("opening --trace {path}: {e}"))?;
            Ok(Some(TraceGuard))
        }
    }
}

/// Closes the global trace sink on drop (see [`start_trace`]).
struct TraceGuard;

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let _ = abhsf::obs::trace::finish();
    }
}

/// The resolved `--backend` selection: the type-erased storage every
/// subcommand runs over, plus the concrete handles that carry end-of-run
/// report counters (the [`SimFs`] clock, the [`RemoteFs`] wire stats).
struct Backend {
    storage: Arc<dyn Storage>,
    sim: Option<Arc<SimFs>>,
    remote: Option<RemoteFs>,
}

/// `--backend local|mem|sim|remote:HOST:PORT` (+ `--sim-scale`/`--fault`
/// for sim, `--net-timeout`/`--net-retries` for remote): the storage
/// backend every dataset-touching subcommand goes through. An unknown
/// backend or a malformed fault spec is a *usage* error (exit 2); a
/// daemon that refuses the connection is a runtime error (exit 1).
fn parse_backend(a: &Args) -> anyhow::Result<Backend> {
    let kind = a.str_or("backend", "local");
    Ok(match kind.as_str() {
        "local" => Backend {
            storage: abhsf::vfs::local(),
            sim: None,
            remote: None,
        },
        "mem" => Backend {
            storage: Arc::new(MemFs::new()),
            sim: None,
            remote: None,
        },
        "sim" => {
            let mut sim = SimFs::new(abhsf::vfs::local(), FsModel::anselm_lustre())
                .time_scale(a.parse_or("sim-scale", 0.0f64)?);
            if let Some(spec) = a.get("fault") {
                sim = sim.faults(FaultSpec::parse(spec).map_err(|e| {
                    usage_error(format!("malformed --fault spec: {e}"))
                })?);
            }
            let sim = Arc::new(sim);
            Backend {
                storage: Arc::clone(&sim) as Arc<dyn Storage>,
                sim: Some(sim),
                remote: None,
            }
        }
        other => match other.strip_prefix("remote:") {
            Some(addr) if !addr.is_empty() => {
                let policy = RetryPolicy {
                    max_retries: a.parse_or("net-retries", 4u32)?,
                    io_timeout: Duration::from_secs_f64(a.parse_or("net-timeout", 10.0f64)?),
                    ..Default::default()
                };
                let remote = RemoteFs::connect_with(addr, policy)
                    .map_err(|e| anyhow::anyhow!("connecting to pallas-served at {addr}: {e}"))?;
                Backend {
                    storage: Arc::new(remote.clone()),
                    sim: None,
                    remote: Some(remote),
                }
            }
            Some(_) => {
                return Err(usage_error("--backend remote: needs an address (remote:HOST:PORT)"))
            }
            None => {
                return Err(usage_error(format!(
                    "unknown backend {other} (local|mem|sim|remote:HOST:PORT)"
                )))
            }
        },
    })
}

impl Backend {
    /// Trailer lines for the backends that accumulate counters: the
    /// simulated-I/O clock (`sim`) and the wire stats (`remote`).
    fn print_trailer(&self) {
        if let Some(sim) = &self.sim {
            println!("sim backend     : {:.3} s simulated I/O", sim.simulated_seconds());
        }
        if let Some(remote) = &self.remote {
            println!("remote backend  : {}: {}", remote.addr(), remote.stats());
        }
    }
}

/// Dataset-open boilerplate shared by every dataset-consuming subcommand
/// (`info`/`load`/`repack`/`spmv`/`serve`): resolve the `--backend`
/// selection (+ sim options) and open `--dir` (default `matrix`) on it.
fn open_dataset(a: &Args) -> anyhow::Result<(Dataset, Backend)> {
    let backend = parse_backend(a)?;
    let dir = PathBuf::from(a.str_or("dir", "matrix"));
    let dataset = Dataset::open_on(Arc::clone(&backend.storage), &dir)?;
    Ok((dataset, backend))
}

/// Shared workload options.
struct Workload {
    gen: Arc<KroneckerGen>,
}

fn parse_workload(a: &Args) -> anyhow::Result<Workload> {
    let seed_n: u64 = a.parse_or("seed-size", 16u64)?;
    let seed_kind = a.str_or("seed", "cage");
    let order: u32 = a.parse_or("order", 2u32)?;
    let rng_seed: u64 = a.parse_or("rng-seed", 42u64)?;
    let seed = match seed_kind.as_str() {
        "cage" => SeedMatrix::cage_like(seed_n, rng_seed),
        "diag" => SeedMatrix::diagonal(seed_n),
        "random" => SeedMatrix::random(seed_n, a.parse_or("density", 0.1f64)?, rng_seed),
        "rmat" => {
            let scale = (seed_n as f64).log2().ceil() as u32;
            SeedMatrix::rmat(scale, a.parse_or("avg-row", 8u64)?, rng_seed)
        }
        other => anyhow::bail!("unknown seed kind {other} (cage|diag|random|rmat)"),
    };
    Ok(Workload {
        gen: Arc::new(KroneckerGen::new(seed, order)),
    })
}

fn parse_mapping(
    a: &Args,
    gen: &KroneckerGen,
    p: usize,
) -> anyhow::Result<Arc<dyn ProcessMapping>> {
    let n = gen.dim();
    Ok(match a.str_or("mapping", "rowwise").as_str() {
        "rowwise" => Arc::new(gen.balanced_rowwise(p)),
        "rowwise-regular" => Arc::new(Rowwise::regular(n, n, p)),
        "colwise" => Arc::new(Colwise::regular(n, n, p)),
        "2d" => {
            let pr = (p as f64).sqrt() as usize;
            anyhow::ensure!(pr * pr == p, "--mapping 2d requires a square process count");
            Arc::new(Block2d::regular(n, n, pr, pr))
        }
        other => anyhow::bail!("unknown mapping {other} (rowwise|rowwise-regular|colwise|2d)"),
    })
}

fn cmd_generate(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf generate", argv, &[])?;
    let w = parse_workload(&a)?;
    let gen = &w.gen;
    println!("seed        : {}", gen.seed.name);
    println!("order       : {}", gen.order);
    println!(
        "dimension   : {} x {}",
        human::count(gen.dim()),
        human::count(gen.dim())
    );
    println!("nonzeros    : {}", human::count(gen.nnz()));
    println!("coo payload : {}", human::bytes(gen.nnz() * 16));
    let p: usize = a.parse_or("procs", 4usize)?;
    let map = gen.balanced_rowwise(p);
    let counts: Vec<u64> = (0..p)
        .map(|k| {
            let (r0, _, ml, _) = abhsf::mapping::ProcessMapping::window(&map, k);
            (r0..r0 + ml).map(|r| gen.row_nnz(r)).sum()
        })
        .collect();
    println!("balanced row-wise nnz over P={p}: {counts:?}");
    Ok(())
}

fn cmd_store(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf store", argv, &[])?;
    let w = parse_workload(&a)?;
    let dir = PathBuf::from(a.str_or("dir", "matrix"));
    let p: usize = a.parse_or("procs", 4usize)?;
    let s: u64 = a.parse_or("block-size", 64u64)?;
    let mapping = parse_mapping(&a, &w.gen, p)?;
    let backend = parse_backend(&a)?;
    let cluster = Cluster::new(p, 64);
    let mut opts = StoreOptions {
        block_size: s,
        ..Default::default()
    };
    if let Some(path) = a.get("calibrate") {
        let table = load_measured_table(std::path::Path::new(path))?;
        opts.cost_model = CostModel::from_measurements(table);
    }
    let (dataset, report) = if let Some(shift) = a.get("spd") {
        let shift: f64 = shift
            .parse()
            .map_err(|e| usage_error(format!("--spd: {e}")))?;
        anyhow::ensure!(shift >= 0.0, "--spd shift must be non-negative");
        let (parts, sigma) = abhsf::gen::spd_parts(&w.gen, mapping.as_ref(), shift);
        println!("spd shift {sigma:.6e} (S = (A + At)/2 + sigma I, extra {shift})");
        Dataset::store_parts_on(
            Arc::clone(&backend.storage),
            &cluster,
            parts,
            &mapping,
            &dir,
            opts,
        )?
    } else {
        Dataset::store_on(
            Arc::clone(&backend.storage),
            &cluster,
            &w.gen,
            &mapping,
            &dir,
            opts,
        )?
    };
    println!(
        "stored {} nnz into {} files in {:.3}s ({} payload, mapping {}, backend {}, \
         schemes by {})",
        human::count(report.total_nnz()),
        p,
        report.wall_s,
        human::bytes(report.total_bytes()),
        dataset.mapping().kind(),
        dataset.storage().label(),
        dataset.manifest().cost_table,
    );
    backend.print_trailer();
    Ok(())
}

fn cmd_info(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf info", argv, &[])?;
    let (dataset, backend) = open_dataset(&a)?;
    let (m, n) = dataset.dims();
    println!(
        "dataset: {} x {}, {} nnz, stored by P={} ({} mapping), s={}, {}, \
         schemes by {}",
        human::count(m),
        human::count(n),
        human::count(dataset.nnz()),
        dataset.nprocs(),
        dataset.mapping().kind(),
        dataset.block_size(),
        human::bytes(dataset.manifest().total_bytes()),
        dataset.manifest().cost_table,
    );
    let mut t = Table::new(&[
        "file", "m_local", "n_local", "z_local", "s", "blocks", "COO", "CSR", "bitmap", "dense",
        "bytes",
    ]);
    for k in 0..dataset.nprocs() {
        let path = abhsf::abhsf::matrix_file_path(dataset.dir(), k);
        let r = H5Reader::open_on(dataset.storage().as_ref(), &path)?;
        let hdr = read_header(&r)?;
        let schemes: Vec<u8> = r.read_all("schemes")?;
        let mut counts = [0u64; 4];
        for tag in &schemes {
            counts[*tag as usize] += 1;
        }
        let bytes = dataset.storage().len(&path)?;
        t.row(&[
            format!("matrix-{k}"),
            hdr.info.m_local.to_string(),
            hdr.info.n_local.to_string(),
            human::count(hdr.info.z_local),
            hdr.block_size.to_string(),
            hdr.blocks.to_string(),
            counts[0].to_string(),
            counts[1].to_string(),
            counts[2].to_string(),
            counts[3].to_string(),
            human::bytes(bytes),
        ]);
    }
    t.print();
    backend.print_trailer();
    Ok(())
}

fn cmd_load(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf load", argv, &["same-config", "no-prune"])?;
    let _trace = start_trace(&a)?;
    let (dataset, backend) = open_dataset(&a)?;
    let format: InMemFormat = a.str_or("format", "csr").parse()?;
    let model = FsModel::anselm_lustre();

    if a.flag("same-config") {
        // Auto on a matching configuration takes the fast path.
        let cluster = Cluster::new(dataset.nprocs(), 64);
        let (_, report) = dataset.load().format(format).run(&cluster)?;
        print_load_report(&report, &model);
        backend.print_trailer();
        return Ok(());
    }
    let p: usize = a.parse_or("procs", dataset.nprocs())?;
    let (m, n) = dataset.dims();
    let mapping = parse_target_mapping(&a.str_or("mapping", "colwise"), m, n, p)?;
    let strategy: Strategy = a.str_or("strategy", "auto").parse()?;
    let cluster = Cluster::new(p, 64);
    let (_, report) = dataset
        .load()
        .nprocs(p)
        .mapping(&mapping)
        .format(format)
        .strategy(strategy)
        .prune(!a.flag("no-prune"))
        .run(&cluster)?;
    print_load_report(&report, &model);
    backend.print_trailer();
    Ok(())
}

fn print_load_report(report: &abhsf::coordinator::LoadReport, model: &FsModel) {
    let sim = report.simulate(model);
    println!("scenario        : {}", report.scenario);
    println!("loading procs   : {}", report.nprocs);
    println!("nnz loaded      : {}", human::count(report.total_nnz()));
    println!("unique bytes    : {}", human::bytes(report.unique_bytes));
    println!(
        "bytes read      : {}",
        human::bytes(report.total_read_bytes())
    );
    println!("wall time       : {:.4} s", report.wall_s);
    if let Some(ratio) = report.prune_ratio() {
        println!(
            "block pruning   : {} of {} blocks skipped ({:.1}%), {} payload skipped",
            human::count(report.blocks_skipped()),
            human::count(report.blocks_total()),
            ratio * 100.0,
            human::bytes(report.bytes_skipped()),
        );
        println!(
            "read-ahead      : {} prefetch hits, {:.2} ms decoder stall",
            human::count(report.prefetch_hits()),
            report.prefetch_stall_s() * 1e3,
        );
    }
    println!(
        "sim (Lustre)    : {:.3} s  [disk {:.3} s, sync {:.3} s]",
        sim.makespan_s, sim.disk_s, sim.sync_s
    );
    if let Some(auto) = &report.auto {
        let cands: Vec<String> = auto
            .predicted
            .iter()
            .map(|(label, t)| format!("{label} {t:.3}s"))
            .collect();
        println!(
            "auto strategy   : {}{} (predicted: {})",
            auto.chosen,
            if auto.same_config {
                " [same-config fast path]"
            } else {
                ""
            },
            cands.join(", ")
        );
    }
}

fn cmd_roundtrip(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf roundtrip", argv, &[])?;
    let w = parse_workload(&a)?;
    let dir = std::env::temp_dir().join(format!("abhsf-roundtrip-{}", std::process::id()));
    let p: usize = a.parse_or("procs", 4usize)?;
    let s: u64 = a.parse_or("block-size", 32u64)?;
    let mapping = parse_mapping(&a, &w.gen, p)?;
    let backend = parse_backend(&a)?;
    let cluster = Cluster::new(p, 64);
    let (dataset, sreport) = Dataset::store_on(
        Arc::clone(&backend.storage),
        &cluster,
        &w.gen,
        &mapping,
        &dir,
        StoreOptions {
            block_size: s,
            ..Default::default()
        },
    )?;
    let (mats, lreport) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
    anyhow::ensure!(
        lreport.total_nnz() == sreport.total_nnz(),
        "nnz mismatch: stored {}, loaded {}",
        sreport.total_nnz(),
        lreport.total_nnz()
    );
    let n = w.gen.dim();
    let x: Vec<f64> = (0..n).map(|i| ((i % 11) as f64) * 0.3 + 0.5).collect();
    let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
    let y = SpmvParts::Csr(&parts).spmv(&x);
    let mut want = vec![0.0; n as usize];
    w.gen
        .visit_row_range(0, n, |i, j, v| want[i as usize] += v * x[j as usize]);
    let diff = abhsf::spmv::max_abs_diff(&y, &want);
    anyhow::ensure!(diff < 1e-9, "spmv mismatch {diff}");
    println!(
        "roundtrip OK: {} nnz, store {:.3}s, load {:.3}s, spmv maxdiff {diff:.2e} \
         (backend {})",
        human::count(sreport.total_nnz()),
        sreport.wall_s,
        lreport.wall_s,
        dataset.storage().label(),
    );
    backend.print_trailer();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// `abhsf spmv` — the end-to-end consumer. Default: the *distributed*
/// path — every stored rank builds a [`RankEngine`], runs `--iters`
/// normalized power-iteration steps with halo exchange, and one SpMV of
/// a fixed deterministic vector is then checked **bitwise** against the
/// resident (single-address-space) [`SpmvParts`] kernel — the
/// differential oracle. `--resident` keeps the old behavior entirely in
/// one address space (implied by `--pjrt-check`, which cross-checks
/// per-part products against the PJRT engine).
fn cmd_spmv(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf spmv", argv, &["pjrt-check", "resident"])?;
    let _trace = start_trace(&a)?;
    let iters: usize = a.parse_or("iters", 10usize)?;
    let (dataset, backend) = open_dataset(&a)?;
    let (gm, gn) = dataset.dims();
    anyhow::ensure!(
        gm == gn,
        "power iteration requires a square matrix; dataset is {gm} x {gn}"
    );
    let cluster = Cluster::new(dataset.nprocs(), 64);
    let (mats, report) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
    let parts: Vec<Csr> = mats.into_iter().map(|m| m.into_csr()).collect();
    let n = parts[0].info.n;
    println!(
        "loaded {} nnz with P={} ({})",
        human::count(report.total_nnz()),
        report.nprocs,
        report.scenario
    );

    let resident = a.flag("resident") || a.flag("pjrt-check");
    if !resident {
        return spmv_distributed(&dataset, &cluster, parts, iters, &backend);
    }

    // Normalized power iteration: x' = A x / |A x|_2, over the shared
    // kernel path (`SpmvParts`) the cached serving reader also uses.
    let kernel = SpmvParts::Csr(&parts);
    let mut x: Vec<f64> = vec![1.0 / (n as f64).sqrt(); n as usize];
    let mut lambda = 0.0f64;
    for it in 1..=iters {
        let (next, norm) = abhsf::spmv::power_iteration_step_parts(&kernel, &x);
        lambda = norm;
        x = next;
        println!("iter {it:>3}: |A x|_2 = {lambda:.12e}");
        if lambda == 0.0 {
            break;
        }
    }
    let y = kernel.spmv(&x);
    let resid = y
        .iter()
        .zip(&x)
        .map(|(yi, xi)| (yi - lambda * xi) * (yi - lambda * xi))
        .sum::<f64>()
        .sqrt();
    println!("dominant eigenvalue estimate : {lambda:.12e}");
    println!("residual |A x - lambda x|_2  : {resid:.6e}");

    if a.flag("pjrt-check") {
        match abhsf::runtime::Runtime::from_default_dir() {
            Ok(rt) => {
                println!("pjrt platform: {}", rt.platform());
                let mut checked = 0usize;
                let mut max_diff = 0f64;
                for part in &parts {
                    match rt.spmv_csr(part, &x) {
                        Ok(yp) => {
                            let ro = part.info.m_offset as usize;
                            let mut local_want = vec![0.0f64; part.info.m as usize];
                            part.spmv_into(&x, &mut local_want);
                            for i in 0..part.info.m_local as usize {
                                max_diff =
                                    max_diff.max((yp[i] as f64 - local_want[ro + i]).abs());
                            }
                            checked += 1;
                        }
                        Err(e) => println!("rank part skipped ({e})"),
                    }
                }
                anyhow::ensure!(checked > 0, "no part fit any artifact");
                println!(
                    "pjrt vs native: {checked}/{} parts checked, maxdiff {max_diff:.3e}",
                    parts.len()
                );
                anyhow::ensure!(max_diff < 1e-2, "pjrt/native divergence {max_diff}");
            }
            Err(e) => println!("pjrt engine unavailable ({e}); skipping cross-check"),
        }
    }
    backend.print_trailer();
    Ok(())
}

/// The default `abhsf spmv` path: distributed power iteration over the
/// halo-exchange engine, closed by a bitwise differential check of one
/// SpMV against the resident kernel.
fn spmv_distributed(
    dataset: &Dataset,
    cluster: &Cluster,
    parts: Vec<Csr>,
    iters: usize,
    backend: &Backend,
) -> anyhow::Result<()> {
    let (gm, gn) = dataset.dims();
    let p = dataset.nprocs();
    let desc = dataset.mapping().clone();
    let pred = predict_spmv_comm(&desc, gm, gn);
    let parts = Arc::new(parts);
    let oracle_parts = Arc::clone(&parts);

    let t0 = std::time::Instant::now();
    let out = cluster.run(move |ctx| {
        let (xp, yp) = spmv_partitions(&desc, gm, gn);
        let mut op = CsrOperator::new(std::slice::from_ref(&parts[ctx.rank]));
        let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
        let outcome = power_iteration(&mut engine, &mut op, 0.0, iters)
            .expect("the in-memory CSR operator cannot fail");
        // Differential oracle: one distributed SpMV of a fixed
        // deterministic vector, to compare bitwise on the leader.
        let (x0, x1) = engine.x_owned_range();
        let x_local: Vec<f64> = (x0..x1).map(|i| ((i % 11) as f64) * 0.3 + 0.5).collect();
        let (y0, y1) = engine.y_owned_range();
        let mut y_local = vec![0.0; (y1 - y0) as usize];
        engine
            .spmv(&mut op, &x_local, &mut y_local)
            .expect("the in-memory CSR operator cannot fail");
        (outcome, y_local, engine.stats().clone())
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let outcome = &out[0].0;
    println!("dominant eigenvalue estimate : {:.12e}", outcome.value);
    if let Some(rel) = outcome.residuals.last() {
        println!(
            "relative change at iter {:>3}  : {rel:.6e}",
            outcome.iterations
        );
    }

    // The oracle: distributed y (owned segments concatenated in rank
    // order) must be bit-identical to the resident kernel — the fold
    // order of the engine matches the parts order of `SpmvParts`.
    let oracle_x: Vec<f64> = (0..gn).map(|i| ((i % 11) as f64) * 0.3 + 0.5).collect();
    let want = SpmvParts::Csr(&oracle_parts).spmv(&oracle_x);
    let got: Vec<f64> = out.iter().flat_map(|(_, y, _)| y.iter().copied()).collect();
    anyhow::ensure!(
        got.len() == want.len()
            && got
                .iter()
                .zip(&want)
                .all(|(g, w)| g.to_bits() == w.to_bits()),
        "distributed SpMV diverged from the resident oracle"
    );
    println!(
        "differential check: distributed SpMV bitwise-identical to the \
         resident oracle ({} entries)",
        human::count(want.len() as u64),
    );

    let report = DistReport {
        alg: "spmv".to_string(),
        nprocs: p,
        wall_s,
        iterations: outcome.iterations,
        converged: outcome.converged,
        value: outcome.value,
        residuals: outcome.residuals.clone(),
        per_rank: out.iter().map(|(_, _, s)| s.clone()).collect(),
    };
    print_dist_comm(&report, &pred);
    backend.print_trailer();
    Ok(())
}

/// Dispatch one rank's solver run (`--alg`). CG's right-hand side is the
/// fixed deterministic pattern `b[i] = 1 + (i mod 17)/4` over the rank's
/// owned rows, so runs are reproducible across process counts.
fn run_solver<O: LocalOperator + ?Sized>(
    engine: &mut RankEngine<'_>,
    op: &mut O,
    alg: &str,
    tol: f64,
    max_iters: usize,
    steps: usize,
) -> Result<SolveOutcome, abhsf::coordinator::DatasetError> {
    match alg {
        "power" => power_iteration(engine, op, tol, max_iters),
        "lanczos" => lanczos(engine, op, steps),
        _ => {
            let (y0, y1) = engine.y_owned_range();
            let b: Vec<f64> = (y0..y1).map(|i| 1.0 + ((i % 17) as f64) * 0.25).collect();
            conjugate_gradient(engine, op, &b, tol, max_iters)
        }
    }
}

/// `abhsf solve` — distributed iterative solvers (CG, power iteration,
/// Lanczos) over the halo-exchange SpMV engine: the cluster matches the
/// stored process count, every rank holds only its owned vector
/// segments, and all dot/norm reductions go through the fixed-rank-order
/// allreduce. `--from-blocks` applies the matrix straight from decoded
/// ABHSF blocks through the cache read-ahead pipeline instead of loading
/// CSR parts first.
fn cmd_solve(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf solve", argv, &["from-blocks"])?;
    let _trace = start_trace(&a)?;
    let alg = a.str_or("alg", "cg");
    if !matches!(alg.as_str(), "cg" | "power" | "lanczos") {
        return Err(usage_error(format!("unknown --alg {alg} (cg|power|lanczos)")));
    }
    let tol: f64 = a.parse_or("tol", 1e-8f64)?;
    let max_iters: usize = a.parse_or("max-iters", 500usize)?;
    let steps: usize = a.parse_or("steps", 50usize)?;
    let from_blocks = a.flag("from-blocks");
    let (dataset, backend) = open_dataset(&a)?;
    let (gm, gn) = dataset.dims();
    anyhow::ensure!(
        gm == gn,
        "iterative solvers need a square matrix; dataset is {gm} x {gn}"
    );
    let p = dataset.nprocs();
    let desc = dataset.mapping().clone();
    let pred = predict_spmv_comm(&desc, gm, gn);
    let cluster = Cluster::new(p, 64);
    println!(
        "solve: alg={alg} P={p} mapping={} n={} nnz={} tol={tol:.1e} operator={}",
        desc.kind(),
        human::count(gn),
        human::count(dataset.nnz()),
        if from_blocks { "blocks" } else { "csr" },
    );

    let t0 = std::time::Instant::now();
    let out: Vec<(SolveOutcome, abhsf::dist::DistStats)> = if from_blocks {
        let cache = Arc::new(BlockCache::with_budget(256 << 20));
        let ds = dataset.clone();
        let alg = alg.clone();
        cluster.run(move |ctx| {
            let reader = ds
                .reader(&cache)
                .expect("opening the per-rank dataset reader");
            let mut op = BlockOperator::new(&reader, ctx.rank);
            let (xp, yp) = spmv_partitions(&desc, gm, gn);
            let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            let outcome = run_solver(&mut engine, &mut op, &alg, tol, max_iters, steps)
                .expect("block fetch failed during the solve");
            (outcome, engine.stats().clone())
        })
    } else {
        let (mats, _) = dataset.load().format(InMemFormat::Csr).run(&cluster)?;
        let parts: Arc<Vec<Csr>> = Arc::new(mats.into_iter().map(|m| m.into_csr()).collect());
        let alg = alg.clone();
        cluster.run(move |ctx| {
            let mut op = CsrOperator::new(std::slice::from_ref(&parts[ctx.rank]));
            let (xp, yp) = spmv_partitions(&desc, gm, gn);
            let mut engine = RankEngine::new(ctx, xp, yp, op.row_window(), op.col_window());
            let outcome = run_solver(&mut engine, &mut op, &alg, tol, max_iters, steps)
                .expect("the in-memory CSR operator cannot fail");
            (outcome, engine.stats().clone())
        })
    };
    let wall_s = t0.elapsed().as_secs_f64();

    let outcome = &out[0].0;
    print_residual_trajectory(&outcome.residuals);
    if let Some((lmin, lmax)) = outcome.extremal {
        println!("extremal eigenvalues (Ritz): min {lmin:.12e} max {lmax:.12e}");
    } else if outcome.converged {
        println!(
            "converged: residual {:.6e} (tol {tol:.1e}, {} iters, {:.3}s)",
            outcome.residuals.last().copied().unwrap_or(0.0),
            outcome.iterations,
            wall_s,
        );
    } else {
        println!(
            "no convergence: residual {:.6e} after {} iters (tol {tol:.1e})",
            outcome.residuals.last().copied().unwrap_or(f64::NAN),
            outcome.iterations,
        );
    }
    println!("headline value: {:.12e} ({})", outcome.value, outcome.alg);

    let report = DistReport {
        alg: outcome.alg.to_string(),
        nprocs: p,
        wall_s,
        iterations: outcome.iterations,
        converged: outcome.converged,
        value: outcome.value,
        residuals: outcome.residuals.clone(),
        per_rank: out.iter().map(|(_, s)| s.clone()).collect(),
    };
    print_dist_comm(&report, &pred);
    backend.print_trailer();
    Ok(())
}

/// Residual trajectory: every iteration when short, every 10th (plus
/// the last) when long.
fn print_residual_trajectory(residuals: &[f64]) {
    let n = residuals.len();
    for (i, r) in residuals.iter().enumerate() {
        if n <= 30 || i % 10 == 0 || i + 1 == n {
            println!("iter {i:>4}: residual {r:.6e}");
        }
    }
}

/// The per-rank halo counters and the measured-vs-predicted comm line
/// shared by `spmv` and `solve`.
fn print_dist_comm(report: &DistReport, pred: &CommPrediction) {
    for (k, s) in report.per_rank.iter().enumerate() {
        println!(
            "halo: rank {k} sent {} recv {} in {} msgs, exchange {:.4}s \
             compute {:.4}s decode {:.4}s",
            human::bytes(s.halo_bytes_sent),
            human::bytes(s.halo_bytes_recv),
            human::count(s.halo_msgs_sent + s.halo_msgs_recv),
            s.exchange_s,
            s.compute_s,
            s.decode_s,
        );
    }
    println!(
        "comm: measured {} B/spmv over {} spmvs, predicted {} B/spmv ({}), \
         resident broadcast {} B",
        report.bytes_per_spmv(),
        report.spmvs(),
        pred.total_bytes(),
        if pred.exact { "exact" } else { "upper bound" },
        pred.broadcast_bytes,
    );
}

/// `abhsf serve` — the concurrent serving harness: `--threads` workers
/// issue `--queries` seeded random rect/row-slice/nnz/SpMV queries
/// against the datasets named by `--dir` (comma-separated), all through
/// one shared decoded-block cache of `--budget` bytes, and the final
/// report shows throughput, latency percentiles and the cache counters.
///
/// With `--gen` (implied on the ephemeral `--backend mem`, which can
/// never hold a pre-stored dataset) a directory without a dataset gets a
/// small generated workload stored into it first, so a self-contained
/// smoke run is one invocation.
fn cmd_serve(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf serve", argv, &["gen", "metrics"])?;
    let _trace = start_trace(&a)?;
    let backend = parse_backend(&a)?;
    let storage = Arc::clone(&backend.storage);
    let dirs: Vec<String> = a
        .str_or("dir", "matrix")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    anyhow::ensure!(!dirs.is_empty(), "--dir names no dataset directory");
    let budget = human::parse_bytes(&a.str_or("budget", "64MiB"))
        .map_err(|e| anyhow::anyhow!("--budget: {e}"))?;
    let cfg = ServeConfig {
        threads: a.parse_or("threads", 4usize)?,
        queries: a.parse_or("queries", 200u64)?,
        seed: a.parse_or("query-seed", 42u64)?,
        spmv_every: a.parse_or("spmv-every", 16u64)?,
        workload: a
            .str_or("workload", "uniform")
            .parse::<Workload>()
            .map_err(|e| usage_error(format!("--workload: {e}")))?,
    };

    let mut datasets = Vec::with_capacity(dirs.len());
    for d in &dirs {
        let dir = PathBuf::from(d);
        let dataset = match Dataset::open_on(Arc::clone(&storage), &dir) {
            Ok(ds) => ds,
            // Generation is only for a directory that holds NO dataset
            // (`--gen`, implied on the ephemeral mem backend). Any other
            // open failure — corrupt manifest, unreadable files — must
            // surface, never be papered over by storing generated data
            // on top of the user's directory.
            Err(abhsf::coordinator::DatasetError::NotADataset { .. })
                if a.flag("gen") || storage.label() == "mem" =>
            {
                let w = parse_workload(&a)?;
                let p: usize = a.parse_or("procs", 4usize)?;
                let s: u64 = a.parse_or("block-size", 16u64)?;
                let mapping = parse_mapping(&a, &w.gen, p)?;
                let cluster = Cluster::new(p, 64);
                let (ds, report) = Dataset::store_on(
                    Arc::clone(&storage),
                    &cluster,
                    &w.gen,
                    &mapping,
                    &dir,
                    StoreOptions {
                        block_size: s,
                        ..Default::default()
                    },
                )?;
                println!(
                    "stored {} nnz into {d} ({p} files, backend {}) for serving",
                    human::count(report.total_nnz()),
                    ds.storage().label(),
                );
                ds
            }
            Err(e) => return Err(e.into()),
        };
        datasets.push(dataset);
    }

    // --t2-budget: how the total --budget splits across tiers.
    //   auto (default) — measure each dataset's footprint from its block
    //     directories and plan the split (uniform traffic weights: no
    //     traffic has been observed yet; a long-running deployment would
    //     replan from `dataset_stats`);
    //   off | 0 — single-tier T1 (the pre-tiering behavior);
    //   BYTES — explicit T2 slice of the budget, the rest is T1.
    // T1 + T2 always equals --budget, so tiered and single-tier runs at
    // the same --budget are directly comparable.
    let t2_arg = a.str_or("t2-budget", "auto");
    let (cache, plan) = match t2_arg.as_str() {
        "off" | "0" => (BlockCache::with_budget(budget), None),
        "auto" => {
            let mut planner = BudgetPlanner::new(budget);
            for (i, (d, label)) in datasets.iter().zip(&dirs).enumerate() {
                let fp = DatasetFootprint::measure(d)?;
                planner = planner.dataset(i as u64, label.clone(), fp, 1.0);
            }
            let plan = planner.plan();
            let t2 = plan.t2_total().min(budget);
            let cache = BlockCache::with_tiered_budget(budget - t2, t2);
            // Register ids in dataset order so the plan's ids line up
            // with the ones the serving readers will look up.
            for d in &datasets {
                let st = d.storage();
                cache.dataset_id(st.medium(), &st.canonical(d.dir()));
            }
            cache.apply_plan(&plan);
            (cache, Some(plan))
        }
        bytes => {
            let t2 = human::parse_bytes(bytes)
                .map_err(|e| usage_error(format!("--t2-budget: {e}")))?
                .min(budget);
            (BlockCache::with_tiered_budget(budget - t2, t2), None)
        }
    };
    if let Some(path) = a.get("calibrate") {
        // Measured kernel table: prices every T2 revival's re-decode.
        cache.set_measured_costs(load_measured_table(std::path::Path::new(path))?);
    }
    let report = abhsf::serve::run_closed_loop(&datasets, &cache, &cfg)?;
    println!(
        "serve           : {} queries ({} spmv, workload {}) over {} dataset(s), {} threads",
        human::count(report.queries),
        human::count(report.spmv_queries),
        cfg.workload,
        datasets.len(),
        report.threads,
    );
    println!(
        "throughput      : {:.0} q/s ({:.3} s wall)",
        report.qps(),
        report.wall_s,
    );
    println!(
        "latency         : p50 {:.3} ms, p90 {:.3} ms, p99 {:.3} ms, p99.9 {:.3} ms, \
         max {:.3} ms",
        report.p50_ms, report.p90_ms, report.p99_ms, report.p999_ms, report.max_ms,
    );
    println!(
        "elements        : {} returned/counted",
        human::count(report.elements_returned),
    );
    println!(
        "storage I/O     : {} in {} ops, {} opens (hits never touch storage)",
        human::bytes(report.io.bytes),
        human::count(report.io.ops),
        human::count(report.io.opens),
    );
    let cs = report.cache;
    println!(
        "cache           : {:.1}% hit rate ({} hits, {} t2 hits, {} misses, {} coalesced), \
         {} evictions, resident {} of {} budget",
        cs.hit_rate() * 100.0,
        human::count(cs.hits),
        human::count(cs.decode_saves),
        human::count(cs.misses),
        human::count(cs.coalesced_waits),
        human::count(cs.evictions),
        human::bytes(cs.resident_bytes),
        human::format_bytes(budget),
    );
    let priced = if cs.decode_save_ps > 0 {
        format!(" (~{:.3} ms modeled decode)", cs.decode_save_ps as f64 / 1e9)
    } else {
        String::new()
    };
    println!(
        "tiers           : T1 {} in {} blocks ({} protected) of {}, \
         T2 {} in {} blocks of {}, {} promotions, {} demotions, {} decode-saves{}",
        human::bytes(cs.resident_bytes),
        human::count(cs.resident_blocks),
        human::count(cs.protected_blocks),
        human::format_bytes(cache.t1_budget_bytes()),
        human::bytes(cs.t2_resident_bytes),
        human::count(cs.t2_resident_blocks),
        human::format_bytes(cache.t2_budget_bytes()),
        human::count(cs.promotions),
        human::count(cs.demotions),
        human::count(cs.decode_saves),
        priced,
    );
    if let Some(plan) = &plan {
        println!(
            "budget plan     : T1 {} + T2 {} across {} dataset(s) (footprint-capped waterfill)",
            human::bytes(plan.t1_total()),
            human::bytes(plan.t2_total()),
            plan.datasets.len(),
        );
    }
    if report.per_dataset.len() > 1 {
        for (label, ds) in &report.per_dataset {
            println!(
                "dataset {label}: {:.1}% hit rate ({} hits, {} t2 hits, {} misses), \
                 T1 {} resident, T2 {} resident",
                ds.hit_rate() * 100.0,
                human::count(ds.hits),
                human::count(ds.decode_saves),
                human::count(ds.misses),
                human::bytes(ds.resident_bytes),
                human::bytes(ds.t2_resident_bytes),
            );
        }
    }
    if a.flag("metrics") {
        print_metrics_snapshot();
    }
    backend.print_trailer();
    Ok(())
}

/// `--metrics`: dump the global metrics registry, one line per metric in
/// name order (counters and gauges as bare values, histograms as
/// count/quantiles/max).
fn print_metrics_snapshot() {
    use abhsf::obs::metrics::MetricSnapshot;
    for (name, metric) in abhsf::obs::metrics::global().snapshot() {
        match metric {
            MetricSnapshot::Counter(v) => println!("metric {name} = {v}"),
            MetricSnapshot::Gauge(v) => println!("metric {name} = {v}"),
            MetricSnapshot::Histogram(h) => println!(
                "metric {name}: count={} p50={:.6} p90={:.6} p99={:.6} p999={:.6} max={:.6}",
                h.count,
                h.quantile(0.50),
                h.quantile(0.90),
                h.quantile(0.99),
                h.quantile(0.999),
                h.max,
            ),
        }
    }
}

/// `abhsf served` — the `pallas-served` storage daemon: bind `--listen`
/// and serve the files under `--root` on any VFS backend to
/// `--backend remote:HOST:PORT` clients, until killed. Wrapping the
/// inner backend in `sim` (`--fault`, `--sim-scale`) makes the daemon a
/// fault-injected storage node; `--drop-every N` injects *transport*
/// faults by hanging up before every Nth request, exercising client
/// retry.
fn cmd_served(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf served", argv, &[])?;
    let kind = a.str_or("backend", "local");
    let inner: Arc<dyn Storage> = match kind.as_str() {
        "local" => abhsf::vfs::local(),
        "mem" => Arc::new(MemFs::new()),
        "sim" => {
            let mut sim = SimFs::new(abhsf::vfs::local(), FsModel::anselm_lustre())
                .time_scale(a.parse_or("sim-scale", 0.0f64)?);
            if let Some(spec) = a.get("fault") {
                sim = sim.faults(FaultSpec::parse(spec).map_err(|e| {
                    usage_error(format!("malformed --fault spec: {e}"))
                })?);
            }
            Arc::new(sim)
        }
        other => {
            return Err(usage_error(format!(
                "served --backend must be local|mem|sim (a daemon serves storage, \
                 it cannot chain to remote:), got {other}"
            )))
        }
    };
    let listen = a.str_or("listen", "127.0.0.1:7311");
    let root = PathBuf::from(a.str_or("root", "."));
    let opts = ServeOptions {
        root: root.clone(),
        io_timeout: Duration::from_secs_f64(a.parse_or("net-timeout", 30.0f64)?),
        drop_every: a.parse_or("drop-every", 0u64)?,
    };
    let drop_every = opts.drop_every;
    let mut handle = abhsf::net::serve(inner, &listen, opts)
        .map_err(|e| anyhow::anyhow!("binding {listen}: {e}"))?;
    println!(
        "pallas-served   : listening on {} (backend {kind}, root {})",
        handle.addr(),
        root.display(),
    );
    if drop_every > 0 {
        println!("fault injection : hanging up before every {drop_every}th request");
    }
    let status_every: f64 = a.parse_or("status-every", 0.0f64)?;
    if status_every > 0.0 {
        handle.spawn_status_reporter(Duration::from_secs_f64(status_every));
        println!("status reports  : every {status_every} s");
    }
    // The daemon usually runs piped/backgrounded: push the listening line
    // out now, not at (never-reached) exit.
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    handle.run_forever()
}

/// Read a kernel calibration table — a whole `BENCH_kernels.json`
/// document or a bare `{"entries": [...]}` table — from disk.
fn load_measured_table(path: &std::path::Path) -> anyhow::Result<MeasuredCosts> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading calibration table {}: {e}", path.display()))?;
    let json = abhsf::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    MeasuredCosts::from_json(&json)
        .map_err(|e| anyhow::anyhow!("invalid calibration table {}: {e}", path.display()))
}

/// Contiguous fill intervals `[lo, hi]` of the scheme `model` chooses at
/// block size `s`, for `zeta` in `1..=s*s`.
fn scheme_intervals(model: &CostModel, s: u64) -> Vec<(Scheme, u64, u64)> {
    let mut out: Vec<(Scheme, u64, u64)> = Vec::new();
    for zeta in 1..=s * s {
        let sch = model.choose(s, zeta);
        match out.last_mut() {
            Some((cur, _, hi)) if *cur == sch => *hi = zeta,
            _ => out.push((sch, zeta, zeta)),
        }
    }
    out
}

fn format_intervals(intervals: &[(Scheme, u64, u64)]) -> String {
    intervals
        .iter()
        .map(|(sch, lo, hi)| format!("{} zeta {lo}..={hi}", sch.name()))
        .collect::<Vec<_>>()
        .join(", ")
}

/// `abhsf calibrate` — inspect a kernel calibration table: for every
/// calibrated block size, the measured scheme-decision map next to the
/// analytic (byte-minimizing) one, and how many fills flip between them.
fn cmd_calibrate(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf calibrate", argv, &[])?;
    let path = PathBuf::from(a.str_or("table", "BENCH_kernels.json"));
    let table = load_measured_table(&path)?;
    println!("table           : {} (from {})", table.label(), path.display());
    let analytic = CostModel::default();
    let measured = CostModel::from_measurements(table.clone());
    for s in table.block_sizes() {
        let cells = s * s;
        println!("s = {s}:");
        println!(
            "  analytic (bytes)    : {}",
            format_intervals(&scheme_intervals(&analytic, s))
        );
        println!(
            "  measured (kernel ps): {}",
            format_intervals(&scheme_intervals(&measured, s))
        );
        let flips = (1..=cells)
            .filter(|&zeta| measured.choose(s, zeta) != analytic.choose(s, zeta))
            .count();
        println!(
            "  decisions flipped   : {flips} of {cells} fills ({:.1}%)",
            flips as f64 * 100.0 / cells as f64
        );
    }
    Ok(())
}

/// `abhsf trace` — summarize a `--trace PATH` JSONL span trace: validate
/// well-formedness (unique ids, every span closed, parents resolve),
/// then print per-kind totals, the slowest spans, cache-claim outcome
/// counts, and one example query chain reconstructed from parent links.
fn cmd_trace(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf trace", argv, &[])?;
    let path = match a.get("file") {
        Some(p) => p.to_string(),
        None => a
            .positional()
            .first()
            .cloned()
            .ok_or_else(|| usage_error("trace needs a file: abhsf trace FILE (or --file PATH)"))?,
    };
    let path = PathBuf::from(path);
    let events = abhsf::obs::trace::read_trace(&path)
        .map_err(|e| anyhow::anyhow!("reading trace {}: {e}", path.display()))?;
    abhsf::obs::trace::check(&events)
        .map_err(|e| anyhow::anyhow!("malformed trace {}: {e}", path.display()))?;
    println!("file: {}", path.display());
    print!("{}", abhsf::obs::trace::summarize(&events));
    Ok(())
}

/// `abhsf stats` — query a live `pallas-served` daemon's lifetime
/// counters over the wire `Stats` opcode, plus a measured ping RTT. The
/// server's counters mirror a client's [`abhsf::net::NetStats`] view
/// (DESIGN.md §14), so the two sides can be cross-checked.
fn cmd_stats(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf stats", argv, &[])?;
    let backend = a.str_or("backend", "");
    let addr = match backend.strip_prefix("remote:") {
        Some(addr) if !addr.is_empty() => addr.to_string(),
        _ => {
            return Err(usage_error(
                "stats queries a live pallas-served daemon: --backend remote:HOST:PORT",
            ))
        }
    };
    let policy = RetryPolicy {
        max_retries: a.parse_or("net-retries", 4u32)?,
        io_timeout: Duration::from_secs_f64(a.parse_or("net-timeout", 10.0f64)?),
        ..Default::default()
    };
    let remote = RemoteFs::connect_with(&addr, policy)
        .map_err(|e| anyhow::anyhow!("connecting to pallas-served at {addr}: {e}"))?;
    let rtt = remote.ping().map_err(|e| anyhow::anyhow!("pinging {addr}: {e}"))?;
    let stats = remote
        .server_stats()
        .map_err(|e| anyhow::anyhow!("querying server stats at {addr}: {e}"))?;
    println!("pallas-served   : {}", remote.addr());
    println!("ping            : {:.3} ms", rtt.as_secs_f64() * 1e3);
    println!("requests        : {}", stats.requests);
    println!("errors          : {}", stats.errors);
    println!("bytes in        : {}", human::bytes(stats.bytes_in));
    println!("bytes out       : {}", human::bytes(stats.bytes_out));
    println!("connections     : {}", stats.connections);
    println!("uptime          : {:.1} s", stats.uptime_ms as f64 / 1e3);
    println!("probe client    : {}", remote.stats());
    Ok(())
}

/// Target-mapping parser for configurations derived from a dataset's
/// global dims (repack / future commands that have no generator at hand).
fn parse_target_mapping(
    kind: &str,
    m: u64,
    n: u64,
    p: usize,
) -> anyhow::Result<Arc<dyn ProcessMapping>> {
    Ok(match kind {
        "rowwise" => Arc::new(Rowwise::regular(m, n, p)),
        "colwise" => Arc::new(Colwise::regular(m, n, p)),
        "2d" => Arc::new(Block2d::regular_auto(m, n, p)),
        "cyclic" => Arc::new(CyclicRows { m, n, p }),
        other => anyhow::bail!("unknown mapping {other} (rowwise|colwise|2d|cyclic)"),
    })
}

/// `abhsf repack` — migrate a stored dataset to a new configuration:
/// pruned streaming read of the source containers, bounded-memory
/// re-bucketing into the new block grid, fresh per-block scheme
/// selection, fresh containers + manifest. Prints the per-phase report
/// and the parfs forecast (repack-then-load vs direct loads).
fn cmd_repack(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf repack", argv, &["no-prune"])?;
    let _trace = start_trace(&a)?;
    let out = PathBuf::from(a.str_or("out", "matrix-repacked"));
    let (dataset, backend) = open_dataset(&a)?;
    let p: usize = if a.get("nprocs").is_some() {
        a.parse_or("nprocs", dataset.nprocs())?
    } else {
        a.parse_or("procs", dataset.nprocs())?
    };
    let (m, n) = dataset.dims();
    let block_size: u64 = a.parse_or("block-size", dataset.block_size())?;
    let chunk: u64 = a.parse_or("chunk-size", abhsf::h5::DEFAULT_CHUNK_ELEMS)?;
    let mapping: Option<Arc<dyn ProcessMapping>> = match a.get("mapping") {
        None => None,
        Some(kind) => Some(parse_target_mapping(kind, m, n, p)?),
    };

    let mut plan = dataset
        .repack()
        .nprocs(p)
        .block_size(block_size)
        .chunk_elems(chunk)
        .prune(!a.flag("no-prune"));
    if let Some(mapping) = &mapping {
        plan = plan.mapping(mapping);
    }
    let forecast = plan.forecast();
    let cluster = Cluster::new(p, 64);
    let (repacked, report) = plan.run(&cluster, &out)?;

    println!(
        "repacked        : P={} ({}, s={}) -> P={} ({}, s={}) into {}",
        report.source_nprocs,
        dataset.mapping().kind(),
        dataset.block_size(),
        report.nprocs,
        repacked.mapping().kind(),
        report.block_size,
        out.display(),
    );
    println!("nnz             : {}", human::count(report.total_nnz()));
    println!(
        "read            : {} from {} source files",
        human::bytes(report.read.total_bytes()),
        report.source_nprocs,
    );
    if let Some(ratio) = report.prune_ratio() {
        println!(
            "block pruning   : {} of {} source blocks skipped ({:.1}%), {} payload skipped",
            human::count(report.blocks_skipped()),
            human::count(report.blocks_total()),
            ratio * 100.0,
            human::bytes(report.bytes_skipped()),
        );
    }
    println!(
        "written         : {} files, {} ({} blocks: {})",
        report.nprocs,
        human::bytes(report.write.total_bytes()),
        human::count(report.blocks_written()),
        report.scheme_summary(),
    );
    println!(
        "peak staging    : {} elements on one rank (of {} total)",
        human::count(report.max_peak_staging()),
        human::count(report.total_nnz()),
    );
    println!("wall time       : {:.4} s", report.wall_s);
    match forecast.break_even_loads {
        Some(k) => println!(
            "forecast        : direct {} load {:.3}s vs repack {:.3}s + same-config {:.3}s \
             -> repack pays off after {k} load(s)",
            forecast.direct_strategy,
            forecast.direct_load_s,
            forecast.repack_s,
            forecast.post_repack_load_s,
        ),
        None => println!(
            "forecast        : direct {} load {:.3}s already ~optimal \
             (post-repack {:.3}s); repack buys layout, not load speed",
            forecast.direct_strategy,
            forecast.direct_load_s,
            forecast.post_repack_load_s,
        ),
    }
    backend.print_trailer();
    Ok(())
}

fn cmd_fig1(argv: Vec<String>) -> anyhow::Result<()> {
    let a = Args::parse("abhsf fig1", argv, &[])?;
    let cfg = Fig1Config {
        seed_n: a.parse_or("seed-size", 12u64)?,
        order: a.parse_or("order", 2u32)?,
        p_store: a.parse_or("store-procs", 6usize)?,
        p_loads: a.list_or("procs", &[2usize, 3, 4, 6, 8])?,
        block_size: a.parse_or("block-size", 32u64)?,
        rng_seed: a.parse_or("rng-seed", 42u64)?,
        reps: a.parse_or("reps", 3usize)?,
    };
    run_fig1(&cfg, true)?;
    Ok(())
}
