//! Matrix-element → process mappings (the paper's `M(i, j)` and the
//! partitioning schemes its experiments use).
//!
//! A *configuration* in the paper is (process count, mapping, in-memory
//! format). The experiments store with a balanced **row-wise** mapping and
//! reload with a regular **column-wise** mapping; 2D block and cyclic
//! schemes (surveyed in ref [2]) are provided for the ablation benches and
//! to exercise the fully general `M(i, j)` path.

use std::sync::Arc;

use crate::formats::LocalInfo;
use crate::util::json::Json;

/// A total mapping of global matrix coordinates to process ranks.
pub trait ProcessMapping: Send + Sync {
    /// Number of processes `P`.
    fn nprocs(&self) -> usize;

    /// `M(i, j)`: owner rank of element `(i, j)`.
    fn owner(&self, i: u64, j: u64) -> usize;

    /// The *declared* submatrix window of `rank` as
    /// `(m_offset, n_offset, m_local, n_local)`.
    ///
    /// For contiguous schemes this is the exact owned region; schemes with
    /// non-contiguous ownership (e.g. cyclic) return the whole matrix, and
    /// the storing side will shrink it to the tight bounding window of the
    /// actually owned elements (paper §2 defines `r^(k)`, `c^(k)` et al. as
    /// min/max over owned nonzeros).
    fn window(&self, rank: usize) -> (u64, u64, u64, u64);

    /// Scheme label for logs and bench tables.
    fn label(&self) -> String;

    /// The *exact* owned region of `rank` as `(r0, c0, rows, cols)`, when
    /// ownership is a contiguous rectangle: every element owned by `rank`
    /// lies inside the rectangle AND every element inside is owned by
    /// `rank`. Mappings with non-rectangular ownership (cyclic, arbitrary
    /// closures) return `None`, which disables block pruning for them but
    /// keeps loading correct (see [`ProcessMapping::intersects`]).
    fn rank_rect(&self, rank: usize) -> Option<(u64, u64, u64, u64)> {
        let _ = rank;
        None
    }

    /// Whether *every* rank's ownership is an exact contiguous rectangle
    /// (all [`ProcessMapping::rank_rect`] queries answer `Some`). The
    /// repacking pipeline keys its staging mode on this: rectangular
    /// mappings stage spill-free (a rank's resident set is bounded by its
    /// own rectangle), irregular ones fall back to chunked accumulation.
    fn is_rectangular(&self) -> bool {
        (0..self.nprocs()).all(|k| self.rank_rect(k).is_some())
    }

    /// Whether any element of the rectangle `rect = (r0, c0, rows, cols)`
    /// *may* be owned by `rank`. The contract is conservative: `false` is
    /// only allowed when provably no element of `rect` maps to `rank`;
    /// mappings without an exact [`ProcessMapping::rank_rect`] must answer
    /// `true`. Block-pruned loading relies on exactly this one-sided
    /// guarantee — a spurious `true` costs decode time, a wrong `false`
    /// would silently drop elements.
    fn intersects(&self, rank: usize, rect: (u64, u64, u64, u64)) -> bool {
        match self.rank_rect(rank) {
            Some(own) => rects_intersect(own, rect),
            None => true,
        }
    }

    /// Self-describing descriptor of this mapping, persisted in the
    /// dataset manifest so a later load can *discover* the storing
    /// configuration instead of being told. Mappings that cannot be
    /// reconstructed from data (e.g. arbitrary closures) fall back to
    /// [`MappingDesc::Opaque`], which disables the same-configuration
    /// fast path but keeps everything else working.
    fn descriptor(&self) -> MappingDesc {
        MappingDesc::Opaque {
            label: self.label(),
            p: self.nprocs(),
        }
    }
}

/// Serializable description of a [`ProcessMapping`] — the "mapping" leg of
/// the paper's configuration triple as stored in `dataset.json`.
///
/// Two configurations use *the same* mapping exactly when their
/// descriptors compare equal; [`MappingDesc::Opaque`] never equals itself
/// across store/load boundaries by construction of the comparison in
/// [`MappingDesc::same_mapping`], because an opaque label carries no
/// evidence about `M(i, j)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MappingDesc {
    /// Contiguous row chunks with explicit boundaries.
    Rowwise {
        /// Global rows.
        m: u64,
        /// Global columns.
        n: u64,
        /// Chunk starts (`P + 1` entries).
        starts: Vec<u64>,
    },
    /// Contiguous column chunks with explicit boundaries.
    Colwise {
        /// Global rows.
        m: u64,
        /// Global columns.
        n: u64,
        /// Chunk starts (`P + 1` entries).
        starts: Vec<u64>,
    },
    /// Checkerboard over a `pr × pc` regular grid.
    Block2d {
        /// Global rows.
        m: u64,
        /// Global columns.
        n: u64,
        /// Process-grid rows.
        pr: usize,
        /// Process-grid columns.
        pc: usize,
    },
    /// Row-cyclic: row `i` belongs to rank `i mod P`.
    CyclicRows {
        /// Global rows.
        m: u64,
        /// Global columns.
        n: u64,
        /// Process count.
        p: usize,
    },
    /// A mapping that cannot be reconstructed from data (`FnMapping`,
    /// user-defined implementations without a descriptor override).
    Opaque {
        /// The mapping's label, for diagnostics only.
        label: String,
        /// Process count.
        p: usize,
    },
}

impl MappingDesc {
    /// Process count `P`.
    pub fn nprocs(&self) -> usize {
        match self {
            MappingDesc::Rowwise { starts, .. } | MappingDesc::Colwise { starts, .. } => {
                starts.len().saturating_sub(1)
            }
            MappingDesc::Block2d { pr, pc, .. } => pr * pc,
            MappingDesc::CyclicRows { p, .. } => *p,
            MappingDesc::Opaque { p, .. } => *p,
        }
    }

    /// Short kind tag used in the manifest and in log lines.
    pub fn kind(&self) -> &'static str {
        match self {
            MappingDesc::Rowwise { .. } => "rowwise",
            MappingDesc::Colwise { .. } => "colwise",
            MappingDesc::Block2d { .. } => "block2d",
            MappingDesc::CyclicRows { .. } => "cyclic-rows",
            MappingDesc::Opaque { .. } => "opaque",
        }
    }

    /// Whether two descriptors provably describe the same `M(i, j)`.
    /// Opaque descriptors carry no evidence, so they never match.
    pub fn same_mapping(&self, other: &MappingDesc) -> bool {
        if matches!(self, MappingDesc::Opaque { .. })
            || matches!(other, MappingDesc::Opaque { .. })
        {
            return false;
        }
        self == other
    }

    /// The exact owned rectangle of `rank` under the described mapping,
    /// with the same contract as [`ProcessMapping::rank_rect`]: `Some`
    /// only for rectangular-ownership kinds (row-wise, column-wise, 2D
    /// block), `None` for cyclic and opaque descriptors. This is the
    /// serialization leg of the pruning contract — a descriptor parsed
    /// back from `dataset.json` answers the same region queries as the
    /// live mapping it was written from, so block pruning survives the
    /// manifest round-trip.
    pub fn rank_rect(&self, rank: usize) -> Option<(u64, u64, u64, u64)> {
        match self {
            MappingDesc::Rowwise { n, starts, .. } => {
                let (r0, r1) = (*starts.get(rank)?, *starts.get(rank + 1)?);
                Some((r0, 0, r1 - r0, *n))
            }
            MappingDesc::Colwise { m, starts, .. } => {
                let (c0, c1) = (*starts.get(rank)?, *starts.get(rank + 1)?);
                Some((0, c0, *m, c1 - c0))
            }
            MappingDesc::Block2d { m, n, pr, pc } => {
                if rank >= pr * pc {
                    return None;
                }
                let row_starts = even_starts(*m, *pr);
                let col_starts = even_starts(*n, *pc);
                let (bi, bj) = (rank / pc, rank % pc);
                Some((
                    row_starts[bi],
                    col_starts[bj],
                    row_starts[bi + 1] - row_starts[bi],
                    col_starts[bj + 1] - col_starts[bj],
                ))
            }
            MappingDesc::CyclicRows { .. } | MappingDesc::Opaque { .. } => None,
        }
    }

    /// Reconstruct the mapping this descriptor describes; `None` for
    /// [`MappingDesc::Opaque`].
    pub fn build(&self) -> Option<Arc<dyn ProcessMapping>> {
        Some(match self.clone() {
            MappingDesc::Rowwise { m, n, starts } => Arc::new(Rowwise { m, n, starts }),
            MappingDesc::Colwise { m, n, starts } => Arc::new(Colwise { m, n, starts }),
            MappingDesc::Block2d { m, n, pr, pc } => Arc::new(Block2d::regular(m, n, pr, pc)),
            MappingDesc::CyclicRows { m, n, p } => Arc::new(CyclicRows { m, n, p }),
            MappingDesc::Opaque { .. } => return None,
        })
    }

    /// Serialize for the dataset manifest.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("kind".to_string(), Json::str(self.kind()));
        match self {
            MappingDesc::Rowwise { m, n, starts } | MappingDesc::Colwise { m, n, starts } => {
                obj.insert("m".to_string(), Json::num(*m));
                obj.insert("n".to_string(), Json::num(*n));
                obj.insert("starts".to_string(), Json::arr_u64(starts));
            }
            MappingDesc::Block2d { m, n, pr, pc } => {
                obj.insert("m".to_string(), Json::num(*m));
                obj.insert("n".to_string(), Json::num(*n));
                obj.insert("pr".to_string(), Json::num(*pr as u64));
                obj.insert("pc".to_string(), Json::num(*pc as u64));
            }
            MappingDesc::CyclicRows { m, n, p } => {
                obj.insert("m".to_string(), Json::num(*m));
                obj.insert("n".to_string(), Json::num(*n));
                obj.insert("p".to_string(), Json::num(*p as u64));
            }
            MappingDesc::Opaque { label, p } => {
                obj.insert("label".to_string(), Json::str(label.clone()));
                obj.insert("p".to_string(), Json::num(*p as u64));
            }
        }
        Json::Obj(obj)
    }

    /// Parse back from manifest JSON.
    pub fn from_json(v: &Json) -> Result<MappingDesc, String> {
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("mapping descriptor missing \"kind\"")?;
        let num = |key: &str| -> Result<u64, String> {
            v.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("mapping descriptor missing numeric {key:?}"))
        };
        let starts = || -> Result<Vec<u64>, String> {
            v.get("starts")
                .and_then(Json::as_arr)
                .ok_or("mapping descriptor missing \"starts\"")?
                .iter()
                .map(|s| s.as_u64().ok_or_else(|| "non-integer start".to_string()))
                .collect()
        };
        Ok(match kind {
            "rowwise" => MappingDesc::Rowwise {
                m: num("m")?,
                n: num("n")?,
                starts: starts()?,
            },
            "colwise" => MappingDesc::Colwise {
                m: num("m")?,
                n: num("n")?,
                starts: starts()?,
            },
            "block2d" => MappingDesc::Block2d {
                m: num("m")?,
                n: num("n")?,
                pr: num("pr")? as usize,
                pc: num("pc")? as usize,
            },
            "cyclic-rows" => MappingDesc::CyclicRows {
                m: num("m")?,
                n: num("n")?,
                p: num("p")? as usize,
            },
            "opaque" => MappingDesc::Opaque {
                label: v
                    .get("label")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                p: num("p")? as usize,
            },
            other => return Err(format!("unknown mapping kind {other:?}")),
        })
    }
}

/// Whether two `(r0, c0, rows, cols)` rectangles share at least one cell.
/// Empty rectangles (zero rows or columns) intersect nothing.
pub fn rects_intersect(a: (u64, u64, u64, u64), b: (u64, u64, u64, u64)) -> bool {
    let (ar, ac, am, an) = a;
    let (br, bc, bm, bn) = b;
    if am == 0 || an == 0 || bm == 0 || bn == 0 {
        return false;
    }
    ar < br + bm && br < ar + am && ac < bc + bn && bc < ac + an
}

/// Build a [`LocalInfo`] for `rank` from a mapping's declared window.
pub fn window_info(mapping: &dyn ProcessMapping, rank: usize, m: u64, n: u64, z: u64) -> LocalInfo {
    let (ro, co, ml, nl) = mapping.window(rank);
    LocalInfo {
        m,
        n,
        z,
        m_local: ml,
        n_local: nl,
        z_local: 0,
        m_offset: ro,
        n_offset: co,
    }
}

/// Split `total` into `parts` contiguous chunks as evenly as possible;
/// returns the start of each chunk plus the end sentinel (`parts + 1`
/// entries). The first `total % parts` chunks get one extra element.
pub fn even_starts(total: u64, parts: usize) -> Vec<u64> {
    assert!(parts > 0);
    let p = parts as u64;
    let base = total / p;
    let extra = total % p;
    let mut starts = Vec::with_capacity(parts + 1);
    let mut pos = 0u64;
    starts.push(0);
    for k in 0..p {
        pos += base + u64::from(k < extra);
        starts.push(pos);
    }
    starts
}

/// Row-wise mapping over contiguous row chunks: rank `k` owns rows
/// `[starts[k], starts[k+1])` and all columns. The paper's storage
/// configuration uses the *balanced* variant (equal amortized nonzeros).
#[derive(Debug, Clone)]
pub struct Rowwise {
    /// Global shape.
    pub m: u64,
    /// Global columns.
    pub n: u64,
    /// Chunk starts, `P + 1` entries, ascending, `starts[0] = 0`,
    /// `starts[P] = m`.
    pub starts: Vec<u64>,
}

impl Rowwise {
    /// Equal-row-count chunks ("regular" row-wise).
    pub fn regular(m: u64, n: u64, p: usize) -> Self {
        Self {
            m,
            n,
            starts: even_starts(m, p),
        }
    }

    /// Balanced chunks: choose boundaries so each rank's nonzero count is
    /// as close as possible to `total/P`, given per-row counts.
    /// This is the paper's "amortized number of nonzero elements treated
    /// by each process was the same".
    pub fn balanced_by_nnz(m: u64, n: u64, p: usize, row_nnz: impl Fn(u64) -> u64) -> Self {
        let total: u64 = (0..m).map(&row_nnz).sum();
        let mut starts = Vec::with_capacity(p + 1);
        starts.push(0u64);
        let mut acc = 0u64;
        let mut row = 0u64;
        for k in 1..p as u64 {
            let target = total * k / p as u64;
            while row < m && acc < target {
                acc += row_nnz(row);
                row += 1;
            }
            // Leave at least one row per remaining rank when possible.
            let max_start = m.saturating_sub(p as u64 - k);
            starts.push(row.min(max_start).max(*starts.last().unwrap()));
        }
        starts.push(m);
        Self { m, n, starts }
    }
}

impl ProcessMapping for Rowwise {
    fn nprocs(&self) -> usize {
        self.starts.len() - 1
    }

    fn owner(&self, i: u64, _j: u64) -> usize {
        // Binary search the row chunk.
        match self.starts.binary_search(&i) {
            Ok(k) => k.min(self.nprocs() - 1),
            Err(k) => k - 1,
        }
    }

    fn window(&self, rank: usize) -> (u64, u64, u64, u64) {
        let r0 = self.starts[rank];
        let r1 = self.starts[rank + 1];
        (r0, 0, r1 - r0, self.n)
    }

    fn label(&self) -> String {
        format!("row-wise(P={})", self.nprocs())
    }

    fn rank_rect(&self, rank: usize) -> Option<(u64, u64, u64, u64)> {
        // Contiguous row chunk: the declared window is the exact region.
        Some(self.window(rank))
    }

    fn descriptor(&self) -> MappingDesc {
        MappingDesc::Rowwise {
            m: self.m,
            n: self.n,
            starts: self.starts.clone(),
        }
    }
}

/// Column-wise regular mapping: rank `k` owns an equal contiguous chunk of
/// columns and all rows — the paper's *loading* configuration ("regular
/// column-wise mapping, same amortized number of columns per process").
#[derive(Debug, Clone)]
pub struct Colwise {
    /// Global rows.
    pub m: u64,
    /// Global columns.
    pub n: u64,
    /// Chunk starts, `P + 1` entries.
    pub starts: Vec<u64>,
}

impl Colwise {
    /// Equal-column-count chunks.
    pub fn regular(m: u64, n: u64, p: usize) -> Self {
        Self {
            m,
            n,
            starts: even_starts(n, p),
        }
    }
}

impl ProcessMapping for Colwise {
    fn nprocs(&self) -> usize {
        self.starts.len() - 1
    }

    fn owner(&self, _i: u64, j: u64) -> usize {
        match self.starts.binary_search(&j) {
            Ok(k) => k.min(self.nprocs() - 1),
            Err(k) => k - 1,
        }
    }

    fn window(&self, rank: usize) -> (u64, u64, u64, u64) {
        let c0 = self.starts[rank];
        let c1 = self.starts[rank + 1];
        (0, c0, self.m, c1 - c0)
    }

    fn label(&self) -> String {
        format!("col-wise(P={})", self.nprocs())
    }

    fn rank_rect(&self, rank: usize) -> Option<(u64, u64, u64, u64)> {
        Some(self.window(rank))
    }

    fn descriptor(&self) -> MappingDesc {
        MappingDesc::Colwise {
            m: self.m,
            n: self.n,
            starts: self.starts.clone(),
        }
    }
}

/// 2D block (checkerboard) mapping over a `pr × pc` process grid.
#[derive(Debug, Clone)]
pub struct Block2d {
    /// Global rows.
    pub m: u64,
    /// Global columns.
    pub n: u64,
    /// Process-grid rows.
    pub pr: usize,
    /// Process-grid columns.
    pub pc: usize,
    row_starts: Vec<u64>,
    col_starts: Vec<u64>,
}

impl Block2d {
    /// Regular 2D grid.
    pub fn regular(m: u64, n: u64, pr: usize, pc: usize) -> Self {
        Self {
            m,
            n,
            pr,
            pc,
            row_starts: even_starts(m, pr),
            col_starts: even_starts(n, pc),
        }
    }

    /// Regular grid over `p` processes with an automatically chosen
    /// shape: grid rows = the largest divisor of `p` not exceeding
    /// `√p` (the most-square grid, with columns ≥ rows). The single
    /// source of truth for "2d over p ranks" across the CLI and the
    /// differential harness.
    pub fn regular_auto(m: u64, n: u64, p: usize) -> Self {
        assert!(p > 0, "p must be positive");
        let mut pr = 1;
        for d in 1..=p {
            if p % d == 0 && d * d <= p {
                pr = d;
            }
        }
        Self::regular(m, n, pr, p / pr)
    }
}

impl ProcessMapping for Block2d {
    fn nprocs(&self) -> usize {
        self.pr * self.pc
    }

    fn owner(&self, i: u64, j: u64) -> usize {
        let bi = match self.row_starts.binary_search(&i) {
            Ok(k) => k.min(self.pr - 1),
            Err(k) => k - 1,
        };
        let bj = match self.col_starts.binary_search(&j) {
            Ok(k) => k.min(self.pc - 1),
            Err(k) => k - 1,
        };
        bi * self.pc + bj
    }

    fn window(&self, rank: usize) -> (u64, u64, u64, u64) {
        let bi = rank / self.pc;
        let bj = rank % self.pc;
        (
            self.row_starts[bi],
            self.col_starts[bj],
            self.row_starts[bi + 1] - self.row_starts[bi],
            self.col_starts[bj + 1] - self.col_starts[bj],
        )
    }

    fn label(&self) -> String {
        format!("2d({}x{})", self.pr, self.pc)
    }

    fn rank_rect(&self, rank: usize) -> Option<(u64, u64, u64, u64)> {
        Some(self.window(rank))
    }

    fn descriptor(&self) -> MappingDesc {
        MappingDesc::Block2d {
            m: self.m,
            n: self.n,
            pr: self.pr,
            pc: self.pc,
        }
    }
}

/// Row-cyclic mapping: row `i` belongs to rank `i mod P`. Ownership is
/// non-contiguous, so the declared window is the whole matrix (the tight
/// per-rank window is computed from actual elements at store time).
#[derive(Debug, Clone)]
pub struct CyclicRows {
    /// Global rows.
    pub m: u64,
    /// Global columns.
    pub n: u64,
    /// Process count.
    pub p: usize,
}

impl ProcessMapping for CyclicRows {
    fn nprocs(&self) -> usize {
        self.p
    }

    fn owner(&self, i: u64, _j: u64) -> usize {
        (i % self.p as u64) as usize
    }

    fn window(&self, _rank: usize) -> (u64, u64, u64, u64) {
        (0, 0, self.m, self.n)
    }

    fn label(&self) -> String {
        format!("cyclic-rows(P={})", self.p)
    }

    fn descriptor(&self) -> MappingDesc {
        MappingDesc::CyclicRows {
            m: self.m,
            n: self.n,
            p: self.p,
        }
    }
}

/// Arbitrary user-supplied `M(i, j)` — the fully general case the paper's
/// different-configuration algorithm supports.
pub struct FnMapping<F: Fn(u64, u64) -> usize + Send + Sync> {
    /// Global rows.
    pub m: u64,
    /// Global columns.
    pub n: u64,
    /// Process count.
    pub p: usize,
    /// The mapping function.
    pub f: F,
}

impl<F: Fn(u64, u64) -> usize + Send + Sync> ProcessMapping for FnMapping<F> {
    fn nprocs(&self) -> usize {
        self.p
    }

    fn owner(&self, i: u64, j: u64) -> usize {
        let k = (self.f)(i, j);
        debug_assert!(k < self.p, "M({i},{j}) = {k} out of range");
        k
    }

    fn window(&self, _rank: usize) -> (u64, u64, u64, u64) {
        (0, 0, self.m, self.n)
    }

    fn label(&self) -> String {
        format!("fn(P={})", self.p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every element must belong to exactly one rank, and contiguous
    /// schemes must agree with their declared windows.
    fn check_partition(mapping: &dyn ProcessMapping, m: u64, n: u64) {
        for i in 0..m {
            for j in 0..n {
                let k = mapping.owner(i, j);
                assert!(k < mapping.nprocs(), "owner {k} out of range at ({i},{j})");
            }
        }
    }

    #[test]
    fn even_starts_cover() {
        assert_eq!(even_starts(10, 3), vec![0, 4, 7, 10]);
        assert_eq!(even_starts(9, 3), vec![0, 3, 6, 9]);
        assert_eq!(even_starts(2, 4), vec![0, 1, 2, 2, 2]);
    }

    #[test]
    fn rowwise_regular_owner_and_window() {
        let map = Rowwise::regular(10, 6, 3);
        check_partition(&map, 10, 6);
        assert_eq!(map.owner(0, 5), 0);
        assert_eq!(map.owner(3, 0), 0);
        assert_eq!(map.owner(4, 0), 1);
        assert_eq!(map.owner(9, 0), 2);
        assert_eq!(map.window(0), (0, 0, 4, 6));
        assert_eq!(map.window(2), (7, 0, 3, 6));
    }

    #[test]
    fn rowwise_balanced_by_nnz() {
        // Rows with wildly uneven counts: balanced boundaries should even
        // the per-rank totals to within one heavy row.
        let m = 100u64;
        let row_nnz = |r: u64| if r < 10 { 50 } else { 1 };
        let map = Rowwise::balanced_by_nnz(m, m, 4, row_nnz);
        assert_eq!(map.nprocs(), 4);
        let mut per_rank = vec![0u64; 4];
        for r in 0..m {
            per_rank[map.owner(r, 0)] += row_nnz(r);
        }
        let total: u64 = per_rank.iter().sum();
        assert_eq!(total, 590);
        let target = total / 4;
        for (k, &cnt) in per_rank.iter().enumerate() {
            assert!(
                cnt as i64 >= target as i64 - 50 && cnt as i64 <= target as i64 + 50,
                "rank {k} holds {cnt}, target {target}"
            );
        }
    }

    #[test]
    fn colwise_owner_and_window() {
        let map = Colwise::regular(5, 12, 4);
        check_partition(&map, 5, 12);
        assert_eq!(map.owner(0, 0), 0);
        assert_eq!(map.owner(4, 11), 3);
        assert_eq!(map.window(1), (0, 3, 5, 3));
    }

    #[test]
    fn block2d_owner_matches_window() {
        let map = Block2d::regular(8, 8, 2, 2);
        check_partition(&map, 8, 8);
        for rank in 0..4 {
            let (r0, c0, ml, nl) = map.window(rank);
            for i in r0..r0 + ml {
                for j in c0..c0 + nl {
                    assert_eq!(map.owner(i, j), rank);
                }
            }
        }
    }

    #[test]
    fn block2d_regular_auto_picks_most_square_grid() {
        let cases = [(1, (1, 1)), (4, (2, 2)), (6, (2, 3)), (7, (1, 7)), (9, (3, 3)), (12, (3, 4))];
        for (p, want) in cases {
            let map = Block2d::regular_auto(24, 24, p);
            assert_eq!((map.pr, map.pc), want, "p={p}");
            assert_eq!(map.nprocs(), p);
        }
    }

    #[test]
    fn cyclic_rows_owner() {
        let map = CyclicRows { m: 10, n: 4, p: 3 };
        check_partition(&map, 10, 4);
        assert_eq!(map.owner(0, 0), 0);
        assert_eq!(map.owner(4, 2), 1);
        assert_eq!(map.owner(5, 0), 2);
    }

    #[test]
    fn fn_mapping_arbitrary() {
        let map = FnMapping {
            m: 6,
            n: 6,
            p: 2,
            f: |i, j| ((i + j) % 2) as usize,
        };
        check_partition(&map, 6, 6);
        assert_eq!(map.owner(1, 1), 0);
        assert_eq!(map.owner(1, 2), 1);
    }

    #[test]
    fn window_info_builds_local_info() {
        let map = Rowwise::regular(10, 6, 2);
        let info = window_info(&map, 1, 10, 6, 99);
        assert_eq!(info.m_offset, 5);
        assert_eq!(info.m_local, 5);
        assert_eq!(info.n_local, 6);
        assert_eq!(info.z, 99);
        assert!(info.validate().is_ok());
    }

    /// Every concrete mapping must survive descriptor → JSON → descriptor
    /// → build, and the rebuilt mapping must agree on ownership.
    #[test]
    fn descriptors_roundtrip_through_json() {
        let mappings: Vec<Box<dyn ProcessMapping>> = vec![
            Box::new(Rowwise::regular(10, 6, 3)),
            Box::new(Rowwise::balanced_by_nnz(20, 20, 4, |r| r + 1)),
            Box::new(Colwise::regular(5, 12, 4)),
            Box::new(Block2d::regular(8, 8, 2, 2)),
            Box::new(CyclicRows { m: 10, n: 4, p: 3 }),
        ];
        for mapping in mappings {
            let desc = mapping.descriptor();
            let json = desc.to_json().to_string();
            let back = MappingDesc::from_json(&Json::parse(&json).unwrap()).unwrap();
            assert_eq!(back, desc, "{json}");
            assert!(desc.same_mapping(&back));
            assert_eq!(back.nprocs(), mapping.nprocs());
            let rebuilt = back.build().expect("concrete mappings rebuild");
            let (m, n) = match &desc {
                MappingDesc::Rowwise { m, n, .. }
                | MappingDesc::Colwise { m, n, .. }
                | MappingDesc::Block2d { m, n, .. }
                | MappingDesc::CyclicRows { m, n, .. } => (*m, *n),
                MappingDesc::Opaque { .. } => unreachable!(),
            };
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(rebuilt.owner(i, j), mapping.owner(i, j), "({i},{j})");
                }
            }
        }
    }

    /// Closure mappings degrade to an opaque descriptor that never claims
    /// to match anything — including itself.
    #[test]
    fn fn_mapping_descriptor_is_opaque() {
        let map = FnMapping {
            m: 6,
            n: 6,
            p: 2,
            f: |i, j| ((i + j) % 2) as usize,
        };
        let desc = map.descriptor();
        assert_eq!(desc.kind(), "opaque");
        assert_eq!(desc.nprocs(), 2);
        assert!(desc.build().is_none());
        assert!(!desc.same_mapping(&desc.clone()));
        let json = desc.to_json().to_string();
        let back = MappingDesc::from_json(&Json::parse(&json).unwrap()).unwrap();
        assert_eq!(back, desc);
    }

    /// `rank_rect` must be exact where offered: every owned element falls
    /// inside the rectangle and every rectangle cell is owned.
    #[test]
    fn rank_rect_exact_for_rectangular_mappings() {
        let mappings: Vec<Box<dyn ProcessMapping>> = vec![
            Box::new(Rowwise::regular(10, 6, 3)),
            Box::new(Rowwise::balanced_by_nnz(12, 9, 4, |r| r + 1)),
            Box::new(Colwise::regular(5, 12, 4)),
            Box::new(Block2d::regular(8, 10, 2, 3)),
        ];
        for mapping in &mappings {
            // All test mappings above cover the whole matrix; derive
            // global bounds from the declared windows.
            let mut m = 0;
            let mut n = 0;
            for k in 0..mapping.nprocs() {
                let (r0, c0, ml, nl) = mapping.window(k);
                m = m.max(r0 + ml);
                n = n.max(c0 + nl);
            }
            for k in 0..mapping.nprocs() {
                let (r0, c0, ml, nl) = mapping.rank_rect(k).expect("rectangular mapping");
                for i in 0..m {
                    for j in 0..n {
                        let inside = i >= r0 && i < r0 + ml && j >= c0 && j < c0 + nl;
                        assert_eq!(
                            mapping.owner(i, j) == k,
                            inside,
                            "{} rank {k} at ({i},{j})",
                            mapping.label()
                        );
                    }
                }
            }
        }
    }

    /// Irregular mappings answer conservatively: no rect, and
    /// `intersects` is always true.
    #[test]
    fn irregular_mappings_prune_conservatively() {
        let cyclic = CyclicRows { m: 10, n: 4, p: 3 };
        let f = FnMapping {
            m: 6,
            n: 6,
            p: 2,
            f: |i, j| ((i + j) % 2) as usize,
        };
        for rank in 0..3 {
            assert!(cyclic.rank_rect(rank).is_none());
            assert!(cyclic.intersects(rank, (9, 3, 1, 1)));
        }
        assert!(f.rank_rect(0).is_none());
        assert!(f.intersects(1, (0, 0, 1, 1)));
        assert!(!cyclic.is_rectangular());
        assert!(!f.is_rectangular());
        assert!(Rowwise::regular(10, 6, 3).is_rectangular());
        assert!(Colwise::regular(5, 12, 4).is_rectangular());
        assert!(Block2d::regular(8, 8, 2, 2).is_rectangular());
    }

    #[test]
    fn intersects_matches_ownership() {
        // A colwise mapping: rank 0 owns columns [0, 3).
        let map = Colwise::regular(8, 12, 4);
        assert!(map.intersects(0, (0, 0, 2, 2)));
        assert!(map.intersects(0, (5, 2, 1, 1))); // touches column 2
        assert!(!map.intersects(0, (0, 3, 8, 9))); // columns [3, 12)
        assert!(!map.intersects(0, (0, 0, 0, 5))); // empty rect
        // Block2d rank 3 of a 2x2 grid owns the lower-right quadrant.
        let map = Block2d::regular(8, 8, 2, 2);
        assert!(map.intersects(3, (4, 4, 1, 1)));
        assert!(!map.intersects(3, (0, 0, 4, 4)));
        assert!(map.intersects(3, (3, 3, 2, 2))); // straddles the seam
    }

    #[test]
    fn rects_intersect_cases() {
        assert!(rects_intersect((0, 0, 2, 2), (1, 1, 2, 2)));
        assert!(!rects_intersect((0, 0, 2, 2), (2, 0, 2, 2)));
        assert!(!rects_intersect((0, 0, 2, 2), (0, 2, 2, 2)));
        assert!(!rects_intersect((0, 0, 0, 2), (0, 0, 2, 2)));
        assert!(rects_intersect((5, 5, 1, 1), (0, 0, 10, 10)));
    }

    /// Descriptor rectangles agree with the live mapping's, including
    /// after a JSON round-trip — the property pruning relies on when the
    /// mapping is rebuilt from `dataset.json`.
    #[test]
    fn descriptor_rank_rect_survives_roundtrip() {
        let mappings: Vec<Box<dyn ProcessMapping>> = vec![
            Box::new(Rowwise::regular(10, 6, 3)),
            Box::new(Colwise::regular(5, 12, 4)),
            Box::new(Block2d::regular(8, 10, 2, 3)),
            Box::new(CyclicRows { m: 10, n: 4, p: 3 }),
        ];
        for mapping in &mappings {
            let desc = mapping.descriptor();
            let json = desc.to_json().to_string();
            let back = MappingDesc::from_json(&Json::parse(&json).unwrap()).unwrap();
            for k in 0..mapping.nprocs() {
                assert_eq!(back.rank_rect(k), mapping.rank_rect(k), "rank {k}");
                assert_eq!(desc.rank_rect(k), mapping.rank_rect(k), "rank {k}");
            }
            assert_eq!(back.rank_rect(mapping.nprocs() + 1), None);
        }
    }

    #[test]
    fn bad_descriptors_rejected() {
        for doc in [
            r#"{"m": 4}"#,
            r#"{"kind": "mystery", "p": 2}"#,
            r#"{"kind": "rowwise", "m": 4, "n": 4}"#,
        ] {
            let v = Json::parse(doc).unwrap();
            assert!(MappingDesc::from_json(&v).is_err(), "{doc}");
        }
    }
}
