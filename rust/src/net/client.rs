//! [`RemoteFs`]: a [`Storage`] backend that speaks the wire protocol to a
//! `pallas-served` daemon.
//!
//! Because `RemoteFs` is just another `Storage`, every existing layer —
//! `LoadPlan`, `RepackPlan`, `BlockCache`/`DatasetReader`,
//! `run_closed_loop` — works over the network unchanged; the loaders
//! cannot tell a TCP daemon from a local directory except through the
//! latency and the [`NetStats`] counters.
//!
//! ## Transport failures vs. remote errors
//!
//! The client distinguishes two failure classes strictly. A *remote
//! error* is a typed error frame from the server — the request executed
//! (or was validly refused) and the backend answered; it is surfaced to
//! the caller immediately and **never retried** (retrying a `NotFound`
//! cannot help). A *transport failure* — dial refusal, timeout, reset,
//! a garbled or mismatched frame — means the request's fate is unknown;
//! the connection is discarded and the call retries with exponential
//! backoff + jitter, bounded by [`RetryPolicy::max_retries`], provided
//! the request is safe to resend: always when it never hit the wire, and
//! after send only for idempotent requests ([`super::wire::Request::idempotent`]
//! — everything except `Rename`).

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::io::{self};
use std::net::{TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::net::wire::{self, Reply, Request, ServerStats};
use crate::obs::metrics::LogHistogram;
use crate::obs::trace::{self, Tag};
use crate::util::rng::SplitMix64;
use crate::vfs::{Storage, StorageRead, StorageWrite};

/// Cap on idle pooled connections per client.
const POOL_CAP: usize = 8;

/// Retry/backoff/timeout knobs for one [`RemoteFs`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retries after the first attempt (so `max_retries + 1` tries total).
    pub max_retries: u32,
    /// First backoff; doubles per retry.
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Per-dial TCP connect budget.
    pub connect_timeout: Duration,
    /// Per-request read/write budget on an established connection.
    pub io_timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 4,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(10),
        }
    }
}

/// Snapshot of a client's wire counters (the `IoStats` of the network
/// tier).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Request attempts put on the wire (retries count again).
    pub requests: u64,
    /// Bytes sent, including frame headers.
    pub wire_sent_bytes: u64,
    /// Bytes received, including frame headers.
    pub wire_received_bytes: u64,
    /// Requests that were retried after a transport failure.
    pub retries: u64,
    /// Dials after the initial connect (dropped/expired connections).
    pub reconnects: u64,
}

impl std::fmt::Display for NetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests, {} sent, {} received, {} retries, {} reconnects",
            self.requests,
            crate::util::human::bytes(self.wire_sent_bytes),
            crate::util::human::bytes(self.wire_received_bytes),
            self.retries,
            self.reconnects
        )
    }
}

/// One established, handshaken connection.
struct Conn {
    stream: TcpStream,
}

struct Inner {
    addr: String,
    policy: RetryPolicy,
    pool: Mutex<Vec<Conn>>,
    next_id: AtomicU64,
    dials: AtomicU64,
    requests: AtomicU64,
    wire_sent: AtomicU64,
    wire_received: AtomicU64,
    retries: AtomicU64,
    /// Jitter source for backoff (seeded from the address so runs are
    /// reproducible per target).
    rng: Mutex<SplitMix64>,
    /// The server's `Storage::medium`, learned in the first welcome.
    server_medium: AtomicU64,
    /// Registry handle: end-to-end RPC latency in seconds, shared with
    /// every other `RemoteFs` in the process under `"net.rpc_s"`.
    rpc_s: Arc<LogHistogram>,
}

/// TCP client backend for `pallas-served`. Cheap to clone (all clones
/// share the pool and counters).
#[derive(Clone)]
pub struct RemoteFs {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for RemoteFs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteFs")
            .field("addr", &self.inner.addr)
            .field("stats", &self.stats())
            .finish()
    }
}

impl RemoteFs {
    /// Connect to a daemon at `addr` (`HOST:PORT`) with default policy.
    /// Dials eagerly: a bad address or an incompatible server fails here,
    /// not on the first read.
    pub fn connect(addr: &str) -> io::Result<RemoteFs> {
        RemoteFs::connect_with(addr, RetryPolicy::default())
    }

    /// [`RemoteFs::connect`] with explicit retry/timeout policy.
    pub fn connect_with(addr: &str, policy: RetryPolicy) -> io::Result<RemoteFs> {
        let fs = RemoteFs {
            inner: Arc::new(Inner {
                addr: addr.to_string(),
                policy,
                pool: Mutex::new(Vec::new()),
                next_id: AtomicU64::new(1),
                dials: AtomicU64::new(0),
                requests: AtomicU64::new(0),
                wire_sent: AtomicU64::new(0),
                wire_received: AtomicU64::new(0),
                retries: AtomicU64::new(0),
                rng: Mutex::new(SplitMix64::new(seed_of(addr))),
                server_medium: AtomicU64::new(0),
                rpc_s: crate::obs::metrics::global().histogram("net.rpc_s"),
            }),
        };
        // Eager handshake: validates the server and learns its medium, so
        // a bad address or incompatible daemon fails here.
        let (conn, medium) = fs.dial()?;
        fs.inner.server_medium.store(medium, Ordering::Relaxed);
        fs.checkin(conn);
        Ok(fs)
    }

    /// The daemon address this client talks to.
    pub fn addr(&self) -> &str {
        &self.inner.addr
    }

    /// Counter snapshot.
    pub fn stats(&self) -> NetStats {
        let dials = self.inner.dials.load(Ordering::Relaxed);
        NetStats {
            requests: self.inner.requests.load(Ordering::Relaxed),
            wire_sent_bytes: self.inner.wire_sent.load(Ordering::Relaxed),
            wire_received_bytes: self.inner.wire_received.load(Ordering::Relaxed),
            retries: self.inner.retries.load(Ordering::Relaxed),
            reconnects: dials.saturating_sub(1),
        }
    }

    /// Dial, handshake, and return the connection plus the server medium.
    fn dial(&self) -> io::Result<(Conn, u64)> {
        self.inner.dials.fetch_add(1, Ordering::Relaxed);
        let policy = &self.inner.policy;
        let mut last: Option<io::Error> = None;
        let addrs = self.inner.addr.to_socket_addrs()?;
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, policy.connect_timeout) {
                Ok(stream) => {
                    stream.set_nodelay(true)?;
                    stream.set_read_timeout(Some(policy.io_timeout))?;
                    stream.set_write_timeout(Some(policy.io_timeout))?;
                    let mut conn = Conn { stream };
                    wire::write_hello(&mut conn.stream)?;
                    let (version, medium) = wire::read_welcome(&mut conn.stream)?;
                    if version != wire::VERSION {
                        return Err(io::Error::new(
                            io::ErrorKind::Unsupported,
                            format!(
                                "protocol version mismatch: server {} speaks v{version}, \
                                 client speaks v{}",
                                self.inner.addr,
                                wire::VERSION
                            ),
                        ));
                    }
                    return Ok((conn, medium));
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(
                io::ErrorKind::AddrNotAvailable,
                format!("{} resolved to no addresses", self.inner.addr),
            )
        }))
    }

    fn checkout(&self) -> Option<Conn> {
        self.inner.pool.lock().unwrap().pop()
    }

    fn checkin(&self, conn: Conn) {
        let mut pool = self.inner.pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(conn);
        }
    }

    /// Backoff before retry `attempt` (1-based): `base · 2^(attempt-1)`,
    /// capped, jittered to 50–100% so synchronized clients desynchronize.
    fn backoff(&self, attempt: u32) -> Duration {
        let policy = &self.inner.policy;
        let exp = policy
            .base_backoff
            .saturating_mul(1u32 << (attempt - 1).min(16));
        let capped = exp.min(policy.max_backoff);
        let jitter = {
            let mut rng = self.inner.rng.lock().unwrap();
            0.5 + 0.5 * (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64
        };
        capped.mul_f64(jitter)
    }

    /// Ask the daemon for its lifetime counters via the wire `Stats`
    /// opcode (see [`ServerStats`] for how they map onto [`NetStats`]).
    pub fn server_stats(&self) -> io::Result<ServerStats> {
        self.call(&Request::Stats)?.into_stats()
    }

    /// Round-trip a `Ping`, returning the measured RTT.
    pub fn ping(&self) -> io::Result<Duration> {
        let t0 = Instant::now();
        self.call(&Request::Ping)?.into_unit()?;
        Ok(t0.elapsed())
    }

    /// Issue one request with the full retry loop; the heart of the
    /// backend. Every call is one `net_rpc` trace span and one
    /// `net.rpc_s` histogram sample (retries included in the duration).
    fn call(&self, req: &Request) -> io::Result<Reply> {
        let _span = trace::span("net_rpc", &[("op", Tag::S(req.name()))]);
        let t0 = Instant::now();
        let result = self.call_inner(req);
        self.inner.rpc_s.record(t0.elapsed().as_secs_f64());
        result
    }

    fn call_inner(&self, req: &Request) -> io::Result<Reply> {
        let mut attempt = 0u32;
        loop {
            match self.try_once(req) {
                Ok(reply) => return Ok(reply),
                // The server answered with a typed error: definitive.
                Err(CallError::Remote(e)) => return Err(e),
                Err(CallError::Transport { error, sent }) => {
                    let resendable = !sent || req.idempotent();
                    if !resendable || attempt >= self.inner.policy.max_retries {
                        return Err(error);
                    }
                    attempt += 1;
                    self.inner.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(self.backoff(attempt));
                }
            }
        }
    }

    /// One attempt over one connection. On any transport failure the
    /// connection is dropped (never pooled back).
    fn try_once(&self, req: &Request) -> Result<Reply, CallError> {
        let mut conn = match self.checkout() {
            Some(c) => c,
            None => {
                let (c, _) = self
                    .dial()
                    .map_err(|e| CallError::Transport { error: e, sent: false })?;
                c
            }
        };
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner.requests.fetch_add(1, Ordering::Relaxed);

        let payload = req.encode(id);
        let sent_bytes = 4 + payload.len() as u64;
        if let Err(e) = wire::write_frame(&mut conn.stream, &payload) {
            // The frame may be partially on the wire: treat as sent.
            return Err(CallError::Transport { error: e, sent: true });
        }
        self.inner.wire_sent.fetch_add(sent_bytes, Ordering::Relaxed);

        let frame = match wire::read_frame(&mut conn.stream) {
            Ok(f) => f,
            Err(e) => return Err(CallError::Transport { error: e, sent: true }),
        };
        self.inner
            .wire_received
            .fetch_add(4 + frame.len() as u64, Ordering::Relaxed);

        let (reply_id, result) = match wire::decode_reply(&frame) {
            Ok(r) => r,
            Err(e) => return Err(CallError::Transport { error: e, sent: true }),
        };
        if reply_id != id {
            return Err(CallError::Transport {
                error: io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("reply id {reply_id} does not match request id {id}"),
                ),
                sent: true,
            });
        }
        match result {
            Ok(reply) => {
                self.checkin(conn);
                Ok(reply)
            }
            Err(wire_err) => {
                // Typed remote error: the connection itself is healthy.
                self.checkin(conn);
                Err(CallError::Remote(wire_err.into()))
            }
        }
    }
}

fn seed_of(addr: &str) -> u64 {
    let mut h = DefaultHasher::new();
    addr.hash(&mut h);
    h.finish()
}

/// Why one attempt failed, and whether the request had hit the wire.
enum CallError {
    /// Typed error frame from the server; never retried.
    Remote(io::Error),
    /// The transport broke; `sent` records whether the request may have
    /// reached the server.
    Transport { error: io::Error, sent: bool },
}

// ------------------------------------------------------------ handles

/// Positioned read handle over the wire: stateless `ReadAt` requests,
/// chunked at [`wire::MAX_READ`].
struct RemoteFile {
    fs: RemoteFs,
    path: PathBuf,
    len: u64,
}

impl StorageRead for RemoteFile {
    fn read_exact_at(&self, offset: u64, buf: &mut [u8]) -> io::Result<()> {
        let mut pos = 0usize;
        while pos < buf.len() {
            let chunk = (buf.len() - pos).min(wire::MAX_READ as usize);
            let reply = self.fs.call(&Request::ReadAt {
                path: self.path.clone(),
                offset: offset + pos as u64,
                len: chunk as u32,
            })?;
            let bytes = reply.into_bytes()?;
            if bytes.len() != chunk {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("server returned {} bytes for a {chunk}-byte read", bytes.len()),
                ));
            }
            buf[pos..pos + chunk].copy_from_slice(&bytes);
            pos += chunk;
        }
        Ok(())
    }

    fn len(&self) -> io::Result<u64> {
        Ok(self.len)
    }
}

/// Write handle: buffers locally, ships the whole file as one atomic
/// `WriteFile` on sync (mirroring `MemWriter` — the buffered bytes become
/// visible all at once, and a resend after a transport failure converges
/// on the same contents, which is what lets writes participate in the
/// retry loop).
struct RemoteWriter {
    fs: RemoteFs,
    path: PathBuf,
    buf: Vec<u8>,
    dirty: bool,
}

impl RemoteWriter {
    fn publish(&mut self) -> io::Result<()> {
        self.fs
            .call(&Request::WriteFile {
                path: self.path.clone(),
                bytes: self.buf.clone(),
            })?
            .into_unit()?;
        self.dirty = false;
        Ok(())
    }
}

impl StorageWrite for RemoteWriter {
    fn append(&mut self, buf: &[u8]) -> io::Result<()> {
        self.buf.extend_from_slice(buf);
        self.dirty = true;
        Ok(())
    }

    fn patch_at(&mut self, offset: u64, buf: &[u8]) -> io::Result<()> {
        let end = offset as usize + buf.len();
        if end > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "patch_at beyond written bytes",
            ));
        }
        self.buf[offset as usize..end].copy_from_slice(buf);
        self.dirty = true;
        Ok(())
    }

    fn sync(&mut self) -> io::Result<()> {
        self.publish()
    }
}

impl Drop for RemoteWriter {
    fn drop(&mut self) {
        if self.dirty {
            let _ = self.publish();
        }
    }
}

// -------------------------------------------------------------- Storage

impl Storage for RemoteFs {
    fn open(&self, path: &Path) -> io::Result<Arc<dyn StorageRead>> {
        // `Len` doubles as the existence check `open` promises.
        let len = self.call(&Request::Len { path: path.to_path_buf() })?.into_num()?;
        Ok(Arc::new(RemoteFile {
            fs: self.clone(),
            path: path.to_path_buf(),
            len,
        }))
    }

    fn create(&self, path: &Path) -> io::Result<Box<dyn StorageWrite>> {
        // Publish the empty file immediately: `create` is `O_TRUNC` on
        // every other backend, and a crash between create and sync must
        // leave a truncated file, not a stale one.
        self.call(&Request::WriteFile {
            path: path.to_path_buf(),
            bytes: Vec::new(),
        })?
        .into_unit()?;
        Ok(Box::new(RemoteWriter {
            fs: self.clone(),
            path: path.to_path_buf(),
            buf: Vec::new(),
            dirty: false,
        }))
    }

    fn len(&self, path: &Path) -> io::Result<u64> {
        self.call(&Request::Len { path: path.to_path_buf() })?.into_num()
    }

    fn list(&self, dir: &Path) -> io::Result<Vec<PathBuf>> {
        self.call(&Request::List { dir: dir.to_path_buf() })?.into_paths()
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.call(&Request::Rename {
            from: from.to_path_buf(),
            to: to.to_path_buf(),
        })?
        .into_unit()
    }

    fn read_file(&self, path: &Path) -> io::Result<Vec<u8>> {
        self.call(&Request::ReadFile { path: path.to_path_buf() })?.into_bytes()
    }

    fn write_file(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        self.call(&Request::WriteFile {
            path: path.to_path_buf(),
            bytes: bytes.to_vec(),
        })?
        .into_unit()
    }

    fn create_dir_all(&self, dir: &Path) -> io::Result<()> {
        self.call(&Request::CreateDirAll { dir: dir.to_path_buf() })?.into_unit()
    }

    fn canonical(&self, path: &Path) -> PathBuf {
        // Server-side identity when reachable; lexical fallback keeps the
        // method infallible.
        match self.call(&Request::Canonical { path: path.to_path_buf() }) {
            Ok(reply) => reply.into_path().unwrap_or_else(|_| crate::vfs::normalize(path)),
            Err(_) => crate::vfs::normalize(path),
        }
    }

    fn medium(&self) -> usize {
        // Distinct from every local medium, stable per (address, server
        // store): two clients of one daemon agree; a restarted daemon
        // over a *different* MemFs does not.
        let mut h = DefaultHasher::new();
        "remote".hash(&mut h);
        self.inner.addr.hash(&mut h);
        self.inner.server_medium.load(Ordering::Relaxed).hash(&mut h);
        h.finish() as usize
    }

    fn label(&self) -> &'static str {
        "remote"
    }
}
