//! Network serving subsystem: datasets stored by one machine, loaded by
//! another.
//!
//! The paper's central claim (arXiv:1412.8299 §3) is that ABHSF datasets
//! are loadable by a *different* process configuration than stored them;
//! this module removes the remaining assumption that both sides share a
//! filesystem. Three pieces:
//!
//! * [`wire`] — a length-prefixed binary protocol: request ids, one
//!   opcode per [`crate::vfs::Storage`] method, typed error frames and a
//!   versioned handshake;
//! * [`server`] — the `pallas-served` daemon (`abhsf served` in the
//!   CLI): serves any existing VFS backend over TCP, thread per
//!   connection, graceful shutdown;
//! * [`client`] — [`RemoteFs`], a `Storage` backend speaking the
//!   protocol, with a small connection pool, bounded retries with
//!   exponential backoff + jitter, and wire-level [`NetStats`] counters.
//!
//! Because `RemoteFs` is just another `Storage`, every existing layer
//! (`LoadPlan`, `RepackPlan`, `BlockCache`, `run_closed_loop`) works over
//! the network unchanged — and serving a [`crate::vfs::SimFs`]-wrapped
//! backend composes fault injection with real TCP, giving an N-daemon ×
//! M-client fault-injected cluster simulation on one machine (DESIGN.md
//! §11).

pub mod client;
pub mod server;
pub mod wire;

pub use client::{NetStats, RemoteFs, RetryPolicy};
pub use server::{serve, ServeOptions, ServerHandle};
