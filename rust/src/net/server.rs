//! `pallas-served`: the storage daemon. Serves any [`Storage`] backend
//! over TCP to concurrent clients, one thread per connection.
//!
//! The server is a thin, stateless shim: each decoded [`Request`] maps to
//! exactly one call on the inner backend, successes and failures both
//! travel back as typed frames, and no request leaves server-side session
//! state behind (no open-handle table to desynchronize on reconnect).
//! Because the backend is `Arc<dyn Storage>`, serving a `SimFs`-wrapped
//! backend turns the daemon into a fault-injected storage node — the
//! building block of the N-daemon × M-client cluster simulation described
//! in DESIGN.md §11.
//!
//! Client paths are confined to the served root: they are lexically
//! normalized, absolute prefixes are stripped, and any `..` component is
//! refused with `PermissionDenied` before the backend sees the path.

use std::collections::HashMap;
use std::io::{self, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Component, Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::wire::{self, Reply, Request, ServerStats};
use crate::vfs::{Storage, StorageRead};

/// How often a connection thread wakes from a blocking read to check the
/// shutdown flag and its idle budget.
const POLL_TICK: Duration = Duration::from_millis(200);

/// Cap on cached per-connection read handles (plain LRU-free reset:
/// the map is cleared when full — datasets hold a handful of containers,
/// so this effectively never triggers in practice).
const HANDLE_CACHE_CAP: usize = 64;

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Directory prefix all client paths are resolved under.
    pub root: PathBuf,
    /// Per-connection inactivity budget and write timeout. A connection
    /// idle longer than this is closed.
    pub io_timeout: Duration,
    /// Fault injection: if nonzero, close the connection *instead of*
    /// executing every Nth request (counted across all connections).
    /// Exercises client-side retry; `0` disables.
    pub drop_every: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            root: PathBuf::from("."),
            io_timeout: Duration::from_secs(30),
            drop_every: 0,
        }
    }
}

struct Shared {
    backend: Arc<dyn Storage>,
    opts: ServeOptions,
    shutdown: AtomicBool,
    /// Requests received across all connections (drives `drop_every`).
    /// Counted the way the client's `NetStats.requests` is: one per
    /// request frame fully read off the wire, whether or not it decodes.
    served: AtomicU64,
    /// Requests answered with a typed error frame.
    errors: AtomicU64,
    /// Request-frame bytes read, including the 4-byte frame headers
    /// (mirrors `NetStats.wire_sent`; the handshake is excluded).
    bytes_in: AtomicU64,
    /// Reply-frame bytes written, including the 4-byte frame headers
    /// (mirrors `NetStats.wire_received`).
    bytes_out: AtomicU64,
    /// Connections accepted over the daemon's lifetime.
    conns_total: AtomicU64,
    /// When the daemon started serving (drives `uptime_ms`).
    started: Instant,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn server_stats(&self) -> ServerStats {
        ServerStats {
            requests: self.served.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            uptime_ms: self.started.elapsed().as_millis() as u64,
            connections: self.conns_total.load(Ordering::Relaxed),
        }
    }
}

/// A running daemon: bound socket + accept thread.
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl std::fmt::Debug for ServerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerHandle")
            .field("addr", &self.addr)
            .field("backend", &self.shared.backend.label())
            .finish()
    }
}

/// Bind `listen` and serve `backend` until [`ServerHandle::shutdown`].
/// Returns once the socket is bound and accepting, so a caller can
/// immediately connect (tests, CI) or park in
/// [`ServerHandle::run_forever`] (the CLI).
pub fn serve(
    backend: Arc<dyn Storage>,
    listen: &str,
    opts: ServeOptions,
) -> io::Result<ServerHandle> {
    let listener = TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        backend,
        opts,
        shutdown: AtomicBool::new(false),
        served: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        bytes_in: AtomicU64::new(0),
        bytes_out: AtomicU64::new(0),
        conns_total: AtomicU64::new(0),
        started: Instant::now(),
        conns: Mutex::new(Vec::new()),
    });

    let accept_shared = Arc::clone(&shared);
    let accept = std::thread::Builder::new()
        .name("pallas-served-accept".into())
        .spawn(move || accept_loop(listener, accept_shared))?;

    Ok(ServerHandle {
        addr,
        shared,
        accept: Some(accept),
    })
}

impl ServerHandle {
    /// The bound address (resolves `:0` ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Total requests received so far, across all connections.
    pub fn requests_served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    /// Snapshot the daemon's lifetime counters — the same numbers the
    /// wire-level [`Request::Stats`] opcode answers, but read in-process
    /// (tests use this for exact cross-checks against client `NetStats`
    /// without the probe itself perturbing the counters).
    pub fn stats(&self) -> ServerStats {
        self.shared.server_stats()
    }

    /// Spawn a detached reporter thread printing one status line to
    /// stderr every `every` until shutdown (the CLI's `--status-every`).
    pub fn spawn_status_reporter(&self, every: Duration) {
        let shared = Arc::clone(&self.shared);
        let _ = std::thread::Builder::new()
            .name("pallas-served-status".into())
            .spawn(move || {
                let mut last = Instant::now();
                while !shared.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(POLL_TICK.min(every));
                    if last.elapsed() >= every {
                        eprintln!("status: {}", shared.server_stats());
                        last = Instant::now();
                    }
                }
            });
    }

    /// Stop accepting, close every connection, join all threads. Safe to
    /// call more than once; returns when the daemon is fully down.
    pub fn shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection; harmless
        // if the listener already saw the flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }

    /// Park the calling thread until the process dies (CLI daemon mode).
    pub fn run_forever(&mut self) -> ! {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        loop {
            std::thread::park();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        shared.conns_total.fetch_add(1, Ordering::Relaxed);
        let conn_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name("pallas-served-conn".into())
            .spawn(move || {
                let _ = handle_connection(stream, conn_shared);
            });
        if let Ok(h) = handle {
            let mut conns = shared.conns.lock().unwrap();
            conns.retain(|c| !c.is_finished());
            conns.push(h);
        }
    }
}

/// One connection: handshake, then a request/reply loop until EOF, error,
/// idle timeout or shutdown.
fn handle_connection(mut stream: TcpStream, shared: Arc<Shared>) -> io::Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(POLL_TICK))?;
    stream.set_write_timeout(Some(shared.opts.io_timeout))?;

    // Handshake: reply with our version either way, then drop mismatches
    // so the client can name both versions in its error.
    let client_version = read_hello_polled(&mut stream, &shared)?;
    wire::write_welcome(&mut stream, shared.backend.medium() as u64)?;
    if client_version != wire::VERSION {
        return Ok(());
    }

    let mut cache: HashMap<PathBuf, Arc<dyn StorageRead>> = HashMap::new();
    loop {
        let frame = match read_frame_polled(&mut stream, &shared)? {
            Some(f) => f,
            None => return Ok(()), // clean EOF, idle timeout or shutdown
        };
        let n = shared.served.fetch_add(1, Ordering::Relaxed) + 1;
        shared.bytes_in.fetch_add(4 + frame.len() as u64, Ordering::Relaxed);
        if shared.opts.drop_every > 0 && n % shared.opts.drop_every == 0 {
            // Injected transient fault: hang up *before* decoding, so the
            // request provably did not execute.
            return Ok(());
        }
        let (id, req) = match Request::decode(&frame) {
            Ok(r) => r,
            Err(e) => {
                // Can't attribute a request id; answer id 0 and close.
                shared.errors.fetch_add(1, Ordering::Relaxed);
                let payload = wire::encode_err(0, e.kind(), &e.to_string());
                if wire::write_frame(&mut stream, &payload).is_ok() {
                    shared.bytes_out.fetch_add(4 + payload.len() as u64, Ordering::Relaxed);
                }
                return Ok(());
            }
        };
        let payload = match execute(&req, &shared, &mut cache) {
            Ok(reply) => wire::encode_ok(id, &reply),
            Err(e) => {
                shared.errors.fetch_add(1, Ordering::Relaxed);
                wire::encode_err(id, e.kind(), &e.to_string())
            }
        };
        wire::write_frame(&mut stream, &payload)?;
        shared.bytes_out.fetch_add(4 + payload.len() as u64, Ordering::Relaxed);
    }
}

/// Read the 8-byte hello under the poll tick, honoring shutdown and the
/// idle budget.
fn read_hello_polled(stream: &mut TcpStream, shared: &Shared) -> io::Result<u16> {
    let mut buf = [0u8; 8];
    let mut filled = 0;
    let deadline = Instant::now() + shared.opts.io_timeout;
    while filled < buf.len() {
        if shared.shutdown.load(Ordering::SeqCst) || Instant::now() >= deadline {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "handshake timed out"));
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => filled += n,
            Err(e) if is_poll_tick(&e) => continue,
            Err(e) => return Err(e),
        }
    }
    let mut cursor = &buf[..];
    wire::read_hello(&mut cursor)
}

/// Read one frame under the poll tick. `Ok(None)` means the connection
/// should close quietly: clean EOF between requests, shutdown, or the
/// idle budget ran out.
fn read_frame_polled(stream: &mut TcpStream, shared: &Shared) -> io::Result<Option<Vec<u8>>> {
    let mut hdr = [0u8; 4];
    let mut filled = 0;
    let mut idle = Instant::now();
    // Header: may legitimately wait forever-ish (idle budget) for the
    // next request; a clean EOF at byte 0 is a normal close.
    while filled < hdr.len() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return Ok(None);
        }
        if idle.elapsed() >= shared.opts.io_timeout {
            return Ok(None);
        }
        match stream.read(&mut hdr[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None);
                }
                return Err(io::ErrorKind::UnexpectedEof.into());
            }
            Ok(n) => {
                filled += n;
                idle = Instant::now();
            }
            Err(e) if is_poll_tick(&e) => continue,
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(hdr);
    if len > wire::MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("peer announced a {len}-byte frame (cap {})", wire::MAX_FRAME),
        ));
    }
    // Body: a partial frame followed by silence is a real timeout error.
    let mut buf = vec![0u8; len as usize];
    let mut filled = 0;
    let mut idle = Instant::now();
    while filled < buf.len() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return Ok(None);
        }
        if idle.elapsed() >= shared.opts.io_timeout {
            return Err(io::Error::new(io::ErrorKind::TimedOut, "mid-frame timeout"));
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => {
                filled += n;
                idle = Instant::now();
            }
            Err(e) if is_poll_tick(&e) => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(Some(buf))
}

/// A read that merely hit the poll-tick timeout (platform-dependent kind).
fn is_poll_tick(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Confine a client path to the served root: lexical normalization, strip
/// absolute/current-dir components, refuse parent components outright.
fn resolve(root: &Path, client: &Path) -> io::Result<PathBuf> {
    let normalized = crate::vfs::normalize(client);
    let mut out = root.to_path_buf();
    for comp in normalized.components() {
        match comp {
            Component::RootDir | Component::Prefix(_) | Component::CurDir => {}
            Component::ParentDir => {
                return Err(io::Error::new(
                    io::ErrorKind::PermissionDenied,
                    format!("path escapes the served root: {}", client.display()),
                ));
            }
            Component::Normal(c) => out.push(c),
        }
    }
    Ok(out)
}

/// Execute one request against the backend. The per-connection `cache`
/// memoizes read handles (`Storage::open` re-validates existence and
/// re-reads nothing, but skipping it saves a round of backend lookups on
/// every positioned read).
fn execute(
    req: &Request,
    shared: &Shared,
    cache: &mut HashMap<PathBuf, Arc<dyn StorageRead>>,
) -> io::Result<Reply> {
    let backend = &shared.backend;
    let root = &shared.opts.root;
    match req {
        Request::ReadAt { path, offset, len } => {
            if *len > wire::MAX_READ {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("read of {len} bytes exceeds MAX_READ {}", wire::MAX_READ),
                ));
            }
            let resolved = resolve(root, path)?;
            let file = match cache.get(&resolved) {
                Some(f) => Arc::clone(f),
                None => {
                    let f = backend.open(&resolved)?;
                    if cache.len() >= HANDLE_CACHE_CAP {
                        cache.clear();
                    }
                    cache.insert(resolved, Arc::clone(&f));
                    f
                }
            };
            let mut buf = vec![0u8; *len as usize];
            file.read_exact_at(*offset, &mut buf)?;
            Ok(Reply::Bytes(buf))
        }
        Request::Len { path } => {
            let n = backend.len(&resolve(root, path)?)?;
            Ok(Reply::Num(n))
        }
        Request::List { dir } => {
            let entries = backend.list(&resolve(root, dir)?)?;
            // Map results back into the client's namespace: the client
            // asked about `dir`, so that is the prefix it gets back.
            let mapped = entries
                .into_iter()
                .map(|p| match p.file_name() {
                    Some(name) => dir.join(name),
                    None => p,
                })
                .collect();
            Ok(Reply::Paths(mapped))
        }
        Request::ReadFile { path } => {
            let bytes = backend.read_file(&resolve(root, path)?)?;
            if bytes.len() as u64 > wire::MAX_FRAME as u64 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("file of {} bytes exceeds one frame; use ReadAt", bytes.len()),
                ));
            }
            Ok(Reply::Bytes(bytes))
        }
        Request::WriteFile { path, bytes } => {
            let resolved = resolve(root, path)?;
            // The backend's write_file is the atomic temp+rename path —
            // this is what makes WriteFile idempotent and so retryable.
            backend.write_file(&resolved, bytes)?;
            cache.remove(&resolved);
            Ok(Reply::Unit)
        }
        Request::Rename { from, to } => {
            let rfrom = resolve(root, from)?;
            let rto = resolve(root, to)?;
            backend.rename(&rfrom, &rto)?;
            cache.remove(&rfrom);
            cache.remove(&rto);
            Ok(Reply::Unit)
        }
        Request::CreateDirAll { dir } => {
            backend.create_dir_all(&resolve(root, dir)?)?;
            Ok(Reply::Unit)
        }
        Request::Canonical { path } => {
            // Server-side canonical identity: two clients naming the same
            // file through different spellings agree on one path.
            Ok(Reply::Path(backend.canonical(&resolve(root, path)?)))
        }
        Request::Ping => Ok(Reply::Unit),
        // Counter snapshot; includes the Stats request itself (its frame
        // was read — and counted — before execute ran).
        Request::Stats => Ok(Reply::Stats(shared.server_stats())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_confines_to_root() {
        let root = Path::new("/srv/data");
        assert_eq!(
            resolve(root, Path::new("matrix/m-0.h5spm")).unwrap(),
            PathBuf::from("/srv/data/matrix/m-0.h5spm")
        );
        // Absolute client paths are re-rooted, not trusted.
        assert_eq!(
            resolve(root, Path::new("/matrix/a")).unwrap(),
            PathBuf::from("/srv/data/matrix/a")
        );
        // `a/b/../c` normalizes away the inner parent, then resolves.
        assert_eq!(
            resolve(root, Path::new("a/b/../c")).unwrap(),
            PathBuf::from("/srv/data/a/c")
        );
        // Escapes are refused with a typed error.
        let err = resolve(root, Path::new("../secrets")).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::PermissionDenied);
        let err = resolve(root, Path::new("a/../../x")).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::PermissionDenied);
    }
}
