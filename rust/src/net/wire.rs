//! The ABHSF storage wire protocol: length-prefixed binary frames, one
//! opcode per [`crate::vfs::Storage`] method, typed error frames and a
//! versioned handshake.
//!
//! Every message is a *frame* — a little-endian `u32` byte length followed
//! by that many payload bytes, capped at [`MAX_FRAME`] so a corrupt or
//! hostile peer cannot force an unbounded allocation. A request frame is
//! `[req_id: u64][opcode: u8][body]`; the matching reply is
//! `[req_id: u64][status: u8][body]` where the status byte tags the reply
//! shape ([`Reply`]) or, for [`ERR_STATUS`], a typed error frame
//! `[kind: u8][len: u32][utf8 message]` whose kind code round-trips
//! through [`std::io::ErrorKind`] (the vocabulary [`crate::vfs`] backends
//! and the dataset layer's typed errors are built from: `NotFound` becomes
//! `DatasetError::MissingFile`, `UnexpectedEof` a truncation, and so on).
//!
//! All requests are *stateless*: a read names its path, offset and length
//! explicitly, so any request may be sent over any connection and — for
//! idempotent operations — safely resent after a transport failure. The
//! connection handshake (`hello`/`welcome`) pins the protocol version and
//! carries the server's storage medium identity back to the client (see
//! DESIGN.md §11 for the full format table and the retry policy).

use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};

/// Handshake magic: both sides lead with it so a stray connection from a
/// non-ABHSF peer fails fast instead of being misparsed as a frame.
pub const HELLO_MAGIC: [u8; 4] = *b"ABnp";

/// Protocol version. A server answers a mismatched client with its own
/// version in the welcome (so the client can report *both* numbers) and
/// closes the connection.
pub const VERSION: u16 = 1;

/// Hard cap on one frame's payload bytes. Whole-file operations
/// (`ReadFile`/`WriteFile`, i.e. manifests) must fit in one frame;
/// positioned reads are chunked client-side at [`MAX_READ`] and never
/// approach it.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// Largest single `ReadAt` the client issues; longer reads are split into
/// consecutive requests so per-request buffers stay bounded.
pub const MAX_READ: u32 = 8 * 1024 * 1024;

/// Reply status byte marking a typed error frame.
pub const ERR_STATUS: u8 = 0xff;

// ---------------------------------------------------------------- frames

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    if payload.len() as u64 > MAX_FRAME as u64 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("frame of {} bytes exceeds MAX_FRAME {MAX_FRAME}", payload.len()),
        ));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame.
pub fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr);
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("peer announced a {len}-byte frame (cap {MAX_FRAME})"),
        ));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

// ------------------------------------------------------------- handshake

/// Client hello: magic + version (+ reserved pad), 8 bytes.
pub fn write_hello(w: &mut impl Write) -> io::Result<()> {
    let mut msg = [0u8; 8];
    msg[..4].copy_from_slice(&HELLO_MAGIC);
    msg[4..6].copy_from_slice(&VERSION.to_le_bytes());
    w.write_all(&msg)?;
    w.flush()
}

/// Server side: read the client hello, returning its protocol version.
pub fn read_hello(r: &mut impl Read) -> io::Result<u16> {
    let mut msg = [0u8; 8];
    r.read_exact(&mut msg)?;
    if msg[..4] != HELLO_MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "peer is not an ABHSF client (bad hello magic)",
        ));
    }
    Ok(u16::from_le_bytes([msg[4], msg[5]]))
}

/// Server welcome: magic + version + reserved pad + storage medium
/// identity, 16 bytes.
pub fn write_welcome(w: &mut impl Write, medium: u64) -> io::Result<()> {
    let mut msg = [0u8; 16];
    msg[..4].copy_from_slice(&HELLO_MAGIC);
    msg[4..6].copy_from_slice(&VERSION.to_le_bytes());
    msg[8..16].copy_from_slice(&medium.to_le_bytes());
    w.write_all(&msg)?;
    w.flush()
}

/// Client side: read the server welcome, returning `(version, medium)`.
pub fn read_welcome(r: &mut impl Read) -> io::Result<(u16, u64)> {
    let mut msg = [0u8; 16];
    r.read_exact(&mut msg)?;
    if msg[..4] != HELLO_MAGIC {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "peer is not an ABHSF server (bad welcome magic)",
        ));
    }
    let version = u16::from_le_bytes([msg[4], msg[5]]);
    let medium = u64::from_le_bytes(msg[8..16].try_into().unwrap());
    Ok((version, medium))
}

// ----------------------------------------------------- error-kind codes

/// The `io::ErrorKind`s that cross the wire losslessly; anything else
/// degrades to code 0 / `ErrorKind::Other` (the message still travels).
const KIND_CODES: [(u8, io::ErrorKind); 10] = [
    (1, io::ErrorKind::NotFound),
    (2, io::ErrorKind::PermissionDenied),
    (3, io::ErrorKind::UnexpectedEof),
    (4, io::ErrorKind::InvalidInput),
    (5, io::ErrorKind::InvalidData),
    (6, io::ErrorKind::TimedOut),
    (7, io::ErrorKind::AlreadyExists),
    (8, io::ErrorKind::ConnectionRefused),
    (9, io::ErrorKind::ConnectionReset),
    (10, io::ErrorKind::Unsupported),
];

/// Wire code of an [`io::ErrorKind`].
pub fn kind_to_code(kind: io::ErrorKind) -> u8 {
    KIND_CODES
        .iter()
        .find(|(_, k)| *k == kind)
        .map(|(c, _)| *c)
        .unwrap_or(0)
}

/// [`io::ErrorKind`] of a wire code.
pub fn code_to_kind(code: u8) -> io::ErrorKind {
    KIND_CODES
        .iter()
        .find(|(c, _)| *c == code)
        .map(|(_, k)| *k)
        .unwrap_or(io::ErrorKind::Other)
}

// -------------------------------------------------------------- requests

/// One storage request, mirroring the [`crate::vfs::Storage`] surface.
/// Every variant is self-contained (stateless): there are no server-side
/// open handles to leak or to desynchronize on reconnect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Positioned read: `len` bytes at `offset` of `path`.
    ReadAt {
        /// File path (client namespace; the server confines it to its root).
        path: PathBuf,
        /// Byte offset.
        offset: u64,
        /// Bytes to read (errors if the file ends first, like
        /// `read_exact_at`).
        len: u32,
    },
    /// File length (`Storage::len`, also backing `Storage::open`'s
    /// existence check).
    Len {
        /// File path.
        path: PathBuf,
    },
    /// Directory listing (`Storage::list`).
    List {
        /// Directory path.
        dir: PathBuf,
    },
    /// Whole small file read (`Storage::read_file`).
    ReadFile {
        /// File path.
        path: PathBuf,
    },
    /// Atomic whole-file write (`Storage::write_file`; the server routes
    /// it through the backend's temp+rename path, so it is idempotent).
    WriteFile {
        /// File path.
        path: PathBuf,
        /// Full new contents.
        bytes: Vec<u8>,
    },
    /// Rename (`Storage::rename`) — the one non-idempotent mutation.
    Rename {
        /// Source path.
        from: PathBuf,
        /// Destination path.
        to: PathBuf,
    },
    /// Recursive directory creation (`Storage::create_dir_all`).
    CreateDirAll {
        /// Directory path.
        dir: PathBuf,
    },
    /// Canonical path identity (`Storage::canonical`).
    Canonical {
        /// Path to canonicalize.
        path: PathBuf,
    },
    /// Liveness probe (no storage side effect).
    Ping,
    /// Daemon introspection: ask the server for its lifetime counters
    /// ([`ServerStats`]). No storage side effect.
    Stats,
}

impl Request {
    /// Wire opcode of this request.
    pub fn opcode(&self) -> u8 {
        match self {
            Request::ReadAt { .. } => 1,
            Request::Len { .. } => 2,
            Request::List { .. } => 3,
            Request::ReadFile { .. } => 4,
            Request::WriteFile { .. } => 5,
            Request::Rename { .. } => 6,
            Request::CreateDirAll { .. } => 7,
            Request::Canonical { .. } => 8,
            Request::Ping => 9,
            Request::Stats => 10,
        }
    }

    /// Stable lower-case operation name (trace-span and metric labels).
    pub fn name(&self) -> &'static str {
        match self {
            Request::ReadAt { .. } => "read_at",
            Request::Len { .. } => "len",
            Request::List { .. } => "list",
            Request::ReadFile { .. } => "read_file",
            Request::WriteFile { .. } => "write_file",
            Request::Rename { .. } => "rename",
            Request::CreateDirAll { .. } => "create_dir_all",
            Request::Canonical { .. } => "canonical",
            Request::Ping => "ping",
            Request::Stats => "stats",
        }
    }

    /// Whether this request may be resent after a transport failure that
    /// happened *after* the request hit the wire. Reads are pure;
    /// `WriteFile` is an atomic whole-file replace (resending the same
    /// bytes converges) and `CreateDirAll` is naturally idempotent. Only
    /// `Rename` is excluded: a retry after a success that the client never
    /// saw would find the source gone and report a spurious `NotFound`.
    pub fn idempotent(&self) -> bool {
        !matches!(self, Request::Rename { .. })
    }

    /// Encode as a request-frame payload.
    pub fn encode(&self, req_id: u64) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(req_id);
        e.u8(self.opcode());
        match self {
            Request::ReadAt { path, offset, len } => {
                e.path(path);
                e.u64(*offset);
                e.u32(*len);
            }
            Request::Len { path }
            | Request::ReadFile { path }
            | Request::Canonical { path } => e.path(path),
            Request::List { dir } | Request::CreateDirAll { dir } => e.path(dir),
            Request::WriteFile { path, bytes } => {
                e.path(path);
                e.bytes(bytes);
            }
            Request::Rename { from, to } => {
                e.path(from);
                e.path(to);
            }
            Request::Ping | Request::Stats => {}
        }
        e.0
    }

    /// Decode a request-frame payload into `(req_id, request)`.
    pub fn decode(frame: &[u8]) -> io::Result<(u64, Request)> {
        let mut d = Dec::new(frame);
        let id = d.u64()?;
        let op = d.u8()?;
        let req = match op {
            1 => Request::ReadAt {
                path: d.path()?,
                offset: d.u64()?,
                len: d.u32()?,
            },
            2 => Request::Len { path: d.path()? },
            3 => Request::List { dir: d.path()? },
            4 => Request::ReadFile { path: d.path()? },
            5 => Request::WriteFile {
                path: d.path()?,
                bytes: d.bytes()?,
            },
            6 => Request::Rename {
                from: d.path()?,
                to: d.path()?,
            },
            7 => Request::CreateDirAll { dir: d.path()? },
            8 => Request::Canonical { path: d.path()? },
            9 => Request::Ping,
            10 => Request::Stats,
            other => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unknown opcode {other}"),
                ))
            }
        };
        d.done()?;
        Ok((id, req))
    }
}

// --------------------------------------------------------------- replies

/// The daemon's lifetime counters, answered to [`Request::Stats`]. The
/// server counts what the client's `NetStats` counts — request frames
/// and frame bytes *including* each frame's 4-byte length header,
/// *excluding* the hello/welcome handshake — so against a healthy
/// daemon `requests == NetStats.requests`, `bytes_in ==
/// NetStats.wire_sent` and `bytes_out == NetStats.wire_received`
/// exactly; under transport faults the client side may exceed the
/// server side by at most its retry count (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStats {
    /// Request frames fully read off the wire (whether or not they
    /// decoded or executed).
    pub requests: u64,
    /// Requests answered with a typed error frame (plus undecodable
    /// frames).
    pub errors: u64,
    /// Request-frame bytes read, including the 4-byte frame headers.
    pub bytes_in: u64,
    /// Reply-frame bytes written, including the 4-byte frame headers.
    pub bytes_out: u64,
    /// Milliseconds since the daemon started serving.
    pub uptime_ms: u64,
    /// Connections accepted over the daemon's lifetime.
    pub connections: u64,
}

impl std::fmt::Display for ServerStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests, {} errors, {} bytes in, {} bytes out, {} connections, up {:.1}s",
            self.requests,
            self.errors,
            self.bytes_in,
            self.bytes_out,
            self.connections,
            self.uptime_ms as f64 / 1e3
        )
    }
}

/// A successful reply's payload shape, tagged by the status byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Reply {
    /// No payload (mutations, `Ping`).
    Unit,
    /// Raw bytes (`ReadAt`, `ReadFile`).
    Bytes(Vec<u8>),
    /// One number (`Len`).
    Num(u64),
    /// One path (`Canonical`).
    Path(PathBuf),
    /// Path list (`List`).
    Paths(Vec<PathBuf>),
    /// Daemon counters (`Stats`).
    Stats(ServerStats),
}

impl Reply {
    fn status(&self) -> u8 {
        match self {
            Reply::Unit => 0,
            Reply::Bytes(_) => 1,
            Reply::Num(_) => 2,
            Reply::Path(_) => 3,
            Reply::Paths(_) => 4,
            Reply::Stats(_) => 5,
        }
    }

    /// Expect the `Bytes` shape.
    pub fn into_bytes(self) -> io::Result<Vec<u8>> {
        match self {
            Reply::Bytes(b) => Ok(b),
            other => Err(shape_error("Bytes", &other)),
        }
    }

    /// Expect the `Num` shape.
    pub fn into_num(self) -> io::Result<u64> {
        match self {
            Reply::Num(n) => Ok(n),
            other => Err(shape_error("Num", &other)),
        }
    }

    /// Expect the `Unit` shape.
    pub fn into_unit(self) -> io::Result<()> {
        match self {
            Reply::Unit => Ok(()),
            other => Err(shape_error("Unit", &other)),
        }
    }

    /// Expect the `Path` shape.
    pub fn into_path(self) -> io::Result<PathBuf> {
        match self {
            Reply::Path(p) => Ok(p),
            other => Err(shape_error("Path", &other)),
        }
    }

    /// Expect the `Paths` shape.
    pub fn into_paths(self) -> io::Result<Vec<PathBuf>> {
        match self {
            Reply::Paths(p) => Ok(p),
            other => Err(shape_error("Paths", &other)),
        }
    }

    /// Expect the `Stats` shape.
    pub fn into_stats(self) -> io::Result<ServerStats> {
        match self {
            Reply::Stats(s) => Ok(s),
            other => Err(shape_error("Stats", &other)),
        }
    }
}

fn shape_error(want: &str, got: &Reply) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("server replied with the wrong shape: wanted {want}, got {got:?}"),
    )
}

/// A typed error carried in an error frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Wire error-kind code (see [`code_to_kind`]).
    pub code: u8,
    /// Human-readable message from the server side.
    pub message: String,
}

impl From<WireError> for io::Error {
    fn from(e: WireError) -> Self {
        io::Error::new(code_to_kind(e.code), format!("remote: {}", e.message))
    }
}

/// Encode a successful reply-frame payload.
pub fn encode_ok(req_id: u64, reply: &Reply) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(req_id);
    e.u8(reply.status());
    match reply {
        Reply::Unit => {}
        Reply::Bytes(b) => e.bytes(b),
        Reply::Num(n) => e.u64(*n),
        Reply::Path(p) => e.path(p),
        Reply::Paths(ps) => {
            e.u32(ps.len() as u32);
            for p in ps {
                e.path(p);
            }
        }
        Reply::Stats(s) => {
            e.u64(s.requests);
            e.u64(s.errors);
            e.u64(s.bytes_in);
            e.u64(s.bytes_out);
            e.u64(s.uptime_ms);
            e.u64(s.connections);
        }
    }
    e.0
}

/// Encode a typed error reply-frame payload.
pub fn encode_err(req_id: u64, kind: io::ErrorKind, message: &str) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(req_id);
    e.u8(ERR_STATUS);
    e.u8(kind_to_code(kind));
    e.bytes(message.as_bytes());
    e.0
}

/// Decode a reply-frame payload into `(req_id, Ok(reply) | Err(wire))`.
pub fn decode_reply(frame: &[u8]) -> io::Result<(u64, Result<Reply, WireError>)> {
    let mut d = Dec::new(frame);
    let id = d.u64()?;
    let status = d.u8()?;
    let res = match status {
        0 => Ok(Reply::Unit),
        1 => Ok(Reply::Bytes(d.bytes()?)),
        2 => Ok(Reply::Num(d.u64()?)),
        3 => Ok(Reply::Path(d.path()?)),
        4 => {
            let n = d.u32()? as usize;
            // Bound the allocation by the frame itself: each path costs
            // at least its 4-byte length prefix.
            if n > frame.len() / 4 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("path list of {n} entries exceeds the frame"),
                ));
            }
            let mut ps = Vec::with_capacity(n);
            for _ in 0..n {
                ps.push(d.path()?);
            }
            Ok(Reply::Paths(ps))
        }
        5 => Ok(Reply::Stats(ServerStats {
            requests: d.u64()?,
            errors: d.u64()?,
            bytes_in: d.u64()?,
            bytes_out: d.u64()?,
            uptime_ms: d.u64()?,
            connections: d.u64()?,
        })),
        ERR_STATUS => {
            let code = d.u8()?;
            let message = String::from_utf8_lossy(&d.bytes()?).into_owned();
            Err(WireError { code, message })
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unknown reply status {other}"),
            ))
        }
    };
    d.done()?;
    Ok((id, res))
}

// ------------------------------------------------------ encode / decode

/// Little-endian append-only encoder. Paths travel as UTF-8 strings
/// (`to_string_lossy`); non-UTF-8 paths are not supported on the wire.
struct Enc(Vec<u8>);

impl Enc {
    fn new() -> Self {
        Enc(Vec::with_capacity(64))
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.0.extend_from_slice(b);
    }
    fn path(&mut self, p: &Path) {
        let s = p.to_string_lossy();
        self.bytes(s.as_bytes());
    }
}

/// Bounds-checked decoder over one frame.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "truncated frame",
            ));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> io::Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn path(&mut self) -> io::Result<PathBuf> {
        let b = self.bytes()?;
        let s = String::from_utf8(b).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 path on the wire")
        })?;
        Ok(PathBuf::from(s))
    }

    /// The frame must be fully consumed — trailing bytes mean a framing
    /// bug or a version skew and must not pass silently.
    fn done(&self) -> io::Result<()> {
        if self.pos != self.buf.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{} trailing bytes in frame", self.buf.len() - self.pos),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: Request) {
        let frame = req.encode(77);
        let (id, back) = Request::decode(&frame).unwrap();
        assert_eq!(id, 77);
        assert_eq!(back, req);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_request(Request::ReadAt {
            path: PathBuf::from("d/matrix-0.h5spm"),
            offset: 4096,
            len: 1 << 20,
        });
        roundtrip_request(Request::Len {
            path: PathBuf::from("d/f"),
        });
        roundtrip_request(Request::List {
            dir: PathBuf::from("d"),
        });
        roundtrip_request(Request::ReadFile {
            path: PathBuf::from("d/dataset.json"),
        });
        roundtrip_request(Request::WriteFile {
            path: PathBuf::from("d/dataset.json"),
            bytes: b"{}".to_vec(),
        });
        roundtrip_request(Request::Rename {
            from: PathBuf::from("a"),
            to: PathBuf::from("b"),
        });
        roundtrip_request(Request::CreateDirAll {
            dir: PathBuf::from("x/y"),
        });
        roundtrip_request(Request::Canonical {
            path: PathBuf::from("x/../y"),
        });
        roundtrip_request(Request::Ping);
        roundtrip_request(Request::Stats);
    }

    #[test]
    fn request_names_are_stable() {
        assert_eq!(Request::Ping.name(), "ping");
        assert_eq!(Request::Stats.name(), "stats");
        assert_eq!(
            Request::ReadAt {
                path: PathBuf::from("f"),
                offset: 0,
                len: 1
            }
            .name(),
            "read_at"
        );
    }

    #[test]
    fn replies_roundtrip() {
        for reply in [
            Reply::Unit,
            Reply::Bytes(vec![1, 2, 3]),
            Reply::Num(42),
            Reply::Path(PathBuf::from("/a/b")),
            Reply::Paths(vec![PathBuf::from("a"), PathBuf::from("b/c")]),
            Reply::Stats(ServerStats {
                requests: 100,
                errors: 2,
                bytes_in: 12_345,
                bytes_out: 67_890,
                uptime_ms: 1_500,
                connections: 4,
            }),
        ] {
            let frame = encode_ok(9, &reply);
            let (id, res) = decode_reply(&frame).unwrap();
            assert_eq!(id, 9);
            assert_eq!(res.unwrap(), reply);
        }
    }

    #[test]
    fn error_frames_carry_kind_and_message() {
        let frame = encode_err(3, io::ErrorKind::NotFound, "no such file: m.h5spm");
        let (id, res) = decode_reply(&frame).unwrap();
        assert_eq!(id, 3);
        let wire = res.unwrap_err();
        assert_eq!(code_to_kind(wire.code), io::ErrorKind::NotFound);
        let io_err: io::Error = wire.into();
        assert_eq!(io_err.kind(), io::ErrorKind::NotFound);
        assert!(io_err.to_string().contains("m.h5spm"), "{io_err}");
    }

    #[test]
    fn kind_codes_roundtrip() {
        use io::ErrorKind::*;
        for kind in [
            NotFound,
            PermissionDenied,
            UnexpectedEof,
            InvalidInput,
            InvalidData,
            TimedOut,
            AlreadyExists,
            ConnectionRefused,
            ConnectionReset,
            Unsupported,
        ] {
            assert_eq!(code_to_kind(kind_to_code(kind)), kind);
        }
        // Unmapped kinds degrade to Other, never panic.
        assert_eq!(code_to_kind(kind_to_code(io::ErrorKind::BrokenPipe)), io::ErrorKind::Other);
    }

    #[test]
    fn frames_roundtrip_and_reject_oversize() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), b"");
        // An announced length beyond the cap is rejected before allocating.
        let mut huge = Vec::new();
        huge.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let err = read_frame(&mut &huge[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn handshake_roundtrips_and_rejects_bad_magic() {
        let mut buf = Vec::new();
        write_hello(&mut buf).unwrap();
        assert_eq!(read_hello(&mut &buf[..]).unwrap(), VERSION);

        let mut buf = Vec::new();
        write_welcome(&mut buf, 0xdead_beef).unwrap();
        let (v, medium) = read_welcome(&mut &buf[..]).unwrap();
        assert_eq!(v, VERSION);
        assert_eq!(medium, 0xdead_beef);

        let junk = [0u8; 16];
        assert!(read_hello(&mut &junk[..8]).is_err());
        assert!(read_welcome(&mut &junk[..]).is_err());
    }

    #[test]
    fn truncated_and_trailing_frames_are_typed_errors() {
        let frame = Request::Ping.encode(1);
        assert!(Request::decode(&frame[..5]).is_err());
        let mut trailing = frame.clone();
        trailing.push(0);
        assert!(Request::decode(&trailing).is_err());
        assert!(Request::decode(&[]).is_err());
    }
}
