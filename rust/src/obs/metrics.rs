//! Bounded-memory metric primitives and the process-wide registry.
//!
//! Three metric kinds, all lock-free on their hot paths:
//!
//! * [`Counter`] — a monotonically increasing sum, striped across
//!   cache-line-padded atomics so concurrent writers do not contend.
//! * [`Gauge`] — a single settable value (residency, pool sizes).
//! * [`LogHistogram`] — a fixed table of geometrically sized buckets
//!   (growth [`HIST_GROWTH`]) covering `[1 ns, ~1000 s]` when values are
//!   seconds. Memory is O([`HIST_BUCKETS`]) regardless of sample count,
//!   and any quantile is reported as its bucket's geometric midpoint —
//!   at most `√1.04 − 1 ≈ 1.98%` relative error from the exact
//!   nearest-rank statistic. The exact minimum and maximum are tracked
//!   separately (so `max` is always exact).
//!
//! The [`MetricsRegistry`] maps stable dotted names
//! (`"cache.claim.hit_t1"`, `"net.rpc_s"`, …) to shared handles.
//! Subsystems resolve their handles once at construction and then touch
//! only the atomics; the registry lock is never on a hot path. A
//! process-wide registry is available through [`global`].

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Stripes per [`Counter`] (padded to distinct cache lines).
const STRIPES: usize = 16;

/// Geometric bucket growth factor of [`LogHistogram`].
pub const HIST_GROWTH: f64 = 1.04;

/// Smallest distinguishable histogram value; everything at or below
/// lands in bucket 0.
const HIST_MIN: f64 = 1e-9;

/// Bucket count: `1.04^720 ≈ 1.9e12`, so seconds-valued samples span
/// 1 ns to ~1900 s before saturating in the last bucket.
pub const HIST_BUCKETS: usize = 720;

#[repr(align(64))]
#[derive(Default)]
struct Stripe(AtomicU64);

/// A striped monotonic counter: `add` touches one cache-line-private
/// atomic chosen by the calling thread, `get` sums all stripes.
#[derive(Default)]
pub struct Counter {
    stripes: [Stripe; STRIPES],
}

/// Stable per-thread stripe index (assigned on first use).
fn stripe_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    IDX.with(|c| {
        let mut v = c.get();
        if v == usize::MAX {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v % STRIPES
    })
}

impl Counter {
    /// New zeroed counter.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` (relaxed; one uncontended atomic op per call).
    pub fn add(&self, n: u64) {
        self.stripes[stripe_index()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current total across all stripes.
    pub fn get(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

impl std::fmt::Debug for Counter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Counter").field(&self.get()).finish()
    }
}

/// A settable instantaneous value (unsigned; `sub` saturates at 0).
#[derive(Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// New zeroed gauge.
    pub fn new() -> Self {
        Self::default()
    }

    /// Overwrite the value.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Increase by `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Decrease by `n`, saturating at 0.
    pub fn sub(&self, n: u64) {
        let _ = self
            .value
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Gauge").field(&self.get()).finish()
    }
}

/// Add `v` to an f64 stored as atomic bits (CAS loop).
fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Lower `cell` toward `v` (atomic running minimum) when `min` is true,
/// raise it (running maximum) otherwise.
fn atomic_f64_extreme(cell: &AtomicU64, v: f64, min: bool) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let cur_f = f64::from_bits(cur);
        let better = if min { v < cur_f } else { v > cur_f };
        if !better {
            return;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Bucket index of value `v` (≤ 0 and sub-[`HIST_MIN`] values land in
/// bucket 0; values beyond the table saturate in the last bucket).
fn bucket_of(v: f64) -> usize {
    if v <= HIST_MIN {
        return 0;
    }
    let r = (v / HIST_MIN).ln() / HIST_GROWTH.ln();
    (r as usize).min(HIST_BUCKETS - 1)
}

/// Geometric midpoint of bucket `i` — the reported representative of
/// every sample that landed there.
fn bucket_mid(i: usize) -> f64 {
    HIST_MIN * HIST_GROWTH.powf(i as f64 + 0.5)
}

/// Concurrent log-bucketed histogram (module docs for the error bound).
/// `record` is lock-free; `snapshot` captures a mergeable copy.
pub struct LogHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// New empty histogram (O([`HIST_BUCKETS`]) memory, fixed forever).
    pub fn new() -> Self {
        Self {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// Record one sample (non-finite samples are dropped).
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.sum_bits, v);
        atomic_f64_extreme(&self.min_bits, v, true);
        atomic_f64_extreme(&self.max_bits, v, false);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Capture a consistent-enough copy for reporting (counters are read
    /// relaxed; concurrent recorders may straddle the snapshot by one
    /// sample, which is irrelevant for percentile reporting).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let min = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        let max = f64::from_bits(self.max_bits.load(Ordering::Relaxed));
        HistogramSnapshot {
            count,
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            min: if count == 0 { 0.0 } else { min },
            max: if count == 0 { 0.0 } else { max },
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }

    /// Fold a snapshot's samples into this histogram (used to aggregate
    /// per-thread histograms into a registry-held one).
    pub fn merge_snapshot(&self, snap: &HistogramSnapshot) {
        if snap.count == 0 {
            return;
        }
        for (b, &n) in self.buckets.iter().zip(&snap.buckets) {
            if n > 0 {
                b.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        atomic_f64_add(&self.sum_bits, snap.sum);
        atomic_f64_extreme(&self.min_bits, snap.min, true);
        atomic_f64_extreme(&self.max_bits, snap.max, false);
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("count", &self.count())
            .finish()
    }
}

/// A point-in-time copy of a [`LogHistogram`]: bucket counts plus exact
/// count/sum/min/max. Merging is exact bucket-count addition, hence
/// associative and commutative (the floating-point `sum` may differ in
/// its last bits across merge orders; every quantile is identical).
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Total samples.
    pub count: u64,
    /// Sum of all samples (exact values, not bucket midpoints).
    pub sum: f64,
    /// Exact smallest sample (0.0 when empty).
    pub min: f64,
    /// Exact largest sample (0.0 when empty).
    pub max: f64,
    buckets: Vec<u64>,
}

impl HistogramSnapshot {
    /// An empty snapshot (merge identity).
    pub fn empty() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            buckets: vec![0; HIST_BUCKETS],
        }
    }

    /// Merge two snapshots into their union.
    pub fn merge(&self, other: &Self) -> Self {
        if self.count == 0 {
            return other.clone();
        }
        if other.count == 0 {
            return self.clone();
        }
        Self {
            count: self.count + other.count,
            sum: self.sum + other.sum,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
            buckets: self
                .buckets
                .iter()
                .zip(&other.buckets)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Nearest-rank quantile, `q ∈ [0, 1]`: the geometric midpoint of
    /// the bucket holding the rank-`⌈q·count⌉` sample, clamped into
    /// `[min, max]` — within `√1.04 − 1 ≈ 1.98%` of the exact order
    /// statistic, and exactly `max` for `q = 1` whenever the largest
    /// sample sits alone past its bucket's midpoint.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= rank {
                return bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Arithmetic mean of the exact samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// One registered metric handle.
#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<LogHistogram>),
}

/// A read-only view of one metric's current value.
#[derive(Debug, Clone)]
pub enum MetricSnapshot {
    /// Counter total.
    Counter(u64),
    /// Gauge value.
    Gauge(u64),
    /// Histogram snapshot.
    Histogram(HistogramSnapshot),
}

/// Name → metric map (module docs for the contract). Handle resolution
/// takes the registry lock; using a resolved handle never does.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    /// New empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric kind.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut map = self.metrics.lock().expect("metrics registry poisoned");
        let m = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())));
        match m {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric {name:?} already registered as {other:?}, wanted a counter"),
        }
    }

    /// Get or create the gauge `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric kind.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut map = self.metrics.lock().expect("metrics registry poisoned");
        let m = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())));
        match m {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric {name:?} already registered as {other:?}, wanted a gauge"),
        }
    }

    /// Get or create the histogram `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different metric kind.
    pub fn histogram(&self, name: &str) -> Arc<LogHistogram> {
        let mut map = self.metrics.lock().expect("metrics registry poisoned");
        let m = map
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(LogHistogram::new())));
        match m {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric {name:?} already registered as {other:?}, wanted a histogram"),
        }
    }

    /// Snapshot every registered metric, in name order.
    pub fn snapshot(&self) -> Vec<(String, MetricSnapshot)> {
        let map = self.metrics.lock().expect("metrics registry poisoned");
        map.iter()
            .map(|(name, m)| {
                let snap = match m {
                    Metric::Counter(c) => MetricSnapshot::Counter(c.get()),
                    Metric::Gauge(g) => MetricSnapshot::Gauge(g.get()),
                    Metric::Histogram(h) => MetricSnapshot::Histogram(h.snapshot()),
                };
                (name.clone(), snap)
            })
            .collect()
    }
}

/// The process-wide registry every subsystem registers into.
pub fn global() -> &'static MetricsRegistry {
    static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn counter_sums_across_threads() {
        let c = Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_set_add_sub() {
        let g = Gauge::new();
        g.set(10);
        g.add(5);
        g.sub(3);
        assert_eq!(g.get(), 12);
        g.sub(100);
        assert_eq!(g.get(), 0, "sub saturates at zero");
    }

    /// Seeded random samples across five decades: every histogram
    /// quantile must sit within the advertised ~2% relative error of
    /// the exact nearest-rank order statistic, and `max` must be exact.
    #[test]
    fn quantiles_within_error_bound_of_exact_sort() {
        let mut rng = SplitMix64::new(0xC0FFEE);
        let h = LogHistogram::new();
        let mut exact: Vec<f64> = Vec::new();
        let (lo, hi) = ((1e-6f64).ln(), (10.0f64).ln());
        for _ in 0..20_000 {
            let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
            let v = (lo + u * (hi - lo)).exp();
            h.record(v);
            exact.push(v);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let snap = h.snapshot();
        assert_eq!(snap.count, exact.len() as u64);
        assert_eq!(snap.max, *exact.last().unwrap(), "max must be exact");
        assert_eq!(snap.min, exact[0], "min must be exact");
        for q in [0.01, 0.10, 0.50, 0.90, 0.99, 0.999, 1.0] {
            let rank = ((q * exact.len() as f64).ceil() as usize).clamp(1, exact.len());
            let truth = exact[rank - 1];
            let got = snap.quantile(q);
            let rel = (got - truth).abs() / truth;
            assert!(
                rel <= 0.0205,
                "q={q}: histogram {got} vs exact {truth} (rel err {rel:.4})"
            );
        }
    }

    #[test]
    fn merge_is_associative_on_bucket_counts() {
        let mk = |seed: u64| {
            let mut rng = SplitMix64::new(seed);
            let h = LogHistogram::new();
            for _ in 0..5_000 {
                let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
                h.record(1e-5 + u * u * 3.0);
            }
            h.snapshot()
        };
        let (a, b, c) = (mk(1), mk(2), mk(3));
        let left = a.merge(&b).merge(&c);
        let right = a.merge(&b.merge(&c));
        assert_eq!(left.buckets, right.buckets, "bucket counts are exact");
        assert_eq!(left.count, right.count);
        assert_eq!(left.min, right.min);
        assert_eq!(left.max, right.max);
        assert!((left.sum - right.sum).abs() <= 1e-9 * left.sum.abs());
        // And every derived quantile agrees bit-for-bit.
        for q in [0.5, 0.9, 0.99, 1.0] {
            assert_eq!(left.quantile(q), right.quantile(q));
        }
        // Identity element.
        assert_eq!(a.merge(&HistogramSnapshot::empty()).buckets, a.buckets);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let snap = LogHistogram::new().snapshot();
        assert_eq!(snap.count, 0);
        assert_eq!(snap.quantile(0.5), 0.0);
        assert_eq!(snap.max, 0.0);
        assert_eq!(snap.mean(), 0.0);
    }

    #[test]
    fn merge_snapshot_folds_into_live_histogram() {
        let a = LogHistogram::new();
        a.record(0.5);
        let b = LogHistogram::new();
        b.record(2.0);
        b.record(0.001);
        a.merge_snapshot(&b.snapshot());
        let snap = a.snapshot();
        assert_eq!(snap.count, 3);
        assert_eq!(snap.max, 2.0);
        assert_eq!(snap.min, 0.001);
    }

    #[test]
    fn registry_returns_shared_handles_and_snapshots() {
        let reg = MetricsRegistry::new();
        let c1 = reg.counter("a.hits");
        let c2 = reg.counter("a.hits");
        c1.add(3);
        c2.add(4);
        assert_eq!(reg.counter("a.hits").get(), 7, "same name, same counter");
        reg.gauge("a.resident").set(99);
        reg.histogram("a.lat_s").record(0.25);
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["a.hits", "a.lat_s", "a.resident"], "name-ordered");
        assert!(matches!(snap[0].1, MetricSnapshot::Counter(7)));
        assert!(matches!(snap[2].1, MetricSnapshot::Gauge(99)));
        match &snap[1].1 {
            MetricSnapshot::Histogram(h) => assert_eq!(h.count, 1),
            other => panic!("wanted histogram, got {other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn registry_rejects_kind_clash() {
        let reg = MetricsRegistry::new();
        let _ = reg.counter("x");
        let _ = reg.gauge("x");
    }
}
