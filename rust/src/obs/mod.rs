//! Cross-cutting observability: the process-wide metrics registry and
//! the structured trace-span layer (DESIGN.md §14).
//!
//! Every subsystem reports through one of two channels:
//!
//! * **Metrics** ([`metrics`]) — always-on, bounded-memory aggregates: a
//!   [`metrics::MetricsRegistry`] of sharded counters, gauges and
//!   log-bucketed histograms ([`metrics::LogHistogram`], ≤ ~2% relative
//!   quantile error) that `cache`, `vfs`/`h5`, `net`, `serve`, `dist`
//!   and `abhsf::load` register into. The serving harness's latency
//!   percentiles are computed from these histograms — memory stays
//!   O(buckets) no matter how many queries run.
//! * **Traces** ([`trace`]) — opt-in, per-event structured spans: when a
//!   CLI run passes `--trace PATH`, every directory walk, prefetch
//!   batch, block decode, cache claim/publish, kernel execution, halo
//!   exchange and remote round trip emits a JSONL span event with a
//!   unique id, a parent link and a monotonic timestamp, so one query's
//!   path through `DatasetReader → BlockCache → vfs → RemoteFs →
//!   daemon` is reconstructable offline (`abhsf trace FILE`). With
//!   tracing disabled the instrumentation fast-path is a single relaxed
//!   atomic load.

pub mod metrics;
pub mod trace;
