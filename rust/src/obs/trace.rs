//! Structured trace spans: opt-in JSONL event emission and the offline
//! tools (`parse` / `check` / `summarize`) the `trace` CLI subcommand is
//! built on.
//!
//! # Event schema (one JSON object per line)
//!
//! | field    | events  | meaning                                        |
//! |----------|---------|------------------------------------------------|
//! | `ev`     | all     | `"b"` begin span, `"e"` end span, `"p"` point  |
//! | `id`     | all     | unique event id (never 0; 0 means "no parent") |
//! | `parent` | `b`,`p` | id of the enclosing span, 0 for a root         |
//! | `kind`   | `b`,`p` | span kind (`query`, `vfs_read`, `net_rpc`, …)  |
//! | `t_us`   | all     | microseconds since the tracer was enabled      |
//! | *tags*   | `b`,`p` | kind-specific: numbers or identifier strings   |
//!
//! Parent links are established by a per-thread span stack: a span begun
//! while another is open on the same thread becomes its child. Work
//! handed to another thread (the prefetch fetcher) carries its parent
//! across via [`current_id`] + [`adopt_parent`]. Events are written
//! whole-line under one mutex, so a trace file is valid JSONL even with
//! many recording threads; ids are process-unique and allocated in begin
//! order, so a `parent` always refers to an *earlier* line — [`check`]
//! enforces this, plus unique ids, every span closed, and `end ≥ begin`.
//!
//! With tracing disabled (the default), every instrumentation site costs
//! one relaxed atomic load and no allocation.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// A tag value on a begin/point event.
#[derive(Debug, Clone, Copy)]
pub enum Tag {
    /// Unsigned number (bytes, counts, indices).
    U(u64),
    /// Static identifier string (outcomes, op names, phases).
    S(&'static str),
}

/// The span emitter: id allocator, monotonic clock origin, and the
/// line-buffered sink. One process-wide instance lives behind the
/// module-level [`enable`]/[`span`]/[`point`] functions.
pub struct Tracer {
    enabled: AtomicBool,
    next_id: AtomicU64,
    start: Instant,
    sink: Mutex<Option<Box<dyn Write + Send>>>,
}

impl Tracer {
    fn new() -> Self {
        Self {
            enabled: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            start: Instant::now(),
            sink: Mutex::new(None),
        }
    }

    fn t_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn write_line(&self, line: &str) {
        let mut sink = self.sink.lock().expect("trace sink poisoned");
        if let Some(w) = sink.as_mut() {
            // A failed write disables tracing rather than failing the
            // traced operation; `finish` will surface flush errors.
            if writeln!(w, "{line}").is_err() {
                self.enabled.store(false, Ordering::Relaxed);
            }
        }
    }

    fn emit_open(&self, ev: char, id: u64, parent: u64, kind: &str, tags: &[(&str, Tag)]) {
        let mut line = String::with_capacity(96);
        let _ = write!(
            line,
            "{{\"ev\":\"{ev}\",\"id\":{id},\"parent\":{parent},\"kind\":\"{kind}\",\"t_us\":{}",
            self.t_us()
        );
        for (key, val) in tags {
            match val {
                Tag::U(n) => {
                    let _ = write!(line, ",\"{key}\":{n}");
                }
                Tag::S(s) => {
                    let _ = write!(line, ",\"{key}\":\"{}\"", escape(s));
                }
            }
        }
        line.push('}');
        self.write_line(&line);
    }

    fn emit_end(&self, id: u64) {
        self.write_line(&format!("{{\"ev\":\"e\",\"id\":{id},\"t_us\":{}}}", self.t_us()));
    }
}

/// Escape a tag string for a JSON literal (tags are identifiers, so
/// this is almost always a no-op pass-through).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(Tracer::new)
}

thread_local! {
    /// Open spans on this thread, innermost last.
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    /// Parent adopted from another thread, used when the stack is empty.
    static BASE_PARENT: Cell<u64> = const { Cell::new(0) };
}

/// Route span events to a JSONL file at `path` (truncating it) and turn
/// instrumentation on process-wide.
pub fn enable(path: &Path) -> io::Result<()> {
    let file = std::fs::File::create(path)?;
    let t = tracer();
    *t.sink.lock().expect("trace sink poisoned") = Some(Box::new(BufWriter::new(file)));
    t.enabled.store(true, Ordering::Relaxed);
    Ok(())
}

/// Stop tracing and flush + close the sink. A no-op if tracing was
/// never enabled.
pub fn finish() -> io::Result<()> {
    let t = tracer();
    t.enabled.store(false, Ordering::Relaxed);
    let sink = t.sink.lock().expect("trace sink poisoned").take();
    if let Some(mut w) = sink {
        w.flush()?;
    }
    Ok(())
}

/// Whether instrumentation is currently recording.
pub fn is_enabled() -> bool {
    tracer().enabled.load(Ordering::Relaxed)
}

/// Id of the innermost open span on this thread (or the adopted base
/// parent), 0 if none — pass to [`adopt_parent`] on a worker thread to
/// carry the parent link across a `thread::spawn`.
pub fn current_id() -> u64 {
    let top = STACK.with(|s| s.borrow().last().copied());
    top.unwrap_or_else(|| BASE_PARENT.with(|b| b.get()))
}

/// Make `parent` the default parent for spans opened on this thread
/// while its own stack is empty (cross-thread parenting).
pub fn adopt_parent(parent: u64) {
    BASE_PARENT.with(|b| b.set(parent));
}

/// Open a span of `kind`; it closes (emitting the end event) when the
/// returned guard drops. Inert and allocation-free when tracing is off.
pub fn span(kind: &'static str, tags: &[(&'static str, Tag)]) -> SpanGuard {
    let t = tracer();
    if !t.enabled.load(Ordering::Relaxed) {
        return SpanGuard { id: 0 };
    }
    let id = t.alloc_id();
    t.emit_open('b', id, current_id(), kind, tags);
    STACK.with(|s| s.borrow_mut().push(id));
    SpanGuard { id }
}

/// Emit an instantaneous event of `kind` parented to the current span.
pub fn point(kind: &'static str, tags: &[(&'static str, Tag)]) {
    let t = tracer();
    if !t.enabled.load(Ordering::Relaxed) {
        return;
    }
    let id = t.alloc_id();
    t.emit_open('p', id, current_id(), kind, tags);
}

/// Closes its span on drop. The 0-id guard (tracing disabled) does
/// nothing.
#[must_use = "dropping the guard ends the span"]
pub struct SpanGuard {
    id: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.id == 0 {
            return;
        }
        STACK.with(|s| {
            let mut stack = s.borrow_mut();
            if stack.last() == Some(&self.id) {
                stack.pop();
            } else {
                // Out-of-order drop — remove wherever it is.
                stack.retain(|&v| v != self.id);
            }
        });
        tracer().emit_end(self.id);
    }
}

// ---------------------------------------------------------------------
// Offline side: parse, check, summarize.
// ---------------------------------------------------------------------

/// Event kind discriminant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ev {
    /// Span begin.
    Begin,
    /// Span end.
    End,
    /// Instantaneous point.
    Point,
}

/// One parsed trace line.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Begin / end / point.
    pub ev: Ev,
    /// Unique event id.
    pub id: u64,
    /// Parent span id, 0 for roots (always 0 on end events).
    pub parent: u64,
    /// Span kind (empty on end events).
    pub kind: String,
    /// Microseconds since tracing was enabled.
    pub t_us: u64,
    /// Kind-specific tags; numeric values are kept as decimal strings.
    pub tags: Vec<(String, String)>,
}

/// Minimal parser for the flat JSON objects this module emits.
struct P<'a> {
    b: &'a [u8],
    i: usize,
}

impl P<'_> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).ok_or("bad \\u escape")?);
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid UTF-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<String, String> {
        let start = self.i;
        while matches!(self.peek(), Some(b'0'..=b'9') | Some(b'.') | Some(b'-')) {
            self.i += 1;
        }
        if self.i == start {
            return Err(format!("expected number at byte {start}"));
        }
        Ok(std::str::from_utf8(&self.b[start..self.i]).unwrap().to_string())
    }
}

/// Parse one trace line into a [`TraceEvent`].
pub fn parse_line(line: &str) -> Result<TraceEvent, String> {
    let mut p = P {
        b: line.trim().as_bytes(),
        i: 0,
    };
    p.eat(b'{')?;
    let mut ev = None;
    let mut id = None;
    let mut parent = 0u64;
    let mut kind = String::new();
    let mut t_us = None;
    let mut tags = Vec::new();
    loop {
        let key = p.string()?;
        p.eat(b':')?;
        let val = if p.peek() == Some(b'"') {
            p.string()?
        } else {
            p.number()?
        };
        match key.as_str() {
            "ev" => {
                ev = Some(match val.as_str() {
                    "b" => Ev::Begin,
                    "e" => Ev::End,
                    "p" => Ev::Point,
                    other => return Err(format!("unknown ev {other:?}")),
                })
            }
            "id" => id = Some(val.parse().map_err(|_| "bad id")?),
            "parent" => parent = val.parse().map_err(|_| "bad parent")?,
            "kind" => kind = val,
            "t_us" => t_us = Some(val.parse().map_err(|_| "bad t_us")?),
            _ => tags.push((key, val)),
        }
        match p.peek() {
            Some(b',') => p.i += 1,
            Some(b'}') => {
                p.i += 1;
                break;
            }
            other => return Err(format!("expected ',' or '}}', got {other:?}")),
        }
    }
    if p.i != p.b.len() {
        return Err("trailing bytes after object".into());
    }
    let ev = ev.ok_or("missing ev")?;
    let id = id.ok_or("missing id")?;
    let t_us = t_us.ok_or("missing t_us")?;
    if id == 0 {
        return Err("id 0 is reserved".into());
    }
    if ev != Ev::End && kind.is_empty() {
        return Err("begin/point event missing kind".into());
    }
    Ok(TraceEvent {
        ev,
        id,
        parent,
        kind,
        t_us,
        tags,
    })
}

/// Read and parse a whole trace file; the error names the offending
/// line number.
pub fn read_trace(path: &Path) -> io::Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path)?;
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = parse_line(line).map_err(|e| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}:{}: {e}", path.display(), lineno + 1),
            )
        })?;
        events.push(ev);
    }
    Ok(events)
}

/// Structural well-formedness: unique ids, every begin matched by
/// exactly one later end with `t_us ≥` the begin's, no stray ends, and
/// every parent link resolving to a span begun earlier in the file.
pub fn check(events: &[TraceEvent]) -> Result<(), String> {
    let mut begun: BTreeMap<u64, (u64, bool)> = BTreeMap::new(); // id -> (t_us, closed)
    let mut seen_ids: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for (i, e) in events.iter().enumerate() {
        let at = |msg: String| format!("event {}: {msg}", i + 1);
        match e.ev {
            Ev::Begin | Ev::Point => {
                if !seen_ids.insert(e.id) {
                    return Err(at(format!("duplicate id {}", e.id)));
                }
                if e.parent != 0 && !begun.contains_key(&e.parent) {
                    return Err(at(format!("parent {} not begun earlier", e.parent)));
                }
                if e.ev == Ev::Begin {
                    begun.insert(e.id, (e.t_us, false));
                }
            }
            Ev::End => match begun.get_mut(&e.id) {
                None => return Err(at(format!("end for unknown span {}", e.id))),
                Some((_, closed)) if *closed => {
                    return Err(at(format!("span {} ended twice", e.id)))
                }
                Some((t0, closed)) => {
                    if e.t_us < *t0 {
                        return Err(at(format!(
                            "span {} ends at {} before its begin at {}",
                            e.id, e.t_us, t0
                        )));
                    }
                    *closed = true;
                }
            },
        }
    }
    let open: Vec<u64> = begun
        .iter()
        .filter(|(_, (_, closed))| !closed)
        .map(|(id, _)| *id)
        .collect();
    if !open.is_empty() {
        return Err(format!("{} span(s) never closed: {:?}", open.len(), open));
    }
    Ok(())
}

/// Aggregate for one span kind in a [`Summary`].
#[derive(Debug, Clone, Default)]
pub struct KindStat {
    /// Spans (or points) of this kind.
    pub count: u64,
    /// Summed duration in microseconds (0 for points).
    pub total_us: u64,
    /// Longest single span in microseconds.
    pub max_us: u64,
}

/// What `abhsf trace FILE` prints: per-kind totals, the slowest spans,
/// the cache-claim outcome breakdown, and one example query's span
/// chain.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    /// Total parsed events.
    pub events: usize,
    /// Completed spans.
    pub spans: u64,
    /// Point events.
    pub points: u64,
    /// Per-kind aggregates, name-ordered.
    pub kinds: BTreeMap<String, KindStat>,
    /// Slowest spans as `(kind, id, duration_us)`, longest first.
    pub slowest: Vec<(String, u64, u64)>,
    /// `cache_claim` outcome tag → count.
    pub claim_outcomes: BTreeMap<String, u64>,
    /// Indented `kind [tags]` lines for the most diverse query subtree.
    pub chain: Vec<String>,
}

/// Number of slowest spans a [`Summary`] retains.
pub const SLOWEST_KEPT: usize = 10;

/// Build a [`Summary`] from parsed events (tolerant of unmatched spans;
/// run [`check`] first to reject malformed traces).
pub fn summarize(events: &[TraceEvent]) -> Summary {
    struct Node {
        kind: String,
        tags: Vec<(String, String)>,
        begin_us: u64,
        dur_us: Option<u64>,
        children: Vec<u64>,
        is_point: bool,
    }
    let mut nodes: BTreeMap<u64, Node> = BTreeMap::new();
    let mut roots: Vec<u64> = Vec::new();
    let mut summary = Summary {
        events: events.len(),
        ..Summary::default()
    };
    for e in events {
        match e.ev {
            Ev::Begin | Ev::Point => {
                nodes.insert(
                    e.id,
                    Node {
                        kind: e.kind.clone(),
                        tags: e.tags.clone(),
                        begin_us: e.t_us,
                        dur_us: if e.ev == Ev::Point { Some(0) } else { None },
                        children: Vec::new(),
                        is_point: e.ev == Ev::Point,
                    },
                );
                if e.parent == 0 || !nodes.contains_key(&e.parent) {
                    roots.push(e.id);
                } else if let Some(p) = nodes.get_mut(&e.parent) {
                    p.children.push(e.id);
                }
                if e.ev == Ev::Point {
                    summary.points += 1;
                    let stat = summary.kinds.entry(e.kind.clone()).or_default();
                    stat.count += 1;
                    if e.kind == "cache_claim" {
                        if let Some((_, outcome)) = e.tags.iter().find(|(k, _)| k == "outcome") {
                            *summary.claim_outcomes.entry(outcome.clone()).or_default() += 1;
                        }
                    }
                }
            }
            Ev::End => {
                if let Some(n) = nodes.get_mut(&e.id) {
                    if n.dur_us.is_none() {
                        let dur = e.t_us.saturating_sub(n.begin_us);
                        n.dur_us = Some(dur);
                        summary.spans += 1;
                        let stat = summary.kinds.entry(n.kind.clone()).or_default();
                        stat.count += 1;
                        stat.total_us += dur;
                        stat.max_us = stat.max_us.max(dur);
                        summary.slowest.push((n.kind.clone(), e.id, dur));
                    }
                }
            }
        }
    }
    summary.slowest.sort_by(|a, b| b.2.cmp(&a.2));
    summary.slowest.truncate(SLOWEST_KEPT);

    // Example chain: the query span whose subtree covers the most
    // distinct kinds (ties → the earlier one).
    fn collect(
        nodes: &BTreeMap<u64, Node>,
        id: u64,
        depth: usize,
        kinds: &mut std::collections::BTreeSet<String>,
        lines: &mut Vec<String>,
    ) {
        let Some(n) = nodes.get(&id) else { return };
        kinds.insert(n.kind.clone());
        let mut line = format!("{}{}", "  ".repeat(depth), n.kind);
        if !n.tags.is_empty() {
            let rendered: Vec<String> = n.tags.iter().map(|(k, v)| format!("{k}={v}")).collect();
            let _ = write!(line, " [{}]", rendered.join(" "));
        }
        if let (Some(d), false) = (n.dur_us, n.is_point) {
            let _ = write!(line, " ({d} us)");
        }
        lines.push(line);
        for &c in &n.children {
            collect(nodes, c, depth + 1, kinds, lines);
        }
    }
    let mut best: Option<(usize, Vec<String>)> = None;
    for (&id, n) in &nodes {
        if n.kind != "query" {
            continue;
        }
        let mut kinds = std::collections::BTreeSet::new();
        let mut lines = Vec::new();
        collect(&nodes, id, 0, &mut kinds, &mut lines);
        let score = kinds.len();
        if best.as_ref().is_none_or(|(s, _)| score > *s) {
            best = Some((score, lines));
        }
    }
    if let Some((_, lines)) = best {
        summary.chain = lines;
    }
    summary
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "trace: {} events, {} spans, {} points",
            self.events, self.spans, self.points
        )?;
        writeln!(
            f,
            "{:<16} {:>8} {:>12} {:>10}",
            "kind", "count", "total_ms", "max_ms"
        )?;
        for (kind, stat) in &self.kinds {
            writeln!(
                f,
                "{:<16} {:>8} {:>12.3} {:>10.3}",
                kind,
                stat.count,
                stat.total_us as f64 / 1e3,
                stat.max_us as f64 / 1e3
            )?;
        }
        if !self.claim_outcomes.is_empty() {
            let parts: Vec<String> = self
                .claim_outcomes
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            writeln!(f, "cache_claim outcomes: {}", parts.join(" "))?;
        }
        if !self.slowest.is_empty() {
            writeln!(f, "slowest spans:")?;
            for (kind, id, dur) in &self.slowest {
                writeln!(f, "  {:>10.3} ms  {kind} (id {id})", *dur as f64 / 1e3)?;
            }
        }
        if !self.chain.is_empty() {
            writeln!(f, "example query chain:")?;
            for line in &self.chain {
                writeln!(f, "  {line}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_begin_point_end() {
        let e = parse_line(
            r#"{"ev":"b","id":3,"parent":1,"kind":"vfs_read","t_us":120,"bytes":4096,"ds":"values"}"#,
        )
        .unwrap();
        assert_eq!(e.ev, Ev::Begin);
        assert_eq!((e.id, e.parent, e.t_us), (3, 1, 120));
        assert_eq!(e.kind, "vfs_read");
        assert_eq!(
            e.tags,
            vec![
                ("bytes".to_string(), "4096".to_string()),
                ("ds".to_string(), "values".to_string())
            ]
        );
        let p = parse_line(
            r#"{"ev":"p","id":4,"parent":3,"kind":"cache_claim","t_us":125,"outcome":"hit_t1"}"#,
        )
        .unwrap();
        assert_eq!(p.ev, Ev::Point);
        let end = parse_line(r#"{"ev":"e","id":3,"t_us":300}"#).unwrap();
        assert_eq!(end.ev, Ev::End);
        assert_eq!(end.parent, 0);
        assert!(end.kind.is_empty());
    }

    #[test]
    fn parse_rejects_malformed_lines() {
        for bad in [
            "",
            "not json",
            r#"{"ev":"x","id":1,"kind":"q","t_us":1}"#, // unknown ev
            r#"{"ev":"b","id":0,"kind":"q","t_us":1}"#, // reserved id
            r#"{"ev":"b","id":1,"t_us":1}"#,            // begin without kind
            r#"{"ev":"b","id":1,"kind":"q"}"#,          // missing t_us
            r#"{"ev":"b","id":1,"kind":"q","t_us":1}x"#, // trailing bytes
        ] {
            assert!(parse_line(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("plain_id"), "plain_id");
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        let e = parse_line(&format!(
            "{{\"ev\":\"p\",\"id\":1,\"parent\":0,\"kind\":\"k\",\"t_us\":0,\"v\":\"{}\"}}",
            escape("a\"b\\c")
        ))
        .unwrap();
        assert_eq!(e.tags[0].1, "a\"b\\c");
    }

    fn parse_all(lines: &[&str]) -> Vec<TraceEvent> {
        lines.iter().map(|l| parse_line(l).unwrap()).collect()
    }

    #[test]
    fn check_accepts_wellformed_nested_trace() {
        let events = parse_all(&[
            r#"{"ev":"b","id":1,"parent":0,"kind":"query","t_us":0}"#,
            r#"{"ev":"b","id":2,"parent":1,"kind":"vfs_read","t_us":5}"#,
            r#"{"ev":"p","id":3,"parent":1,"kind":"cache_claim","t_us":6,"outcome":"miss"}"#,
            r#"{"ev":"e","id":2,"t_us":9}"#,
            r#"{"ev":"e","id":1,"t_us":10}"#,
        ]);
        check(&events).unwrap();
    }

    #[test]
    fn check_rejects_structural_defects() {
        // Unclosed span.
        let e = parse_all(&[r#"{"ev":"b","id":1,"parent":0,"kind":"q","t_us":0}"#]);
        assert!(check(&e).unwrap_err().contains("never closed"));
        // Duplicate id.
        let e = parse_all(&[
            r#"{"ev":"b","id":1,"parent":0,"kind":"q","t_us":0}"#,
            r#"{"ev":"b","id":1,"parent":0,"kind":"q","t_us":1}"#,
        ]);
        assert!(check(&e).unwrap_err().contains("duplicate id"));
        // Dangling parent.
        let e = parse_all(&[r#"{"ev":"b","id":2,"parent":9,"kind":"q","t_us":0}"#]);
        assert!(check(&e).unwrap_err().contains("not begun earlier"));
        // End without begin.
        let e = parse_all(&[r#"{"ev":"e","id":7,"t_us":1}"#]);
        assert!(check(&e).unwrap_err().contains("unknown span"));
        // Double end.
        let e = parse_all(&[
            r#"{"ev":"b","id":1,"parent":0,"kind":"q","t_us":0}"#,
            r#"{"ev":"e","id":1,"t_us":1}"#,
            r#"{"ev":"e","id":1,"t_us":2}"#,
        ]);
        assert!(check(&e).unwrap_err().contains("ended twice"));
        // End before begin time.
        let e = parse_all(&[
            r#"{"ev":"b","id":1,"parent":0,"kind":"q","t_us":10}"#,
            r#"{"ev":"e","id":1,"t_us":4}"#,
        ]);
        assert!(check(&e).unwrap_err().contains("before its begin"));
    }

    #[test]
    fn summarize_totals_slowest_and_chain() {
        let events = parse_all(&[
            r#"{"ev":"b","id":1,"parent":0,"kind":"query","t_us":0,"kq":"rect"}"#,
            r#"{"ev":"p","id":2,"parent":1,"kind":"cache_claim","t_us":1,"outcome":"miss"}"#,
            r#"{"ev":"b","id":3,"parent":1,"kind":"vfs_read","t_us":2,"bytes":100}"#,
            r#"{"ev":"b","id":4,"parent":3,"kind":"net_rpc","t_us":3,"op":"read_at"}"#,
            r#"{"ev":"e","id":4,"t_us":33}"#,
            r#"{"ev":"e","id":3,"t_us":40}"#,
            r#"{"ev":"e","id":1,"t_us":50}"#,
            r#"{"ev":"b","id":5,"parent":0,"kind":"query","t_us":60}"#,
            r#"{"ev":"p","id":6,"parent":5,"kind":"cache_claim","t_us":61,"outcome":"hit_t1"}"#,
            r#"{"ev":"e","id":5,"t_us":62}"#,
        ]);
        check(&events).unwrap();
        let s = summarize(&events);
        assert_eq!((s.events, s.spans, s.points), (10, 4, 2));
        assert_eq!(s.kinds["query"].count, 2);
        assert_eq!(s.kinds["query"].total_us, 52);
        assert_eq!(s.kinds["query"].max_us, 50);
        assert_eq!(s.kinds["net_rpc"].total_us, 30);
        assert_eq!(s.claim_outcomes["miss"], 1);
        assert_eq!(s.claim_outcomes["hit_t1"], 1);
        assert_eq!(s.slowest[0], ("query".to_string(), 1, 50));
        // The richer query (id 1) wins the example chain: its subtree
        // holds query → cache_claim + vfs_read → net_rpc.
        let chain = s.chain.join("\n");
        assert!(chain.contains("query"), "{chain}");
        assert!(chain.contains("  cache_claim [outcome=miss]"), "{chain}");
        assert!(chain.contains("  vfs_read"), "{chain}");
        assert!(chain.contains("    net_rpc [op=read_at]"), "{chain}");
        let rendered = s.to_string();
        assert!(rendered.contains("trace: 10 events"), "{rendered}");
        assert!(rendered.contains("cache_claim outcomes:"), "{rendered}");
        assert!(rendered.contains("slowest spans:"), "{rendered}");
        assert!(rendered.contains("example query chain:"), "{rendered}");
    }

    // The global-tracer end-to-end test lives in `rust/tests/obs.rs`:
    // enabling the process-wide tracer from a unit test would race other
    // lib tests (cache claims, serve loops) emitting into the same sink,
    // so it needs a process of its own.
}
