//! Parallel file system / MPI-IO cost simulator.
//!
//! The paper measures loading times on a Lustre file system (Anselm,
//! IT4Innovations) at 256 GB per process — a scale and a hardware stack we
//! cannot touch here, so the benches combine **real local-FS wall times**
//! with a **calibrated analytic cost model** that extrapolates the same
//! I/O traces (opens / read ops / bytes per rank, unique bytes per file)
//! to the cluster's regime. Figure 1's *shape* comes from three effects
//! the model captures:
//!
//! 1. **Same configuration**: every rank reads only its own file once;
//!    the back-end storage moves `total_bytes` from disk exactly once, so
//!    the makespan is dominated by aggregate disk bandwidth.
//! 2. **Different configuration, independent I/O**: every rank reads
//!    *all* files. Each byte still leaves the *disks* only once (server
//!    page cache serves re-reads), but it crosses the *network* once per
//!    reader, and every rank is client-bandwidth-bound on `total_bytes` —
//!    hence times sit well above the same-config case yet are nearly flat
//!    in the number of readers and far below `T_same × P` (the figure's
//!    observation), until the interconnect saturates.
//! 3. **Collective I/O**: each read becomes a synchronizing collective
//!    with two-phase aggregation — per-op barrier latency scaling with
//!    `log₂ P` plus redistribution traffic — which the paper observed to
//!    be considerably slower than independent reads for this all-read-all
//!    pattern.
//!
//! [`model::FsModel::anselm_lustre`] carries literature-typical constants
//! for a ~2013 Bullx/Lustre system; they set the *scale* of the simulated
//! seconds, while the ordering/flatness conclusions are robust across wide
//! parameter ranges (see `benches/fig1_loading.rs` sensitivity sweep).

pub mod model;

pub use model::{FsModel, IoStrategy, RankLoadProfile, SimReport};
