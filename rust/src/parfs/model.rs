//! Analytic parallel-I/O cost model (see module docs in [`crate::parfs`]).

/// HDF5 parallel I/O strategy (paper §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IoStrategy {
    /// Each process issues reads on its own (`H5FD_MPIO_INDEPENDENT`).
    Independent,
    /// Every read is a synchronizing collective with two-phase
    /// aggregation (`H5FD_MPIO_COLLECTIVE`).
    Collective,
}

impl IoStrategy {
    /// Label for tables.
    pub fn label(self) -> &'static str {
        match self {
            IoStrategy::Independent => "independent",
            IoStrategy::Collective => "collective",
        }
    }
}

/// Cost model constants of the simulated parallel file system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FsModel {
    /// Aggregate back-end (disk/OST) bandwidth, bytes/s. Each distinct
    /// byte leaves the disks once; re-reads hit server caches.
    pub disk_agg_bps: f64,
    /// Aggregate interconnect bandwidth between storage servers and
    /// compute nodes, bytes/s.
    pub net_agg_bps: f64,
    /// Per-client (per-process) achievable read bandwidth, bytes/s.
    pub client_bps: f64,
    /// Latency of one file open (metadata server round trip), s.
    pub open_lat_s: f64,
    /// Latency of one read operation (RPC + seek), s.
    pub op_lat_s: f64,
    /// One barrier hop latency; a P-process barrier costs
    /// `barrier_lat_s * log2(P)`, s.
    pub barrier_lat_s: f64,
    /// Extra traffic factor of two-phase collective I/O (aggregate +
    /// redistribute), ≥ 1.
    pub collective_traffic_factor: f64,
}

impl FsModel {
    /// Constants representative of the paper's testbed class: Anselm
    /// (Bullx, 2013) — Lustre over Infiniband QDR. ~6 GB/s aggregate
    /// back-end, ~40 GB/s fabric, ~1 GB/s per client, millisecond-scale
    /// metadata ops.
    pub fn anselm_lustre() -> Self {
        Self {
            disk_agg_bps: 6.0e9,
            net_agg_bps: 100.0e9,
            client_bps: 1.0e9,
            open_lat_s: 2.0e-3,
            op_lat_s: 3.0e-4,
            barrier_lat_s: 5.0e-6,
            collective_traffic_factor: 2.0,
        }
    }

    /// A single local NVMe-class disk (for sanity checks against the
    /// wall-clock measurements this repo actually performs).
    pub fn local_nvme() -> Self {
        Self {
            disk_agg_bps: 3.0e9,
            net_agg_bps: 1.0e12, // no network
            client_bps: 3.0e9,
            open_lat_s: 2.0e-5,
            op_lat_s: 5.0e-6,
            barrier_lat_s: 1.0e-6,
            collective_traffic_factor: 2.0,
        }
    }
}

/// The I/O footprint of one loading rank, extracted from real
/// [`crate::h5::IoStats`] traces.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RankLoadProfile {
    /// Files opened by this rank.
    pub opens: u64,
    /// Read operations issued by this rank.
    pub ops: u64,
    /// Bytes transferred to this rank.
    pub bytes: u64,
}

/// Simulated timing outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Per-rank completion times, s.
    pub per_rank_s: Vec<f64>,
    /// Simulated makespan (load completes when the slowest rank does), s.
    pub makespan_s: f64,
    /// Back-end disk drain time component, s.
    pub disk_s: f64,
    /// Synchronization overhead component (collective only), s.
    pub sync_s: f64,
}

impl FsModel {
    /// Simulate a parallel load.
    ///
    /// * `profiles` — per loading-rank I/O footprints (length = P readers);
    /// * `unique_bytes` — total distinct file bytes touched by the whole
    ///   job (each leaves the disks once regardless of reader count);
    /// * `strategy` — independent or collective.
    pub fn simulate(
        &self,
        profiles: &[RankLoadProfile],
        unique_bytes: u64,
        strategy: IoStrategy,
    ) -> SimReport {
        assert!(!profiles.is_empty(), "no rank profiles");
        let p = profiles.len() as f64;
        let disk_s = unique_bytes as f64 / self.disk_agg_bps;
        let traffic_factor = match strategy {
            IoStrategy::Independent => 1.0,
            IoStrategy::Collective => self.collective_traffic_factor,
        };
        // Network: every rank's bytes cross the fabric; the fabric is
        // shared by all ranks.
        let total_traffic: f64 =
            profiles.iter().map(|r| r.bytes as f64).sum::<f64>() * traffic_factor;
        let net_shared_s = total_traffic / self.net_agg_bps;

        let mut per_rank_s = Vec::with_capacity(profiles.len());
        let mut sync_total = 0.0;
        for r in profiles {
            let lat_s = r.opens as f64 * self.open_lat_s + r.ops as f64 * self.op_lat_s;
            let client_s = r.bytes as f64 * traffic_factor / self.client_bps;
            let sync_s = match strategy {
                IoStrategy::Independent => 0.0,
                // Every op is a collective: all ranks synchronize.
                IoStrategy::Collective => {
                    r.ops as f64 * self.barrier_lat_s * p.log2().max(1.0)
                }
            };
            sync_total += sync_s;
            // A rank finishes no sooner than its own serial latency+stream
            // time; shared resources (disk drain, fabric) bound everyone.
            per_rank_s.push(lat_s + sync_s + client_s.max(net_shared_s));
        }
        let slowest = per_rank_s.iter().cloned().fold(0.0, f64::max);
        let makespan_s = slowest.max(disk_s);
        SimReport {
            per_rank_s,
            makespan_s,
            disk_s,
            sync_s: sync_total / p,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build the paper's three scenarios over a synthetic footprint and
    /// check Figure 1's qualitative shape.
    fn scenario(model: &FsModel) -> (f64, Vec<f64>, Vec<f64>) {
        let total_bytes: u64 = 60 * 4 * 1024 * 1024 * 1024; // 240 GiB
        let p_store = 60usize;
        let per_file = total_bytes / p_store as u64;
        let ops_per_file = per_file / (1 << 20); // 1 MiB chunks

        // Same configuration: rank k reads only file k.
        let same: Vec<RankLoadProfile> = (0..p_store)
            .map(|_| RankLoadProfile {
                opens: 1,
                ops: ops_per_file,
                bytes: per_file,
            })
            .collect();
        let t_same = model
            .simulate(&same, total_bytes, IoStrategy::Independent)
            .makespan_s;

        let loaders = [15usize, 20, 30, 40, 60];
        let mut indep = Vec::new();
        let mut coll = Vec::new();
        for &pl in &loaders {
            let all: Vec<RankLoadProfile> = (0..pl)
                .map(|_| RankLoadProfile {
                    opens: p_store as u64,
                    ops: ops_per_file * p_store as u64,
                    bytes: total_bytes,
                })
                .collect();
            indep.push(
                model
                    .simulate(&all, total_bytes, IoStrategy::Independent)
                    .makespan_s,
            );
            coll.push(
                model
                    .simulate(&all, total_bytes, IoStrategy::Collective)
                    .makespan_s,
            );
        }
        (t_same, indep, coll)
    }

    #[test]
    fn figure1_shape_same_config_fastest() {
        let m = FsModel::anselm_lustre();
        let (t_same, indep, coll) = scenario(&m);
        for (&ti, &tc) in indep.iter().zip(&coll) {
            assert!(t_same < ti, "same {t_same} !< indep {ti}");
            assert!(ti < tc, "indep {ti} !< collective {tc}");
        }
    }

    #[test]
    fn figure1_shape_indep_flat_and_below_p_times_same() {
        let m = FsModel::anselm_lustre();
        let (t_same, indep, _) = scenario(&m);
        let min = indep.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = indep.iter().cloned().fold(0.0, f64::max);
        assert!(
            max / min < 1.5,
            "independent times not ~flat: {indep:?}"
        );
        // Well below the proportional-data bound T_same * P for P >= 15.
        assert!(
            max < t_same * 15.0 * 0.8,
            "indep {max} not well below T_same*P = {}",
            t_same * 15.0
        );
    }

    #[test]
    fn collective_grows_with_readers() {
        let m = FsModel::anselm_lustre();
        let (_, _, coll) = scenario(&m);
        assert!(
            coll.last().unwrap() > coll.first().unwrap(),
            "collective should worsen with P: {coll:?}"
        );
    }

    #[test]
    fn disk_bound_when_aggregate_is_bottleneck() {
        let mut m = FsModel::anselm_lustre();
        m.disk_agg_bps = 1e8; // cripple the disks
        let profiles = vec![
            RankLoadProfile {
                opens: 1,
                ops: 10,
                bytes: 1 << 30
            };
            4
        ];
        let rep = m.simulate(&profiles, 4 << 30, IoStrategy::Independent);
        assert!((rep.makespan_s - rep.disk_s).abs() < 1e-9);
    }

    #[test]
    fn latency_terms_counted() {
        let m = FsModel::anselm_lustre();
        let a = m.simulate(
            &[RankLoadProfile {
                opens: 1,
                ops: 0,
                bytes: 0,
            }],
            0,
            IoStrategy::Independent,
        );
        let b = m.simulate(
            &[RankLoadProfile {
                opens: 100,
                ops: 1000,
                bytes: 0,
            }],
            0,
            IoStrategy::Independent,
        );
        assert!(b.makespan_s > a.makespan_s);
        assert!((b.makespan_s - (100.0 * m.open_lat_s + 1000.0 * m.op_lat_s)).abs() < 1e-9);
    }

    #[test]
    fn ordering_robust_across_parameters() {
        // The figure-1 ordering must not be an artifact of one parameter
        // choice: sweep disk/net/client bandwidths over wide ranges.
        for disk in [2.0e9, 6.0e9, 20.0e9] {
            for net in [20.0e9, 40.0e9, 100.0e9] {
                for client in [0.5e9, 1.0e9, 2.0e9] {
                    let m = FsModel {
                        disk_agg_bps: disk,
                        net_agg_bps: net,
                        client_bps: client,
                        ..FsModel::anselm_lustre()
                    };
                    let (t_same, indep, coll) = scenario(&m);
                    for (&ti, &tc) in indep.iter().zip(&coll) {
                        assert!(t_same < ti && ti < tc,
                            "ordering broken at disk={disk} net={net} client={client}: {t_same} {ti} {tc}");
                    }
                }
            }
        }
    }
}
