//! Parfs cost prediction: repack-then-load vs repeated direct
//! different-configuration loads.
//!
//! A repack pays once — a pruned all-read-all over the source containers
//! plus writing the new ones — and every later load of the new dataset
//! takes the same-configuration fast path (rank `k` reads only its own
//! file). A direct different-configuration load pays its (cost-model
//! cheapest) §4 strategy *every time*. The forecast predicts all three
//! figures from the manifest's file sizes and reports the break-even load
//! count, the number the CLI and DESIGN.md §8 quote when recommending one
//! route over the other.

use crate::coordinator::dataset::{ops_estimate, Dataset};
use crate::coordinator::Strategy;
use crate::mapping::ProcessMapping;
use crate::parfs::{FsModel, IoStrategy, RankLoadProfile};

/// Predicted economics of repacking a dataset to a new configuration.
#[derive(Debug, Clone)]
pub struct RepackForecast {
    /// Predicted makespan of one direct different-configuration load
    /// (cheapest §4 candidate), s.
    pub direct_load_s: f64,
    /// The strategy behind [`RepackForecast::direct_load_s`].
    pub direct_strategy: Strategy,
    /// Predicted makespan of the repack itself (pruned read + re-encoded
    /// write), s.
    pub repack_s: f64,
    /// Predicted makespan of one same-configuration load of the repacked
    /// dataset, s.
    pub post_repack_load_s: f64,
    /// Smallest number of loads after which `repack + k × post` beats
    /// `k × direct`; `None` when direct loads are predicted no slower
    /// than post-repack loads (repacking never pays off).
    pub break_even_loads: Option<u64>,
}

impl RepackForecast {
    /// Whether repacking is predicted cheaper over `loads` future loads.
    pub fn prefers_repack(&self, loads: u64) -> bool {
        self.break_even_loads.is_some_and(|k| loads >= k)
    }
}

/// Build the forecast for repacking `dataset` to `p` target processes
/// under `mapping` (`None` degrades pruning estimates to whole-matrix
/// overlap, exactly like [`Dataset::predict_load`]).
pub(crate) fn forecast(
    dataset: &Dataset,
    p: usize,
    mapping: Option<&dyn ProcessMapping>,
    prune: bool,
    model: &FsModel,
) -> RepackForecast {
    let candidates = dataset.predict_load(p, model, mapping, prune);
    let (direct_strategy, direct_load_s) = candidates
        .iter()
        .copied()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .expect("predict_load returns candidates");

    // Repack read phase = the pruned independent all-read-all figure (the
    // repack reader is exactly that loop, minus CSR assembly).
    let read_s = candidates
        .iter()
        .find(|(s, _)| *s == Strategy::Independent)
        .map(|(_, t)| *t)
        .unwrap_or(direct_load_s);

    // Write phase and post-repack loads: assume the re-encoded containers
    // total roughly the source bytes (scheme selection minimizes both
    // sides; block-size changes move the total by far less than the
    // P × re-read factor the forecast is discriminating).
    let unique = dataset.manifest().total_bytes();
    let per_file = unique / p.max(1) as u64;
    let one_file_each: Vec<RankLoadProfile> = (0..p)
        .map(|_| RankLoadProfile {
            opens: 1,
            ops: ops_estimate(per_file),
            bytes: per_file,
        })
        .collect();
    let write_s = model
        .simulate(&one_file_each, unique, IoStrategy::Independent)
        .makespan_s;
    let post_repack_load_s = write_s; // same footprint, read direction
    let repack_s = read_s + write_s;

    let break_even_loads = (direct_load_s > post_repack_load_s).then(|| {
        let gain = direct_load_s - post_repack_load_s;
        (repack_s / gain).ceil().max(1.0) as u64
    });
    RepackForecast {
        direct_load_s,
        direct_strategy,
        repack_s,
        post_repack_load_s,
        break_even_loads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{Colwise, CyclicRows};

    /// Figure-1-scale manifest shared by the forecast tests: 8 × 1 GiB
    /// files, 400 M nonzeros.
    fn big_dataset() -> Dataset {
        Dataset::synthetic_for_tests(
            8,
            1 << 20,
            1 << 20,
            8 * 50_000_000,
            64,
            1 << 30,
            50_000_000,
        )
    }

    /// An *irregular* target mapping cannot prune its direct loads (every
    /// load re-reads everything), so the repack amortizes in finitely
    /// many loads and the post-repack fast path is the cheapest figure.
    #[test]
    fn break_even_exists_for_irregular_targets() {
        let model = FsModel::anselm_lustre();
        let p = 16;
        let cyclic = CyclicRows {
            m: 1 << 20,
            n: 1 << 20,
            p,
        };
        let f = forecast(&big_dataset(), p, Some(&cyclic), true, &model);
        assert!(f.post_repack_load_s < f.direct_load_s, "{f:?}");
        assert!(f.repack_s > f.post_repack_load_s, "{f:?}");
        let k = f.break_even_loads.expect("repack must amortize");
        assert!(k >= 1, "{f:?}");
        assert!(!f.prefers_repack(k.saturating_sub(1)));
        assert!(f.prefers_repack(k));
        // Sanity: at the break-even count the totals actually cross.
        let repack_route = f.repack_s + k as f64 * f.post_repack_load_s;
        let direct_route = k as f64 * f.direct_load_s;
        assert!(repack_route <= direct_route + 1e-9, "{f:?}");
    }

    /// A rectangular target that prunes perfectly makes direct loads
    /// ~disk-bound already — the forecast then honestly reports that
    /// repacking never pays off (no break-even) instead of inventing one.
    #[test]
    fn no_break_even_when_pruned_direct_is_disk_bound() {
        let model = FsModel::anselm_lustre();
        let p = 16;
        let colwise = Colwise::regular(1 << 20, 1 << 20, p);
        let f = forecast(&big_dataset(), p, Some(&colwise), true, &model);
        // Pruned direct loads and post-repack loads both drain the same
        // unique bytes; direct cannot be meaningfully slower.
        assert!(f.direct_load_s <= f.post_repack_load_s * 1.5, "{f:?}");
        if f.direct_load_s <= f.post_repack_load_s {
            assert!(f.break_even_loads.is_none(), "{f:?}");
            assert!(!f.prefers_repack(u64::MAX));
        }
    }
}
