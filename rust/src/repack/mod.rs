//! Out-of-core dataset repacking: stream-transcode a stored ABHSF
//! dataset to a new process count, mapping and block size — the write
//! side of the paper's "configurations differ" story.
//!
//! PR 1/2 made stored datasets *readable* under any configuration; this
//! subsystem makes them *migratable*: `dataset.repack()` returns a
//! [`RepackPlan`] builder (mirroring [`crate::coordinator::LoadPlan`])
//! that re-materializes the dataset under a new configuration **without
//! ever holding the full matrix in one memory**:
//!
//! 1. **Pruned read.** Each *target* rank streams only the source blocks
//!    intersecting its region through
//!    [`visit_elements_pruned`](crate::abhsf::visit_elements_pruned)
//!    (the Algorithm 3–6 slice decoders behind it), exactly the
//!    block-pruned §3 loop of the load path — `RepackReport` carries the
//!    same skip counters.
//! 2. **Re-bucket.** Surviving elements land in a bounded-memory
//!    [`Rebucketer`](crate::abhsf::Rebucketer): spill-free single-buffer
//!    staging when the target mapping is rectangular (the rank's
//!    resident set is bounded by its own
//!    [`rank_rect`](crate::mapping::ProcessMapping::rank_rect), never by
//!    the total nonzero count), chunked sorted-run accumulation for
//!    irregular mappings.
//! 3. **Re-encode + write.** The merged stream is partitioned into the
//!    *new* `s × s` grid, per-block scheme selection reruns from scratch
//!    (COO/CSR/bitmap/dense byte minimization — the optimum depends on
//!    the block geometry, so a re-partition *requires* re-selection),
//!    and each rank writes a fresh `matrix-<k>.h5spm` plus the leader a
//!    new `dataset.json`, through the same storer/`H5Writer` path
//!    `Dataset::store` uses.
//!
//! [`RepackForecast`] (via [`RepackPlan::forecast`]) prices the
//! operation against repeated direct different-configuration loads with
//! the [`crate::parfs`] model; see DESIGN.md §8 for when the break-even
//! favors repacking.

mod forecast;
mod report;

pub use forecast::RepackForecast;
pub use report::{PhaseStats, RepackReport};

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::abhsf::cost::CostModel;
use crate::abhsf::store::store_data_chunked_on;
use crate::abhsf::{
    matrix_file_path, rebucket_into_abhsf, visit_elements, visit_elements_pruned, Rebucketer,
};
use crate::coordinator::cluster::Cluster;
use crate::coordinator::dataset::Dataset;
use crate::coordinator::error::DatasetError;
use crate::coordinator::metrics::StoreReport;
use crate::formats::element::window_or_tight;
use crate::h5::{H5Reader, IoStats};
use crate::mapping::{MappingDesc, ProcessMapping};
use crate::parfs::FsModel;
use crate::vfs::Storage;

/// Default staging-chunk size (elements) for irregular target mappings —
/// bounds the unsorted working set of the re-bucketer at ~1.5 MiB per
/// rank.
pub const DEFAULT_STAGING_CHUNK: usize = 64 * 1024;

/// Builder for one repack of a [`Dataset`]: target process count,
/// mapping, block size and container chunking, validated as a whole by
/// [`RepackPlan::run`]. Obtained from [`Dataset::repack`].
#[derive(Clone)]
pub struct RepackPlan<'d> {
    dataset: &'d Dataset,
    nprocs: Option<usize>,
    mapping: Option<Arc<dyn ProcessMapping>>,
    block_size: Option<u64>,
    chunk_elems: u64,
    cost_model: CostModel,
    prune: bool,
    staging_chunk: Option<usize>,
    model: FsModel,
    out_storage: Option<Arc<dyn Storage>>,
}

impl Dataset {
    /// Begin planning a repack of this dataset to a new configuration.
    pub fn repack(&self) -> RepackPlan<'_> {
        RepackPlan {
            dataset: self,
            nprocs: None,
            mapping: None,
            block_size: None,
            chunk_elems: crate::h5::DEFAULT_CHUNK_ELEMS,
            cost_model: CostModel::default(),
            prune: true,
            staging_chunk: None,
            model: FsModel::anselm_lustre(),
            out_storage: None,
        }
    }
}

impl<'d> RepackPlan<'d> {
    /// Target process count (defaults to the cluster's size at
    /// [`RepackPlan::run`]).
    pub fn nprocs(mut self, p: usize) -> Self {
        self.nprocs = Some(p);
        self
    }

    /// Target mapping `M(i, j)`. Optional when repacking with the stored
    /// process count: the stored mapping is reused (a block-size-only
    /// repack).
    pub fn mapping(mut self, mapping: &Arc<dyn ProcessMapping>) -> Self {
        self.mapping = Some(Arc::clone(mapping));
        self
    }

    /// Target ABHSF block size `s` (defaults to the stored one).
    pub fn block_size(mut self, s: u64) -> Self {
        self.block_size = Some(s);
        self
    }

    /// Container dataset chunk size for the written files (elements).
    pub fn chunk_elems(mut self, elems: u64) -> Self {
        self.chunk_elems = elems;
        self
    }

    /// Scheme-selection cost model for the re-encoded blocks.
    pub fn cost_model(mut self, model: CostModel) -> Self {
        self.cost_model = model;
        self
    }

    /// Block-pruned reading of the source containers (default `true`);
    /// `false` restores the decode-everything loop (A/B measurements).
    pub fn prune(mut self, prune: bool) -> Self {
        self.prune = prune;
        self
    }

    /// Override the staging-chunk size (elements) of the re-bucketer.
    /// `0` forces spill-free single-buffer staging. The default is
    /// automatic: spill-free for rectangular target mappings,
    /// [`DEFAULT_STAGING_CHUNK`] for irregular ones.
    pub fn staging_chunk(mut self, elems: usize) -> Self {
        self.staging_chunk = Some(elems);
        self
    }

    /// File-system model used by [`RepackPlan::forecast`].
    pub fn fs_model(mut self, model: FsModel) -> Self {
        self.model = model;
        self
    }

    /// Storage backend the repacked dataset is written to (default: the
    /// source dataset's backend). Reads always go through the source
    /// backend, so a repack can migrate a dataset *between* media — e.g.
    /// stage an in-memory dataset out to disk.
    pub fn storage(mut self, storage: Arc<dyn Storage>) -> Self {
        self.out_storage = Some(storage);
        self
    }

    /// Price this repack against repeated direct different-configuration
    /// loads under the plan's [`FsModel`] (no I/O happens).
    pub fn forecast(&self) -> RepackForecast {
        let p = self
            .nprocs
            .or_else(|| self.mapping.as_ref().map(|m| m.nprocs()))
            .unwrap_or_else(|| self.dataset.nprocs());
        forecast::forecast(
            self.dataset,
            p,
            self.mapping.as_deref(),
            self.prune,
            &self.model,
        )
    }

    /// Validate the plan, stream-transcode the dataset into `out_dir`
    /// (one fresh container per target rank plus a new manifest), and
    /// return the new dataset handle with the per-phase report.
    pub fn run(
        &self,
        cluster: &Cluster,
        out_dir: impl AsRef<Path>,
    ) -> Result<(Dataset, RepackReport), DatasetError> {
        let out_dir = out_dir.as_ref();
        let p = self.nprocs.unwrap_or_else(|| cluster.nprocs());
        if cluster.nprocs() != p {
            return Err(DatasetError::ClusterMismatch {
                cluster: cluster.nprocs(),
                required: p,
                what: "the plan's target process count",
            });
        }
        if let Some(mapping) = &self.mapping {
            if mapping.nprocs() != p {
                return Err(DatasetError::MappingMismatch {
                    mapping: mapping.nprocs(),
                    nprocs: p,
                });
            }
        }
        let block_size = self.block_size.unwrap_or_else(|| self.dataset.block_size());
        if block_size == 0 || block_size > u16::MAX as u64 + 1 {
            return Err(DatasetError::InvalidBlockSize(block_size));
        }
        // A zero chunk size would otherwise only surface as an H5Writer
        // panic inside a worker, after the whole read phase was paid.
        if self.chunk_elems == 0 {
            return Err(DatasetError::InvalidChunkSize);
        }
        let mapping = self.resolve_mapping(p)?;
        let stored = self.dataset.nprocs();
        self.dataset.verify_files()?;
        let src_storage = Arc::clone(self.dataset.storage());
        let out_storage = self
            .out_storage
            .clone()
            .unwrap_or_else(|| Arc::clone(&src_storage));
        out_storage.create_dir_all(out_dir)?;
        // Refuse to clobber the containers being read: same backing
        // medium and same canonical directory. Both directories exist by
        // now, so LocalFs canonicalization is exact (symlinks included);
        // writing the same path on a *different* medium is a migration,
        // not a clobber.
        if out_storage.medium() == src_storage.medium()
            && out_storage.canonical(out_dir) == src_storage.canonical(self.dataset.dir())
        {
            return Err(DatasetError::RepackIntoSource {
                dir: out_dir.to_path_buf(),
            });
        }
        let staging_chunk = self.staging_chunk.unwrap_or_else(|| {
            if mapping.is_rectangular() {
                0
            } else {
                DEFAULT_STAGING_CHUNK
            }
        });

        let src = self.dataset.dir().to_path_buf();
        let dst = out_dir.to_path_buf();
        let (m, n) = self.dataset.dims();
        let z = self.dataset.nnz();
        let prune = self.prune;
        let cost_model = self.cost_model.clone();
        let cost_table = cost_model.table_id();
        let chunk_elems = self.chunk_elems;
        let map = Arc::clone(&mapping);
        let src_fs = Arc::clone(&src_storage);
        let out_fs = Arc::clone(&out_storage);

        type RankOut = anyhow::Result<RankRepack>;
        let t0 = Instant::now();
        let results: Vec<RankOut> = cluster.run(move |ctx| {
            let rank = ctx.rank;
            let map = map.as_ref();
            // Phase 1: pruned streaming read of every source container.
            let t_read = Instant::now();
            let mut read_io = IoStats::default();
            let mut bucket = Rebucketer::new(staging_chunk);
            for file in 0..stored {
                let reader = H5Reader::open_on(src_fs.as_ref(), matrix_file_path(&src, file))?;
                if prune {
                    let ps = visit_elements_pruned(
                        &reader,
                        |r0, c0, rows, cols| map.intersects(rank, (r0, c0, rows, cols)),
                        |i, j, v| {
                            if map.owner(i, j) == rank {
                                bucket.push(i, j, v);
                            }
                        },
                    )?;
                    read_io.blocks_total += ps.blocks_total;
                    read_io.blocks_skipped += ps.blocks_skipped;
                    read_io.bytes_skipped += ps.bytes_skipped;
                } else {
                    visit_elements(&reader, |i, j, v| {
                        if map.owner(i, j) == rank {
                            bucket.push(i, j, v);
                        }
                    })?;
                }
                read_io.add(reader.stats());
            }
            let read_s = t_read.elapsed().as_secs_f64();

            // Phase 2: merge the staged runs and re-encode into the new
            // block grid with fresh scheme selection.
            let t_encode = Instant::now();
            let peak_staging = bucket.len();
            let peak_unsorted = bucket.peak_unsorted();
            let elems = bucket.into_sorted_global();
            // Whole-matrix declarations (irregular mappings) tighten to
            // the owned bounding box, as the storer does (paper §2).
            let window = window_or_tight(map.window(rank), m, n, &elems);
            let data =
                rebucket_into_abhsf(elems, window, (m, n, z), block_size, &cost_model)?;
            let mut scheme_counts = [0u64; 4];
            for &tag in &data.schemes {
                scheme_counts[tag as usize] += 1;
            }
            let encode_s = t_encode.elapsed().as_secs_f64();

            // Phase 3: write this rank's fresh container.
            let t_write = Instant::now();
            let nnz = data.info.z_local;
            let payload_bytes = data.payload_bytes();
            let write_io = store_data_chunked_on(
                out_fs.as_ref(),
                matrix_file_path(&dst, rank),
                &data,
                chunk_elems,
            )?;
            Ok(RankRepack {
                read_io,
                write_io,
                read_s,
                encode_s,
                write_s: t_write.elapsed().as_secs_f64(),
                nnz,
                payload_bytes,
                peak_staging,
                peak_unsorted,
                scheme_counts,
            })
        });

        let mut read = PhaseStats::default();
        let mut write = PhaseStats::default();
        let mut per_rank_encode_s = Vec::with_capacity(p);
        let mut per_rank_nnz = Vec::with_capacity(p);
        let mut per_rank_bytes = Vec::with_capacity(p);
        let mut per_rank_peak_staging = Vec::with_capacity(p);
        let mut per_rank_peak_unsorted = Vec::with_capacity(p);
        let mut scheme_counts = [0u64; 4];
        for r in results {
            let r = r.map_err(DatasetError::from)?;
            read.per_rank_io.push(r.read_io);
            read.per_rank_s.push(r.read_s);
            write.per_rank_io.push(r.write_io);
            write.per_rank_s.push(r.write_s);
            per_rank_encode_s.push(r.encode_s);
            per_rank_nnz.push(r.nnz);
            per_rank_bytes.push(r.payload_bytes);
            per_rank_peak_staging.push(r.peak_staging);
            per_rank_peak_unsorted.push(r.peak_unsorted);
            for (acc, c) in scheme_counts.iter_mut().zip(r.scheme_counts) {
                *acc += c;
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();

        // The new manifest: target mapping descriptor, target block size,
        // per-file sizes scanned from the freshly written containers.
        let store_report = StoreReport {
            wall_s,
            per_rank_io: write.per_rank_io.clone(),
            per_rank_nnz: per_rank_nnz.clone(),
            per_rank_bytes,
        };
        let new_dataset = Dataset::write_manifest(
            out_storage,
            out_dir,
            mapping.descriptor(),
            m,
            n,
            &store_report,
            block_size,
            cost_table,
        )?;

        let report = RepackReport {
            source_nprocs: stored,
            nprocs: p,
            block_size,
            pruned: self.prune,
            wall_s,
            read,
            write,
            per_rank_encode_s,
            per_rank_nnz,
            per_rank_peak_staging,
            per_rank_peak_unsorted,
            scheme_counts,
        };
        Ok((new_dataset, report))
    }

    /// The target mapping: the explicit one, or the stored mapping
    /// rebuilt from its descriptor when repacking with the stored process
    /// count (block-size-only repacks).
    fn resolve_mapping(&self, p: usize) -> Result<Arc<dyn ProcessMapping>, DatasetError> {
        if let Some(mapping) = &self.mapping {
            return Ok(Arc::clone(mapping));
        }
        let stored = self.dataset.nprocs();
        if p != stored {
            return Err(DatasetError::MappingRequired { nprocs: p, stored });
        }
        self.dataset.mapping().build().ok_or_else(|| {
            DatasetError::MappingNotReconstructible {
                label: match self.dataset.mapping() {
                    MappingDesc::Opaque { label, .. } => label.clone(),
                    other => other.kind().to_string(),
                },
            }
        })
    }
}

/// One target rank's repack outcome (worker → leader).
struct RankRepack {
    read_io: IoStats,
    write_io: IoStats,
    read_s: f64,
    encode_s: f64,
    write_s: f64,
    nnz: u64,
    payload_bytes: u64,
    peak_staging: u64,
    peak_unsorted: u64,
    scheme_counts: [u64; 4],
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    use crate::coordinator::{InMemFormat, LoadedMatrix, StoreOptions, Strategy};
    use crate::gen::{KroneckerGen, SeedMatrix};
    use crate::mapping::{Block2d, Colwise, CyclicRows, Rowwise};

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("abhsf-repack-tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn setup(name: &str, p_store: usize, s: u64) -> (PathBuf, Arc<KroneckerGen>, u64, Dataset) {
        let gen = Arc::new(KroneckerGen::new(SeedMatrix::cage_like(8, 42), 2));
        let n = gen.dim();
        let mapping: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, p_store));
        let cluster = Cluster::new(p_store, 64);
        let dir = tmpdir(name);
        let (dataset, _) = Dataset::store(
            &cluster,
            &gen,
            &mapping,
            &dir,
            StoreOptions {
                block_size: s,
                chunk_elems: 1024,
                ..Default::default()
            },
        )
        .unwrap();
        (dir, gen, n, dataset)
    }

    fn collect(mats: Vec<LoadedMatrix>) -> Vec<(u64, u64, f64)> {
        let mut out = Vec::new();
        for lm in mats {
            let coo = lm.into_coo();
            let (ro, co) = (coo.info.m_offset, coo.info.n_offset);
            for (i, j, v) in coo.iter() {
                out.push((i + ro, j + co, v));
            }
        }
        out.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        out
    }

    /// The acceptance scenario: Rowwise P=4 → Block2d P=6 with a new
    /// block size. All three load strategies (plus the same-config fast
    /// path) read the repacked dataset back element-identically, the
    /// pruned read phase skips blocks, and no rank ever staged more than
    /// its own region (peak < total nnz).
    #[test]
    fn acceptance_rowwise4_to_block2d6() {
        let (dir, gen, n, dataset) = setup("accept", 4, 8);
        let truth = {
            let cluster = Cluster::new(4, 64);
            let (mats, _) = dataset
                .load()
                .format(InMemFormat::Coo)
                .run(&cluster)
                .unwrap();
            collect(mats)
        };
        assert_eq!(truth.len() as u64, gen.nnz());

        let p_new = 6;
        let new_map: Arc<dyn ProcessMapping> = Arc::new(Block2d::regular(n, n, 2, 3));
        let out = tmpdir("accept-out");
        let cluster = Cluster::new(p_new, 64);
        let (repacked, report) = dataset
            .repack()
            .nprocs(p_new)
            .mapping(&new_map)
            .block_size(16)
            .chunk_elems(512)
            .run(&cluster, &out)
            .unwrap();

        // Report invariants.
        assert_eq!(report.nprocs, 6);
        assert_eq!(report.source_nprocs, 4);
        assert_eq!(report.block_size, 16);
        assert_eq!(report.total_nnz(), gen.nnz());
        assert!(report.blocks_skipped() > 0, "pruned read skipped nothing");
        assert!(report.bytes_skipped() > 0);
        assert!(report.prune_ratio().unwrap() > 0.0);
        assert!(
            report.max_peak_staging() < gen.nnz(),
            "a rank staged the whole matrix: {} of {}",
            report.max_peak_staging(),
            gen.nnz()
        );
        let max_rank_nnz = report.per_rank_nnz.iter().copied().max().unwrap();
        assert_eq!(report.max_peak_staging(), max_rank_nnz);
        assert!(report.blocks_written() > 0);
        assert_eq!(report.write.total_opens(), p_new as u64);

        // Manifest invariants: self-describing under the new config, and
        // the per-file nnz sum to the original.
        assert_eq!(repacked.nprocs(), p_new);
        assert_eq!(repacked.block_size(), 16);
        assert_eq!(repacked.dims(), (n, n));
        let manifest_nnz: u64 = repacked.manifest().files.iter().map(|f| f.nnz).sum();
        assert_eq!(manifest_nnz, gen.nnz());
        assert!(repacked
            .mapping()
            .same_mapping(&new_map.descriptor()));

        // Reopen from disk: the new dataset must be fully self-describing.
        let reopened = Dataset::open(&out).unwrap();
        assert_eq!(reopened.manifest(), repacked.manifest());

        // Same-config fast path on the new layout.
        let same_cluster = Cluster::new(p_new, 64);
        let (mats, lreport) = reopened
            .load()
            .format(InMemFormat::Csr)
            .run(&same_cluster)
            .unwrap();
        assert_eq!(lreport.scenario, "same-config");
        assert_eq!(collect(mats), truth, "same-config diverged after repack");

        // All three explicit strategies under yet another configuration.
        let p_load = 5;
        let load_map: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, p_load));
        let load_cluster = Cluster::new(p_load, 8);
        for strategy in [Strategy::Independent, Strategy::Collective, Strategy::Exchange] {
            let (mats, _) = reopened
                .load()
                .mapping(&load_map)
                .strategy(strategy)
                .format(InMemFormat::Csr)
                .run(&load_cluster)
                .unwrap();
            assert_eq!(collect(mats), truth, "{strategy} diverged after repack");
        }
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&out);
    }

    /// Block-size-only repack: same process count, no explicit mapping —
    /// the stored mapping is rebuilt from the manifest.
    #[test]
    fn reblock_without_mapping_reuses_stored() {
        let (dir, gen, _n, dataset) = setup("reblock", 3, 8);
        let out = tmpdir("reblock-out");
        let cluster = Cluster::new(3, 64);
        let (repacked, report) = dataset
            .repack()
            .block_size(32)
            .run(&cluster, &out)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        assert_eq!(repacked.block_size(), 32);
        assert_eq!(repacked.nprocs(), 3);
        assert!(repacked.mapping().same_mapping(dataset.mapping()));
        // Content identical.
        let (a, _) = dataset
            .load()
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        let (b, _) = Dataset::open(&out)
            .unwrap()
            .load()
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        assert_eq!(collect(a), collect(b));
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&out);
    }

    /// Irregular target mapping (CyclicRows): chunked staging kicks in
    /// (bounded unsorted working set), pruning degrades to a no-op
    /// conservatively, and content survives.
    #[test]
    fn irregular_mapping_repacks_with_chunked_staging() {
        let (dir, gen, n, dataset) = setup("cyclic", 4, 8);
        let p_new = 3;
        let new_map: Arc<dyn ProcessMapping> = Arc::new(CyclicRows { m: n, n, p: p_new });
        let out = tmpdir("cyclic-out");
        let cluster = Cluster::new(p_new, 64);
        let (repacked, report) = dataset
            .repack()
            .nprocs(p_new)
            .mapping(&new_map)
            .staging_chunk(64)
            .run(&cluster, &out)
            .unwrap();
        assert_eq!(report.total_nnz(), gen.nnz());
        // Conservative pruning: every block intersects (keep-all).
        assert_eq!(report.blocks_skipped(), 0);
        // The falsifiable staging bound: the unsorted working set never
        // exceeded the requested chunk, even though every rank's
        // resident share is far larger.
        assert!(
            report.max_peak_unsorted() <= 64,
            "unsorted staging {} exceeded the 64-element chunk",
            report.max_peak_unsorted()
        );
        assert!(report.max_peak_staging() > 64);
        let (mats, _) = Dataset::open(&out)
            .unwrap()
            .load()
            .format(InMemFormat::Coo)
            .run(&cluster)
            .unwrap();
        let orig_cluster = Cluster::new(4, 64);
        let (orig, _) = dataset
            .load()
            .format(InMemFormat::Coo)
            .run(&orig_cluster)
            .unwrap();
        assert_eq!(collect(mats), collect(orig));
        assert_eq!(repacked.nprocs(), p_new);
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&out);
    }

    /// Typed validation: repacking into the source directory, block sizes
    /// out of range, missing mapping for a different process count.
    #[test]
    fn plan_validation_is_typed() {
        let (dir, _gen, n, dataset) = setup("validate", 2, 8);
        let cluster = Cluster::new(2, 64);
        let err = dataset.repack().run(&cluster, &dir).unwrap_err();
        assert!(matches!(err, DatasetError::RepackIntoSource { .. }), "{err}");

        let out = tmpdir("validate-out");
        let err = dataset
            .repack()
            .block_size(0)
            .run(&cluster, &out)
            .unwrap_err();
        assert!(matches!(err, DatasetError::InvalidBlockSize(0)), "{err}");

        let err = dataset
            .repack()
            .chunk_elems(0)
            .run(&cluster, &out)
            .unwrap_err();
        assert!(matches!(err, DatasetError::InvalidChunkSize), "{err}");

        let cluster5 = Cluster::new(5, 64);
        let err = dataset
            .repack()
            .nprocs(5)
            .run(&cluster5, &out)
            .unwrap_err();
        assert!(matches!(err, DatasetError::MappingRequired { .. }), "{err}");

        let wrong: Arc<dyn ProcessMapping> = Arc::new(Rowwise::regular(n, n, 3));
        let err = dataset
            .repack()
            .nprocs(5)
            .mapping(&wrong)
            .run(&cluster5, &out)
            .unwrap_err();
        assert!(matches!(err, DatasetError::MappingMismatch { .. }), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&out);
    }

    /// The forecast is reachable from the plan and self-consistent.
    #[test]
    fn plan_forecast_is_consistent() {
        let (dir, _gen, n, dataset) = setup("forecast", 4, 8);
        let new_map: Arc<dyn ProcessMapping> = Arc::new(Colwise::regular(n, n, 6));
        let f = dataset.repack().nprocs(6).mapping(&new_map).forecast();
        assert!(f.repack_s > 0.0);
        assert!(f.direct_load_s > 0.0);
        assert!(f.post_repack_load_s > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
