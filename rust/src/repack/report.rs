//! Outcome report of one dataset repack, phase by phase.

use crate::abhsf::Scheme;
use crate::h5::IoStats;
use crate::parfs::{FsModel, IoStrategy, RankLoadProfile, SimReport};

/// One phase's per-rank I/O traces and wall times (the read phase carries
/// the prune counters in its [`IoStats`]; the write phase the fresh
/// container writes).
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    /// Per-rank I/O counters for this phase.
    pub per_rank_io: Vec<IoStats>,
    /// Per-rank wall times of this phase, s.
    pub per_rank_s: Vec<f64>,
}

impl PhaseStats {
    /// Total bytes transferred in this phase.
    pub fn total_bytes(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.bytes).sum()
    }

    /// Total file opens in this phase.
    pub fn total_opens(&self) -> u64 {
        self.per_rank_io.iter().map(|s| s.opens).sum()
    }

    /// Slowest rank's wall time, s.
    pub fn max_s(&self) -> f64 {
        self.per_rank_s.iter().cloned().fold(0.0, f64::max)
    }

    /// Bridge into the [`crate::parfs`] cost model (independent I/O —
    /// repack phases never synchronize per-operation).
    pub fn simulate(&self, model: &FsModel, unique_bytes: u64) -> SimReport {
        let profiles: Vec<RankLoadProfile> = self
            .per_rank_io
            .iter()
            .map(|s| RankLoadProfile {
                opens: s.opens,
                ops: s.ops,
                bytes: s.bytes,
            })
            .collect();
        model.simulate(&profiles, unique_bytes, IoStrategy::Independent)
    }
}

/// Outcome of one [`crate::repack::RepackPlan::run`]: the per-phase I/O
/// traces (pruned read, re-encoded write), staging-memory evidence, and
/// the scheme re-selection histogram of the new containers.
#[derive(Debug, Clone)]
pub struct RepackReport {
    /// Source (stored) process count.
    pub source_nprocs: usize,
    /// Target process count (= files written).
    pub nprocs: usize,
    /// Target ABHSF block size.
    pub block_size: u64,
    /// Whether the read phase went through the block-pruned decoder.
    pub pruned: bool,
    /// Wall time of the whole repack (leader-observed), s.
    pub wall_s: f64,
    /// Read phase: pruned streaming of the source containers
    /// (`blocks_total` / `blocks_skipped` / `bytes_skipped` live in its
    /// [`IoStats`]).
    pub read: PhaseStats,
    /// Write phase: fresh containers through the storer.
    pub write: PhaseStats,
    /// Per-rank re-encode (re-bucket + scheme selection) times, s.
    pub per_rank_encode_s: Vec<f64>,
    /// Per-rank nonzeros written.
    pub per_rank_nnz: Vec<u64>,
    /// Per-rank peak staging set (elements resident at once). By
    /// construction of the per-rank owner filter this equals the rank's
    /// own nonzero count — recorded as bookkeeping evidence that no rank
    /// ever stages the whole matrix.
    pub per_rank_peak_staging: Vec<u64>,
    /// Per-rank peak *unsorted* working set of the re-bucketer — the
    /// falsifiable staging bound: in chunked mode it must never exceed
    /// the plan's `staging_chunk` (asserted by the differential
    /// harness); in spill-free mode it equals the resident set.
    pub per_rank_peak_unsorted: Vec<u64>,
    /// Blocks written per scheme, indexed by [`Scheme`] tag — the
    /// re-selection outcome over the new block geometry.
    pub scheme_counts: [u64; 4],
}

impl RepackReport {
    /// Total nonzeros written (must equal the source dataset's).
    pub fn total_nnz(&self) -> u64 {
        self.per_rank_nnz.iter().sum()
    }

    /// Source blocks examined across all ranks (pruned reads only).
    pub fn blocks_total(&self) -> u64 {
        self.read.per_rank_io.iter().map(|s| s.blocks_total).sum()
    }

    /// Source blocks skipped without fetching their payload.
    pub fn blocks_skipped(&self) -> u64 {
        self.read.per_rank_io.iter().map(|s| s.blocks_skipped).sum()
    }

    /// Payload bytes of the skipped source blocks.
    pub fn bytes_skipped(&self) -> u64 {
        self.read.per_rank_io.iter().map(|s| s.bytes_skipped).sum()
    }

    /// Fraction of examined source blocks that were skipped; `None` for
    /// unpruned repacks.
    pub fn prune_ratio(&self) -> Option<f64> {
        let total = self.blocks_total();
        (total > 0).then(|| self.blocks_skipped() as f64 / total as f64)
    }

    /// Largest per-rank staging set (elements) — the quantity the
    /// bounded-memory claim is about.
    pub fn max_peak_staging(&self) -> u64 {
        self.per_rank_peak_staging.iter().copied().max().unwrap_or(0)
    }

    /// Largest per-rank unsorted working set (elements); ≤ the plan's
    /// `staging_chunk` whenever chunked staging was in effect.
    pub fn max_peak_unsorted(&self) -> u64 {
        self.per_rank_peak_unsorted.iter().copied().max().unwrap_or(0)
    }

    /// Blocks written into the new containers.
    pub fn blocks_written(&self) -> u64 {
        self.scheme_counts.iter().sum()
    }

    /// Human-readable scheme histogram (`COO a, CSR b, …`).
    pub fn scheme_summary(&self) -> String {
        Scheme::ALL
            .iter()
            .map(|&s| format!("{} {}", s.name(), self.scheme_counts[s as u8 as usize]))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> RepackReport {
        RepackReport {
            source_nprocs: 4,
            nprocs: 6,
            block_size: 16,
            pruned: true,
            wall_s: 0.25,
            read: PhaseStats {
                per_rank_io: vec![
                    IoStats {
                        bytes: 4000,
                        ops: 12,
                        opens: 4,
                        blocks_total: 10,
                        blocks_skipped: 6,
                        bytes_skipped: 900,
                        ..IoStats::default()
                    };
                    6
                ],
                per_rank_s: vec![0.1; 6],
            },
            write: PhaseStats {
                per_rank_io: vec![
                    IoStats {
                        bytes: 700,
                        ops: 3,
                        opens: 1,
                        ..IoStats::default()
                    };
                    6
                ],
                per_rank_s: vec![0.05; 6],
            },
            per_rank_encode_s: vec![0.01; 6],
            per_rank_nnz: vec![10, 20, 30, 5, 15, 20],
            per_rank_peak_staging: vec![10, 20, 30, 5, 15, 20],
            per_rank_peak_unsorted: vec![8, 8, 8, 5, 8, 8],
            scheme_counts: [3, 1, 2, 4],
        }
    }

    #[test]
    fn aggregates() {
        let r = dummy();
        assert_eq!(r.total_nnz(), 100);
        assert_eq!(r.blocks_total(), 60);
        assert_eq!(r.blocks_skipped(), 36);
        assert_eq!(r.bytes_skipped(), 5400);
        assert_eq!(r.prune_ratio(), Some(0.6));
        assert_eq!(r.max_peak_staging(), 30);
        assert_eq!(r.max_peak_unsorted(), 8);
        assert_eq!(r.blocks_written(), 10);
        assert_eq!(r.read.total_bytes(), 24000);
        assert_eq!(r.write.total_bytes(), 4200);
        assert_eq!(r.write.total_opens(), 6);
        assert!(r.scheme_summary().contains("bitmap 2"), "{}", r.scheme_summary());
    }

    #[test]
    fn phase_simulation_runs() {
        let r = dummy();
        let model = FsModel::anselm_lustre();
        let sim = r.read.simulate(&model, 24000);
        assert!(sim.makespan_s > 0.0);
        assert_eq!(sim.per_rank_s.len(), 6);
    }
}
