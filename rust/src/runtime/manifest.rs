//! Artifact manifest (`artifacts/manifest.json`) parsing.

use std::path::{Path, PathBuf};

use crate::runtime::{Result, RuntimeError};
use crate::util::json::Json;

/// One tensor's declared dtype+shape in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Logical name (`blocks`, `cols`, `x`, …).
    pub name: String,
    /// `"f32"` or `"i32"`.
    pub dtype: String,
    /// Dimensions.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    /// Unique name (e.g. `spmv_r32_k8_s16_n512`).
    pub name: String,
    /// Kind: `spmv`, `power_step` or `assemble`.
    pub kind: String,
    /// HLO text file name within the artifact directory.
    pub file: String,
    /// Input tensor specs, in execution order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
    /// Named integer parameters (r, k, s, n / z, t, s).
    pub params: std::collections::BTreeMap<String, u64>,
}

impl Artifact {
    /// Integer parameter accessor.
    pub fn param(&self, name: &str) -> Result<u64> {
        self.params
            .get(name)
            .copied()
            .ok_or_else(|| RuntimeError::Artifact(format!("{}: missing param {name}", self.name)))
    }
}

/// The parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory containing the artifacts.
    pub dir: PathBuf,
    /// All artifacts.
    pub artifacts: Vec<Artifact>,
}

fn tensor_specs(j: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| RuntimeError::Artifact(format!("{what} is not an array")))?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| RuntimeError::Artifact(format!("{what}: missing name")))?
                    .to_string(),
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .ok_or_else(|| RuntimeError::Artifact(format!("{what}: missing dtype")))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| RuntimeError::Artifact(format!("{what}: missing shape")))?
                    .iter()
                    .map(|d| {
                        d.as_u64()
                            .map(|x| x as usize)
                            .ok_or_else(|| RuntimeError::Artifact(format!("{what}: bad dim")))
                    })
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            RuntimeError::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let root = Json::parse(&text)
            .map_err(|e| RuntimeError::Artifact(format!("manifest parse error: {e}")))?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| RuntimeError::Artifact("manifest: no artifacts[]".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let mut params = std::collections::BTreeMap::new();
            if let Some(Json::Obj(p)) = a.get("params") {
                for (k, v) in p {
                    if let Some(x) = v.as_u64() {
                        params.insert(k.clone(), x);
                    }
                }
            }
            artifacts.push(Artifact {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| RuntimeError::Artifact("artifact: missing name".into()))?
                    .to_string(),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| RuntimeError::Artifact("artifact: missing file".into()))?
                    .to_string(),
                inputs: tensor_specs(
                    a.get("inputs").unwrap_or(&Json::Arr(vec![])),
                    "inputs",
                )?,
                outputs: tensor_specs(
                    a.get("outputs").unwrap_or(&Json::Arr(vec![])),
                    "outputs",
                )?,
                params,
            });
        }
        Ok(Self { dir, artifacts })
    }

    /// Look up an artifact by name.
    pub fn find(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| RuntimeError::Artifact(format!("no artifact named {name}")))
    }

    /// All artifacts of a kind.
    pub fn of_kind(&self, kind: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, art: &Artifact) -> PathBuf {
        self.dir.join(&art.file)
    }

    /// The default artifact directory: `$ABHSF_ARTIFACTS` or `artifacts/`
    /// next to the current directory.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ABHSF_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("abhsf-manifest-test");
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","artifacts":[
              {"name":"spmv_a","kind":"spmv","file":"a.hlo.txt",
               "inputs":[{"name":"x","dtype":"f32","shape":[8]}],
               "outputs":[{"name":"y","dtype":"f32","shape":[8]}],
               "params":{"r":2,"k":2,"s":4,"n":8}}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("spmv_a").unwrap();
        assert_eq!(a.kind, "spmv");
        assert_eq!(a.param("r").unwrap(), 2);
        assert!(a.param("zzz").is_err());
        assert_eq!(a.inputs[0].elems(), 8);
        assert_eq!(m.of_kind("spmv").len(), 1);
        assert!(m.find("nope").is_err());
        assert_eq!(m.path_of(a), dir.join("a.hlo.txt"));
    }

    #[test]
    fn missing_manifest_is_informative() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // Exercise against the repo's actual artifacts when present.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            assert!(!m.of_kind("spmv").is_empty());
            for a in &m.artifacts {
                assert!(m.path_of(a).exists(), "{} missing", a.file);
            }
        }
    }
}
