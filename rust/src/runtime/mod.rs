//! PJRT runtime bridge: load AOT artifacts (`artifacts/*.hlo.txt`,
//! produced once at build time by `python/compile/aot.py`) and execute
//! them from the Rust request path — Python is never invoked here.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (the pinned xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-id protos; the text parser reassigns ids).
//!
//! The engine needs the external `xla` crate plus a native xla_extension
//! install, so it is gated behind the `pjrt` cargo feature. Default
//! builds get [`stub::Runtime`]: the same public surface whose
//! constructors return [`RuntimeError::Unavailable`], which every
//! consumer already treats as "skip the PJRT cross-check" (see
//! DESIGN.md §5).

pub mod manifest;
pub mod pack;
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use manifest::{Artifact, Manifest};
pub use pack::BlockedTensors;
#[cfg(feature = "pjrt")]
pub use runtime::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Errors from artifact loading/execution.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    /// XLA/PJRT failure.
    #[error("xla error: {0}")]
    Xla(String),
    /// Manifest/artifact problems.
    #[error("artifact error: {0}")]
    Artifact(String),
    /// A matrix does not fit the artifact's static shapes.
    #[error("shape mismatch: {0}")]
    Shape(String),
    /// The engine was not compiled in (built without the `pjrt` feature).
    #[error("pjrt runtime unavailable: {0}")]
    Unavailable(String),
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// Result alias.
pub type Result<T> = std::result::Result<T, RuntimeError>;
