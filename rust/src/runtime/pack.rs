//! Packing loaded CSR submatrices into the blocked tensors the AOT
//! artifacts consume (`blocks f32[R,K,s,s]`, `cols i32[R,K]`, `x f32[n]`).

use std::collections::BTreeMap;

use crate::formats::Csr;
use crate::runtime::manifest::Artifact;
use crate::runtime::{Result, RuntimeError};

/// Host-side blocked tensors matching one `spmv`/`power_step` artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockedTensors {
    /// Block rows R.
    pub r: usize,
    /// Blocks per row K.
    pub k: usize,
    /// Block size s.
    pub s: usize,
    /// Vector length n.
    pub n: usize,
    /// `R*K*s*s` f32, row-major `[R, K, s, s]`.
    pub blocks: Vec<f32>,
    /// `R*K` i32, `[R, K]` (padding slots point at block-column 0 with
    /// zero blocks).
    pub cols: Vec<i32>,
    /// Blocks actually used per row (diagnostics).
    pub used_per_row: Vec<usize>,
}

impl BlockedTensors {
    /// Pack a local CSR submatrix into the static shapes of `art`.
    ///
    /// Requirements (checked):
    /// * `m_local ≤ R*s` — the rows fit;
    /// * `n_offset + n_local ≤ n` and `n % s == 0` — columns fit the
    ///   vector; block-column indexes are *global* so SpMV against the
    ///   full-length `x` is correct for any window;
    /// * every block row holds at most K distinct nonzero blocks.
    pub fn pack_csr(csr: &Csr, art: &Artifact) -> Result<Self> {
        let r = art.param("r")? as usize;
        let k = art.param("k")? as usize;
        let s = art.param("s")? as usize;
        let n = art.param("n")? as usize;
        if n % s != 0 {
            return Err(RuntimeError::Shape(format!("artifact n={n} not a multiple of s={s}")));
        }
        if csr.info.m_local as usize > r * s {
            return Err(RuntimeError::Shape(format!(
                "m_local={} exceeds artifact capacity R*s={}",
                csr.info.m_local,
                r * s
            )));
        }
        if (csr.info.n_offset + csr.info.n_local) as usize > n {
            return Err(RuntimeError::Shape(format!(
                "column window end {} exceeds artifact n={n}",
                csr.info.n_offset + csr.info.n_local
            )));
        }
        let mut blocks = vec![0f32; r * k * s * s];
        let mut cols = vec![0i32; r * k];
        let mut used_per_row = vec![0usize; r];
        // Map: block row -> (global block col -> slot index).
        let mut slot_of: Vec<BTreeMap<usize, usize>> = vec![BTreeMap::new(); r];
        let co = csr.info.n_offset as usize;
        for lr in 0..csr.info.m_local as usize {
            let br = lr / s;
            let (lo, hi) = csr.row_range(lr);
            for e in lo..hi {
                let gc = co + csr.colinds[e] as usize; // global column
                let bc = gc / s;
                let next = used_per_row[br];
                let slot = match slot_of[br].get(&bc) {
                    Some(&slot) => slot,
                    None => {
                        if next >= k {
                            return Err(RuntimeError::Shape(format!(
                                "block row {br} needs more than K={k} blocks"
                            )));
                        }
                        slot_of[br].insert(bc, next);
                        cols[br * k + next] = bc as i32;
                        used_per_row[br] = next + 1;
                        next
                    }
                };
                let base = ((br * k) + slot) * s * s;
                blocks[base + (lr % s) * s + (gc % s)] = csr.vals[e] as f32;
            }
        }
        Ok(Self {
            r,
            k,
            s,
            n,
            blocks,
            cols,
            used_per_row,
        })
    }

    /// Pad/convert a global x vector (f64, length ≥ logical n) to the
    /// artifact's f32 `[n]` input.
    pub fn pack_x(&self, x: &[f64]) -> Result<Vec<f32>> {
        if x.len() > self.n {
            return Err(RuntimeError::Shape(format!(
                "x length {} exceeds artifact n={}",
                x.len(),
                self.n
            )));
        }
        let mut out = vec![0f32; self.n];
        for (o, &v) in out.iter_mut().zip(x.iter()) {
            *o = v as f32;
        }
        Ok(out)
    }

    /// Total VMEM footprint of one grid step of the corresponding kernel,
    /// in bytes (see DESIGN.md §Perf): K·s² blocks + x + y segment.
    pub fn vmem_per_grid_step(&self) -> usize {
        (self.k * self.s * self.s + self.n + self.s) * 4 + self.k * 4
    }

    /// MXU utilization proxy: fraction of loaded block slots that are
    /// real (non-padding) blocks.
    pub fn slot_utilization(&self) -> f64 {
        let used: usize = self.used_per_row.iter().sum();
        used as f64 / (self.r * self.k) as f64
    }
}

/// Native oracle of the artifact computation: y = blocks · x over the
/// packed representation (f32 math, mirroring the kernel).
pub fn blocked_spmv_native(t: &BlockedTensors, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), t.n);
    let (r, k, s) = (t.r, t.k, t.s);
    let mut y = vec![0f32; r * s];
    for br in 0..r {
        for slot in 0..k {
            let bc = t.cols[br * k + slot] as usize;
            let base = ((br * k) + slot) * s * s;
            for i in 0..s {
                let mut acc = 0f32;
                for j in 0..s {
                    acc += t.blocks[base + i * s + j] * x[bc * s + j];
                }
                y[br * s + i] += acc;
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{Coo, LocalInfo};
    use crate::runtime::manifest::Artifact;
    use crate::util::rng::Xoshiro256;

    fn art(r: u64, k: u64, s: u64, n: u64) -> Artifact {
        let mut params = std::collections::BTreeMap::new();
        params.insert("r".into(), r);
        params.insert("k".into(), k);
        params.insert("s".into(), s);
        params.insert("n".into(), n);
        Artifact {
            name: "test".into(),
            kind: "spmv".into(),
            file: "test.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
            params,
        }
    }

    fn random_csr(seed: u64, m: u64, n: u64, nnz: usize, offs: (u64, u64), dims: (u64, u64)) -> Csr {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let info = LocalInfo {
            m: dims.0,
            n: dims.1,
            z: nnz as u64,
            m_local: m,
            n_local: n,
            z_local: 0,
            m_offset: offs.0,
            n_offset: offs.1,
        };
        let mut coo = Coo::with_info(info);
        let mut seen = std::collections::HashSet::new();
        while coo.nnz() < nnz {
            let r = rng.next_below(m);
            let c = rng.next_below(n);
            if seen.insert((r, c)) {
                coo.push(r, c, rng.range_f64(-2.0, 2.0));
            }
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn pack_and_native_spmv_matches_csr() {
        let csr = random_csr(5, 32, 64, 300, (0, 0), (32, 64));
        let a = art(8, 16, 4, 64);
        let t = BlockedTensors::pack_csr(&csr, &a).unwrap();
        let x64: Vec<f64> = (0..64).map(|i| (i as f64) * 0.1 - 3.0).collect();
        let xf = t.pack_x(&x64).unwrap();
        let y = blocked_spmv_native(&t, &xf);
        // Oracle through the f64 CSR path.
        let mut want = vec![0.0f64; 32];
        csr.spmv_into(&x64, &mut want);
        for (i, (&g, &w)) in y.iter().zip(want.iter()).enumerate() {
            assert!((g as f64 - w).abs() < 1e-3, "row {i}: {g} vs {w}");
        }
    }

    #[test]
    fn pack_respects_column_offsets() {
        // Window with n_offset != 0: global block-col indexes must be used.
        let csr = random_csr(7, 16, 16, 60, (0, 16), (16, 32));
        let a = art(4, 8, 4, 32);
        let t = BlockedTensors::pack_csr(&csr, &a).unwrap();
        let x64: Vec<f64> = (0..32).map(|i| 1.0 + i as f64).collect();
        let xf = t.pack_x(&x64).unwrap();
        let y = blocked_spmv_native(&t, &xf);
        let mut want = vec![0.0f64; 16];
        csr.spmv_into(&x64, &mut want);
        for (g, w) in y.iter().zip(&want) {
            assert!((*g as f64 - w).abs() < 1e-3);
        }
    }

    #[test]
    fn rejects_oversized_matrix() {
        let csr = random_csr(1, 40, 16, 100, (0, 0), (40, 16));
        let a = art(4, 8, 4, 16); // capacity 16 rows < 40
        assert!(BlockedTensors::pack_csr(&csr, &a).is_err());
    }

    #[test]
    fn rejects_too_many_blocks_per_row() {
        // Dense row across 16 block columns but K = 2.
        let info = LocalInfo::whole(4, 64, 64);
        let mut coo = Coo::with_info(info);
        for c in 0..64 {
            coo.push(0, c, 1.0);
        }
        let csr = Csr::from_coo(&coo);
        let a = art(1, 2, 4, 64);
        let err = BlockedTensors::pack_csr(&csr, &a).unwrap_err();
        assert!(format!("{err}").contains("more than K"));
    }

    #[test]
    fn diagnostics_sane() {
        let csr = random_csr(9, 16, 16, 64, (0, 0), (16, 16));
        let a = art(4, 4, 4, 16);
        let t = BlockedTensors::pack_csr(&csr, &a).unwrap();
        assert!(t.slot_utilization() > 0.0 && t.slot_utilization() <= 1.0);
        assert!(t.vmem_per_grid_step() > 0);
    }

    #[test]
    fn pack_x_pads_and_rejects() {
        let csr = random_csr(3, 8, 8, 20, (0, 0), (8, 8));
        let t = BlockedTensors::pack_csr(&csr, &art(2, 8, 4, 16)).unwrap();
        let xf = t.pack_x(&[1.0; 8]).unwrap();
        assert_eq!(xf.len(), 16);
        assert_eq!(&xf[8..], &[0f32; 8]);
        assert!(t.pack_x(&[0.0; 17]).is_err());
    }
}
