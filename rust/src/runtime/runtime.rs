//! The PJRT execution engine: compile-once, execute-many.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::formats::Csr;
use crate::runtime::manifest::{Artifact, Manifest};
use crate::runtime::pack::BlockedTensors;
use crate::runtime::{Result, RuntimeError};

/// A PJRT CPU client plus a cache of compiled executables keyed by
/// artifact name. One `Runtime` is shared by all coordinator workers
/// (compilation happens once per artifact; execution is reentrant).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU runtime over the artifact directory.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Create from the default artifact directory (`$ABHSF_ARTIFACTS` or
    /// `./artifacts`).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(Manifest::load(Manifest::default_dir())?)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Borrow the manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let art = self.manifest.find(name)?.clone();
        let path = self.manifest.path_of(&art);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| RuntimeError::Artifact("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact on literal inputs, returning the un-tupled
    /// output literals.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the output is a tuple.
        Ok(result.to_tuple()?)
    }

    /// Run an `spmv` artifact: `y = A @ x` with pre-packed tensors.
    pub fn spmv(&self, art: &Artifact, t: &BlockedTensors, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != t.n {
            return Err(RuntimeError::Shape(format!(
                "x length {} != artifact n {}",
                x.len(),
                t.n
            )));
        }
        let blocks = xla::Literal::vec1(&t.blocks).reshape(&[
            t.r as i64,
            t.k as i64,
            t.s as i64,
            t.s as i64,
        ])?;
        let cols = xla::Literal::vec1(&t.cols).reshape(&[t.r as i64, t.k as i64])?;
        let xs = xla::Literal::vec1(x);
        let out = self.execute(&art.name, &[blocks, cols, xs])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Run a `power_step` artifact: returns `(x_next, norm)`.
    pub fn power_step(
        &self,
        art: &Artifact,
        t: &BlockedTensors,
        x: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        let blocks = xla::Literal::vec1(&t.blocks).reshape(&[
            t.r as i64,
            t.k as i64,
            t.s as i64,
            t.s as i64,
        ])?;
        let cols = xla::Literal::vec1(&t.cols).reshape(&[t.r as i64, t.k as i64])?;
        let xs = xla::Literal::vec1(x);
        let out = self.execute(&art.name, &[blocks, cols, xs])?;
        let x_next = out[0].to_vec::<f32>()?;
        let norm = out[1].to_vec::<f32>()?[0];
        Ok((x_next, norm))
    }

    /// Run an `assemble` artifact on padded triplets.
    pub fn assemble(
        &self,
        art: &Artifact,
        lrows: &[i32],
        lcols: &[i32],
        vals: &[f32],
    ) -> Result<Vec<f32>> {
        let z = art.param("z")? as i64;
        let t = art.param("t")? as i64;
        let lr = xla::Literal::vec1(lrows).reshape(&[z, t])?;
        let lc = xla::Literal::vec1(lcols).reshape(&[z, t])?;
        let vs = xla::Literal::vec1(vals).reshape(&[z, t])?;
        let out = self.execute(&art.name, &[lr, lc, vs])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Pick the smallest `spmv` artifact a CSR packs into, execute it,
    /// and return `y` (length `R*s`, covering rows
    /// `[m_offset, m_offset + R*s)` of the global y).
    ///
    /// This is the end-to-end validation hook the coordinator calls after
    /// a load: the result is compared against the native Rust SpMV.
    pub fn spmv_csr(&self, csr: &Csr, x: &[f64]) -> Result<Vec<f32>> {
        let (art, t) = self.pack_best_spmv(csr)?;
        let xf = t.pack_x(x)?;
        self.spmv(&art, &t, &xf)
    }

    /// Try spmv artifacts in ascending capacity order and return the first
    /// one the matrix actually packs into (dimension *and* blocks-per-row
    /// K constraints).
    pub fn pack_best_spmv(&self, csr: &Csr) -> Result<(Artifact, BlockedTensors)> {
        let mut candidates: Vec<&Artifact> = self
            .manifest
            .of_kind("spmv")
            .into_iter()
            .filter(|a| a.params.contains_key("r"))
            .collect();
        candidates.sort_by_key(|a| {
            a.param("r").unwrap_or(0) * a.param("k").unwrap_or(0) * a.param("s").unwrap_or(0)
                * a.param("s").unwrap_or(0)
        });
        let mut last_err = None;
        for art in candidates {
            match BlockedTensors::pack_csr(csr, art) {
                Ok(t) => return Ok((art.clone(), t)),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| {
            RuntimeError::Shape(format!(
                "no spmv artifact fits m_local={} n={}",
                csr.info.m_local, csr.info.n
            ))
        }))
    }

    /// Choose the smallest-capacity spmv artifact that fits `csr`
    /// (dimensions and K); convenience wrapper over [`Self::pack_best_spmv`].
    pub fn pick_spmv_artifact(&self, csr: &Csr) -> Result<Artifact> {
        Ok(self.pack_best_spmv(csr)?.0)
    }
}
