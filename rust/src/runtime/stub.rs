//! Stub execution engine used when the crate is built without the
//! `pjrt` feature (the default — the `xla` crate and its native
//! xla_extension are not in the offline registry).
//!
//! The public surface mirrors [`crate::runtime::runtime::Runtime`]
//! one-for-one so consumers compile unchanged; both constructors return
//! [`RuntimeError::Unavailable`], which callers already handle as "skip
//! the PJRT cross-check" (examples print a note, `tests/runtime_pjrt.rs`
//! skips). No method on an instance is reachable, because no instance
//! can be constructed.

use std::sync::Arc;

use crate::formats::Csr;
use crate::runtime::manifest::{Artifact, Manifest};
use crate::runtime::pack::BlockedTensors;
use crate::runtime::{Result, RuntimeError};

/// Placeholder for the compiled-executable handle of the real engine.
#[derive(Debug)]
pub struct Executable;

/// The unavailable engine. Constructors always fail; the struct exists
/// only so downstream signatures typecheck without the `pjrt` feature.
pub struct Runtime {
    manifest: Manifest,
}

fn unavailable<T>() -> Result<T> {
    Err(RuntimeError::Unavailable(
        "built without the `pjrt` cargo feature (see DESIGN.md §5)".into(),
    ))
}

impl Runtime {
    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn new(manifest: Manifest) -> Result<Self> {
        let _ = &manifest;
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn from_default_dir() -> Result<Self> {
        unavailable()
    }

    /// Platform name (never reachable — no instance exists).
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Borrow the manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn executable(&self, _name: &str) -> Result<Arc<Executable>> {
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn spmv(&self, _art: &Artifact, _t: &BlockedTensors, _x: &[f32]) -> Result<Vec<f32>> {
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn power_step(
        &self,
        _art: &Artifact,
        _t: &BlockedTensors,
        _x: &[f32],
    ) -> Result<(Vec<f32>, f32)> {
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn assemble(
        &self,
        _art: &Artifact,
        _lrows: &[i32],
        _lcols: &[i32],
        _vals: &[f32],
    ) -> Result<Vec<f32>> {
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn spmv_csr(&self, _csr: &Csr, _x: &[f64]) -> Result<Vec<f32>> {
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn pack_best_spmv(&self, _csr: &Csr) -> Result<(Artifact, BlockedTensors)> {
        unavailable()
    }

    /// Always fails with [`RuntimeError::Unavailable`].
    pub fn pick_spmv_artifact(&self, _csr: &Csr) -> Result<Artifact> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_unavailable() {
        let err = Runtime::from_default_dir().expect_err("stub must not construct");
        assert!(matches!(err, RuntimeError::Unavailable(_)), "{err}");
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
